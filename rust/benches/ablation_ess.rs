//! A3 — effective-sample-size threshold sweep (§3: resample when
//! `n_eff/m` crosses a pre-specified threshold).
//!
//! Too high → constant resampling (all time in the Sampler, the Fig-3
//! plateaus dominate); too low → stale skewed samples (slow, noisy
//! certification). The sweep exposes the sweet spot.
//!
//!     cargo bench --bench ablation_ess

use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let secs = 10.0;

    let mut t = Table::new(&["n_eff/m threshold", "Rules", "Resamples", "Final loss"]);
    for thr in [0.05, 0.15, 0.3, 0.5, 0.8] {
        let out = harness::run_sparrow(2, &store_path, &test, &format!("ess{thr}"), |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = 100_000;
            c.ess_threshold = thr;
        })?;
        let resamples: u64 = out.workers.iter().map(|w| w.resamples).sum();
        let p = out.series.points.last().unwrap();
        t.row(&[
            format!("{thr:.2}"),
            out.model.len().to_string(),
            resamples.to_string(),
            format!("{:.4}", p.exp_loss),
        ]);
    }
    println!("\nA3 — n_eff/m resampling-threshold sweep ({secs:.0}s budget, 2 workers)");
    t.print();
    Ok(())
}
