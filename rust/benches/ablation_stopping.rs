//! A1 — stopping-rule ablation: Balsubramani-LIL (the paper's rule) vs
//! naive Hoeffding vs fixed full-scan (no early stopping).
//!
//! Measures examples scanned per certified rule and end-to-end progress.
//! Expected shape: LIL stops earliest (tightest anytime bound, §3 "sound
//! and tight"), Hoeffding needs more samples, fixed-scan devolves to full
//! passes.
//!
//!     cargo bench --bench ablation_stopping

use sparrow::config::StoppingKind;
use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let secs = 12.0;

    let mut t = Table::new(&[
        "Stopping rule",
        "Rules",
        "Scanned/rule",
        "Final loss",
        "Final AUPRC",
    ]);
    for (kind, name) in [
        (StoppingKind::Lil, "lil (paper)"),
        (StoppingKind::Hoeffding, "hoeffding"),
        (StoppingKind::DomingoWatanabe, "domingo-watanabe [14]"),
        (StoppingKind::FixedScan, "fixed-scan"),
    ] {
        let out = harness::run_sparrow(2, &store_path, &test, name, |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = 100_000;
            c.stopping = kind;
        })?;
        let scanned: u64 = out.workers.iter().map(|w| w.scanned).sum();
        let rules = out.model.len();
        let p = out.series.points.last().unwrap();
        t.row(&[
            name.to_string(),
            rules.to_string(),
            if rules > 0 {
                format!("{}", scanned / rules as u64)
            } else {
                "—".into()
            },
            format!("{:.4}", p.exp_loss),
            format!("{:.4}", p.auprc),
        ]);
    }
    println!("\nA1 — stopping-rule ablation ({secs:.0}s budget, 2 workers)");
    t.print();
    Ok(())
}
