//! A5 — TMSN gap/γ sensitivity: the initial target advantage γ₀ and the
//! floor γ_min control how ambitious each certification attempt is.
//!
//! Small γ₀ certifies fast but adds weak rules (small α, slow bound
//! progress); large γ₀ spends scans halving down. The γ-halving schedule
//! (Alg. 2) makes the system self-tuning — the sweep shows the flat
//! region that self-tuning creates.
//!
//!     cargo bench --bench ablation_gap

use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let secs = 10.0;

    let mut t = Table::new(&["gamma0", "Rules", "GammaShrinks", "Bound", "Final loss"]);
    for gamma0 in [0.4, 0.25, 0.1, 0.05, 0.02] {
        let out = harness::run_sparrow(2, &store_path, &test, &format!("g{gamma0}"), |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = 100_000;
            c.gamma0 = gamma0;
        })?;
        let shrinks = out
            .events
            .iter()
            .filter(|e| e.kind == sparrow::metrics::EventKind::GammaShrink)
            .count();
        let p = out.series.points.last().unwrap();
        t.row(&[
            format!("{gamma0:.2}"),
            out.model.len().to_string(),
            shrinks.to_string(),
            format!("{:.4}", out.loss_bound),
            format!("{:.4}", p.exp_loss),
        ]);
    }
    println!("\nA5 — γ₀ sensitivity sweep ({secs:.0}s budget, 2 workers)");
    t.print();
    Ok(())
}
