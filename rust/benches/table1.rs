//! E1 — Table 1: training time to an almost-optimal loss.
//!
//! Paper row set: {XGBoost, LightGBM} x {in-memory, off-memory} plus
//! Sparrow(TMSN) with 1 and 10 workers (off-memory sampler). Absolute
//! times differ from the paper (their testbed: EC2 + 50M examples); the
//! *shape* — who wins and by roughly what factor — is the reproduction
//! target (EXPERIMENTS.md §E1).
//!
//!     cargo bench --bench table1      (honors SPARROW_BENCH_SCALE)

use sparrow::baselines::DataSource;
use sparrow::data::DiskStore;
use sparrow::eval::MetricSeries;
use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let train_mem = DiskStore::open(&store_path)?.read_all()?;
    let bw = harness::off_memory_bandwidth();
    let secs = 40.0;
    let rules = 250;

    eprintln!("table1: workload {} x {}, off-mem bw {:.0} MB/s", w.train_n, w.features, bw / 1e6);

    let mut series: Vec<(MetricSeries, &str)> = Vec::new();
    eprintln!("  fullscan in-memory...");
    series.push((
        harness::run_fullscan(
            &DataSource::memory(train_mem.clone()),
            &test,
            harness::stop(rules, secs, 0.0),
            "XGBoost-like, in-memory",
        ),
        "in-memory",
    ));
    eprintln!("  fullscan off-memory...");
    series.push((
        harness::run_fullscan(
            &DataSource::disk(&store_path, bw)?,
            &test,
            harness::stop(rules, secs, 0.0),
            "XGBoost-like, off-memory",
        ),
        "off-memory",
    ));
    eprintln!("  goss in-memory...");
    series.push((
        harness::run_goss(
            &DataSource::memory(train_mem.clone()),
            &test,
            harness::stop(rules, secs, 0.0),
            "LightGBM-like, in-memory",
        ),
        "in-memory",
    ));
    eprintln!("  goss off-memory...");
    series.push((
        harness::run_goss(
            &DataSource::disk(&store_path, bw)?,
            &test,
            harness::stop(rules, secs, 0.0),
            "LightGBM-like, off-memory",
        ),
        "off-memory",
    ));
    for workers in [1usize, 10] {
        eprintln!("  sparrow x{workers}...");
        let label = if workers == 1 {
            "TMSN Sparrow, 1 worker"
        } else {
            "TMSN Sparrow, 10 workers"
        };
        series.push((
            harness::run_sparrow(workers, &store_path, &test, label, |c| {
                c.time_limit = std::time::Duration::from_secs_f64(secs);
                c.max_rules = rules;
                c.disk_bandwidth = bw;
            })?
            .series,
            "off-memory",
        ));
    }

    let best = series
        .iter()
        .flat_map(|(s, _)| s.points.iter().map(|p| p.exp_loss))
        .fold(f64::INFINITY, f64::min);
    let target = best * 1.03;

    println!("\nTable 1 analogue — time to test exp-loss <= {target:.4}");
    let mut t = Table::new(&["Algorithm", "Memory", "Training (s)", "Final loss"]);
    for (s, tier) in &series {
        let p = s.points.last().unwrap();
        t.row(&[
            s.label.clone(),
            tier.to_string(),
            harness::time_to(s, target),
            format!("{:.4}", p.exp_loss),
        ]);
    }
    t.print();

    // paper-shape checks printed as a verdict line
    let tt = |i: usize| series[i].0.time_to_loss(target).map(|d| d.as_secs_f64());
    if let (Some(fs_mem), Some(sp1)) = (tt(0), tt(4)) {
        println!("\nspeedup sparrow-1 vs fullscan-in-mem: {:.1}x", fs_mem / sp1);
    }
    if let (Some(sp1), Some(sp10)) = (tt(4), tt(5)) {
        println!("speedup sparrow-10 vs sparrow-1:      {:.1}x (paper: 3.2x)", sp1 / sp10);
    }
    Ok(())
}
