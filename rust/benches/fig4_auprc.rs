//! E4 — Figure 4: AUPRC as a function of time, linear and log time axes.
//!
//!     cargo bench --bench fig4_auprc

use sparrow::baselines::DataSource;
use sparrow::data::DiskStore;
use sparrow::eval::MetricSeries;
use sparrow::harness::{self, Workload};

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let train_mem = DiskStore::open(&store_path)?.read_all()?;
    let secs = 25.0;
    let rules = 250;

    let fs = harness::run_fullscan(
        &DataSource::memory(train_mem.clone()),
        &test,
        harness::stop(rules, secs, 0.0),
        "XGBoost-like",
    );
    let goss = harness::run_goss(
        &DataSource::memory(train_mem),
        &test,
        harness::stop(rules, secs, 0.0),
        "LightGBM-like",
    );
    let sparrow = harness::run_sparrow(4, &store_path, &test, "Sparrow-4", |c| {
        c.time_limit = std::time::Duration::from_secs_f64(secs);
        c.max_rules = rules;
        c.disk_bandwidth = harness::off_memory_bandwidth();
    })?
    .series;

    println!("Figure 4 (left) — AUPRC vs time, linear axis (higher is better)");
    print!(
        "{}",
        MetricSeries::ascii_chart(&[&sparrow, &fs, &goss], |p| p.auprc, 80, 14, false)
    );
    println!("\nFigure 4 (right) — AUPRC vs time, log axis");
    print!(
        "{}",
        MetricSeries::ascii_chart(&[&sparrow, &fs, &goss], |p| p.auprc, 80, 14, true)
    );

    println!("\nfinal AUPRC:");
    for s in [&sparrow, &fs, &goss] {
        println!(
            "  {:<14} {:.4} (best {:.4})",
            s.label,
            s.points.last().unwrap().auprc,
            s.best_auprc().unwrap_or(0.0)
        );
    }
    println!("(paper Fig. 4: the full-scan baselines ultimately edge out Sparrow on AUPRC\n while Sparrow gets there much faster — check the shape above)");

    let dir = std::env::temp_dir().join("sparrow_fig4");
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("label,seconds,iterations,exp_loss,auprc\n");
    for s in [&sparrow, &fs, &goss] {
        csv.push_str(&s.to_csv());
    }
    std::fs::write(dir.join("fig4.csv"), &csv)?;
    Ok(())
}
