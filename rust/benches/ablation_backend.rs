//! A4 — scan-backend ablation: native Rust hot loop vs the AOT-lowered
//! XLA artifacts (Pallas edge kernel and pure-jnp variant) through PJRT.
//!
//! Measures raw scan-batch throughput on identical inputs. Interpret-mode
//! Pallas lowers to a while-loop over grid tiles, so on CPU the jnp
//! variant fuses better; on a real TPU the Pallas kernel's VMEM tiling is
//! the point (DESIGN.md §2 and §7 carry the estimate).
//!
//! Requires `make artifacts` for the XLA rows (skipped otherwise).
//!
//!     cargo bench --bench ablation_backend

use std::path::Path;

use sparrow::boosting::CandidateGrid;
use sparrow::data::{BinnedBatch, DataBlock};
use sparrow::model::{StrongRule, Stump};
use sparrow::runtime::{Manifest, XlaScanBackend};
use sparrow::scanner::{BatchResult, BinnedBackend, NativeBackend, ScanBackend};
use sparrow::util::bench::BenchRunner;
use sparrow::util::rng::Rng;

const F: usize = 32;
const NT: usize = 4;
const B: usize = 128;

fn inputs(n: usize) -> (DataBlock, Vec<f32>, Vec<f32>, Vec<u32>, StrongRule, CandidateGrid) {
    let mut rng = Rng::new(9);
    let mut block = DataBlock::empty(F);
    for _ in 0..n {
        let row: Vec<f32> = (0..F).map(|_| rng.gauss() as f32).collect();
        block.push(&row, if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
    }
    let w = vec![1.0f32; n];
    let s = vec![0.0f32; n];
    let l = vec![0u32; n];
    let mut model = StrongRule::new();
    for t in 0..10 {
        model.push(Stump::new(t % F as u32, 0.1, 1.0), 0.2);
    }
    let grid = CandidateGrid::uniform(F, NT, -1.5, 1.5);
    (block, w, s, l, model, grid)
}

fn bench_backend(name: &str, be: &mut dyn ScanBackend, runner: &BenchRunner) -> f64 {
    let (block, w, s, l, model, grid) = inputs(B);
    let stats = runner.bench(name, || {
        std::hint::black_box(be.scan_batch(&block, &w, &s, &l, &model, &grid, (0, F)))
    });
    let per_ex = stats.median.as_secs_f64() / B as f64;
    let cand_updates = (B * F * NT) as f64 / stats.median.as_secs_f64();
    println!(
        "    {name}: {:.2} µs/example, {:.1} M candidate-updates/s",
        per_ex * 1e6,
        cand_updates / 1e6
    );
    per_ex
}

fn main() {
    let runner = BenchRunner {
        warmup: 3,
        runs: 15,
        ..BenchRunner::default()
    };
    println!("A4 — scan backend throughput (B={B}, F={F}, NT={NT}, model=10 stumps)\n");

    let mut native = NativeBackend;
    let native_t = bench_backend("native", &mut native, &runner);

    // binned CPU engine (--scan-engine binned): same inputs plus the
    // prebuilt per-sample bins (built outside the timed region, as at
    // sample-install time in the worker)
    {
        let (block, w, s, l, model, grid) = inputs(B);
        let stripe_bins = grid.bin_spec((0, F)).bin_block(&block);
        let idx: Vec<usize> = (0..B).collect();
        let mut bins = BinnedBatch::default();
        bins.gather(&stripe_bins, &idx);
        let mut be = BinnedBackend::new(1);
        let mut out = BatchResult::zeros(F, NT);
        let stats = runner.bench("binned", || {
            out.reset(F, NT);
            be.scan_batch_into(&block, Some(&bins), &w, &s, &l, &model, &grid, (0, F), &mut out);
            out.edges.count
        });
        let per_ex = stats.median.as_secs_f64() / B as f64;
        println!(
            "    binned: {:.2} µs/example, {:.1} M candidate-updates/s ({:.2}x vs native)",
            per_ex * 1e6,
            (B * F * NT) as f64 / stats.median.as_secs_f64() / 1e6,
            native_t / per_ex
        );
    }

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Err(e) => println!("\nSKIP xla backends: {e}"),
        Ok(m) => {
            for (pallas, label) in [(true, "xla-pallas"), (false, "xla-jnp")] {
                match m.find_scan(pallas, F, NT) {
                    Err(e) => println!("SKIP {label}: {e}"),
                    Ok(spec) => {
                        let mut be = XlaScanBackend::load(&m, spec, pallas).expect("load artifact");
                        let t = bench_backend(label, &mut be, &runner);
                        println!("    {label} vs native: {:.2}x", t / native_t);
                    }
                }
            }
        }
    }
}
