//! E3 — Figure 3: test exponential loss as a function of wall-clock time
//! for Sparrow, fullscan ("XGBoost") and GOSS ("LightGBM"), including the
//! flat plateaus while Sparrow resamples.
//!
//!     cargo bench --bench fig3_loss_curve

use sparrow::baselines::DataSource;
use sparrow::data::DiskStore;
use sparrow::eval::MetricSeries;
use sparrow::harness::{self, Workload};

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let train_mem = DiskStore::open(&store_path)?.read_all()?;
    let secs = 25.0;
    let rules = 250;

    let fs = harness::run_fullscan(
        &DataSource::memory(train_mem.clone()),
        &test,
        harness::stop(rules, secs, 0.0),
        "XGBoost-like",
    );
    let goss = harness::run_goss(
        &DataSource::memory(train_mem),
        &test,
        harness::stop(rules, secs, 0.0),
        "LightGBM-like",
    );
    let sparrow = harness::run_sparrow(4, &store_path, &test, "Sparrow-4", |c| {
        c.time_limit = std::time::Duration::from_secs_f64(secs);
        c.max_rules = rules;
        c.disk_bandwidth = harness::off_memory_bandwidth();
    })?
    .series;

    println!("Figure 3 — test exponential loss vs time (lower is better)");
    print!(
        "{}",
        MetricSeries::ascii_chart(&[&sparrow, &fs, &goss], |p| p.exp_loss, 80, 16, false)
    );

    let dir = std::env::temp_dir().join("sparrow_fig3");
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("label,seconds,iterations,exp_loss,auprc\n");
    for s in [&sparrow, &fs, &goss] {
        csv.push_str(&s.to_csv());
    }
    std::fs::write(dir.join("fig3.csv"), &csv)?;
    println!("series CSV: {}", dir.join("fig3.csv").display());

    // resampling plateaus: assert they exist in the event structure
    let flat = sparrow
        .points
        .windows(2)
        .filter(|p| (p[0].exp_loss - p[1].exp_loss).abs() < 1e-12)
        .count();
    println!("sparrow flat segments (resampling plateaus): {flat}");
    Ok(())
}
