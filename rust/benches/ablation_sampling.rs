//! A2 — sampler ablation: minimal-variance (paper) vs rejection vs
//! weight-blind uniform sampling.
//!
//! Expected shape (§4.1 fn. 4 + §3): minimal-variance ≈ rejection in
//! expectation but with lower variance in the kept set; uniform wastes
//! memory on easy examples (its kept set has low n_eff), slowing
//! certification of specialist rules.
//!
//!     cargo bench --bench ablation_sampling

use sparrow::config::SamplerKind;
use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let secs = 12.0;

    let mut t = Table::new(&["Sampler", "Rules", "Resamples", "Final loss", "Final AUPRC"]);
    for (kind, name) in [
        (SamplerKind::MinimalVariance, "minimal-variance (paper)"),
        (SamplerKind::Rejection, "rejection"),
        (SamplerKind::Uniform, "uniform (weight-blind)"),
    ] {
        let out = harness::run_sparrow(2, &store_path, &test, name, |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = 100_000;
            c.sampler = kind;
        })?;
        let resamples: u64 = out.workers.iter().map(|w| w.resamples).sum();
        let p = out.series.points.last().unwrap();
        t.row(&[
            name.to_string(),
            out.model.len().to_string(),
            resamples.to_string(),
            format!("{:.4}", p.exp_loss),
            format!("{:.4}", p.auprc),
        ]);
    }
    println!("\nA2 — sampler ablation ({secs:.0}s budget, 2 workers)");
    t.print();
    Ok(())
}
