//! Serve-path latency under an adoption storm (DESIGN.md §10).
//!
//! Measures end-to-end prediction latency (TCP round trip through the
//! RPC framing) in three regimes:
//!   1. in-process dispatch only (no socket) — the protocol floor,
//!   2. quiet: TCP round trips against a fixed served model,
//!   3. storm: the same client while a publisher thread hot-swaps the
//!      served model as fast as it can.
//! The claim under test: a swap never blocks or drops a request, so the
//! storm p99 stays in the same regime as the quiet p99 (no
//! stop-the-world swap pause), and served versions remain monotone.
//!
//!     cargo bench --bench serve_latency

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use sparrow::admin::{dispatch, RpcClient, RpcServer};
use sparrow::model::{StrongRule, Stump};
use sparrow::serve::{ModelSlot, ServeHandler};
use sparrow::util::json::Json;

const MODEL_RULES: usize = 64;
const FEATURES: usize = 64;
const QUIET_REQS: usize = 2_000;
const STORM_REQS: usize = 2_000;

fn model(version: u64) -> StrongRule {
    let mut m = StrongRule::new();
    for t in 0..MODEL_RULES {
        // vary thresholds by version so every swap installs new content
        let thr = (version % 7) as f32 * 0.1 - 0.3;
        m.push(Stump::new((t % FEATURES) as u32, thr, 1.0), 0.05);
    }
    m
}

fn predict_params() -> Json {
    let row: Vec<Json> = (0..FEATURES)
        .map(|i| Json::Num((i as f64 * 0.37).sin()))
        .collect();
    let mut o = Json::obj();
    o.set("row", Json::Arr(row));
    o
}

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn report(label: &str, lat: &mut Vec<Duration>) {
    lat.sort();
    println!(
        "{label}: n={} p50={:?} p90={:?} p99={:?} max={:?}",
        lat.len(),
        percentile(lat, 0.50),
        percentile(lat, 0.90),
        percentile(lat, 0.99),
        lat.last().unwrap(),
    );
}

fn main() {
    let slot = Arc::new(ModelSlot::new());
    slot.publish(model(1), 1, 0.9);

    // ---- 1. protocol floor: dispatch without a socket ---------------------
    let handler = ServeHandler::new(Arc::clone(&slot));
    let raw = {
        let mut req = Json::obj();
        req.set("v", 1.0)
            .set("id", 1.0)
            .set("method", "predict")
            .set("params", predict_params());
        req.to_string().into_bytes()
    };
    let mut lat = Vec::with_capacity(QUIET_REQS);
    for _ in 0..200 {
        dispatch(&handler, &raw); // warmup
    }
    for _ in 0..QUIET_REQS {
        let t0 = Instant::now();
        let out = dispatch(&handler, &raw);
        lat.push(t0.elapsed());
        assert!(out.windows(8).any(|w| w == b"\"score\":"), "bad reply");
    }
    report("dispatch-only", &mut lat);

    // ---- 2. quiet TCP round trips -----------------------------------------
    let server = RpcServer::bind("127.0.0.1:0", Arc::new(ServeHandler::new(Arc::clone(&slot))))
        .expect("bind serve endpoint");
    let mut client = RpcClient::connect(&server.local_addr().to_string()).expect("connect");
    let params = predict_params();
    for _ in 0..200 {
        client.call_ok("predict", params.clone()).expect("warmup");
    }
    let mut lat = Vec::with_capacity(QUIET_REQS);
    for _ in 0..QUIET_REQS {
        let t0 = Instant::now();
        client.call_ok("predict", params.clone()).expect("quiet predict");
        lat.push(t0.elapsed());
    }
    report("tcp quiet   ", &mut lat);

    // ---- 3. adoption storm ------------------------------------------------
    let stop = Arc::new(AtomicBool::new(false));
    let publisher = {
        let slot = Arc::clone(&slot);
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            let mut v = slot.version();
            let mut published = 0u64;
            while !stop.load(Ordering::Relaxed) {
                v += 1;
                slot.publish(model(v), v, 1.0 / v as f64);
                published += 1;
            }
            published
        })
    };
    let mut lat = Vec::with_capacity(STORM_REQS);
    let mut last_version = 0u64;
    for _ in 0..STORM_REQS {
        let t0 = Instant::now();
        let r = client.call_ok("predict", params.clone()).expect("storm predict");
        lat.push(t0.elapsed());
        let v = r.get("model_version").and_then(Json::as_u64).unwrap();
        assert!(v >= last_version, "served version went backwards under storm");
        last_version = v;
    }
    stop.store(true, Ordering::Relaxed);
    let published = publisher.join().unwrap();
    report("tcp storm   ", &mut lat);
    println!(
        "storm: {published} models published, {} swaps installed, final served v{}",
        slot.swaps(),
        slot.version()
    );
}
