//! E5 — worker-count scaling (Table 1's 1 → 10 workers = 3.2x claim).
//!
//! Sweeps the cluster size and reports time-to-target-loss and rules/sec.
//! NOTE: this testbed has a single core, so *compute* does not speed up
//! with workers — what scales is the protocol (feature-striping means each
//! worker certifies from a narrower candidate set, so certification is
//! cheaper, and accepted remote rules are free). The wall-clock speedup on
//! a real multi-core box is bounded below by the numbers here.
//!
//!     cargo bench --bench scaling

use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let secs = 20.0;
    let rules = 200;

    let mut t = Table::new(&[
        "Workers",
        "Rules",
        "Time-to-target (s)",
        "Final loss",
        "Broadcasts",
        "Accepts",
    ]);
    let mut baseline_time: Option<f64> = None;
    // calibration: single worker's reachable loss defines the target
    let mut target = 0.0;
    for workers in [1usize, 2, 4, 8, 10] {
        let out = harness::run_sparrow(workers, &store_path, &test, &format!("w{workers}"), |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = rules;
        })?;
        if workers == 1 {
            let best = out
                .series
                .points
                .iter()
                .map(|p| p.exp_loss)
                .fold(f64::INFINITY, f64::min);
            target = best * 1.05;
        }
        let tt = out.series.time_to_loss(target).map(|d| d.as_secs_f64());
        if workers == 1 {
            baseline_time = tt;
        }
        let p = out.series.points.last().unwrap();
        let accepts: u64 = out.workers.iter().map(|w| w.accepts).sum();
        t.row(&[
            workers.to_string(),
            out.model.len().to_string(),
            tt.map(|v| format!("{v:.2}")).unwrap_or_else(|| "—".into()),
            format!("{:.4}", p.exp_loss),
            out.net.0.to_string(),
            accepts.to_string(),
        ]);
        if let (Some(base), Some(now)) = (baseline_time, tt) {
            if workers > 1 {
                eprintln!("  {workers} workers: {:.2}x vs single (paper @10: 3.2x)", base / now);
            }
        }
    }
    println!("\nScaling sweep — target loss {target:.4}");
    t.print();
    Ok(())
}
