//! Micro-benchmarks of the L3 hot paths (§Perf): edge accumulation
//! (row engine vs the binned columnar engine, scalar vs lane kernels,
//! × thread counts {1,2,4,8}), the threaded bucket→edge suffix fold,
//! incremental scoring, selective sampling, broadcast fan-out latency,
//! stopping-rule sweep. Baseline + after numbers live in EXPERIMENTS.md
//! §Perf.
//!
//!     cargo bench [--features simd] --bench micro_hotpath [-- --json BENCH_scan.json]
//!
//! `--json PATH` additionally writes the scan sweep as a JSON artifact
//! (`make bench-scan` emits it to the repo root as `BENCH_scan.json`;
//! CI's bench-scan job uploads it, tracking the perf trajectory across
//! PRs). The sweep asserts rows == binned-scalar == binned-simd before
//! timing anything — a number from a divergent kernel is worthless
//! (DESIGN.md §14).

use std::time::{Duration, Instant};

use sparrow::boosting::{
    edges::{accumulate_edges_stripe, accumulate_edges_stripe_into, fold_buckets_par},
    CandidateGrid, EdgeMatrix,
};
use sparrow::data::{BinnedBatch, DataBlock};
use sparrow::model::{StrongRule, Stump};
use sparrow::network::{Fabric, NetConfig};
use sparrow::sampling::{MinimalVarianceSampler, SelectiveSampler};
use sparrow::scanner::{lane_kernel, BinnedBackend};
use sparrow::stopping::{CandidateStats, LilRule, StoppingRule};
use sparrow::util::bench::BenchRunner;
use sparrow::util::json::Json;
use sparrow::util::rng::Rng;

const SCAN_THREADS: [usize; 4] = [1, 2, 4, 8];

/// Assert two accumulations agree bitwise on every stripe edge and
/// stopping scalar — the precondition for comparing their timings.
fn assert_identical(a: &EdgeMatrix, b: &EdgeMatrix, f: usize, nt: usize, ctx: &str) {
    for ff in 0..f {
        for t in 0..nt {
            assert_eq!(
                a.edge(ff, t).to_bits(),
                b.edge(ff, t).to_bits(),
                "{ctx}: edge f={ff} t={t}"
            );
        }
    }
    assert_eq!(a.sum_w.to_bits(), b.sum_w.to_bits(), "{ctx}: sum_w");
    assert_eq!(a.sum_w2.to_bits(), b.sum_w2.to_bits(), "{ctx}: sum_w2");
    assert_eq!(a.count, b.count, "{ctx}: count");
}

/// The scan sweep at the acceptance shape (F=64, NT=8): the row engine's
/// per-example threshold search vs the binned engine's bucket
/// accumulation (DESIGN.md §8) under both kernels — scalar always, the
/// lane kernel when built with `--features simd` — × threads {1,2,4,8},
/// all through their zero-allocation scanner entries (scoring is the
/// shared row-view step and benched separately below). Before any timing,
/// every config's EdgeMatrix is checked bitwise-identical to every other
/// binned config and 1e-9-relative to rows. Also sweeps the threaded
/// bucket→edge suffix fold. Returns the object written to
/// `BENCH_scan.json` by `--json`.
fn scan_engine_sweep(runner: &BenchRunner) -> Json {
    const N: usize = 32_768; // many BIN_CHUNK chunks → thread scaling visible
    const F: usize = 64;
    const NT: usize = 8;
    let mut rng = Rng::new(11);
    let mut block = DataBlock::empty(F);
    for _ in 0..N {
        let row: Vec<f32> = (0..F).map(|_| rng.gauss() as f32).collect();
        block.push(&row, if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
    }
    let grid = CandidateGrid::uniform(F, NT, -1.5, 1.5);
    let w = vec![1.0f32; N];
    // bins are built once per sample and reused — not part of the hot path
    let stripe_bins = grid.bin_spec((0, F)).bin_block(&block);
    let idx: Vec<usize> = (0..N).collect();
    let mut bins = BinnedBatch::default();
    bins.gather(&stripe_bins, &idx);

    // scalar always; the lane kernel when compiled in
    let mut modes: Vec<(&str, bool)> = vec![("scalar", false)];
    if cfg!(feature = "simd") {
        modes.push(("simd", true));
    }

    // ---- identity gate: rows == every binned (mode × threads) config ----
    let mut rows_acc = EdgeMatrix::zeros(F, NT);
    let mut bucket = Vec::new();
    accumulate_edges_stripe_into(&block, &w, &grid, (0, F), &mut rows_acc, &mut bucket);
    let mut reference: Option<EdgeMatrix> = None;
    for &(mode, lanes) in &modes {
        for threads in SCAN_THREADS {
            let mut be = BinnedBackend::with_simd(threads, lanes);
            let mut acc = EdgeMatrix::zeros(F, NT);
            be.accumulate_batch(&bins, &w, &block.labels, NT, (0, F), &mut acc);
            assert_eq!(acc.sum_w.to_bits(), rows_acc.sum_w.to_bits());
            assert_eq!(acc.sum_w2.to_bits(), rows_acc.sum_w2.to_bits());
            assert_eq!(acc.count, rows_acc.count);
            for ff in 0..F {
                for t in 0..NT {
                    let (a, b) = (rows_acc.edge(ff, t), acc.edge(ff, t));
                    assert!(
                        (a - b).abs() <= 1e-9 * (1.0 + a.abs()),
                        "rows vs binned/{mode} t={threads}: f={ff} thr={t}: {a} vs {b}"
                    );
                }
            }
            match &reference {
                None => reference = Some(acc),
                Some(r) => assert_identical(r, &acc, F, NT, &format!("{mode} t={threads}")),
            }
        }
    }
    println!("  -> identity: rows == binned across kernels x threads (checked)");

    // ---- timings ----
    let mut acc = EdgeMatrix::zeros(F, NT);
    let rows = runner.bench("scan rows 32768x64x8", || {
        acc.reset();
        accumulate_edges_stripe_into(&block, &w, &grid, (0, F), &mut acc, &mut bucket);
        acc.count
    });
    let rows_s = rows.median.as_secs_f64();
    println!(
        "  -> rows: {:.1} M candidate-updates/s",
        (N * F * NT) as f64 / rows_s / 1e6
    );

    let mut result = Json::obj();
    result
        .set("bench", "scan_engine")
        .set("n", N)
        .set("features", F)
        .set("nthr", NT)
        .set("simd_kernel", lane_kernel())
        .set("rows_s", rows_s)
        .set("identical", true);
    let mut scalar_1t = rows_s;
    for &(mode, lanes) in &modes {
        let mut sweep = Json::obj();
        let mut t1 = rows_s;
        let mut last = rows_s;
        for threads in SCAN_THREADS {
            let mut be = BinnedBackend::with_simd(threads, lanes);
            let stats = runner.bench(&format!("scan binned/{mode} 32768x64x8 t={threads}"), || {
                acc.reset();
                be.accumulate_batch(&bins, &w, &block.labels, NT, (0, F), &mut acc);
                acc.count
            });
            let t_s = stats.median.as_secs_f64();
            if threads == 1 {
                t1 = t_s;
                println!("  -> binned/{mode} 1t speedup over rows: {:.2}x", rows_s / t_s);
            } else {
                println!("  -> binned/{mode} {threads}t scaling vs 1t: {:.2}x", t1 / t_s);
            }
            last = t_s;
            sweep.set(&format!("t{threads}"), t_s);
        }
        if lanes {
            result
                .set("simd_s", sweep)
                .set("simd_over_scalar_1t", scalar_1t / t1);
        } else {
            scalar_1t = t1;
            result
                .set("scalar_s", sweep)
                .set("speedup_scalar_1t", rows_s / t1)
                .set("scaling_scalar_8t", t1 / last);
        }
    }

    // ---- threaded bucket→edge suffix fold (wide stripe) ----
    const FOLD_F: usize = 4096;
    const FOLD_NT: usize = 16;
    let fold_bucket: Vec<f64> = (0..FOLD_F * (FOLD_NT + 1)).map(|_| rng.gauss()).collect();
    let mut fold_ref = EdgeMatrix::zeros(FOLD_F, FOLD_NT);
    fold_buckets_par(&fold_bucket, (0, FOLD_F), FOLD_NT, &mut fold_ref, 1);
    let mut fold_sweep = Json::obj();
    let mut fold_1t = 0.0f64;
    let mut fold_last = 0.0f64;
    for threads in SCAN_THREADS {
        let mut facc = EdgeMatrix::zeros(FOLD_F, FOLD_NT);
        fold_buckets_par(&fold_bucket, (0, FOLD_F), FOLD_NT, &mut facc, threads);
        assert_identical(&fold_ref, &facc, FOLD_F, FOLD_NT, &format!("fold t={threads}"));
        let stats = runner.bench(&format!("fold 4096x16 t={threads}"), || {
            facc.reset();
            fold_buckets_par(&fold_bucket, (0, FOLD_F), FOLD_NT, &mut facc, threads);
            facc.count
        });
        let t_s = stats.median.as_secs_f64();
        if threads == 1 {
            fold_1t = t_s;
        } else {
            println!("  -> fold {threads}t scaling vs 1t: {:.2}x", fold_1t / t_s);
        }
        fold_last = t_s;
        fold_sweep.set(&format!("t{threads}"), t_s);
    }
    result
        .set("fold_s", fold_sweep)
        .set("fold_scaling_8t", fold_1t / fold_last);
    result
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .windows(2)
        .find(|w| w[0] == "--json")
        .map(|w| w[1].clone());

    let runner = BenchRunner {
        warmup: 2,
        runs: 9,
        ..BenchRunner::default()
    };

    // ---- edge accumulation (the scanner's inner loop) ---------------------
    let n = 4096;
    let f = 64;
    let nt = 8;
    let mut rng = Rng::new(1);
    let mut block = DataBlock::empty(f);
    for _ in 0..n {
        let row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
        block.push(&row, if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
    }
    let w = vec![1.0f32; n];
    let grid = CandidateGrid::uniform(f, nt, -1.5, 1.5);
    let stats = runner.bench("edges 4096x64x8", || {
        let mut acc = EdgeMatrix::zeros(f, nt);
        accumulate_edges_stripe(&block, &w, &grid, (0, f), &mut acc);
        acc
    });
    let updates = (n * f * nt) as f64 / stats.median.as_secs_f64();
    println!("  -> {:.1} M candidate-updates/s", updates / 1e6);

    // ---- scan engines: rows vs binned × threads (§Perf, DESIGN.md §8) -----
    let scan_json = scan_engine_sweep(&runner);
    if let Some(path) = &json_path {
        std::fs::write(path, scan_json.to_string() + "\n").expect("write BENCH_scan json");
        println!("scan sweep written to {path}");
    }

    // ---- incremental strong-rule scoring ----------------------------------
    let mut model = StrongRule::new();
    for t in 0..64u32 {
        model.push(Stump::new(t % f as u32, 0.0, 1.0), 0.1);
    }
    let stats = runner.bench("score-suffix 4096x64stumps", || {
        let mut acc = 0f32;
        for i in 0..n {
            acc += model.score_suffix(block.row(i), 0);
        }
        acc
    });
    let sps = (n * 64) as f64 / stats.median.as_secs_f64();
    println!("  -> {:.1} M stump-evals/s", sps / 1e6);

    // ---- selective sampling -------------------------------------------------
    let weights: Vec<f64> = (0..100_000).map(|i| 0.1 + (i % 13) as f64 * 0.2).collect();
    let stats = runner.bench("mvs-sampler 100k offers", || {
        let mut rng = Rng::new(2);
        let mut s = MinimalVarianceSampler::new(2.0, &mut rng);
        let mut kept = 0usize;
        for &w in &weights {
            kept += s.offer(w, &mut rng);
        }
        kept
    });
    println!(
        "  -> {:.1} M offers/s",
        100_000.0 / stats.median.as_secs_f64() / 1e6
    );

    // ---- stopping-rule sweep -------------------------------------------------
    let rule = LilRule::default();
    let cands: Vec<CandidateStats> = (0..512)
        .map(|i| CandidateStats {
            m: i as f64 * 0.1,
            sum_w: 1000.0,
            sum_w2: 900.0,
            count: 1000,
        })
        .collect();
    let stats = runner.bench("lil-sweep 512 candidates", || {
        cands.iter().filter(|c| rule.fires(c, 0.05)).count()
    });
    println!(
        "  -> {:.1} M candidate-checks/s",
        512.0 / stats.median.as_secs_f64() / 1e6
    );

    // ---- broadcast fan-out latency -------------------------------------------
    let (fabric, eps) = Fabric::<u64>::new(8, NetConfig::ideal());
    let t0 = Instant::now();
    let rounds = 200;
    for i in 0..rounds {
        eps[0].broadcast(i, 64);
        for ep in &eps[1..] {
            while ep.recv_timeout(Duration::from_secs(1)).is_none() {}
        }
    }
    let per_round = t0.elapsed() / rounds as u32;
    println!("broadcast fan-out (8 endpoints, ideal net): {per_round:?}/round");
    fabric.shutdown();
}
