//! Micro-benchmarks of the L3 hot paths (§Perf): edge accumulation
//! (row engine vs the binned columnar engine × thread counts), incremental
//! scoring, selective sampling, broadcast fan-out latency, stopping-rule
//! sweep. Baseline + after numbers live in EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench micro_hotpath [-- --json BENCH_scan.json]
//!
//! `--json PATH` additionally writes the rows-vs-binned scan sweep as a
//! JSON artifact (`make artifacts` emits it to the repo root as
//! `BENCH_scan.json`, tracking the perf trajectory across PRs).

use std::time::{Duration, Instant};

use sparrow::boosting::{
    edges::{accumulate_edges_stripe, accumulate_edges_stripe_into},
    CandidateGrid, EdgeMatrix,
};
use sparrow::data::{BinnedBatch, DataBlock};
use sparrow::model::{StrongRule, Stump};
use sparrow::network::{Fabric, NetConfig};
use sparrow::sampling::{MinimalVarianceSampler, SelectiveSampler};
use sparrow::scanner::BinnedBackend;
use sparrow::stopping::{CandidateStats, LilRule, StoppingRule};
use sparrow::util::bench::BenchRunner;
use sparrow::util::json::Json;
use sparrow::util::rng::Rng;

/// The rows-vs-binned × thread-count sweep of the edge-accumulation hot
/// loop at the acceptance shape (F=64, NT=8): the row engine's per-example
/// threshold search vs the binned engine's bucket accumulation (DESIGN.md
/// §8), both through their zero-allocation scanner entries (scoring is the
/// shared row-view step and benched separately below). Returns the result
/// object written to `BENCH_scan.json` by `--json`.
fn scan_engine_sweep(runner: &BenchRunner) -> Json {
    const N: usize = 32_768; // many BIN_CHUNK chunks → thread scaling visible
    const F: usize = 64;
    const NT: usize = 8;
    let mut rng = Rng::new(11);
    let mut block = DataBlock::empty(F);
    for _ in 0..N {
        let row: Vec<f32> = (0..F).map(|_| rng.gauss() as f32).collect();
        block.push(&row, if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
    }
    let grid = CandidateGrid::uniform(F, NT, -1.5, 1.5);
    let w = vec![1.0f32; N];
    // bins are built once per sample and reused — not part of the hot path
    let stripe_bins = grid.bin_spec((0, F)).bin_block(&block);
    let idx: Vec<usize> = (0..N).collect();
    let mut bins = BinnedBatch::default();
    bins.gather(&stripe_bins, &idx);

    let mut acc = EdgeMatrix::zeros(F, NT);
    let mut bucket = Vec::new();
    let rows = runner.bench("scan rows 32768x64x8", || {
        acc.reset();
        accumulate_edges_stripe_into(&block, &w, &grid, (0, F), &mut acc, &mut bucket);
        acc.count
    });
    let rows_s = rows.median.as_secs_f64();
    println!(
        "  -> rows: {:.1} M candidate-updates/s",
        (N * F * NT) as f64 / rows_s / 1e6
    );

    let mut sweep = Json::obj();
    let mut binned_1t = rows_s;
    let mut binned_last = rows_s;
    for threads in [1usize, 2, 4] {
        let mut be = BinnedBackend::new(threads);
        let stats = runner.bench(&format!("scan binned 32768x64x8 t={threads}"), || {
            acc.reset();
            be.accumulate_batch(&bins, &w, &block.labels, NT, (0, F), &mut acc);
            acc.count
        });
        let t_s = stats.median.as_secs_f64();
        if threads == 1 {
            binned_1t = t_s;
            println!("  -> binned 1t speedup over rows: {:.2}x", rows_s / t_s);
        } else {
            println!("  -> binned {threads}t scaling vs 1t: {:.2}x", binned_1t / t_s);
        }
        binned_last = t_s;
        sweep.set(&format!("t{threads}"), t_s);
    }

    let mut result = Json::obj();
    result
        .set("bench", "scan_engine")
        .set("n", N)
        .set("features", F)
        .set("nthr", NT)
        .set("rows_s", rows_s)
        .set("binned_s", sweep)
        .set("speedup_binned_1t", rows_s / binned_1t)
        .set("scaling_4t", binned_1t / binned_last);
    result
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .windows(2)
        .find(|w| w[0] == "--json")
        .map(|w| w[1].clone());

    let runner = BenchRunner {
        warmup: 2,
        runs: 9,
        ..BenchRunner::default()
    };

    // ---- edge accumulation (the scanner's inner loop) ---------------------
    let n = 4096;
    let f = 64;
    let nt = 8;
    let mut rng = Rng::new(1);
    let mut block = DataBlock::empty(f);
    for _ in 0..n {
        let row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
        block.push(&row, if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
    }
    let w = vec![1.0f32; n];
    let grid = CandidateGrid::uniform(f, nt, -1.5, 1.5);
    let stats = runner.bench("edges 4096x64x8", || {
        let mut acc = EdgeMatrix::zeros(f, nt);
        accumulate_edges_stripe(&block, &w, &grid, (0, f), &mut acc);
        acc
    });
    let updates = (n * f * nt) as f64 / stats.median.as_secs_f64();
    println!("  -> {:.1} M candidate-updates/s", updates / 1e6);

    // ---- scan engines: rows vs binned × threads (§Perf, DESIGN.md §8) -----
    let scan_json = scan_engine_sweep(&runner);
    if let Some(path) = &json_path {
        std::fs::write(path, scan_json.to_string() + "\n").expect("write BENCH_scan json");
        println!("scan sweep written to {path}");
    }

    // ---- incremental strong-rule scoring ----------------------------------
    let mut model = StrongRule::new();
    for t in 0..64u32 {
        model.push(Stump::new(t % f as u32, 0.0, 1.0), 0.1);
    }
    let stats = runner.bench("score-suffix 4096x64stumps", || {
        let mut acc = 0f32;
        for i in 0..n {
            acc += model.score_suffix(block.row(i), 0);
        }
        acc
    });
    let sps = (n * 64) as f64 / stats.median.as_secs_f64();
    println!("  -> {:.1} M stump-evals/s", sps / 1e6);

    // ---- selective sampling -------------------------------------------------
    let weights: Vec<f64> = (0..100_000).map(|i| 0.1 + (i % 13) as f64 * 0.2).collect();
    let stats = runner.bench("mvs-sampler 100k offers", || {
        let mut rng = Rng::new(2);
        let mut s = MinimalVarianceSampler::new(2.0, &mut rng);
        let mut kept = 0usize;
        for &w in &weights {
            kept += s.offer(w, &mut rng);
        }
        kept
    });
    println!(
        "  -> {:.1} M offers/s",
        100_000.0 / stats.median.as_secs_f64() / 1e6
    );

    // ---- stopping-rule sweep -------------------------------------------------
    let rule = LilRule::default();
    let cands: Vec<CandidateStats> = (0..512)
        .map(|i| CandidateStats {
            m: i as f64 * 0.1,
            sum_w: 1000.0,
            sum_w2: 900.0,
            count: 1000,
        })
        .collect();
    let stats = runner.bench("lil-sweep 512 candidates", || {
        cands.iter().filter(|c| rule.fires(c, 0.05)).count()
    });
    println!(
        "  -> {:.1} M candidate-checks/s",
        512.0 / stats.median.as_secs_f64() / 1e6
    );

    // ---- broadcast fan-out latency -------------------------------------------
    let (fabric, eps) = Fabric::<u64>::new(8, NetConfig::ideal());
    let t0 = Instant::now();
    let rounds = 200;
    for i in 0..rounds {
        eps[0].broadcast(i, 64);
        for ep in &eps[1..] {
            while ep.recv_timeout(Duration::from_secs(1)).is_none() {}
        }
    }
    let per_round = t0.elapsed() / rounds as u32;
    println!("broadcast fan-out (8 endpoints, ideal net): {per_round:?}/round");
    fabric.shutdown();
}
