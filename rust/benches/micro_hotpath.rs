//! Micro-benchmarks of the L3 hot paths (§Perf): edge accumulation,
//! incremental scoring, selective sampling, broadcast fan-out latency,
//! stopping-rule sweep. Baseline + after numbers live in EXPERIMENTS.md
//! §Perf.
//!
//!     cargo bench --bench micro_hotpath

use std::time::{Duration, Instant};

use sparrow::boosting::{edges::accumulate_edges_stripe, CandidateGrid, EdgeMatrix};
use sparrow::data::DataBlock;
use sparrow::model::{StrongRule, Stump};
use sparrow::network::{Fabric, NetConfig};
use sparrow::sampling::{MinimalVarianceSampler, SelectiveSampler};
use sparrow::stopping::{CandidateStats, LilRule, StoppingRule};
use sparrow::util::bench::BenchRunner;
use sparrow::util::rng::Rng;

fn main() {
    let runner = BenchRunner {
        warmup: 2,
        runs: 9,
        ..BenchRunner::default()
    };

    // ---- edge accumulation (the scanner's inner loop) ---------------------
    let n = 4096;
    let f = 64;
    let nt = 8;
    let mut rng = Rng::new(1);
    let mut block = DataBlock::empty(f);
    for _ in 0..n {
        let row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
        block.push(&row, if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
    }
    let w = vec![1.0f32; n];
    let grid = CandidateGrid::uniform(f, nt, -1.5, 1.5);
    let stats = runner.bench("edges 4096x64x8", || {
        let mut acc = EdgeMatrix::zeros(f, nt);
        accumulate_edges_stripe(&block, &w, &grid, (0, f), &mut acc);
        acc
    });
    let updates = (n * f * nt) as f64 / stats.median.as_secs_f64();
    println!("  -> {:.1} M candidate-updates/s", updates / 1e6);

    // ---- incremental strong-rule scoring ----------------------------------
    let mut model = StrongRule::new();
    for t in 0..64u32 {
        model.push(Stump::new(t % f as u32, 0.0, 1.0), 0.1);
    }
    let stats = runner.bench("score-suffix 4096x64stumps", || {
        let mut acc = 0f32;
        for i in 0..n {
            acc += model.score_suffix(block.row(i), 0);
        }
        acc
    });
    let sps = (n * 64) as f64 / stats.median.as_secs_f64();
    println!("  -> {:.1} M stump-evals/s", sps / 1e6);

    // ---- selective sampling -------------------------------------------------
    let weights: Vec<f64> = (0..100_000).map(|i| 0.1 + (i % 13) as f64 * 0.2).collect();
    let stats = runner.bench("mvs-sampler 100k offers", || {
        let mut rng = Rng::new(2);
        let mut s = MinimalVarianceSampler::new(2.0, &mut rng);
        let mut kept = 0usize;
        for &w in &weights {
            kept += s.offer(w, &mut rng);
        }
        kept
    });
    println!(
        "  -> {:.1} M offers/s",
        100_000.0 / stats.median.as_secs_f64() / 1e6
    );

    // ---- stopping-rule sweep -------------------------------------------------
    let rule = LilRule::default();
    let cands: Vec<CandidateStats> = (0..512)
        .map(|i| CandidateStats {
            m: i as f64 * 0.1,
            sum_w: 1000.0,
            sum_w2: 900.0,
            count: 1000,
        })
        .collect();
    let stats = runner.bench("lil-sweep 512 candidates", || {
        cands.iter().filter(|c| rule.fires(c, 0.05)).count()
    });
    println!(
        "  -> {:.1} M candidate-checks/s",
        512.0 / stats.median.as_secs_f64() / 1e6
    );

    // ---- broadcast fan-out latency -------------------------------------------
    let (fabric, eps) = Fabric::<u64>::new(8, NetConfig::ideal());
    let t0 = Instant::now();
    let rounds = 200;
    for i in 0..rounds {
        eps[0].broadcast(i, 64);
        for ep in &eps[1..] {
            while ep.recv_timeout(Duration::from_secs(1)).is_none() {}
        }
    }
    let per_round = t0.elapsed() / rounds as u32;
    println!("broadcast fan-out (8 endpoints, ideal net): {per_round:?}/round");
    fabric.shutdown();
}
