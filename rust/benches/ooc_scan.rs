//! Out-of-core data-plane benchmark (§Perf, DESIGN.md §11): steady-state
//! background-build rate of the fully-resident stratified store vs the
//! tiered store on a synthetic set ~4× the tiered memory budget, plus a
//! byte-identity assertion between the two planes.
//!
//!     cargo bench --bench ooc_scan [-- --json BENCH_ooc.json]
//!
//! `--json PATH` writes the result object (`make bench-ooc` emits it to
//! the repo root as `BENCH_ooc.json`, tracking the out-of-core cost
//! trajectory across PRs).

use sparrow::config::SamplerKind;
use sparrow::data::{IoThrottle, StrataConfig, StratifiedStore, SynthConfig, TieredConfig, TieredStore};
use sparrow::data::synth::SynthGen;
use sparrow::model::{StrongRule, Stump};
use sparrow::sampler::{build_once, build_tiered, BuildOutcome, BuildStamp, SamplerConfig};
use sparrow::util::bench::BenchRunner;
use sparrow::util::json::Json;

const N: usize = 60_000;
const F: usize = 16;
/// record = label + F features ≈ 68 B ⇒ store ≈ 4.1 MB, ~4× this budget
const BUDGET: u64 = 1 << 20;
const SEED: u64 = 1805;

fn sampler_cfg() -> SamplerConfig {
    SamplerConfig {
        target_m: 2048,
        kind: SamplerKind::MinimalVariance,
        probe: 2048,
        max_passes: 1,
        block: 1024,
    }
}

fn models() -> Vec<StrongRule> {
    let mut m1 = StrongRule::new();
    m1.push(Stump::new(0, 0.0, 1.0), 0.5);
    let mut m2 = m1.clone();
    m2.push(Stump::new(5, 0.3, -1.0), 0.35);
    vec![StrongRule::new(), m1, m2]
}

fn mem_sample(store: &mut StratifiedStore, model: &StrongRule, stamp: BuildStamp) -> BuildOutcome {
    build_once(store, model, stamp, &sampler_cfg(), SEED, || false).expect("mem build")
}

fn tiered_sample(store: &mut TieredStore, model: &StrongRule, stamp: BuildStamp) -> BuildOutcome {
    build_tiered(store, model, stamp, &sampler_cfg(), None, SEED, || false).expect("tiered build")
}

fn sample_of(out: BuildOutcome) -> sparrow::data::SampleSet {
    match out {
        BuildOutcome::Built { sample, .. } => sample,
        other => panic!("expected Built, got {other:?}"),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .windows(2)
        .find(|w| w[0] == "--json")
        .map(|w| w[1].clone());

    let dir = std::env::temp_dir().join("sparrow_ooc_bench");
    std::fs::create_dir_all(&dir).expect("bench tmpdir");
    let path = dir.join(format!("train_{N}_{F}.sprw"));
    SynthGen::new(SynthConfig {
        f: F,
        pos_rate: 0.3,
        informative: 8,
        signal: 0.8,
        flip_rate: 0.02,
        seed: 9,
    })
    .write_store(&path, N)
    .expect("write synthetic store");

    let mut mem = StratifiedStore::open(
        &path,
        IoThrottle::unlimited(),
        StrataConfig::default(),
    )
    .expect("open mem store");
    let mut tiered = TieredStore::open(
        &path,
        TieredConfig {
            memory_budget: BUDGET,
            probe_rows: sampler_cfg().probe,
            ..TieredConfig::default()
        },
    )
    .expect("open tiered store");

    // ---- identity: the tier must never change sample bytes ---------------
    let seq = models();
    for (v, model) in seq.iter().enumerate() {
        let stamp = BuildStamp {
            version: v as u64,
            attempt: 0,
        };
        let a = sample_of(mem_sample(&mut mem, model, stamp));
        let b = sample_of(tiered_sample(&mut tiered, model, stamp));
        assert_eq!(a.data, b.data, "v{v}: tiered sample diverged from mem");
        assert_eq!(a.score_sample, b.score_sample, "v{v}: scores diverged");
    }
    println!("identity: tiered == mem over {} model versions", seq.len());
    println!(
        "tiered resident fraction: {:.3} (budget {} B, store {} B)",
        tiered.resident_fraction(),
        BUDGET,
        (N * 4 * (1 + F)) as u64,
    );

    // ---- steady-state build rate: same model rebuilt (attempt bumps) -----
    // After the identity loop both stores are anchored at the last model;
    // repeated fresh draws at that anchor are the pipeline's steady state —
    // for the tiered store, certified skips make most rejected rows free.
    let runner = BenchRunner {
        warmup: 1,
        runs: 7,
        ..BenchRunner::default()
    };
    let model = seq.last().unwrap().clone();
    let mut attempt = 1u64;
    let mem_stats = runner.bench("ooc mem build 60000x16", || {
        let stamp = BuildStamp {
            version: 2,
            attempt,
        };
        attempt += 1;
        sample_of(mem_sample(&mut mem, &model, stamp)).len()
    });
    let mut attempt_t = 1u64;
    let before = tiered.counters();
    let tiered_stats = runner.bench("ooc tiered build 60000x16", || {
        let stamp = BuildStamp {
            version: 2,
            attempt: attempt_t,
        };
        attempt_t += 1;
        sample_of(tiered_sample(&mut tiered, &model, stamp)).len()
    });
    let after = tiered.counters();

    let mem_s = mem_stats.median.as_secs_f64();
    let tiered_s = tiered_stats.median.as_secs_f64();
    println!(
        "  -> mem: {:.2} M rows/s, tiered: {:.2} M rows/s, ratio {:.2}x",
        N as f64 / mem_s / 1e6,
        N as f64 / tiered_s / 1e6,
        tiered_s / mem_s,
    );
    println!(
        "  -> readahead hits {} misses {}, rows skipped (certified) {}",
        after.readahead_hits - before.readahead_hits,
        after.readahead_misses - before.readahead_misses,
        after.rows_skipped - before.rows_skipped,
    );

    let mut result = Json::obj();
    result
        .set("bench", "ooc_scan")
        .set("n", N)
        .set("features", F)
        .set("budget_bytes", BUDGET as f64)
        .set("store_bytes", (N * 4 * (1 + F)) as f64)
        .set("resident_fraction", tiered.resident_fraction())
        .set("mem_build_s", mem_s)
        .set("tiered_build_s", tiered_s)
        .set("tiered_over_mem", tiered_s / mem_s)
        .set(
            "readahead_hits",
            (after.readahead_hits - before.readahead_hits) as f64,
        )
        .set(
            "readahead_misses",
            (after.readahead_misses - before.readahead_misses) as f64,
        )
        .set(
            "rows_skipped",
            (after.rows_skipped - before.rows_skipped) as f64,
        )
        .set("identical", true);
    if let Some(path) = &json_path {
        std::fs::write(path, result.to_string() + "\n").expect("write BENCH_ooc json");
        println!("ooc sweep written to {path}");
    }
}
