//! Pipeline ablation — scanner stall during resample: blocking vs
//! background sampler (DESIGN.md §4).
//!
//! The paper's Figures 3–4 plateaus are the blocking sampler: the scanner
//! idles for the entire resample pass. The background pipeline builds the
//! next sample on its own thread while the scanner keeps working, so the
//! scanner-observed stall collapses to the *initial fill only* (there is no
//! previous sample to scan during the very first build), and every later
//! resample overlaps with scanning entirely.
//!
//! Also asserts, on a fixed seed, that the blocking sampler is
//! deterministic — two identical runs produce byte-identical samples — so
//! the default mode's behavior is pinned.
//!
//!     cargo bench --bench ablation_pipeline

use std::time::{Duration, Instant};

use sparrow::config::SamplerKind;
use sparrow::data::synth::SynthGen;
use sparrow::data::{IoThrottle, SampleSet, StrataConfig, SynthConfig};
use sparrow::metrics::EventLog;
use sparrow::model::{StrongRule, Stump};
use sparrow::sampler::{BackgroundSampler, Sampler, SamplerConfig};
use sparrow::util::bench::Table;
use sparrow::util::rng::Rng;

/// Emulate scanner work on the current sample for roughly `budget`.
fn scan_for(sample: &SampleSet, model: &StrongRule, budget: Duration) {
    let t0 = Instant::now();
    let mut acc = 0f32;
    let mut i = 0usize;
    while t0.elapsed() < budget && !sample.is_empty() {
        acc += model.score(sample.data.row(i % sample.len()));
        i += 1;
    }
    // sink so the loop isn't optimized away
    if acc.is_nan() {
        println!("unreachable: {acc}");
    }
}

fn main() -> anyhow::Result<()> {
    let scale = sparrow::harness::bench_scale();
    let n = ((60_000.0 * scale) as usize).max(5_000);
    let f = 16usize;
    let m = 2048usize;
    let rounds = 4usize;
    // off-memory tier: size the disk bandwidth so one full selective pass
    // costs ~0.4 s — the plateau the pipeline is supposed to erase
    let record_bytes = 4 * (1 + f);
    let bandwidth = (n * record_bytes) as f64 / 0.4;

    let dir = std::env::temp_dir().join("sparrow_bench_pipeline");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("store_{n}.sprw"));
    let store = SynthGen::new(SynthConfig {
        f,
        pos_rate: 0.1,
        informative: 8,
        signal: 0.8,
        flip_rate: 0.02,
        seed: 5,
    })
    .write_store(&path, n)?;

    // a trained-ish model so weights are skewed and sampling is selective
    let mut model = StrongRule::new();
    model.push(Stump::new(0, 0.0, 1.0), 1.2);
    model.push(Stump::new(3, 0.2, -1.0), 0.6);

    let cfg = SamplerConfig {
        target_m: m,
        kind: SamplerKind::MinimalVariance,
        probe: 2048,
        max_passes: 3,
        block: 1024,
    };

    // ---- blocking mode is deterministic on a fixed seed ----------------
    let resample_fixed = |seed: u64| -> anyhow::Result<SampleSet> {
        let mut s = Sampler::new(
            store.stream(IoThrottle::unlimited())?,
            store.len(),
            cfg.clone(),
            Rng::new(seed),
        );
        Ok(s.resample(&model)?.0)
    };
    let a = resample_fixed(42)?;
    let b = resample_fixed(42)?;
    assert_eq!(a.data, b.data, "blocking sampler must be seed-deterministic");
    println!("blocking sampler: fixed-seed resample byte-identical across runs ✓");

    // ---- blocking: the scanner idles for every resample ----------------
    let mut blocking_stall = Duration::ZERO;
    let mut blocking_busy = Duration::ZERO;
    let mut sampler = Sampler::new(
        store.stream(IoThrottle::new(bandwidth))?,
        store.len(),
        cfg.clone(),
        Rng::new(7),
    );
    for _ in 0..rounds {
        let t0 = Instant::now();
        let (sample, stats) = sampler.resample(&model)?;
        blocking_stall += t0.elapsed(); // scanner had nothing to do
        blocking_busy += stats.duration;
        scan_for(&sample, &model, Duration::from_millis(100));
    }

    // ---- background: stall is the initial fill only --------------------
    let (log, _rx) = EventLog::new();
    let mut bg = BackgroundSampler::spawn(
        store.path(),
        IoThrottle::new(bandwidth),
        StrataConfig {
            resident_rows: 4 * m,
        },
        cfg.clone(),
        None,
        7,
        0,
        log,
    )?;
    let mut bg_stall = Duration::ZERO;
    let mut bg_busy = Duration::ZERO;
    bg.request(0, &model);
    let t0 = Instant::now();
    let (mut sample, stats) = bg
        .wait_install(0, || false)?
        .expect("initial sample");
    bg_stall += t0.elapsed(); // the one unavoidable wait
    let initial_fill = bg_stall;
    bg_busy += stats.duration;
    for _ in 1..rounds {
        bg.request(0, &model); // new attempt against the same model
        // the scanner keeps scanning the stale sample while the build
        // runs — by construction it never waits
        loop {
            scan_for(&sample, &model, Duration::from_millis(5));
            if let Some((fresh, stats)) = bg.try_install(0)? {
                sample = fresh;
                bg_busy += stats.duration;
                break;
            }
        }
    }
    drop(bg);

    let secs = |d: Duration| format!("{:.3}", d.as_secs_f64());
    let mut t = Table::new(&[
        "Sampler mode",
        "Resamples",
        "Sampler busy (s)",
        "Scanner stall (s)",
        "Stall / resample (s)",
    ]);
    t.row(&[
        "blocking (paper)".into(),
        rounds.to_string(),
        secs(blocking_busy),
        secs(blocking_stall),
        secs(blocking_stall / rounds as u32),
    ]);
    t.row(&[
        "background".into(),
        rounds.to_string(),
        secs(bg_busy),
        secs(bg_stall),
        secs(bg_stall / rounds as u32),
    ]);
    println!(
        "\npipeline ablation — {n} examples, m={m}, off-memory tier \
         ({:.1} MB/s): resample plateau, blocking vs background",
        bandwidth / (1024.0 * 1024.0)
    );
    t.print();
    println!(
        "background stall is the initial fill only ({}s); every later \
         resample fully overlaps with scanning.",
        secs(initial_fill)
    );
    Ok(())
}
