//! E6 — resilience: "the overall slowdown ... is proportional to the
//! fraction of faulty machines" (§4), contrasted with bulk-synchronous,
//! which runs at the pace of the slowest machine.
//!
//! Two parts:
//!
//! 1. **Sweep** — fraction of 8x-laggard workers for both systems,
//!    reporting retained progress (rules or iterations per second,
//!    relative to the healthy cluster).
//! 2. **Fabric probe** (PR 9) — the self-healing TCP fabric's latency
//!    contract: `broadcast()` cost is one bounded-queue push regardless
//!    of peer health (a blackholed peer must not slow the caller), and
//!    time-to-reconnect after a peer dies and restarts behind its chaos
//!    proxy.
//!
//!     cargo bench --bench resilience [-- --json BENCH_resilience.json]
//!
//! `--json PATH` writes the result object (`make bench-resilience` emits
//! it to the repo root as `BENCH_resilience.json`, consumed by
//! `make artifacts`).

use std::time::{Duration, Instant};

use sparrow::data::DiskStore;
use sparrow::harness::{self, Workload};
use sparrow::model::StrongRule;
use sparrow::network::chaos::{ChaosFault, ChaosProxy, ChaosRules};
use sparrow::network::TcpEndpoint;
use sparrow::tmsn::BoostPayload;
use sparrow::util::bench::Table;
use sparrow::util::json::Json;

/// Percentile over a sorted sample set, in microseconds.
fn pct_us(sorted: &[Duration], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx].as_secs_f64() * 1e6
}

fn timed_pushes(ep: &TcpEndpoint<BoostPayload>, payload: &BoostPayload, n: usize) -> Vec<Duration> {
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        ep.broadcast(payload);
        samples.push(t0.elapsed());
    }
    samples.sort();
    samples
}

/// The fabric latency contract, measured: (healthy p50 us, healthy p99 us,
/// blackholed p99 us, reconnect ms).
fn fabric_probe() -> anyhow::Result<(f64, f64, f64, f64)> {
    let a: TcpEndpoint<BoostPayload> = TcpEndpoint::bind("127.0.0.1:0")?;
    let b: TcpEndpoint<BoostPayload> = TcpEndpoint::bind("127.0.0.1:0")?;
    let rules = ChaosRules::new(9);
    let proxy = ChaosProxy::spawn(&b.local_addr().to_string(), &rules, "a->b")?;
    a.connect(&proxy.listen_addr().to_string())?;

    let deadline = Instant::now() + Duration::from_secs(10);
    while a.peer_table().iter().filter(|p| p.up).count() < 1 {
        anyhow::ensure!(Instant::now() < deadline, "fabric probe: link never came up");
        std::thread::sleep(Duration::from_millis(10));
    }
    let payload = BoostPayload::resume(StrongRule::new(), 0.9);

    // healthy link: warm up, then time the push path
    timed_pushes(&a, &payload, 200);
    let healthy = timed_pushes(&a, &payload, 2_000);

    // blackholed link: the proxy swallows every frame but keeps the
    // connection alive — the sender must not notice at push time
    rules.set("a->b", ChaosFault::Blackhole);
    timed_pushes(&a, &payload, 200);
    let blackholed = timed_pushes(&a, &payload, 2_000);
    rules.clear("a->b");

    // reconnect: kill b, wait for the writer to notice, restart behind
    // the same proxy address, clock redial-to-delivery
    drop(b);
    while a.peer_count() > 0 {
        anyhow::ensure!(Instant::now() < deadline, "fabric probe: peer death never detected");
        std::thread::sleep(Duration::from_millis(10));
    }
    let t0 = Instant::now();
    let b2: TcpEndpoint<BoostPayload> = TcpEndpoint::bind("127.0.0.1:0")?;
    proxy.set_upstream(&b2.local_addr().to_string());
    loop {
        a.broadcast(&payload);
        if b2.recv_timeout(Duration::from_millis(50)).is_some() {
            break;
        }
        anyhow::ensure!(Instant::now() < deadline, "fabric probe: reconnect never delivered");
    }
    let reconnect_ms = t0.elapsed().as_secs_f64() * 1e3;

    Ok((
        pct_us(&healthy, 0.50),
        pct_us(&healthy, 0.99),
        pct_us(&blackholed, 0.99),
        reconnect_ms,
    ))
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let json_path = argv
        .windows(2)
        .find(|w| w[0] == "--json")
        .map(|w| w[1].clone());

    // -- part 2 first: the fabric probe is cheap and fails fast ----------
    let (p50_healthy, p99_healthy, p99_blackholed, reconnect_ms) = fabric_probe()?;
    println!("Fabric probe — broadcast() push latency and recovery");
    println!("  healthy     p50 {p50_healthy:8.1} us   p99 {p99_healthy:8.1} us");
    println!("  blackholed                      p99 {p99_blackholed:8.1} us");
    println!(
        "  ratio (blackholed p99 / healthy p99): {:.2}  — the contract: a dead\n  peer costs the caller one queue-push, nothing more",
        p99_blackholed / p99_healthy.max(1e-9)
    );
    println!("  reconnect-to-delivery after restart: {reconnect_ms:.0} ms\n");

    // -- part 1: laggard sweep (paper §4) --------------------------------
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let train = DiskStore::open(&store_path)?.read_all()?;
    let secs = 10.0;
    let workers = 4usize;
    let slow = 8.0;

    let mut t = Table::new(&[
        "Faulty fraction",
        "TMSN rules",
        "TMSN retained",
        "BSP iters",
        "BSP retained",
    ]);
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut tmsn_base = 0usize;
    let mut bsp_base = 0u64;
    for faulty in 0..=workers / 2 {
        let laggards: Vec<(usize, f64)> = (0..faulty).map(|i| (i, slow)).collect();

        let tmsn = harness::run_sparrow(workers, &store_path, &test, "tmsn", |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = 100_000;
            c.laggards = laggards.clone();
        })?;
        let tmsn_rules = tmsn.model.len();

        let bsp = harness::run_bulk_sync(
            &train,
            &test,
            workers,
            laggards.clone(),
            harness::stop(100_000, secs, 0.0),
            "bsp",
        );
        let bsp_iters = bsp.points.last().map(|p| p.iterations).unwrap_or(0);

        if faulty == 0 {
            tmsn_base = tmsn_rules.max(1);
            bsp_base = bsp_iters.max(1);
        }
        let tmsn_retained = tmsn_rules as f64 / tmsn_base as f64;
        let bsp_retained = bsp_iters as f64 / bsp_base as f64;
        t.row(&[
            format!("{}/{}", faulty, workers),
            tmsn_rules.to_string(),
            format!("{:.0}%", 100.0 * tmsn_retained),
            bsp_iters.to_string(),
            format!("{:.0}%", 100.0 * bsp_retained),
        ]);
        let mut row = Json::obj();
        row.set("faulty", faulty)
            .set("workers", workers)
            .set("tmsn_rules", tmsn_rules)
            .set("tmsn_retained", tmsn_retained)
            .set("bsp_iters", bsp_iters)
            .set("bsp_retained", bsp_retained);
        sweep_rows.push(row);
    }
    println!("\nResilience sweep — {workers} workers, laggard slowdown {slow}x, {secs:.0}s budget");
    t.print();
    println!(
        "\nexpected shape (paper §1/§4): TMSN retained ≈ 1 − faulty_fraction·(1−1/{slow});\nBSP retained ≈ 1/{slow} as soon as one laggard exists"
    );

    if let Some(path) = &json_path {
        let mut fabric = Json::obj();
        fabric
            .set("push_p50_us_healthy", p50_healthy)
            .set("push_p99_us_healthy", p99_healthy)
            .set("push_p99_us_blackholed", p99_blackholed)
            .set("push_p99_ratio", p99_blackholed / p99_healthy.max(1e-9))
            .set("reconnect_ms", reconnect_ms);
        let mut result = Json::obj();
        result
            .set("bench", "resilience")
            .set("laggard_slowdown", slow)
            .set("budget_s", secs)
            .set("fabric", fabric)
            .set("sweep", sweep_rows);
        std::fs::write(path, result.to_string() + "\n")?;
        println!("\nwrote {path}");
    }
    Ok(())
}
