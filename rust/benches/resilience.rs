//! E6 — resilience: "the overall slowdown ... is proportional to the
//! fraction of faulty machines" (§4), contrasted with bulk-synchronous,
//! which runs at the pace of the slowest machine.
//!
//! Sweeps the fraction of 8x-laggard workers for both systems and reports
//! retained progress (rules or iterations per second, relative to the
//! healthy cluster).
//!
//!     cargo bench --bench resilience

use sparrow::data::DiskStore;
use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let train = DiskStore::open(&store_path)?.read_all()?;
    let secs = 10.0;
    let workers = 4usize;
    let slow = 8.0;

    let mut t = Table::new(&[
        "Faulty fraction",
        "TMSN rules",
        "TMSN retained",
        "BSP iters",
        "BSP retained",
    ]);
    let mut tmsn_base = 0usize;
    let mut bsp_base = 0u64;
    for faulty in 0..=workers / 2 {
        let laggards: Vec<(usize, f64)> = (0..faulty).map(|i| (i, slow)).collect();

        let tmsn = harness::run_sparrow(workers, &store_path, &test, "tmsn", |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = 100_000;
            c.laggards = laggards.clone();
        })?;
        let tmsn_rules = tmsn.model.len();

        let bsp = harness::run_bulk_sync(
            &train,
            &test,
            workers,
            laggards.clone(),
            harness::stop(100_000, secs, 0.0),
            "bsp",
        );
        let bsp_iters = bsp.points.last().map(|p| p.iterations).unwrap_or(0);

        if faulty == 0 {
            tmsn_base = tmsn_rules.max(1);
            bsp_base = bsp_iters.max(1);
        }
        t.row(&[
            format!("{}/{}", faulty, workers),
            tmsn_rules.to_string(),
            format!("{:.0}%", 100.0 * tmsn_rules as f64 / tmsn_base as f64),
            bsp_iters.to_string(),
            format!("{:.0}%", 100.0 * bsp_iters as f64 / bsp_base as f64),
        ]);
    }
    println!("\nResilience sweep — {workers} workers, laggard slowdown {slow}x, {secs:.0}s budget");
    t.print();
    println!(
        "\nexpected shape (paper §1/§4): TMSN retained ≈ 1 − faulty_fraction·(1−1/{slow});\nBSP retained ≈ 1/{slow} as soon as one laggard exists"
    );
    Ok(())
}
