//! Compile-only stub of the `xla` crate (PJRT / xla_extension bindings).
//!
//! The offline build environment cannot link the real `xla_extension`
//! runtime, but `sparrow::runtime` must still compile so the rest of the
//! system (native backend, CLI, benches) is buildable and testable. Every
//! entry point here type-checks against the call sites in
//! `sparrow::runtime` and fails at *runtime* with a clear error, which the
//! config-driven backend factory surfaces as "use `--backend native`".
//!
//! Swapping in the real bindings is a one-line Cargo.toml change; the API
//! subset below mirrors the `xla` crate used by the AOT bridge
//! (`HloModuleProto::from_text_file` → `XlaComputation` → compile →
//! execute).

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`: implements `std::error::Error`, so
/// `?` converts it into `anyhow::Error` at the call sites.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA/PJRT runtime is not available in this build (offline stub crate); \
         rebuild with the real `xla` bindings or use the native backend"
            .to_string(),
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// The real binding constructs a TfrtCpuClient; the stub always errors.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation built from an HLO module (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A device buffer returned by execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("native backend"), "{err}");
    }

    #[test]
    fn literal_construction_is_cheap_but_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
