//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors all dependencies in-tree; this shim covers
//! exactly the subset of the real API the workspace uses: [`Error`],
//! [`Result`], [`Error::msg`], the blanket `From<E: std::error::Error>`
//! conversion used by `?`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Swapping in the real crates.io `anyhow` is a one-line Cargo.toml change.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error value.
///
/// Unlike the real `anyhow::Error` there is no backtrace and no downcast;
/// the source chain is flattened into the message at conversion time.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, which
// is what makes this blanket conversion coherent (same as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut msg = err.to_string();
        let mut source = err.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn msg_displays() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
        assert_eq!(format!("{e:?}"), "boom");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");

        fn bails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");

        fn ensures(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            Ok(v)
        }
        assert_eq!(ensures(3).unwrap(), 3);
        assert_eq!(ensures(30).unwrap_err().to_string(), "v too big: 30");
    }

    #[test]
    fn ensure_without_message() {
        fn check(v: bool) -> Result<()> {
            ensure!(v);
            Ok(())
        }
        assert!(check(true).is_ok());
        assert!(check(false).unwrap_err().to_string().contains("condition failed"));
    }
}
