//! AdaBoost vote weights.
//!
//! The paper adds a certified weak rule with `alpha = ½ log((½+γ)/(½−γ))`
//! (Alg. 1), where γ is the *advantage*: half the normalized weighted
//! correlation. With weighted error ε, γ = ½ − ε and this is the classic
//! `½ ln((1−ε)/ε)`.

/// `alpha` from an advantage γ ∈ (0, ½).
pub fn alpha_for_advantage(gamma: f64) -> f64 {
    assert!(
        gamma > 0.0 && gamma < 0.5,
        "advantage must be in (0, 0.5), got {gamma}"
    );
    0.5 * ((0.5 + gamma) / (0.5 - gamma)).ln()
}

/// `alpha` from a normalized correlation `corr = Σ w y h / Σ w ∈ (0, 1)`.
/// The advantage is `corr / 2`.
pub fn alpha_for_correlation(corr: f64) -> f64 {
    alpha_for_advantage(corr / 2.0)
}

/// Clamp a measured correlation into the valid open interval, guarding the
/// log against perfectly-correlated candidates on tiny samples.
pub fn clamp_correlation(corr: f64, max_corr: f64) -> f64 {
    corr.clamp(-max_corr, max_corr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_advantage_is_invalid() {
        assert!(std::panic::catch_unwind(|| alpha_for_advantage(0.0)).is_err());
        assert!(std::panic::catch_unwind(|| alpha_for_advantage(0.5)).is_err());
    }

    #[test]
    fn monotone_in_gamma() {
        let a1 = alpha_for_advantage(0.05);
        let a2 = alpha_for_advantage(0.1);
        let a3 = alpha_for_advantage(0.4);
        assert!(0.0 < a1 && a1 < a2 && a2 < a3);
    }

    #[test]
    fn matches_error_form() {
        // γ = ½ − ε  ⇒  α = ½ ln((1−ε)/ε)
        let eps = 0.3f64;
        let gamma = 0.5 - eps;
        let a = alpha_for_advantage(gamma);
        let want = 0.5 * ((1.0 - eps) / eps).ln();
        assert!((a - want).abs() < 1e-12);
    }

    #[test]
    fn correlation_form_halves() {
        assert!((alpha_for_correlation(0.2) - alpha_for_advantage(0.1)).abs() < 1e-12);
    }

    #[test]
    fn clamp() {
        assert_eq!(clamp_correlation(0.99, 0.9), 0.9);
        assert_eq!(clamp_correlation(-0.99, 0.9), -0.9);
        assert_eq!(clamp_correlation(0.3, 0.9), 0.3);
    }
}
