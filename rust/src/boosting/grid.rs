//! Candidate-threshold grids.
//!
//! Each worker owns a stripe of features (feature-based parallelization,
//! §4) and, per feature, a small grid of candidate thresholds taken from
//! quantiles of a pilot sample — the same approach as XGBoost's
//! "approximate greedy" sketch, which the paper selects as its baseline
//! configuration.

use crate::data::DataBlock;

/// Per-feature candidate thresholds, shaped `(features, nthr)` row-major —
/// exactly the `grid_thr` input of the AOT scan executable.
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateGrid {
    pub f: usize,
    pub nthr: usize,
    /// (f, nthr) row-major; each row ascending
    pub thresholds: Vec<f32>,
}

impl CandidateGrid {
    /// Build from quantiles of a pilot block.
    ///
    /// Thresholds are midpoints of the `nthr+1`-quantile cut points of each
    /// feature's empirical distribution, deduplicated by nudging (constant
    /// features degenerate to copies, which is harmless: their stumps have
    /// edge ≈ 0 and are never certified).
    pub fn from_quantiles(pilot: &DataBlock, nthr: usize) -> CandidateGrid {
        assert!(nthr >= 1);
        assert!(pilot.n >= 2, "pilot sample too small");
        let f = pilot.f;
        let mut thresholds = vec![0f32; f * nthr];
        let mut col = vec![0f32; pilot.n];
        for j in 0..f {
            for i in 0..pilot.n {
                col[i] = pilot.row(i)[j];
            }
            col.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for t in 0..nthr {
                // cut point at quantile (t+1)/(nthr+1)
                let q = (t + 1) as f64 / (nthr + 1) as f64;
                let pos = q * (pilot.n - 1) as f64;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                let frac = (pos - lo as f64) as f32;
                // exact when the bracketing values coincide (constant
                // features must produce the exact constant, not a lerp
                // rounding artifact)
                thresholds[j * nthr + t] = if col[lo] == col[hi] {
                    col[lo]
                } else {
                    col[lo] * (1.0 - frac) + col[hi] * frac
                };
            }
        }
        CandidateGrid { f, nthr, thresholds }
    }

    /// Uniform grid on [lo, hi] for every feature (tests / synthetic data).
    pub fn uniform(f: usize, nthr: usize, lo: f32, hi: f32) -> CandidateGrid {
        assert!(nthr >= 1 && hi > lo);
        let mut thresholds = vec![0f32; f * nthr];
        for j in 0..f {
            for t in 0..nthr {
                let frac = (t + 1) as f32 / (nthr + 1) as f32;
                thresholds[j * nthr + t] = lo + frac * (hi - lo);
            }
        }
        CandidateGrid { f, nthr, thresholds }
    }

    #[inline]
    pub fn row(&self, feature: usize) -> &[f32] {
        &self.thresholds[feature * self.nthr..(feature + 1) * self.nthr]
    }

    /// Number of candidate stumps including both polarities.
    pub fn num_candidates(&self) -> usize {
        self.f * self.nthr * 2
    }

    /// The quantization spec of a feature stripe for the binned scan
    /// engine (DESIGN.md §8): hands `data::binned` exactly the threshold
    /// rows the row engine compares against (copied — the data layer does
    /// not depend on `boosting`).
    pub fn bin_spec(&self, stripe: (usize, usize)) -> crate::data::BinSpec {
        assert!(stripe.0 < stripe.1 && stripe.1 <= self.f);
        crate::data::BinSpec::new(
            stripe,
            self.nthr,
            self.thresholds[stripe.0 * self.nthr..stripe.1 * self.nthr].to_vec(),
        )
    }

    /// Restrict to a stripe of features `[start, end)`; threshold rows are
    /// copied, and the stripe remembers its global feature offset.
    pub fn stripe(&self, start: usize, end: usize) -> FeatureStripe {
        assert!(start < end && end <= self.f);
        FeatureStripe {
            offset: start,
            grid: CandidateGrid {
                f: end - start,
                nthr: self.nthr,
                thresholds: self.thresholds[start * self.nthr..end * self.nthr].to_vec(),
            },
        }
    }
}

/// A worker's stripe of the candidate grid (feature-based parallelization).
#[derive(Debug, Clone)]
pub struct FeatureStripe {
    /// global index of the first feature in this stripe
    pub offset: usize,
    pub grid: CandidateGrid,
}

impl FeatureStripe {
    /// Map a stripe-local feature index to the global one.
    pub fn global_feature(&self, local: usize) -> usize {
        self.offset + local
    }
}

/// Partition `f` features into `n` contiguous stripes (sizes differ by ≤1).
pub fn partition_features(f: usize, n: usize) -> Vec<(usize, usize)> {
    assert!(n >= 1 && f >= n, "need at least one feature per worker");
    let base = f / n;
    let extra = f % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pilot() -> DataBlock {
        let mut b = DataBlock::empty(2);
        for i in 0..100 {
            b.push(&[i as f32, (i % 10) as f32], if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        b
    }

    #[test]
    fn quantile_grid_ascending_and_in_range() {
        let g = CandidateGrid::from_quantiles(&pilot(), 4);
        assert_eq!(g.f, 2);
        assert_eq!(g.nthr, 4);
        for j in 0..2 {
            let row = g.row(j);
            for t in 1..4 {
                assert!(row[t] >= row[t - 1], "row not ascending: {row:?}");
            }
            assert!(row[0] >= 0.0);
        }
        // feature 0 spans 0..99: quantile cuts near 20/40/60/80
        let r0 = g.row(0);
        assert!((r0[0] - 19.8).abs() < 1.0, "{r0:?}");
        assert!((r0[3] - 79.2).abs() < 1.0, "{r0:?}");
    }

    #[test]
    fn uniform_grid() {
        let g = CandidateGrid::uniform(3, 3, 0.0, 4.0);
        assert_eq!(g.row(1), &[1.0, 2.0, 3.0]);
        assert_eq!(g.num_candidates(), 3 * 3 * 2);
    }

    #[test]
    fn stripe_copies_rows() {
        let g = CandidateGrid::uniform(4, 2, 0.0, 3.0);
        let s = g.stripe(2, 4);
        assert_eq!(s.offset, 2);
        assert_eq!(s.grid.f, 2);
        assert_eq!(s.grid.row(0), g.row(2));
        assert_eq!(s.global_feature(1), 3);
    }

    #[test]
    fn bin_spec_copies_stripe_rows() {
        let g = CandidateGrid::uniform(4, 3, 0.0, 4.0);
        let spec = g.bin_spec((1, 3));
        assert_eq!(spec.stripe, (1, 3));
        assert_eq!(spec.nthr, 3);
        assert_eq!(spec.row(0), g.row(1));
        assert_eq!(spec.row(1), g.row(2));
    }

    #[test]
    fn partition_covers_all_features() {
        for (f, n) in [(10, 3), (9, 3), (7, 7), (256, 10)] {
            let parts = partition_features(f, n);
            assert_eq!(parts.len(), n);
            assert_eq!(parts[0].0, 0);
            assert_eq!(parts[n - 1].1, f);
            for w in parts.windows(2) {
                assert_eq!(w[0].1, w[1].0); // contiguous
            }
            let sizes: Vec<usize> = parts.iter().map(|(a, b)| b - a).collect();
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1);
        }
    }

    #[test]
    #[should_panic]
    fn partition_requires_enough_features() {
        partition_features(2, 3);
    }
}
