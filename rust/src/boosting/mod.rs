//! Boosting math shared by Sparrow and the baselines: AdaBoost vote
//! weights, candidate-threshold grids, and native (CPU) edge computation
//! mirroring the L1 kernel exactly.

pub mod alpha;
pub mod edges;
pub mod grid;

pub use alpha::{alpha_for_advantage, alpha_for_correlation};
pub use edges::{edges_native, EdgeMatrix};
pub use grid::CandidateGrid;
