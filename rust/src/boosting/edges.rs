//! Native (CPU) candidate-edge computation — the Rust mirror of the L1
//! Pallas kernel, used by the scanner's native backend and by the
//! full-scan baselines.
//!
//! `edges[f][t] = Σ_i u_i · (2·[x_{i,f} > thr_{f,t}] − 1)`, `u_i = w_i y_i`.
//!
//! Implementation: per example, for each feature, count thresholds below
//! the value (grid rows are ascending) and bucket-accumulate, then convert
//! buckets to edges with one reverse prefix sum. O(n · F · NT) worst case
//! but with a branch-light inner loop; see benches/micro_hotpath.rs.

use crate::boosting::CandidateGrid;
use crate::data::DataBlock;

/// Edge matrix over a candidate grid, plus the stopping-rule scalars
/// accumulated in the same pass.
#[derive(Debug, Clone)]
pub struct EdgeMatrix {
    pub f: usize,
    pub nthr: usize,
    /// (f, nthr) row-major, positive-polarity edges (negate for sign = -1)
    pub edges: Vec<f64>,
    /// Σ |w|  (W of Alg. 2)
    pub sum_w: f64,
    /// Σ w²   (V of Alg. 2)
    pub sum_w2: f64,
    /// examples accumulated
    pub count: u64,
}

impl EdgeMatrix {
    pub fn zeros(f: usize, nthr: usize) -> EdgeMatrix {
        EdgeMatrix {
            f,
            nthr,
            edges: vec![0.0; f * nthr],
            sum_w: 0.0,
            sum_w2: 0.0,
            count: 0,
        }
    }

    #[inline]
    pub fn edge(&self, feature: usize, t: usize) -> f64 {
        self.edges[feature * self.nthr + t]
    }

    /// Zero in place, keeping the shape — pass-accumulator reuse
    /// (the scanner's zero-allocation batch path).
    pub fn reset(&mut self) {
        self.edges.fill(0.0);
        self.sum_w = 0.0;
        self.sum_w2 = 0.0;
        self.count = 0;
    }

    /// Merge another accumulation (e.g. from a second batch).
    pub fn merge(&mut self, other: &EdgeMatrix) {
        assert_eq!(self.f, other.f);
        assert_eq!(self.nthr, other.nthr);
        for (a, b) in self.edges.iter_mut().zip(&other.edges) {
            *a += b;
        }
        self.sum_w += other.sum_w;
        self.sum_w2 += other.sum_w2;
        self.count += other.count;
    }

    /// Best candidate by |edge| over both polarities:
    /// returns `(feature, t, signed_edge)` where the sign picks polarity.
    pub fn best(&self) -> (usize, usize, f64) {
        let mut best = (0, 0, 0.0f64);
        for f in 0..self.f {
            for t in 0..self.nthr {
                let e = self.edge(f, t);
                if e.abs() > best.2.abs() {
                    best = (f, t, e);
                }
            }
        }
        best
    }
}

/// Accumulate candidate edges over `block` with signed weights `u = w·y`.
///
/// `accum` must be shaped to `grid`; pass `EdgeMatrix::zeros` to start.
pub fn accumulate_edges(
    block: &DataBlock,
    w: &[f32],
    grid: &CandidateGrid,
    accum: &mut EdgeMatrix,
) {
    accumulate_edges_stripe(block, w, grid, (0, grid.f), accum)
}

/// Striped variant (feature-based parallelization, §4): only candidate
/// columns in `stripe = [start, end)` are accumulated; the stopping-rule
/// scalars (Σ|w|, Σw², count) still cover the whole batch.
pub fn accumulate_edges_stripe(
    block: &DataBlock,
    w: &[f32],
    grid: &CandidateGrid,
    stripe: (usize, usize),
    accum: &mut EdgeMatrix,
) {
    accumulate_edges_stripe_into(block, w, grid, stripe, accum, &mut Vec::new())
}

/// Scratch-reusing variant: `bucket` is cleared, resized and refilled —
/// pass the same vector every batch and the edge pass allocates nothing
/// (the scanner routes its zero-allocation path through here via
/// `BatchResult`'s bucket scratch).
pub fn accumulate_edges_stripe_into(
    block: &DataBlock,
    w: &[f32],
    grid: &CandidateGrid,
    stripe: (usize, usize),
    accum: &mut EdgeMatrix,
    bucket: &mut Vec<f64>,
) {
    let (fs, fe) = stripe;
    assert_eq!(block.f, grid.f);
    assert_eq!(block.n, w.len());
    assert_eq!(accum.f, grid.f);
    assert_eq!(accum.nthr, grid.nthr);
    assert!(fs < fe && fe <= grid.f, "bad stripe {stripe:?}");
    let nthr = grid.nthr;
    // bucket[(f-fs)*(nthr+1) + k] accumulates u of examples whose value
    // exceeds exactly k thresholds of feature f's ascending row
    bucket.clear();
    bucket.resize((fe - fs) * (nthr + 1), 0.0);
    let mut sum_w = 0.0f64;
    let mut sum_w2 = 0.0f64;
    for i in 0..block.n {
        let wi = w[i] as f64;
        let u = wi * block.label(i) as f64;
        sum_w += wi.abs();
        sum_w2 += wi * wi;
        let row = block.row(i);
        for f in fs..fe {
            let x = row[f];
            let thr = grid.row(f);
            // count thresholds strictly below x (row ascending)
            let mut k = 0usize;
            while k < nthr && x > thr[k] {
                k += 1;
            }
            bucket[(f - fs) * (nthr + 1) + k] += u;
        }
    }
    fold_buckets(bucket, stripe, nthr, accum);
    accum.sum_w += sum_w;
    accum.sum_w2 += sum_w2;
    accum.count += block.n as u64;
}

/// Convert per-feature bucket accumulations into edge contributions:
/// `edges[f][t] += sum_{k > t} bucket[k] − sum_{k <= t} bucket[k]
///              = 2 · suffix_sum(t+1) − total`.
/// Shared by the row engine above and the binned engine
/// (`scanner::backend::BinnedBackend`), so both fold with the identical
/// f64 operation order.
pub(crate) fn fold_buckets(
    bucket: &[f64],
    stripe: (usize, usize),
    nthr: usize,
    accum: &mut EdgeMatrix,
) {
    let (fs, fe) = stripe;
    debug_assert_eq!(bucket.len(), (fe - fs) * (nthr + 1));
    for f in fs..fe {
        let b = &bucket[(f - fs) * (nthr + 1)..(f - fs + 1) * (nthr + 1)];
        fold_column(b, &mut accum.edges[f * nthr..(f + 1) * nthr], nthr);
    }
}

/// One column's bucket → edge fold — the single shared implementation,
/// so the serial and threaded folds have the identical f64 operation
/// order per column by construction.
#[inline]
fn fold_column(b: &[f64], e: &mut [f64], nthr: usize) {
    let total: f64 = b.iter().sum();
    let mut suffix = total;
    for t in 0..nthr {
        suffix -= b[t]; // now sum_{k >= t+1}
        e[t] += 2.0 * suffix - total;
    }
}

/// Minimum fold size (stripe columns × bucket slots) before
/// [`fold_buckets_par`] spawns threads: below this the whole fold is
/// cheaper than one thread spawn, so it stays serial regardless of the
/// requested thread count. A pure perf heuristic — the result is
/// bit-identical either way.
pub const FOLD_PAR_MIN_SLOTS: usize = 1 << 12;

/// Threaded variant of `fold_buckets`: the stripe's columns are split
/// into contiguous ranges folded by up to `threads` scoped workers.
/// Every column writes its own disjoint `nthr`-wide `edges` slice with
/// the identical per-column operation order as the serial fold (shared
/// `fold_column`), and columns never interact, so the `EdgeMatrix` is
/// **bit-identical for every thread count** — there is no merge step to
/// order. Engages threads only when `threads > 1` and the fold spans at
/// least [`FOLD_PAR_MIN_SLOTS`] slots (the binned engine's Amdahl
/// remainder case: wide stripes × many thresholds).
pub fn fold_buckets_par(
    bucket: &[f64],
    stripe: (usize, usize),
    nthr: usize,
    accum: &mut EdgeMatrix,
    threads: usize,
) {
    let (fs, fe) = stripe;
    let width = fe - fs;
    debug_assert_eq!(bucket.len(), width * (nthr + 1));
    let eff = threads.min(width);
    if eff <= 1 || width * (nthr + 1) < FOLD_PAR_MIN_SLOTS {
        return fold_buckets(bucket, stripe, nthr, accum);
    }
    let per = width.div_ceil(eff);
    let region = &mut accum.edges[fs * nthr..fe * nthr];
    std::thread::scope(|s| {
        for (erange, brange) in region
            .chunks_mut(per * nthr)
            .zip(bucket.chunks(per * (nthr + 1)))
        {
            s.spawn(move || {
                let cols = erange.len() / nthr;
                for c in 0..cols {
                    let b = &brange[c * (nthr + 1)..(c + 1) * (nthr + 1)];
                    fold_column(b, &mut erange[c * nthr..(c + 1) * nthr], nthr);
                }
            });
        }
    });
}

/// One-shot edge computation (fresh accumulator).
pub fn edges_native(block: &DataBlock, w: &[f32], grid: &CandidateGrid) -> EdgeMatrix {
    let mut accum = EdgeMatrix::zeros(grid.f, grid.nthr);
    accumulate_edges(block, w, grid, &mut accum);
    accum
}

/// Brute-force reference (tests only): evaluate every stump directly.
pub fn edges_bruteforce(block: &DataBlock, w: &[f32], grid: &CandidateGrid) -> EdgeMatrix {
    let mut accum = EdgeMatrix::zeros(grid.f, grid.nthr);
    for i in 0..block.n {
        let wi = w[i] as f64;
        let u = wi * block.label(i) as f64;
        accum.sum_w += wi.abs();
        accum.sum_w2 += wi * wi;
        let row = block.row(i);
        for f in 0..grid.f {
            for t in 0..grid.nthr {
                let h = if row[f] > grid.row(f)[t] { 1.0 } else { -1.0 };
                accum.edges[f * grid.nthr + t] += u * h;
            }
        }
    }
    accum.count = block.n as u64;
    accum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, prop_check};
    use crate::util::rng::Rng;

    fn random_block(rng: &mut Rng, n: usize, f: usize) -> (DataBlock, Vec<f32>) {
        let feats = gen::normal_vec(rng, n * f);
        let labels = gen::labels(rng, n, 0.4);
        let w = gen::skewed_weights(rng, n, 3.0);
        (DataBlock::new(n, f, feats, labels), w)
    }

    #[test]
    fn matches_bruteforce() {
        let mut rng = Rng::new(1);
        let (block, w) = random_block(&mut rng, 200, 8);
        let grid = CandidateGrid::from_quantiles(&block, 5);
        let fast = edges_native(&block, &w, &grid);
        let slow = edges_bruteforce(&block, &w, &grid);
        for (a, b) in fast.edges.iter().zip(&slow.edges) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert!((fast.sum_w - slow.sum_w).abs() < 1e-9);
        assert!((fast.sum_w2 - slow.sum_w2).abs() < 1e-9);
    }

    #[test]
    fn prop_matches_bruteforce() {
        prop_check("edges_native == bruteforce", 40, |rng| {
            let n = gen::size(rng, 1, 120);
            let f = gen::size(rng, 1, 10);
            let nthr = gen::size(rng, 1, 6);
            let (block, w) = random_block(rng, n, f);
            let grid = CandidateGrid::uniform(f, nthr, -2.0, 2.0);
            let fast = edges_native(&block, &w, &grid);
            let slow = edges_bruteforce(&block, &w, &grid);
            for (a, b) in fast.edges.iter().zip(&slow.edges) {
                if (a - b).abs() > 1e-6 {
                    return Err(format!("edge mismatch {a} vs {b} (n={n} f={f} nthr={nthr})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn merge_equals_single_pass() {
        let mut rng = Rng::new(2);
        let (block, w) = random_block(&mut rng, 100, 4);
        let grid = CandidateGrid::uniform(4, 3, -1.0, 1.0);
        let whole = edges_native(&block, &w, &grid);

        let chunks = block.chunks(33);
        let mut merged = EdgeMatrix::zeros(4, 3);
        let mut off = 0;
        for c in &chunks {
            let part = edges_native(c, &w[off..off + c.n], &grid);
            merged.merge(&part);
            off += c.n;
        }
        for (a, b) in whole.edges.iter().zip(&merged.edges) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(whole.count, merged.count);
    }

    #[test]
    fn scratch_reuse_matches_fresh_bucket() {
        // the zero-allocation entry with a dirty, reused bucket gives a
        // bit-identical accumulation to per-batch fresh buckets
        let mut rng = Rng::new(5);
        let (block, w) = random_block(&mut rng, 150, 5);
        let grid = CandidateGrid::uniform(5, 4, -1.5, 1.5);
        let mut bucket = vec![999.0; 3]; // wrong size AND dirty on purpose
        let mut reused = EdgeMatrix::zeros(5, 4);
        let mut fresh = EdgeMatrix::zeros(5, 4);
        let mut off = 0;
        for chunk in block.chunks(40) {
            let ws = &w[off..off + chunk.n];
            accumulate_edges_stripe(&chunk, ws, &grid, (0, 5), &mut fresh);
            accumulate_edges_stripe_into(&chunk, ws, &grid, (0, 5), &mut reused, &mut bucket);
            off += chunk.n;
        }
        assert_eq!(fresh.edges, reused.edges, "bit-identical accumulation");
        assert_eq!(fresh.count, reused.count);
        assert_eq!(fresh.sum_w.to_bits(), reused.sum_w.to_bits());
    }

    #[test]
    fn reset_zeroes_in_place() {
        let mut rng = Rng::new(6);
        let (block, w) = random_block(&mut rng, 50, 3);
        let grid = CandidateGrid::uniform(3, 2, -1.0, 1.0);
        let mut m = edges_native(&block, &w, &grid);
        assert!(m.count > 0);
        m.reset();
        assert!(m.edges.iter().all(|&e| e == 0.0));
        assert_eq!((m.sum_w, m.sum_w2, m.count), (0.0, 0.0, 0));
        assert_eq!((m.f, m.nthr), (3, 2), "shape preserved");
    }

    #[test]
    fn fold_par_bit_identical_across_thread_counts() {
        // wide enough to cross FOLD_PAR_MIN_SLOTS so threads really
        // engage: 600 columns × (7+1) slots = 4800 ≥ 4096
        let mut rng = Rng::new(9);
        let (width, nthr) = (600usize, 7usize);
        let bucket: Vec<f64> = (0..width * (nthr + 1)).map(|_| rng.gauss()).collect();
        for stripe in [(0, width), (3, 3 + width)] {
            let f_total = stripe.1;
            let mut serial = EdgeMatrix::zeros(f_total, nthr);
            fold_buckets(&bucket, stripe, nthr, &mut serial);
            for threads in [1usize, 2, 7, 64] {
                let mut par = EdgeMatrix::zeros(f_total, nthr);
                fold_buckets_par(&bucket, stripe, nthr, &mut par, threads);
                for (a, b) in serial.edges.iter().zip(&par.edges) {
                    assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn fold_par_small_stripe_stays_serial_and_identical() {
        // below the engage floor the threaded entry must take the serial
        // path — and still accumulate (+=) into a dirty accumulator
        let mut rng = Rng::new(10);
        let (width, nthr) = (6usize, 4usize);
        let bucket: Vec<f64> = (0..width * (nthr + 1)).map(|_| rng.gauss()).collect();
        let mut serial = EdgeMatrix::zeros(width, nthr);
        serial.edges.iter_mut().for_each(|e| *e = 0.25);
        let mut par = serial.clone();
        fold_buckets(&bucket, (0, width), nthr, &mut serial);
        fold_buckets_par(&bucket, (0, width), nthr, &mut par, 8);
        for (a, b) in serial.edges.iter().zip(&par.edges) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn best_picks_largest_abs() {
        let mut m = EdgeMatrix::zeros(2, 2);
        m.edges = vec![0.1, -0.9, 0.5, 0.2];
        let (f, t, e) = m.best();
        assert_eq!((f, t), (0, 1));
        assert_eq!(e, -0.9);
    }

    #[test]
    fn perfect_feature_has_max_edge() {
        // feature 0 == label: stump (f=0, thr=0) has edge == Σw
        let mut b = DataBlock::empty(2);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            b.push(&[y * 2.0, rng.gauss() as f32], y);
        }
        let w = vec![1.0f32; 50];
        let grid = CandidateGrid::uniform(2, 1, -0.5, 0.5); // thr = 0
        let m = edges_native(&b, &w, &grid);
        assert!((m.edge(0, 0) - 50.0).abs() < 1e-9, "{}", m.edge(0, 0));
        assert!(m.edge(1, 0).abs() < 20.0);
    }
}
