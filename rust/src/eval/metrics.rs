//! Metric implementations.

use crate::data::DataBlock;
use crate::model::StrongRule;

/// Average exponential loss `1/n Σ exp(-y_i H(x_i))` (the potential Z_S of
/// §3 — all compared algorithms optimize this).
pub fn exp_loss(model: &StrongRule, data: &DataBlock) -> f64 {
    exp_loss_scores(&scores(model, data), &data.labels)
}

/// Exponential loss from precomputed scores.
pub fn exp_loss_scores(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    if scores.is_empty() {
        return 1.0;
    }
    let mut s = 0.0f64;
    for (&sc, &y) in scores.iter().zip(labels) {
        s += (-(y as f64) * sc as f64).exp();
    }
    s / scores.len() as f64
}

/// 0/1 test error.
pub fn test_error(model: &StrongRule, data: &DataBlock) -> f64 {
    if data.n == 0 {
        return 0.0;
    }
    let mut wrong = 0usize;
    for i in 0..data.n {
        if model.predict(data.row(i)) != data.label(i) {
            wrong += 1;
        }
    }
    wrong as f64 / data.n as f64
}

/// Strong-rule scores over a block.
pub fn scores(model: &StrongRule, data: &DataBlock) -> Vec<f32> {
    (0..data.n).map(|i| model.score(data.row(i))).collect()
}

/// Area under the precision-recall curve, computed by descending-score
/// sweep with step interpolation (scikit-learn's `average_precision`
/// definition: Σ (R_k − R_{k−1}) · P_k).
pub fn auprc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let total_pos = labels.iter().filter(|&&y| y > 0.0).count();
    if total_pos == 0 || scores.is_empty() {
        return 0.0;
    }
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal));

    let mut ap = 0.0f64;
    let mut tp = 0usize;
    let mut seen = 0usize;
    let mut prev_recall = 0.0f64;
    let mut i = 0usize;
    while i < order.len() {
        // advance through ties as one group (a threshold can't split ties)
        let mut j = i;
        while j < order.len() && scores[order[j]] == scores[order[i]] {
            if labels[order[j]] > 0.0 {
                tp += 1;
            }
            seen += 1;
            j += 1;
        }
        let precision = tp as f64 / seen as f64;
        let recall = tp as f64 / total_pos as f64;
        ap += (recall - prev_recall) * precision;
        prev_recall = recall;
        i = j;
    }
    ap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;

    #[test]
    fn exp_loss_empty_model_is_one() {
        let mut d = DataBlock::empty(1);
        d.push(&[0.0], 1.0);
        d.push(&[1.0], -1.0);
        assert!((exp_loss(&StrongRule::new(), &d) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exp_loss_decreases_with_correct_stump() {
        let mut d = DataBlock::empty(1);
        for i in 0..10 {
            let y = if i % 2 == 0 { 1.0 } else { -1.0 };
            d.push(&[y], y); // feature == label
        }
        let mut m = StrongRule::new();
        m.push(Stump::new(0, 0.0, 1.0), 1.0);
        let loss = exp_loss(&m, &d);
        assert!(loss < 1.0);
        assert!((loss - (-1.0f64).exp()).abs() < 1e-6); // every example correct
    }

    #[test]
    fn exp_loss_scores_matches_model_path() {
        let mut d = DataBlock::empty(1);
        d.push(&[2.0], 1.0);
        d.push(&[-2.0], -1.0);
        let mut m = StrongRule::new();
        m.push(Stump::new(0, 0.0, 1.0), 0.7);
        let via_model = exp_loss(&m, &d);
        let via_scores = exp_loss_scores(&scores(&m, &d), &d.labels);
        assert!((via_model - via_scores).abs() < 1e-12);
    }

    #[test]
    fn test_error_counts_mistakes() {
        let mut d = DataBlock::empty(1);
        d.push(&[1.0], 1.0); // correct for the stump below
        d.push(&[1.0], -1.0); // wrong
        let mut m = StrongRule::new();
        m.push(Stump::new(0, 0.0, 1.0), 1.0);
        assert!((test_error(&m, &d) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auprc_perfect_ranking_is_one() {
        let scores = [0.9f32, 0.8, 0.1, 0.0];
        let labels = [1.0f32, 1.0, -1.0, -1.0];
        assert!((auprc(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auprc_random_ranking_near_base_rate() {
        // all scores tied → single PR point at (recall 1, precision = base)
        let scores = vec![0.5f32; 1000];
        let labels: Vec<f32> = (0..1000).map(|i| if i % 10 == 0 { 1.0 } else { -1.0 }).collect();
        let ap = auprc(&scores, &labels);
        assert!((ap - 0.1).abs() < 1e-9, "ap={ap}");
    }

    #[test]
    fn auprc_worst_ranking() {
        // the single positive ranked last: AP = 1/n
        let scores = [0.9f32, 0.8, 0.7, 0.1];
        let labels = [-1.0f32, -1.0, -1.0, 1.0];
        assert!((auprc(&scores, &labels) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn auprc_no_positives_zero() {
        assert_eq!(auprc(&[0.5, 0.2], &[-1.0, -1.0]), 0.0);
        assert_eq!(auprc(&[], &[]), 0.0);
    }

    #[test]
    fn auprc_tie_handling_groups() {
        // two tied at top: one pos one neg → first group P=0.5, R=0.5
        let scores = [0.9f32, 0.9, 0.1, 0.1];
        let labels = [1.0f32, -1.0, 1.0, -1.0];
        // group1: P=1/2 R=1/2 ; group2: P=2/4 R=1 → AP = .5*.5 + .5*.5 = 0.5
        assert!((auprc(&scores, &labels) - 0.5).abs() < 1e-12);
    }
}
