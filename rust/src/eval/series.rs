//! Timed metric series — the data behind Figures 3 and 4 (metric vs
//! wall-clock time, including the flat plateaus while Sparrow resamples).

use std::time::Duration;

use crate::util::json::Json;

/// One evaluation point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricPoint {
    pub elapsed: Duration,
    /// boosting iterations completed at this point
    pub iterations: u64,
    pub exp_loss: f64,
    pub auprc: f64,
}

/// A labeled metric-vs-time series for one algorithm run.
#[derive(Debug, Clone, Default)]
pub struct MetricSeries {
    pub label: String,
    pub points: Vec<MetricPoint>,
}

impl MetricSeries {
    pub fn new(label: &str) -> MetricSeries {
        MetricSeries {
            label: label.to_string(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, p: MetricPoint) {
        self.points.push(p);
    }

    /// First time the exponential loss reaches `target` (Table 1's
    /// "convergence time to an almost optimal loss").
    pub fn time_to_loss(&self, target: f64) -> Option<Duration> {
        self.points
            .iter()
            .find(|p| p.exp_loss <= target)
            .map(|p| p.elapsed)
    }

    /// Final (best) values.
    pub fn final_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.exp_loss)
    }

    pub fn best_auprc(&self) -> Option<f64> {
        self.points
            .iter()
            .map(|p| p.auprc)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// CSV rows `label,seconds,iterations,exp_loss,auprc`.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for p in &self.points {
            out.push_str(&format!(
                "{},{:.4},{},{:.6},{:.6}\n",
                self.label,
                p.elapsed.as_secs_f64(),
                p.iterations,
                p.exp_loss,
                p.auprc
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("label", self.label.as_str());
        o.set(
            "points",
            Json::Arr(
                self.points
                    .iter()
                    .map(|p| {
                        let mut q = Json::obj();
                        q.set("t", p.elapsed.as_secs_f64())
                            .set("iter", p.iterations)
                            .set("exp_loss", p.exp_loss)
                            .set("auprc", p.auprc);
                        q
                    })
                    .collect(),
            ),
        );
        o
    }

    /// Render several series as an ASCII chart of metric vs time
    /// (figures 3/4 for terminals; `log_x` mimics Fig. 4 right).
    pub fn ascii_chart(
        series: &[&MetricSeries],
        metric: fn(&MetricPoint) -> f64,
        width: usize,
        height: usize,
        log_x: bool,
    ) -> String {
        let mut tmax = 0f64;
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for s in series {
            for p in &s.points {
                tmax = tmax.max(p.elapsed.as_secs_f64());
                lo = lo.min(metric(p));
                hi = hi.max(metric(p));
            }
        }
        if !lo.is_finite() || tmax <= 0.0 {
            return String::from("(empty chart)\n");
        }
        if hi - lo < 1e-12 {
            hi = lo + 1.0;
        }
        let tmin = if log_x { (tmax / 1e3).max(1e-3) } else { 0.0 };
        let xpos = |t: f64| -> usize {
            let frac = if log_x {
                ((t.max(tmin) / tmin).ln() / (tmax / tmin).ln()).clamp(0.0, 1.0)
            } else {
                (t / tmax).clamp(0.0, 1.0)
            };
            ((width - 1) as f64 * frac) as usize
        };
        let mut rows = vec![vec![b' '; width]; height];
        for (si, s) in series.iter().enumerate() {
            let glyph = b"*+ox#@"[si % 6];
            for p in &s.points {
                let x = xpos(p.elapsed.as_secs_f64());
                let yfrac = ((metric(p) - lo) / (hi - lo)).clamp(0.0, 1.0);
                let y = ((height - 1) as f64 * (1.0 - yfrac)) as usize;
                rows[y][x] = glyph;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{hi:>10.4} ┤\n"));
        for r in rows {
            out.push_str("           │");
            out.push_str(std::str::from_utf8(&r).unwrap());
            out.push('\n');
        }
        out.push_str(&format!("{lo:>10.4} └{}\n", "─".repeat(width)));
        let legend: Vec<String> = series
            .iter()
            .enumerate()
            .map(|(i, s)| format!("{} {}", b"*+ox#@"[i % 6] as char, s.label))
            .collect();
        out.push_str(&format!(
            "            t ∈ [{:.1}s, {:.1}s]{}   {}\n",
            tmin,
            tmax,
            if log_x { " (log)" } else { "" },
            legend.join("   ")
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series() -> MetricSeries {
        let mut s = MetricSeries::new("test");
        for i in 0..5u64 {
            s.push(MetricPoint {
                elapsed: Duration::from_secs(i),
                iterations: i * 10,
                exp_loss: 1.0 / (i + 1) as f64,
                auprc: 0.1 * i as f64,
            });
        }
        s
    }

    #[test]
    fn time_to_loss() {
        let s = series();
        assert_eq!(s.time_to_loss(0.5), Some(Duration::from_secs(1)));
        assert_eq!(s.time_to_loss(0.2), Some(Duration::from_secs(4)));
        assert_eq!(s.time_to_loss(0.01), None);
    }

    #[test]
    fn final_and_best() {
        let s = series();
        assert!((s.final_loss().unwrap() - 0.2).abs() < 1e-12);
        assert!((s.best_auprc().unwrap() - 0.4).abs() < 1e-12);
        assert_eq!(MetricSeries::new("e").final_loss(), None);
    }

    #[test]
    fn csv_shape() {
        let s = series();
        let csv = s.to_csv();
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("test,0.0000,0,1.000000,0.000000"));
    }

    #[test]
    fn json_contains_points() {
        let j = series().to_json().to_string();
        assert!(j.contains("\"label\":\"test\""));
        assert!(j.contains("\"points\":["));
    }

    #[test]
    fn chart_renders() {
        let s = series();
        let chart = MetricSeries::ascii_chart(&[&s], |p| p.exp_loss, 40, 10, false);
        assert!(chart.contains('*'));
        assert!(chart.lines().count() >= 12);
        let log_chart = MetricSeries::ascii_chart(&[&s], |p| p.exp_loss, 40, 10, true);
        assert!(log_chart.contains("(log)"));
    }

    #[test]
    fn chart_empty_safe() {
        let s = MetricSeries::new("empty");
        let chart = MetricSeries::ascii_chart(&[&s], |p| p.exp_loss, 10, 5, false);
        assert!(chart.contains("empty chart"));
    }
}
