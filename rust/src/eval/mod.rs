//! Held-out evaluation: exponential loss and AUPRC (the paper's two
//! reported metrics, Figs. 3-4), plus timed metric series.

pub mod metrics;
pub mod series;

pub use metrics::{auprc, exp_loss, exp_loss_scores, test_error};
pub use series::{MetricPoint, MetricSeries};
