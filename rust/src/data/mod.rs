//! Data substrate: example schema, disk-resident store, in-memory sampled
//! set, synthetic splice-site workload generator, and LIBSVM ingestion.
//!
//! The paper assumes each worker stores the full training set on local disk
//! (§4, footnote 2) and keeps only a small weighted sample in memory.
//! [`DiskStore`] is that disk-resident set (optionally throttled to model
//! the paper's "off-memory" instance tiers), and [`SampleSet`] is the
//! in-memory set with the per-example incremental-update state
//! `(x, y, w_s, w_l, H_l)` of §4.1.

pub mod binfmt;
pub mod binned;
pub mod block;
pub mod libsvm;
pub mod memstore;
pub mod store;
pub mod strata;
pub mod synth;
pub mod throttle;
pub mod tiered;

pub use binned::{BinSpec, BinnedBatch, BinnedStripe};
pub use block::DataBlock;
pub use memstore::SampleSet;
pub use store::DiskStore;
pub use strata::{StrataConfig, StratifiedStore};
pub use synth::SynthConfig;
pub use throttle::IoThrottle;
pub use tiered::{TieredConfig, TieredCounters, TieredStore};
