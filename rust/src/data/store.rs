//! Disk-resident training store (the paper's per-worker replicated dataset).
//!
//! Two read layers sit on top of the on-disk format ([`crate::data::binfmt`]):
//! the circular [`StoreStream`] used by the blocking sampler's selective
//! pass, and the stratified, weight-indexed view in [`crate::data::strata`]
//! used by the background sampler pipeline (DESIGN.md §4).

#![warn(missing_docs)]

use std::io;
use std::path::{Path, PathBuf};

use crate::data::binfmt::{Header, Reader, Writer};
use crate::data::{DataBlock, IoThrottle};

/// A disk-resident, sequentially-streamable training set.
///
/// The paper's Sampler reads the training set from local disk in a fixed
/// random permutation (Alg. 2: "Randomly permuted, disk-resident
/// training-set"); [`DiskStore::write_permuted`] bakes the permutation in at
/// write time so all subsequent reads are purely sequential.
pub struct DiskStore {
    path: PathBuf,
    /// the on-disk header (example count, feature width)
    pub header: Header,
}

impl DiskStore {
    /// Write `block` to `path` in a random permutation and open it.
    pub fn write_permuted(
        path: &Path,
        block: &DataBlock,
        rng: &mut crate::util::rng::Rng,
    ) -> io::Result<DiskStore> {
        let mut idx: Vec<usize> = (0..block.n).collect();
        rng.shuffle(&mut idx);
        let mut w = Writer::create(path, block.f as u32)?;
        for &i in &idx {
            w.write_example(block.label(i), block.row(i))?;
        }
        let header = w.finish()?;
        Ok(DiskStore {
            path: path.to_path_buf(),
            header,
        })
    }

    /// Write `block` as-is (already permuted / order irrelevant).
    pub fn write(path: &Path, block: &DataBlock) -> io::Result<DiskStore> {
        let mut w = Writer::create(path, block.f as u32)?;
        w.write_block(block)?;
        let header = w.finish()?;
        Ok(DiskStore {
            path: path.to_path_buf(),
            header,
        })
    }

    /// Open an existing store file, validating its header.
    pub fn open(path: &Path) -> io::Result<DiskStore> {
        let r = Reader::open(path)?;
        Ok(DiskStore {
            path: path.to_path_buf(),
            header: r.header,
        })
    }

    /// Path of the backing file (additional readers — e.g. the background
    /// sampler's [`crate::data::StratifiedStore`] — open their own cursor
    /// from it).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of examples in the store.
    pub fn len(&self) -> usize {
        self.header.n as usize
    }

    /// `true` when the store holds no examples.
    pub fn is_empty(&self) -> bool {
        self.header.n == 0
    }

    /// Number of features per example.
    pub fn num_features(&self) -> usize {
        self.header.f as usize
    }

    /// Size of the dataset on disk in bytes (excluding header).
    pub fn data_bytes(&self) -> u64 {
        self.header.n * self.header.record_bytes()
    }

    /// Open a streaming cursor, optionally throttled (off-memory tier).
    pub fn stream(&self, throttle: IoThrottle) -> io::Result<StoreStream> {
        Ok(StoreStream {
            reader: Reader::open(&self.path)?,
            throttle,
        })
    }

    /// Read the whole store into memory (in-memory tier / test helper).
    pub fn read_all(&self) -> io::Result<DataBlock> {
        let mut r = Reader::open(&self.path)?;
        r.read_block(self.len(), false)
    }

    /// Read the first `n` examples (clamped to the store length) without
    /// wrapping. The tiered data plane pins exactly this prefix in memory
    /// for its deterministic scale probe (DESIGN.md §11).
    pub fn read_prefix(&self, n: usize) -> io::Result<DataBlock> {
        let mut r = Reader::open(&self.path)?;
        r.read_block(n.min(self.len()), false)
    }
}

/// Sequential (circular) cursor over a [`DiskStore`] with byte-rate
/// accounting.
pub struct StoreStream {
    reader: Reader,
    throttle: IoThrottle,
}

impl StoreStream {
    /// Next block of up to `max_n` examples, wrapping at EOF.
    pub fn next_block(&mut self, max_n: usize) -> io::Result<DataBlock> {
        let block = self.reader.read_block(max_n, true)?;
        self.throttle
            .consume(block.n as u64 * self.reader.header.record_bytes());
        Ok(block)
    }

    /// Records consumed since the last wrap.
    pub fn position(&self) -> u64 {
        self.reader.position()
    }

    /// Total time this stream's throttle spent sleeping (off-memory tier).
    pub fn stalled(&self) -> std::time::Duration {
        self.throttle.stalled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("sparrow_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn block(n: usize, f: usize) -> DataBlock {
        let mut b = DataBlock::empty(f);
        for i in 0..n {
            let row: Vec<f32> = (0..f).map(|j| (i * f + j) as f32).collect();
            b.push(&row, if i % 3 == 0 { 1.0 } else { -1.0 });
        }
        b
    }

    #[test]
    fn write_read_roundtrip() {
        let path = tmpfile("rt.sprw");
        let b = block(10, 4);
        let store = DiskStore::write(&path, &b).unwrap();
        assert_eq!(store.len(), 10);
        assert_eq!(store.num_features(), 4);
        assert_eq!(store.read_all().unwrap(), b);
    }

    #[test]
    fn permuted_write_preserves_multiset() {
        let path = tmpfile("perm.sprw");
        let b = block(50, 3);
        let mut rng = Rng::new(1);
        let store = DiskStore::write_permuted(&path, &b, &mut rng).unwrap();
        let read = store.read_all().unwrap();
        assert_eq!(read.n, 50);
        // same multiset of first-features
        let mut a: Vec<i64> = (0..50).map(|i| b.row(i)[0] as i64).collect();
        let mut c: Vec<i64> = (0..50).map(|i| read.row(i)[0] as i64).collect();
        a.sort();
        c.sort();
        assert_eq!(a, c);
        // not identical order (astronomically unlikely)
        assert_ne!(b, read);
    }

    #[test]
    fn stream_wraps_circularly() {
        let path = tmpfile("wrap.sprw");
        let store = DiskStore::write(&path, &block(5, 2)).unwrap();
        let mut s = store.stream(IoThrottle::unlimited()).unwrap();
        let b1 = s.next_block(3).unwrap();
        let b2 = s.next_block(3).unwrap();
        let b3 = s.next_block(3).unwrap();
        assert_eq!(b1.n + b2.n + b3.n, 9);
        // reads: b1 = rows 0..3, b2 = rows 3,4,0 (wrap), b3 = rows 1,2,3
        assert_eq!(b2.row(2), block(5, 2).row(0));
        assert_eq!(b3.row(0), block(5, 2).row(1));
    }

    #[test]
    fn data_bytes() {
        let path = tmpfile("bytes.sprw");
        let store = DiskStore::write(&path, &block(10, 4)).unwrap();
        assert_eq!(store.data_bytes(), 10 * 4 * 5);
    }

    #[test]
    fn read_prefix_clamps_and_preserves_order() {
        let path = tmpfile("prefix.sprw");
        let b = block(7, 3);
        let store = DiskStore::write(&path, &b).unwrap();
        // partial prefix
        let p = store.read_prefix(4).unwrap();
        assert_eq!(p.n, 4);
        for i in 0..4 {
            assert_eq!(p.row(i), b.row(i));
            assert_eq!(p.label(i), b.label(i));
        }
        // over-asking clamps to the store length, no wrap
        let all = store.read_prefix(100).unwrap();
        assert_eq!(all, b);
        // zero prefix is an empty block, not an error
        assert!(store.read_prefix(0).unwrap().is_empty());
    }

    #[test]
    fn next_block_zero_is_empty_and_holds_position() {
        let path = tmpfile("zero.sprw");
        let store = DiskStore::write(&path, &block(5, 2)).unwrap();
        let mut s = store.stream(IoThrottle::unlimited()).unwrap();
        let z = s.next_block(0).unwrap();
        assert!(z.is_empty());
        assert_eq!(s.position(), 0);
        // the cursor did not move: the next read starts at row 0
        let b1 = s.next_block(2).unwrap();
        assert_eq!(b1.row(0), block(5, 2).row(0));
        assert_eq!(s.position(), 2);
    }

    #[test]
    fn partial_final_block_then_wrap() {
        let path = tmpfile("partial.sprw");
        let b = block(5, 2);
        let store = DiskStore::write(&path, &b).unwrap();
        let mut s = store.stream(IoThrottle::unlimited()).unwrap();
        assert_eq!(s.next_block(4).unwrap().n, 4);
        // only one record remains before EOF; the circular stream fills the
        // rest of the block from the start of the store
        let tail = s.next_block(4).unwrap();
        assert_eq!(tail.n, 4);
        assert_eq!(tail.row(0), b.row(4));
        assert_eq!(tail.row(1), b.row(0));
        assert_eq!(s.position(), 3); // 3 records past the wrap
    }

    #[test]
    fn truncated_header_rejected_on_open() {
        let path = tmpfile("trunc.sprw");
        DiskStore::write(&path, &block(3, 2)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..10]).unwrap(); // mid-header cut
        assert!(DiskStore::open(&path).is_err());
    }

    #[test]
    fn corrupt_header_rejected_on_open() {
        let path = tmpfile("corrupt.sprw");
        DiskStore::write(&path, &block(3, 2)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X'; // break the magic
        std::fs::write(&path, &bytes).unwrap();
        assert!(DiskStore::open(&path).is_err());

        // unsupported version is rejected too
        DiskStore::write(&path, &block(3, 2)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99;
        std::fs::write(&path, &bytes).unwrap();
        assert!(DiskStore::open(&path).is_err());
    }
}
