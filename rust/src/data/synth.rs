//! Synthetic splice-site-like workload generator.
//!
//! Substitution (DESIGN.md §3): the paper evaluates on the human acceptor
//! splice-site detection set (50M examples, 27 GB, heavily class-skewed,
//! [3,4]). That data is not redistributable; this generator reproduces the
//! properties the algorithms are actually sensitive to:
//!
//!  * **rare positives** (`pos_rate`, default 2.5%) — drives the weight
//!    skew that collapses `n_eff` and forces resampling;
//!  * **many weakly-informative features** — positives shift a random
//!    subset of "motif" features by a small per-feature amount, so every
//!    single stump is a *weak* rule (small true edge), which is exactly the
//!    regime where early stopping pays off;
//!  * **label noise** (`flip_rate`) — bounds the achievable loss away from 0;
//!  * deterministic generation from a seed, streamable in blocks so the
//!    dataset never has to fit in memory.

use std::io;
use std::path::Path;

use crate::data::{DataBlock, DiskStore};
use crate::util::rng::Rng;

/// Configuration of the synthetic task.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// number of features
    pub f: usize,
    /// P(y = +1) before label noise
    pub pos_rate: f64,
    /// how many features carry signal
    pub informative: usize,
    /// mean feature shift for positives, in noise-σ units (weak: ~0.3)
    pub signal: f64,
    /// probability of flipping the label (irreducible error)
    pub flip_rate: f64,
    /// generator seed
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            f: 256,
            pos_rate: 0.025,
            informative: 64,
            signal: 0.35,
            flip_rate: 0.05,
            seed: 0x5EED,
        }
    }
}

impl SynthConfig {
    /// Stable 64-bit fingerprint over *every* field (FNV-1a on the raw
    /// bits). On-disk workload caches must key on this: two configs that
    /// differ only in `pos_rate`, `signal`, or `flip_rate` generate
    /// different data and must never reuse each other's store.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in [
            self.f as u64,
            self.pos_rate.to_bits(),
            self.informative as u64,
            self.signal.to_bits(),
            self.flip_rate.to_bits(),
            self.seed,
        ] {
            for byte in v.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        h
    }
}

/// Streaming generator; deterministic given (config, position).
pub struct SynthGen {
    cfg: SynthConfig,
    /// per-informative-feature shift strengths (fixed by seed)
    shifts: Vec<f32>,
    /// which features are informative
    motif: Vec<usize>,
    rng: Rng,
}

impl SynthGen {
    pub fn new(cfg: SynthConfig) -> SynthGen {
        let mut setup = Rng::new(cfg.seed);
        let motif = setup.sample_indices(cfg.f, cfg.informative.min(cfg.f));
        // Per-feature signal strengths vary ~Uniform(0.3, 1.7)×signal so the
        // candidate stumps have a spread of true edges (some easier to
        // certify early than others — the regime TMSN exploits).
        let shifts: Vec<f32> = motif
            .iter()
            .map(|_| (setup.range_f64(0.3, 1.7) * cfg.signal) as f32)
            .collect();
        let rng = setup.fork(0x57_17);
        SynthGen {
            cfg,
            shifts,
            motif,
            rng,
        }
    }

    /// Generate the next `n` examples.
    pub fn next_block(&mut self, n: usize) -> DataBlock {
        let f = self.cfg.f;
        let mut block = DataBlock::empty(f);
        let mut row = vec![0f32; f];
        for _ in 0..n {
            let is_pos = self.rng.bernoulli(self.cfg.pos_rate);
            for v in row.iter_mut() {
                *v = self.rng.gauss() as f32;
            }
            if is_pos {
                for (k, &j) in self.motif.iter().enumerate() {
                    row[j] += self.shifts[k];
                }
            }
            let mut y = if is_pos { 1.0 } else { -1.0 };
            if self.rng.bernoulli(self.cfg.flip_rate) {
                y = -y;
            }
            block.push(&row, y);
        }
        block
    }

    /// Generate `n` examples straight to a permuted [`DiskStore`].
    ///
    /// (Generation order is already IID so no extra permutation pass is
    /// required — we write sequentially in blocks.)
    pub fn write_store(&mut self, path: &Path, n: usize) -> io::Result<DiskStore> {
        let mut w = crate::data::binfmt::Writer::create(path, self.cfg.f as u32)?;
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(8192);
            let block = self.next_block(take);
            w.write_block(&block)?;
            remaining -= take;
        }
        w.finish()?;
        DiskStore::open(path)
    }

    pub fn config(&self) -> &SynthConfig {
        &self.cfg
    }

    /// Indices of informative features (for tests / diagnostics).
    pub fn motif(&self) -> &[usize] {
        &self.motif
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64) -> SynthConfig {
        SynthConfig {
            f: 32,
            pos_rate: 0.3,
            informative: 8,
            signal: 1.0,
            flip_rate: 0.0,
            seed,
        }
    }

    #[test]
    fn deterministic() {
        let a = SynthGen::new(cfg(7)).next_block(100);
        let b = SynthGen::new(cfg(7)).next_block(100);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SynthGen::new(cfg(1)).next_block(50);
        let b = SynthGen::new(cfg(2)).next_block(50);
        assert_ne!(a, b);
    }

    #[test]
    fn positive_rate_matches_config() {
        let b = SynthGen::new(cfg(3)).next_block(20_000);
        let rate = b.positive_rate();
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn informative_features_shifted_for_positives() {
        let mut g = SynthGen::new(cfg(4));
        let motif = g.motif().to_vec();
        let b = g.next_block(20_000);
        // mean of an informative feature on positives should exceed mean on
        // negatives by roughly the shift
        let j = motif[0];
        let (mut sp, mut np_, mut sn, mut nn) = (0f64, 0f64, 0f64, 0f64);
        for i in 0..b.n {
            if b.label(i) > 0.0 {
                sp += b.row(i)[j] as f64;
                np_ += 1.0;
            } else {
                sn += b.row(i)[j] as f64;
                nn += 1.0;
            }
        }
        let gap = sp / np_ - sn / nn;
        assert!(gap > 0.15, "gap={gap}");
    }

    #[test]
    fn uninformative_features_balanced() {
        let mut g = SynthGen::new(cfg(5));
        let motif: std::collections::HashSet<usize> = g.motif().iter().copied().collect();
        let j = (0..32).find(|j| !motif.contains(j)).unwrap();
        let b = g.next_block(20_000);
        let (mut sp, mut np_, mut sn, mut nn) = (0f64, 0f64, 0f64, 0f64);
        for i in 0..b.n {
            if b.label(i) > 0.0 {
                sp += b.row(i)[j] as f64;
                np_ += 1.0;
            } else {
                sn += b.row(i)[j] as f64;
                nn += 1.0;
            }
        }
        let gap = (sp / np_ - sn / nn).abs();
        assert!(gap < 0.1, "gap={gap}");
    }

    #[test]
    fn write_store_roundtrip() {
        let dir = std::env::temp_dir().join("sparrow_synth_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("synth.sprw");
        let store = SynthGen::new(cfg(6)).write_store(&path, 1000).unwrap();
        assert_eq!(store.len(), 1000);
        assert_eq!(store.num_features(), 32);
        let b = store.read_all().unwrap();
        assert_eq!(b.n, 1000);
    }

    #[test]
    fn fingerprint_covers_every_field() {
        let base = cfg(9);
        let same = cfg(9);
        assert_eq!(base.fingerprint(), same.fingerprint());
        let variants = [
            SynthConfig { f: base.f + 1, ..base.clone() },
            SynthConfig { pos_rate: base.pos_rate + 0.01, ..base.clone() },
            SynthConfig { informative: base.informative + 1, ..base.clone() },
            SynthConfig { signal: base.signal + 0.01, ..base.clone() },
            SynthConfig { flip_rate: base.flip_rate + 0.01, ..base.clone() },
            SynthConfig { seed: base.seed + 1, ..base.clone() },
        ];
        for v in &variants {
            assert_ne!(
                v.fingerprint(),
                base.fingerprint(),
                "fingerprint missed a field: {v:?}"
            );
        }
    }

    #[test]
    fn label_noise_bounds_separability() {
        let mut c = cfg(8);
        c.flip_rate = 0.5; // labels pure noise
        let b = SynthGen::new(c).next_block(10_000);
        // with 50% flips the positive rate is pulled toward 0.5
        assert!((b.positive_rate() - 0.5).abs() < 0.05);
    }
}
