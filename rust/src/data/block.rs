//! Row-major blocks of labeled examples — the unit of streaming I/O and of
//! scanner batches.

/// A dense block of `n` examples with `f` features each.
///
/// Features are row-major (`features[i*f..(i+1)*f]` is example i), labels
/// are in {-1.0, +1.0}. Blocks are immutable once built; mutable scanner
/// state lives in [`crate::data::SampleSet`].
#[derive(Debug, Clone, PartialEq)]
pub struct DataBlock {
    pub n: usize,
    pub f: usize,
    pub features: Vec<f32>,
    pub labels: Vec<f32>,
}

impl DataBlock {
    pub fn new(n: usize, f: usize, features: Vec<f32>, labels: Vec<f32>) -> DataBlock {
        assert_eq!(features.len(), n * f, "features length mismatch");
        assert_eq!(labels.len(), n, "labels length mismatch");
        debug_assert!(labels.iter().all(|&y| y == 1.0 || y == -1.0));
        DataBlock {
            n,
            f,
            features,
            labels,
        }
    }

    pub fn empty(f: usize) -> DataBlock {
        DataBlock {
            n: 0,
            f,
            features: Vec::new(),
            labels: Vec::new(),
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.f..(i + 1) * self.f]
    }

    #[inline]
    pub fn label(&self, i: usize) -> f32 {
        self.labels[i]
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Append one example.
    pub fn push(&mut self, row: &[f32], label: f32) {
        assert_eq!(row.len(), self.f);
        self.features.extend_from_slice(row);
        self.labels.push(label);
        self.n += 1;
    }

    /// Append all rows of `other` (same width).
    pub fn extend(&mut self, other: &DataBlock) {
        assert_eq!(self.f, other.f);
        self.features.extend_from_slice(&other.features);
        self.labels.extend_from_slice(&other.labels);
        self.n += other.n;
    }

    /// A new block containing the selected rows.
    pub fn select(&self, idx: &[usize]) -> DataBlock {
        let mut out = DataBlock::empty(self.f);
        for &i in idx {
            out.push(self.row(i), self.label(i));
        }
        out
    }

    /// Split into sub-blocks of at most `chunk` rows.
    pub fn chunks(&self, chunk: usize) -> Vec<DataBlock> {
        assert!(chunk > 0);
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.n {
            let j = (i + chunk).min(self.n);
            out.push(DataBlock::new(
                j - i,
                self.f,
                self.features[i * self.f..j * self.f].to_vec(),
                self.labels[i..j].to_vec(),
            ));
            i = j;
        }
        out
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.labels.iter().filter(|&&y| y > 0.0).count() as f64 / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block3() -> DataBlock {
        DataBlock::new(
            3,
            2,
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![1.0, -1.0, 1.0],
        )
    }

    #[test]
    fn rows_and_labels() {
        let b = block3();
        assert_eq!(b.row(0), &[1.0, 2.0]);
        assert_eq!(b.row(2), &[5.0, 6.0]);
        assert_eq!(b.label(1), -1.0);
    }

    #[test]
    #[should_panic(expected = "features length mismatch")]
    fn length_checked() {
        DataBlock::new(2, 2, vec![0.0; 3], vec![1.0, 1.0]);
    }

    #[test]
    fn push_and_extend() {
        let mut b = DataBlock::empty(2);
        b.push(&[1.0, 2.0], 1.0);
        b.extend(&block3());
        assert_eq!(b.n, 4);
        assert_eq!(b.row(3), &[5.0, 6.0]);
    }

    #[test]
    fn select_rows() {
        let b = block3();
        let s = b.select(&[2, 0]);
        assert_eq!(s.n, 2);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn chunking() {
        let b = block3();
        let cs = b.chunks(2);
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0].n, 2);
        assert_eq!(cs[1].n, 1);
        assert_eq!(cs[1].row(0), &[5.0, 6.0]);
    }

    #[test]
    fn positive_rate() {
        let b = block3();
        assert!((b.positive_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(DataBlock::empty(4).positive_rate(), 0.0);
    }
}
