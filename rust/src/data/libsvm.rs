//! LIBSVM / SVMlight text ingestion.
//!
//! The splice-site benchmark data ([3,4]) ships in this sparse text format;
//! users with access to the real data convert it once into the binary
//! [`crate::data::DiskStore`] format with `sparrow gen-data --libsvm ...`.
//!
//! Format, one example per line:  `label idx:val idx:val ...` with 1-based
//! indices and labels in {+1, -1} (or {0, 1}: 0 is mapped to -1).

use std::io::{self, BufRead};
use std::path::Path;

use crate::data::DataBlock;

/// Parse one line; returns (label, sparse pairs).
pub fn parse_line(line: &str) -> Result<(f32, Vec<(usize, f32)>), String> {
    let mut parts = line.split_whitespace();
    let label_tok = parts.next().ok_or("empty line")?;
    let raw: f32 = label_tok
        .parse()
        .map_err(|_| format!("bad label {label_tok:?}"))?;
    let label = if raw > 0.0 { 1.0 } else { -1.0 };
    let mut pairs = Vec::new();
    for tok in parts {
        if tok.starts_with('#') {
            break; // trailing comment
        }
        let (idx, val) = tok
            .split_once(':')
            .ok_or_else(|| format!("bad pair {tok:?}"))?;
        let idx: usize = idx.parse().map_err(|_| format!("bad index {idx:?}"))?;
        if idx == 0 {
            return Err("libsvm indices are 1-based".into());
        }
        let val: f32 = val.parse().map_err(|_| format!("bad value {val:?}"))?;
        pairs.push((idx - 1, val));
    }
    Ok((label, pairs))
}

/// Read an entire libsvm file into a dense block with `f` features
/// (pass `f = 0` to infer the max index from the data — two passes).
pub fn read_file(path: &Path, f: usize) -> io::Result<DataBlock> {
    let f = if f > 0 {
        f
    } else {
        infer_num_features(path)?
    };
    let file = std::fs::File::open(path)?;
    let mut block = DataBlock::empty(f);
    let mut row = vec![0f32; f];
    for (lineno, line) in io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        let (label, pairs) = parse_line(&line).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, format!("line {}: {e}", lineno + 1))
        })?;
        row.iter_mut().for_each(|v| *v = 0.0);
        for (idx, val) in pairs {
            if idx < f {
                row[idx] = val;
            }
        }
        block.push(&row, label);
    }
    Ok(block)
}

/// First pass: find the maximum feature index used.
pub fn infer_num_features(path: &Path) -> io::Result<usize> {
    let file = std::fs::File::open(path)?;
    let mut max_idx = 0usize;
    for line in io::BufReader::new(file).lines() {
        let line = line?;
        if line.trim().is_empty() || line.starts_with('#') {
            continue;
        }
        if let Ok((_, pairs)) = parse_line(&line) {
            for (idx, _) in pairs {
                max_idx = max_idx.max(idx + 1);
            }
        }
    }
    Ok(max_idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(name: &str, content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparrow_libsvm_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn parse_basic_line() {
        let (y, pairs) = parse_line("+1 1:0.5 3:2.0").unwrap();
        assert_eq!(y, 1.0);
        assert_eq!(pairs, vec![(0, 0.5), (2, 2.0)]);
    }

    #[test]
    fn zero_label_maps_to_negative() {
        let (y, _) = parse_line("0 1:1").unwrap();
        assert_eq!(y, -1.0);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_line("1 0:5").is_err());
    }

    #[test]
    fn rejects_malformed_pair() {
        assert!(parse_line("1 abc").is_err());
        assert!(parse_line("xyz 1:2").is_err());
    }

    #[test]
    fn trailing_comment_ignored() {
        let (_, pairs) = parse_line("1 1:2 # hello 3:4").unwrap();
        assert_eq!(pairs, vec![(0, 2.0)]);
    }

    #[test]
    fn read_file_dense() {
        let path = tmpfile(
            "basic.svm",
            "+1 1:1.0 3:3.0\n-1 2:2.0\n\n# comment\n+1 3:9.0\n",
        );
        let b = read_file(&path, 3).unwrap();
        assert_eq!(b.n, 3);
        assert_eq!(b.row(0), &[1.0, 0.0, 3.0]);
        assert_eq!(b.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(b.row(2), &[0.0, 0.0, 9.0]);
        assert_eq!(b.labels, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn infer_features() {
        let path = tmpfile("infer.svm", "1 5:1.0\n-1 2:2.0\n");
        assert_eq!(infer_num_features(&path).unwrap(), 5);
        let b = read_file(&path, 0).unwrap();
        assert_eq!(b.f, 5);
    }

    #[test]
    fn out_of_range_index_dropped() {
        let path = tmpfile("oor.svm", "1 2:1.0 9:9.0\n");
        let b = read_file(&path, 2).unwrap();
        assert_eq!(b.row(0), &[0.0, 1.0]);
    }
}
