//! In-memory sampled set with the paper's per-example incremental state.
//!
//! §4.1 "Incremental Updates": for each example we store the tuple
//! `(x, y, w_s, w_l, H_l)` — the feature vector and label, the weight at
//! sample time, the last computed weight, and (the version of) the strong
//! rule last used to compute it. Because strong rules grow append-only,
//! "H_l" is fully identified by the model *length* at last update, and a
//! weight refresh only has to evaluate the suffix of new stumps.

use crate::data::binned::{BinSpec, BinnedStripe};
use crate::data::DataBlock;

/// The in-memory sample the Scanner iterates over.
#[derive(Debug, Clone)]
pub struct SampleSet {
    pub data: DataBlock,
    /// weight at the time the example was (re)sampled  (w_s)
    pub w_sample: Vec<f32>,
    /// strong-rule score at the time the example was (re)sampled
    pub score_sample: Vec<f32>,
    /// last computed weight  (w_l)
    pub w_last: Vec<f32>,
    /// strong-rule score backing w_last
    pub score_last: Vec<f32>,
    /// number of model stumps included in score_last  ("H_l" version)
    pub model_len_last: Vec<u32>,
    /// quantized stripe view for the binned scan engine (DESIGN.md §8);
    /// built once at sample-install time, never touched by weight
    /// refreshes or adoptions (bins depend only on features + grid)
    pub binned: Option<BinnedStripe>,
}

impl SampleSet {
    /// Fresh sample: every example enters with weight 1 (paper §4.1 — the
    /// Sampler assigns each added example an initial weight of 1) and with
    /// its sample-time score recorded so later updates are incremental.
    pub fn fresh(data: DataBlock, scores: Vec<f32>, model_len: u32) -> SampleSet {
        assert_eq!(scores.len(), data.n);
        let n = data.n;
        SampleSet {
            data,
            w_sample: vec![1.0; n],
            score_sample: scores.clone(),
            w_last: vec![1.0; n],
            score_last: scores,
            model_len_last: vec![model_len; n],
            binned: None,
        }
    }

    /// Sample whose examples carry explicit (non-uniform) weights — used
    /// by the weight-blind uniform-sampling ablation, where kept examples
    /// must retain their true boosting weight.
    pub fn with_weights(
        data: DataBlock,
        scores: Vec<f32>,
        weights: Vec<f32>,
        model_len: u32,
    ) -> SampleSet {
        assert_eq!(scores.len(), data.n);
        assert_eq!(weights.len(), data.n);
        let n = data.n;
        SampleSet {
            data,
            w_sample: weights.clone(),
            score_sample: scores.clone(),
            w_last: weights,
            score_last: scores,
            model_len_last: vec![model_len; n],
            binned: None,
        }
    }

    /// Empty set (before the first sampling pass).
    pub fn empty(f: usize) -> SampleSet {
        SampleSet {
            data: DataBlock::empty(f),
            w_sample: Vec::new(),
            score_sample: Vec::new(),
            w_last: Vec::new(),
            score_last: Vec::new(),
            model_len_last: Vec::new(),
            binned: None,
        }
    }

    pub fn len(&self) -> usize {
        self.data.n
    }

    pub fn is_empty(&self) -> bool {
        self.data.n == 0
    }

    /// Effective sample size of the *current* weights (Eq. 4).
    pub fn n_eff(&self) -> f64 {
        crate::sampling::ess::n_eff(&self.w_last)
    }

    /// Update example `i`'s cached weight given the current model score.
    #[inline]
    pub fn set_weight(&mut self, i: usize, score: f32, w: f32, model_len: u32) {
        self.w_last[i] = w;
        self.score_last[i] = score;
        self.model_len_last[i] = model_len;
    }

    /// Sum of current weights.
    pub fn total_weight(&self) -> f64 {
        self.w_last.iter().map(|&w| w as f64).sum()
    }

    /// Attach the quantized stripe view the binned scan engine consumes
    /// (DESIGN.md §8). No-op when a matching view is already attached —
    /// the samplers prebuild it at install time, so the scanner's call is
    /// a shape check, never a hot-path rebuild.
    pub fn ensure_binned(&mut self, spec: &BinSpec) {
        let stale = self
            .binned
            .as_ref()
            .map_or(true, |b| !b.matches(spec, self.data.n));
        if stale {
            self.binned = Some(spec.bin_block(&self.data));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set3() -> SampleSet {
        let data = DataBlock::new(
            3,
            2,
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            vec![1.0, -1.0, 1.0],
        );
        SampleSet::fresh(data, vec![0.5, -0.25, 0.0], 2)
    }

    #[test]
    fn fresh_has_unit_weights() {
        let s = set3();
        assert_eq!(s.w_sample, vec![1.0; 3]);
        assert_eq!(s.w_last, vec![1.0; 3]);
        assert_eq!(s.score_sample, s.score_last);
        assert_eq!(s.model_len_last, vec![2, 2, 2]);
        assert!((s.n_eff() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn set_weight_updates_state() {
        let mut s = set3();
        s.set_weight(1, 0.75, 2.0, 5);
        assert_eq!(s.w_last[1], 2.0);
        assert_eq!(s.score_last[1], 0.75);
        assert_eq!(s.model_len_last[1], 5);
        // others untouched
        assert_eq!(s.w_last[0], 1.0);
    }

    #[test]
    fn n_eff_decreases_with_skew() {
        let mut s = set3();
        s.w_last = vec![1.0, 1.0, 100.0];
        assert!(s.n_eff() < 1.2);
    }

    #[test]
    fn total_weight() {
        let mut s = set3();
        s.w_last = vec![0.5, 1.5, 2.0];
        assert!((s.total_weight() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ensure_binned_builds_once_and_rebuilds_on_mismatch() {
        let mut s = set3();
        assert!(s.binned.is_none());
        let spec = BinSpec::new((0, 2), 2, vec![0.5, 2.5, 3.5, 4.5]);
        s.ensure_binned(&spec);
        let first = s.binned.clone().expect("built");
        // rows are [0,1],[2,3],[4,5]: feature 0 bins vs [0.5, 2.5]
        assert_eq!(first.column(0), &[0, 1, 2]);
        assert_eq!(first.column(1), &[0, 0, 2]);
        // matching spec: untouched (same allocation contents)
        s.ensure_binned(&spec);
        assert_eq!(s.binned.as_ref().unwrap(), &first);
        // a different stripe shape forces a rebuild
        let narrow = BinSpec::new((1, 2), 2, vec![3.5, 4.5]);
        s.ensure_binned(&narrow);
        let rebuilt = s.binned.as_ref().unwrap();
        assert_eq!(rebuilt.stripe, (1, 2));
        assert_eq!(rebuilt.column(0), &[0, 0, 2]);
    }
}
