//! Binary on-disk example format.
//!
//! Layout (little-endian):
//!   header:  magic "SPRW" (4 bytes) | version u32 | n u64 | f u32 | pad u32
//!   records: n × ( label f32 | features f32 × f )
//!
//! Designed for fast *sequential* streaming (the Sampler's access pattern —
//! the paper's disk-resident set is read in randomly-permuted order, which
//! we realize by permuting once at write time).

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::data::DataBlock;

pub const MAGIC: &[u8; 4] = b"SPRW";
pub const VERSION: u32 = 1;
pub const HEADER_LEN: u64 = 24;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub n: u64,
    pub f: u32,
}

impl Header {
    pub fn record_bytes(&self) -> u64 {
        4 * (1 + self.f as u64)
    }
}

pub fn write_header(w: &mut impl Write, h: Header) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&h.n.to_le_bytes())?;
    w.write_all(&h.f.to_le_bytes())?;
    w.write_all(&0u32.to_le_bytes())?;
    Ok(())
}

pub fn read_header(r: &mut impl Read) -> io::Result<Header> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    if version != VERSION {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unsupported version {version}"),
        ));
    }
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8);
    r.read_exact(&mut b4)?;
    let f = u32::from_le_bytes(b4);
    r.read_exact(&mut b4)?; // pad
    Ok(Header { n, f })
}

/// Streaming writer. Call [`Writer::finish`] to patch the record count.
pub struct Writer {
    out: BufWriter<File>,
    f: u32,
    written: u64,
}

impl Writer {
    pub fn create(path: &Path, f: u32) -> io::Result<Writer> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        // placeholder n, patched by finish()
        write_header(&mut out, Header { n: 0, f })?;
        Ok(Writer { out, f, written: 0 })
    }

    pub fn write_example(&mut self, label: f32, features: &[f32]) -> io::Result<()> {
        debug_assert_eq!(features.len(), self.f as usize);
        self.out.write_all(&label.to_le_bytes())?;
        // Bulk-copy the feature row as bytes.
        let bytes = unsafe {
            std::slice::from_raw_parts(features.as_ptr() as *const u8, features.len() * 4)
        };
        self.out.write_all(bytes)?;
        self.written += 1;
        Ok(())
    }

    pub fn write_block(&mut self, block: &DataBlock) -> io::Result<()> {
        assert_eq!(block.f, self.f as usize);
        for i in 0..block.n {
            self.write_example(block.label(i), block.row(i))?;
        }
        Ok(())
    }

    pub fn finish(mut self) -> io::Result<Header> {
        self.out.flush()?;
        let mut file = self.out.into_inner()?;
        file.seek(SeekFrom::Start(8))?;
        file.write_all(&self.written.to_le_bytes())?;
        file.sync_all()?;
        Ok(Header {
            n: self.written,
            f: self.f,
        })
    }
}

/// Sequential reader with circular rewind (the Sampler loops over the
/// permuted disk file indefinitely).
pub struct Reader {
    inp: BufReader<File>,
    pub header: Header,
    /// records read since the last (re)start
    pos: u64,
}

impl Reader {
    pub fn open(path: &Path) -> io::Result<Reader> {
        let file = File::open(path)?;
        let mut inp = BufReader::with_capacity(1 << 20, file);
        let header = read_header(&mut inp)?;
        Ok(Reader {
            inp,
            header,
            pos: 0,
        })
    }

    pub fn position(&self) -> u64 {
        self.pos
    }

    /// Read up to `max_n` examples into a block; rewinds and continues from
    /// the start when the end of file is reached (`circular == true`).
    pub fn read_block(&mut self, max_n: usize, circular: bool) -> io::Result<DataBlock> {
        let f = self.header.f as usize;
        let mut block = DataBlock::empty(f);
        let mut buf = vec![0u8; 4 * (1 + f)];
        let mut row = vec![0f32; f];
        for _ in 0..max_n {
            if self.pos >= self.header.n {
                if !circular || self.header.n == 0 {
                    break;
                }
                self.rewind()?;
            }
            self.inp.read_exact(&mut buf)?;
            let label = f32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
            for (j, r) in row.iter_mut().enumerate() {
                let o = 4 + j * 4;
                *r = f32::from_le_bytes([buf[o], buf[o + 1], buf[o + 2], buf[o + 3]]);
            }
            block.push(&row, label);
            self.pos += 1;
        }
        Ok(block)
    }

    pub fn rewind(&mut self) -> io::Result<()> {
        self.inp.seek(SeekFrom::Start(HEADER_LEN))?;
        self.pos = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparrow_binfmt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_block() -> DataBlock {
        DataBlock::new(
            3,
            2,
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![1.0, -1.0, 1.0],
        )
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip.sprw");
        let mut w = Writer::create(&path, 2).unwrap();
        w.write_block(&sample_block()).unwrap();
        let h = w.finish().unwrap();
        assert_eq!(h, Header { n: 3, f: 2 });

        let mut r = Reader::open(&path).unwrap();
        assert_eq!(r.header, h);
        let b = r.read_block(10, false).unwrap();
        assert_eq!(b, sample_block());
    }

    #[test]
    fn circular_read_wraps() {
        let path = tmpfile("circular.sprw");
        let mut w = Writer::create(&path, 2).unwrap();
        w.write_block(&sample_block()).unwrap();
        w.finish().unwrap();

        let mut r = Reader::open(&path).unwrap();
        let b = r.read_block(7, true).unwrap();
        assert_eq!(b.n, 7);
        // wrapped rows repeat from the start
        assert_eq!(b.row(3), sample_block().row(0));
        assert_eq!(b.label(6), sample_block().label(0));
    }

    #[test]
    fn non_circular_stops_at_eof() {
        let path = tmpfile("eof.sprw");
        let mut w = Writer::create(&path, 2).unwrap();
        w.write_block(&sample_block()).unwrap();
        w.finish().unwrap();

        let mut r = Reader::open(&path).unwrap();
        let b = r.read_block(10, false).unwrap();
        assert_eq!(b.n, 3);
        let b2 = r.read_block(10, false).unwrap();
        assert!(b2.is_empty());
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmpfile("bad.sprw");
        std::fs::write(&path, b"NOPExxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(Reader::open(&path).is_err());
    }

    #[test]
    fn empty_file_ok() {
        let path = tmpfile("empty.sprw");
        let w = Writer::create(&path, 4).unwrap();
        let h = w.finish().unwrap();
        assert_eq!(h.n, 0);
        let mut r = Reader::open(&path).unwrap();
        assert!(r.read_block(5, true).unwrap().is_empty());
    }

    #[test]
    fn header_record_bytes() {
        assert_eq!(Header { n: 0, f: 3 }.record_bytes(), 16);
    }
}
