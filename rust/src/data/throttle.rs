//! I/O throughput throttle — models the paper's storage tiers.
//!
//! Table 1 compares "in-memory" (x1e.xlarge, 122 GB) against "off-memory"
//! (r3.xlarge, 30.5 GB) configurations, where off-memory runs stream from
//! disk. Our synthetic datasets fit in page cache, so the *bandwidth gap*
//! between tiers is reproduced explicitly: a token-bucket throttle caps the
//! byte rate of any component configured as disk-resident.

use std::time::{Duration, Instant};

/// Token-bucket byte-rate limiter.
#[derive(Debug)]
pub struct IoThrottle {
    bytes_per_sec: f64,
    /// tokens currently available (bytes)
    tokens: f64,
    /// max burst (bytes)
    burst: f64,
    last: Instant,
    /// total time spent sleeping — reported in experiment logs
    pub stalled: Duration,
}

impl IoThrottle {
    /// `bytes_per_sec == 0` disables throttling (in-memory tier).
    pub fn new(bytes_per_sec: f64) -> IoThrottle {
        let burst = (bytes_per_sec / 10.0).max((64u64 << 10) as f64);
        IoThrottle {
            bytes_per_sec,
            tokens: burst,
            burst,
            last: Instant::now(),
            stalled: Duration::ZERO,
        }
    }

    pub fn unlimited() -> IoThrottle {
        IoThrottle::new(0.0)
    }

    pub fn is_unlimited(&self) -> bool {
        self.bytes_per_sec <= 0.0
    }

    /// Account for `bytes` of I/O, sleeping as needed to respect the rate.
    pub fn consume(&mut self, bytes: u64) {
        if self.is_unlimited() {
            return;
        }
        let now = Instant::now();
        let refill = now.duration_since(self.last).as_secs_f64() * self.bytes_per_sec;
        self.tokens = (self.tokens + refill).min(self.burst);
        self.last = now;
        self.tokens -= bytes as f64;
        if self.tokens < 0.0 {
            let wait = Duration::from_secs_f64(-self.tokens / self.bytes_per_sec);
            self.stalled += wait;
            std::thread::sleep(wait);
            self.last = Instant::now();
            self.tokens = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_never_sleeps() {
        let mut t = IoThrottle::unlimited();
        let t0 = Instant::now();
        for _ in 0..1000 {
            t.consume(1 << 20);
        }
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert_eq!(t.stalled, Duration::ZERO);
    }

    #[test]
    fn limited_rate_enforced() {
        // 10 MB/s budget, consume ~3 MB beyond the 1 MB burst
        let mut t = IoThrottle::new(10.0 * 1024.0 * 1024.0);
        let t0 = Instant::now();
        for _ in 0..4 {
            t.consume(1 << 20);
        }
        let elapsed = t0.elapsed();
        // 4 MiB at 10 MiB/s with ~1 MiB burst => >= ~200ms
        assert!(elapsed >= Duration::from_millis(150), "elapsed={elapsed:?}");
        assert!(t.stalled > Duration::ZERO);
    }

    #[test]
    fn burst_allows_initial_spike() {
        let mut t = IoThrottle::new(100.0 * 1024.0 * 1024.0);
        let t0 = Instant::now();
        t.consume(1 << 20); // within burst
        assert!(t0.elapsed() < Duration::from_millis(50));
    }
}
