//! I/O throughput throttle — models the paper's storage tiers.
//!
//! Table 1 compares "in-memory" (x1e.xlarge, 122 GB) against "off-memory"
//! (r3.xlarge, 30.5 GB) configurations, where off-memory runs stream from
//! disk. Our synthetic datasets fit in page cache, so the *bandwidth gap*
//! between tiers is reproduced explicitly: a token-bucket throttle caps the
//! byte rate of any component configured as disk-resident.
//!
//! Time flows through a [`Clock`]: with the default [`RealClock`] the
//! throttle sleeps for real; under the simulator's
//! [`crate::sim::SimClock`] the same code *advances virtual time* instead,
//! so a scenario can model slow disks without spending wall time
//! (DESIGN.md §9).
//!
//! # Quarantined to simulation
//!
//! Since the out-of-core tiered data plane landed (DESIGN.md §11), this
//! throttle is **not** the production off-memory story: `--store-tier
//! tiered` performs *real* chunk-file I/O under a real memory budget, and
//! combining it with `--disk-bandwidth` is rejected at config validation —
//! a simulated bandwidth cap layered on actual disk reads would
//! double-count the cost. The throttle remains for what it is good at:
//! `sparrow sim` scenarios and in-memory-tier experiments that *model* a
//! slow disk deterministically (virtual clock, zero wall time) without
//! needing a store larger than RAM. Prefer the tiered plane when you want
//! the real thing measured, and the throttle when you want a counterfactual
//! simulated.

use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::sim::clock::{Clock, RealClock};

/// Token-bucket byte-rate limiter.
pub struct IoThrottle {
    bytes_per_sec: f64,
    /// tokens currently available (bytes)
    tokens: f64,
    /// max burst (bytes)
    burst: f64,
    last: Instant,
    clock: Arc<dyn Clock>,
    /// total time spent sleeping — reported in experiment logs
    pub stalled: Duration,
}

impl fmt::Debug for IoThrottle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("IoThrottle")
            .field("bytes_per_sec", &self.bytes_per_sec)
            .field("tokens", &self.tokens)
            .field("burst", &self.burst)
            .field("stalled", &self.stalled)
            .field("virtual", &self.clock.is_virtual())
            .finish()
    }
}

impl IoThrottle {
    /// `bytes_per_sec == 0` disables throttling (in-memory tier).
    pub fn new(bytes_per_sec: f64) -> IoThrottle {
        IoThrottle::with_clock(bytes_per_sec, Arc::new(RealClock))
    }

    /// A throttle reading time (and sleeping) through `clock`.
    pub fn with_clock(bytes_per_sec: f64, clock: Arc<dyn Clock>) -> IoThrottle {
        let burst = (bytes_per_sec / 10.0).max((64u64 << 10) as f64);
        IoThrottle {
            bytes_per_sec,
            tokens: burst,
            burst,
            last: clock.now(),
            clock,
            stalled: Duration::ZERO,
        }
    }

    pub fn unlimited() -> IoThrottle {
        IoThrottle::new(0.0)
    }

    pub fn is_unlimited(&self) -> bool {
        self.bytes_per_sec <= 0.0
    }

    /// Account for `bytes` of I/O, sleeping as needed to respect the rate.
    pub fn consume(&mut self, bytes: u64) {
        if self.is_unlimited() {
            return;
        }
        let now = self.clock.now();
        let refill = now.duration_since(self.last).as_secs_f64() * self.bytes_per_sec;
        self.tokens = (self.tokens + refill).min(self.burst);
        self.last = now;
        self.tokens -= bytes as f64;
        if self.tokens < 0.0 {
            let wait = Duration::from_secs_f64(-self.tokens / self.bytes_per_sec);
            self.stalled += wait;
            self.clock.sleep(wait);
            self.last = self.clock.now();
            self.tokens = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimClock;

    #[test]
    fn unlimited_never_sleeps() {
        let mut t = IoThrottle::unlimited();
        let t0 = Instant::now();
        for _ in 0..1000 {
            t.consume(1 << 20);
        }
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert_eq!(t.stalled, Duration::ZERO);
    }

    #[test]
    fn limited_rate_enforced() {
        // 10 MB/s budget, consume ~3 MB beyond the 1 MB burst
        let mut t = IoThrottle::new(10.0 * 1024.0 * 1024.0);
        let t0 = Instant::now();
        for _ in 0..4 {
            t.consume(1 << 20);
        }
        let elapsed = t0.elapsed();
        // 4 MiB at 10 MiB/s with ~1 MiB burst => >= ~200ms
        assert!(elapsed >= Duration::from_millis(150), "elapsed={elapsed:?}");
        assert!(t.stalled > Duration::ZERO);
    }

    #[test]
    fn burst_allows_initial_spike() {
        let mut t = IoThrottle::new(100.0 * 1024.0 * 1024.0);
        let t0 = Instant::now();
        t.consume(1 << 20); // within burst
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn virtual_clock_stalls_in_virtual_time_only() {
        // The same throttle code models a 1 MiB/s disk under the sim
        // clock: ~7 MiB past the burst must "cost" ~7 virtual seconds
        // while finishing instantly on the wall clock.
        let clock = Arc::new(SimClock::new());
        let mut t = IoThrottle::with_clock(1024.0 * 1024.0, clock.clone());
        let wall = Instant::now();
        for _ in 0..8 {
            t.consume(1 << 20);
        }
        assert!(wall.elapsed() < Duration::from_millis(100), "must not really sleep");
        let virt = clock.now_virtual();
        assert!(virt >= Duration::from_secs(6), "virtual stall too small: {virt:?}");
        assert_eq!(t.stalled, virt, "all virtual time came from the throttle");
    }

    #[test]
    fn virtual_refill_honors_advances() {
        let clock = Arc::new(SimClock::new());
        let mut t = IoThrottle::with_clock(1024.0 * 1024.0, clock.clone());
        t.consume(1 << 20); // far past the ~100 KiB burst: drains the bucket
        let stalled_before = t.stalled;
        assert!(stalled_before > Duration::ZERO);
        // a long idle period refills the bucket — a within-burst read is free
        clock.advance(Duration::from_secs(10));
        t.consume(64 << 10);
        assert_eq!(t.stalled, stalled_before, "refilled bucket must not stall");
    }
}
