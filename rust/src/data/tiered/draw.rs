//! The exactness-preserving draw: certified weight ceilings that let the
//! tiered store *prove* an example will be rejected before reading it.
//!
//! # Why skipping a read can be exact
//!
//! The background build's acceptance draw for example `i` (see
//! `sampler::background`) spends exactly one uniform coin
//! `u = example_rng(key, i).f64() ∈ [0, 1)`, and for the
//! weight-proportional kinds the example is rejected **iff**
//! `scale · u ≥ w` (when `w/scale ≥ 1` at least one copy is kept
//! unconditionally, and `u < 1` always, so the condition covers both
//! branches). The coin is a pure function of `(seed, version, attempt, i)`
//! — computable without touching the example's bytes. Rejection is
//! monotone in `w`: if we hold a *certified ceiling* `W ≥ w`, then
//! `scale · u ≥ W` implies rejection. Skips fire only when rejection is
//! provable, so the surviving set — and therefore the sample — is
//! byte-identical to the in-memory pass. (`SamplerKind::Uniform` is even
//! simpler: acceptance is `u < m/n`, independent of `w`, so the survivor
//! set is computed exactly with zero reads.)
//!
//! # Where ceilings come from
//!
//! Weights are `w(M) = exp(−y·s_M(x))` and a model `M` that extends the
//! anchor `A` moves any score by at most the suffix alpha mass
//! `d = Σ|α|` (stump outputs are ±1), so `w(M) ≤ w(A) · e^d`. The store
//! keeps a per-example exponent `e` certifying `w(anchor) ≤ 2^e`: set
//! exactly from the fresh weight whenever an example is read
//! ([`exp_ceiling`]), and inflated by [`exp_bump`]`(d)` at commit time for
//! examples the pass skipped. [`drift_bound`] pads `d` for `f32`
//! score-accumulation rounding, so the certificate holds for the weights
//! the sampler actually computes, not just the real-valued ideal. All
//! roundings here are chosen to be safe-side: a ceiling may only ever be
//! too large (costing a read), never too small (which would corrupt the
//! sample).

use crate::data::strata::NUM_STRATA;
use crate::model::StrongRule;

/// Exact `2^e` over the full `f64` range: `+∞` above it, `0` below it.
/// Both extremes are safe ceilings (`∞` forces a read; `0` certifies only
/// weights that are themselves `0`).
pub fn pow2(e: i32) -> f64 {
    if e > 1023 {
        f64::INFINITY
    } else if e >= -1022 {
        f64::from_bits(((e + 1023) as u64) << 52)
    } else if e >= -1074 {
        f64::from_bits(1u64 << (e + 1074))
    } else {
        0.0
    }
}

/// Smallest stored exponent `e` with `w ≤ 2^e` (safe-side under `log2`
/// rounding). Non-finite weights get `i16::MAX` (infinite ceiling —
/// always read). A weight of `0.0` can only come from `exp()`
/// *underflow* — the real weight is positive, just below the smallest
/// subnormal — so it is certified at `2^-1074`, **not** zero: a zero
/// ceiling could never grow back through commit-time bumps and would skip
/// the example forever even after its true weight recovered.
pub fn exp_ceiling(w: f64) -> i16 {
    if !w.is_finite() {
        return i16::MAX;
    }
    if w <= 0.0 {
        return -1074;
    }
    let mut e = w.log2().ceil() as i32;
    // log2 is not correctly rounded — certify by construction
    while pow2(e) < w {
        e += 1;
    }
    e.clamp(i16::MIN as i32, i16::MAX as i32) as i16
}

/// The certified ceiling value `2^e`.
pub fn ceiling_value(e: i16) -> f64 {
    pow2(e as i32)
}

/// Layout stratum for a ceiling exponent — the same bucket
/// [`crate::data::strata::bucket_of`] assigns the weights it certifies
/// (`w ∈ (2^(e-1), 2^e]` has `⌊log₂ w⌋ = e−1` except at the boundary,
/// which only shifts locality, never contents).
pub fn stratum_of_exp(e: i16) -> u8 {
    let k = e as i64 - 1 + (NUM_STRATA as i64) / 2;
    k.clamp(0, NUM_STRATA as i64 - 1) as u8
}

fn alpha_mass(m: &StrongRule) -> f64 {
    m.alphas().iter().map(|&a| a.abs() as f64).sum()
}

/// Upper bound on `|s_model(x) − s_anchor(x)|` for every row `x`,
/// including the `f32` rounding of the score accumulation.
///
/// When `model` extends `anchor` the scores share the prefix fold
/// exactly, so the ideal bound is the suffix alpha mass; otherwise the
/// triangle inequality gives the mass sum. Either way a small guard term
/// covers per-step `f32` rounding (each partial sum is bounded by the
/// total mass; `1e-6` dwarfs the `f32` epsilon per step).
pub fn drift_bound(model: &StrongRule, anchor: &StrongRule) -> f64 {
    let d = if model.extends(anchor) {
        model.alphas()[anchor.len()..]
            .iter()
            .map(|&a| a.abs() as f64)
            .sum()
    } else {
        alpha_mass(model) + alpha_mass(anchor)
    };
    let mass = alpha_mass(model) + alpha_mass(anchor);
    d + (model.len().max(anchor.len()) as f64 + 1.0) * 1e-6 * (mass + 1.0)
}

/// Exponent increment certifying a weight inflation of `e^d`:
/// `ceil(d·log₂e)` nudged up past `ceil`'s own rounding. Saturates into
/// `i16` (saturated ceilings read forever — safe).
pub fn exp_bump(d: f64) -> i16 {
    let b = (d.max(0.0) * std::f64::consts::LOG2_E + 1e-9).ceil();
    b.min(i16::MAX as f64) as i16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;
    use crate::util::rng::Rng;

    #[test]
    fn pow2_exact_at_extremes() {
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(10), 1024.0);
        assert_eq!(pow2(-1), 0.5);
        assert_eq!(pow2(1023), f64::MAX / (2.0 - f64::EPSILON)); // 2^1023
        assert_eq!(pow2(1024), f64::INFINITY);
        assert_eq!(pow2(-1074), f64::from_bits(1)); // min subnormal
        assert_eq!(pow2(-1075), 0.0);
    }

    #[test]
    fn exp_ceiling_always_certifies() {
        // W = 2^exp_ceiling(w) must satisfy W ≥ w for every representable w
        let mut rng = Rng::new(99);
        for _ in 0..20_000 {
            // span the full magnitude range, including subnormals
            let mag = (rng.f64() - 0.5) * 2200.0;
            let w = rng.f64().max(1e-12) * mag.exp2();
            let e = exp_ceiling(w);
            assert!(
                ceiling_value(e) >= w,
                "ceiling 2^{e} < w={w:e}"
            );
        }
        // exact powers of two certify themselves (no wasted doubling)
        for k in [-1074i32, -600, -1, 0, 1, 600, 1023] {
            let w = pow2(k);
            assert_eq!(exp_ceiling(w) as i32, k, "w=2^{k}");
        }
    }

    #[test]
    fn exp_ceiling_degenerate_weights() {
        assert_eq!(exp_ceiling(f64::NAN), i16::MAX);
        assert_eq!(exp_ceiling(f64::INFINITY), i16::MAX);
        assert_eq!(ceiling_value(i16::MAX), f64::INFINITY);
        // exp-underflowed weights certify at the subnormal floor, never 0:
        // the ceiling must stay recoverable through commit-time bumps
        assert_eq!(exp_ceiling(0.0), -1074);
        assert!(ceiling_value(exp_ceiling(0.0)) > 0.0);
        assert_eq!(exp_ceiling((-1000.0f64).exp()), -1074); // true underflow
        assert_eq!(exp_ceiling(-1.0), -1074); // defensive: weights are ≥ 0
        assert!(ceiling_value(exp_ceiling(f64::MIN_POSITIVE)) >= f64::MIN_POSITIVE);
    }

    #[test]
    fn stratum_matches_bucket_of() {
        use crate::data::strata::bucket_of;
        // for weights strictly inside an exponent interval the layout
        // stratum equals the StratifiedStore bucket
        for k in [-20i32, -3, 0, 2, 17] {
            let w = pow2(k) * 1.5; // in (2^k, 2^(k+1))
            assert_eq!(stratum_of_exp(exp_ceiling(w)), bucket_of(w));
        }
        // saturated exponents clamp into the end strata
        assert_eq!(stratum_of_exp(i16::MIN), 0);
        assert_eq!(stratum_of_exp(i16::MAX), NUM_STRATA as u8 - 1);
    }

    #[test]
    fn drift_bound_covers_computed_weights() {
        // the certificate must hold for the f32-accumulated scores the
        // sampler actually computes: w_model ≤ w_anchor · e^drift
        let mut rng = Rng::new(5);
        let mut anchor = StrongRule::new();
        for k in 0..6 {
            anchor.push(Stump::new(k % 3, rng.f64() as f32 - 0.5, 1.0), 0.3 + k as f32 * 0.1);
        }
        let mut model = anchor.clone();
        for k in 0..4 {
            model.push(Stump::new(k % 3, rng.f64() as f32 - 0.5, -1.0), 0.2 + k as f32 * 0.05);
        }
        let d = drift_bound(&model, &anchor);
        let infl = d.exp();
        for _ in 0..2000 {
            let row = [rng.f64() as f32 - 0.5, rng.f64() as f32 - 0.5, rng.f64() as f32 - 0.5];
            for label in [1.0f32, -1.0] {
                let wa = (-(label as f64) * anchor.score(&row) as f64).exp();
                let wm = (-(label as f64) * model.score(&row) as f64).exp();
                assert!(wm <= wa * infl, "wm={wm} wa={wa} infl={infl}");
                // and the commit-time exponent bump certifies the same move
                let e = exp_ceiling(wa);
                let bumped = e.saturating_add(exp_bump(d));
                assert!(ceiling_value(bumped) >= wm);
            }
        }
        // disjoint models fall back to the mass-sum bound
        let mut other = StrongRule::new();
        other.push(Stump::new(0, 0.0, 1.0), 2.0);
        assert!(!other.extends(&anchor) || anchor.is_empty());
        let d2 = drift_bound(&other, &anchor);
        assert!(d2 >= 2.0);
    }

    #[test]
    fn exp_bump_is_safe_side() {
        assert!(exp_bump(0.0) >= 0);
        assert_eq!(exp_bump(f64::ln(2.0)), 1); // e^ln2 = 2 → one doubling
        assert!(exp_bump(10.0) as f64 >= 10.0 * std::f64::consts::LOG2_E);
        assert_eq!(exp_bump(1e9), i16::MAX); // saturates, never wraps
    }
}
