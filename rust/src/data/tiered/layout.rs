//! Tier layout planning: which weight strata live in memory and which
//! spill to chunk files.
//!
//! Strata are served heaviest-first (the mostly-accepted examples), so
//! residency buys the most where acceptance is densest: a resident heavy
//! stratum costs no I/O at all, while the light spilled tail is where the
//! certified-skip draw (see [`super::draw`]) avoids most reads anyway.
//! Residency is all-or-nothing per stratum — chunk files stay homogeneous
//! and the plan is a pure function of the stratum histogram, which keeps
//! re-partition decisions deterministic and testable.

use crate::data::strata::NUM_STRATA;

/// A residency plan over the non-empty strata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierPlan {
    /// non-empty strata in serving order (heaviest first)
    pub order: Vec<u8>,
    /// aligned with `order`: does the stratum stay memory-resident?
    pub resident: Vec<bool>,
}

impl TierPlan {
    /// Greedy heaviest-first plan: walk strata from heaviest to lightest
    /// and mark each resident when its bytes still fit the remaining
    /// budget (lighter strata may still fit after a heavy one did not —
    /// unused budget is never stranded).
    pub fn plan(counts: &[usize; NUM_STRATA], record_bytes: u64, budget_bytes: u64) -> TierPlan {
        let mut order = Vec::new();
        let mut resident = Vec::new();
        let mut remaining = budget_bytes;
        for k in (0..NUM_STRATA).rev() {
            if counts[k] == 0 {
                continue;
            }
            let bytes = counts[k] as u64 * record_bytes;
            let fits = bytes <= remaining;
            if fits {
                remaining -= bytes;
            }
            order.push(k as u8);
            resident.push(fits);
        }
        TierPlan { order, resident }
    }

    /// Number of resident strata in the plan.
    pub fn resident_strata(&self) -> usize {
        self.resident.iter().filter(|&&r| r).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(pairs: &[(usize, usize)]) -> [usize; NUM_STRATA] {
        let mut c = [0usize; NUM_STRATA];
        for &(k, n) in pairs {
            c[k] = n;
        }
        c
    }

    #[test]
    fn everything_fits() {
        let c = counts(&[(10, 5), (20, 7)]);
        let p = TierPlan::plan(&c, 100, 10_000);
        assert_eq!(p.order, vec![20, 10]); // heaviest first
        assert_eq!(p.resident, vec![true, true]);
    }

    #[test]
    fn zero_budget_spills_everything() {
        let c = counts(&[(16, 100)]);
        let p = TierPlan::plan(&c, 100, 0);
        assert_eq!(p.order, vec![16]);
        assert_eq!(p.resident, vec![false]);
        assert_eq!(p.resident_strata(), 0);
    }

    #[test]
    fn partial_budget_prefers_heavy_but_backfills() {
        // heavy stratum too big for the budget; two lighter ones fit
        let c = counts(&[(30, 1000), (20, 4), (10, 5)]);
        let p = TierPlan::plan(&c, 100, 1_000);
        assert_eq!(p.order, vec![30, 20, 10]);
        // 1000*100 > 1000 → spilled; 4*100 then 5*100 both fit
        assert_eq!(p.resident, vec![false, true, true]);
        assert_eq!(p.resident_strata(), 2);
    }

    #[test]
    fn empty_strata_omitted() {
        let p = TierPlan::plan(&counts(&[]), 100, 100);
        assert!(p.order.is_empty());
    }
}
