//! On-disk format for per-stratum spill chunk files, plus a uniform
//! byte-span reader over spill files *and* the base store.
//!
//! Layout (little-endian), deliberately parallel to
//! [`crate::data::binfmt`]:
//!
//! ```text
//!   header:  magic "SPCH" (4 bytes) | version u32 | n u64 | f u32 | pad u32
//!   records: n × ( label f32 | features f32 × f )
//! ```
//!
//! Records are identical to the base `.sprw` records and both headers are
//! 24 bytes, so a [`ChunkSource`] can address either file kind by *slot*
//! (record index within the file) — the base store is just the one chunk
//! source whose slots coincide with global example indices. Readers fetch
//! contiguous slot spans as raw bytes ([`ChunkSource::read_span`]) and
//! decode one record at a time ([`decode_row_into`]); a spilled example
//! therefore never needs more than its own `f32` row materialized.

use std::fs::File;
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::data::binfmt;

/// Magic for spill chunk files (base stores carry `binfmt::MAGIC`).
pub const CHUNK_MAGIC: &[u8; 4] = b"SPCH";
/// Spill format version.
pub const CHUNK_VERSION: u32 = 1;
/// Header length shared by both file kinds.
pub const HEADER_LEN: u64 = binfmt::HEADER_LEN;

/// Streaming writer for one spill chunk file. Call
/// [`ChunkWriter::finish`] to patch the record count.
pub struct ChunkWriter {
    out: BufWriter<File>,
    f: u32,
    written: u64,
}

impl ChunkWriter {
    /// Create `path` with a placeholder record count.
    pub fn create(path: &Path, f: u32) -> io::Result<ChunkWriter> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        out.write_all(CHUNK_MAGIC)?;
        out.write_all(&CHUNK_VERSION.to_le_bytes())?;
        out.write_all(&0u64.to_le_bytes())?;
        out.write_all(&f.to_le_bytes())?;
        out.write_all(&0u32.to_le_bytes())?;
        Ok(ChunkWriter { out, f, written: 0 })
    }

    /// Append one record.
    pub fn write_row(&mut self, label: f32, features: &[f32]) -> io::Result<()> {
        debug_assert_eq!(features.len(), self.f as usize);
        self.out.write_all(&label.to_le_bytes())?;
        for &x in features {
            self.out.write_all(&x.to_le_bytes())?;
        }
        self.written += 1;
        Ok(())
    }

    /// Flush, patch the record count, and return it.
    pub fn finish(mut self) -> io::Result<u64> {
        self.out.flush()?;
        let mut file = self.out.into_inner()?;
        file.seek(SeekFrom::Start(8))?;
        file.write_all(&self.written.to_le_bytes())?;
        file.sync_all()?;
        Ok(self.written)
    }
}

/// A validated, slot-addressable record file: either a spill chunk file
/// or the base `.sprw` store.
#[derive(Debug, Clone)]
pub struct ChunkSource {
    path: PathBuf,
    /// features per record
    pub f: usize,
    /// records in the file
    pub n: usize,
}

impl ChunkSource {
    /// Open a spill chunk file, validating its header.
    pub fn open_spill(path: &Path) -> io::Result<ChunkSource> {
        let mut file = File::open(path)?;
        let mut magic = [0u8; 4];
        file.read_exact(&mut magic)?;
        if &magic != CHUNK_MAGIC {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "bad spill chunk magic",
            ));
        }
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        file.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != CHUNK_VERSION {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "unsupported spill chunk version",
            ));
        }
        file.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        file.read_exact(&mut b4)?;
        let f = u32::from_le_bytes(b4) as usize;
        Ok(ChunkSource {
            path: path.to_path_buf(),
            f,
            n,
        })
    }

    /// Open the base `.sprw` store as a chunk source (slots = global
    /// example indices).
    pub fn open_base(path: &Path) -> io::Result<ChunkSource> {
        let mut file = File::open(path)?;
        let header = binfmt::read_header(&mut file)?;
        Ok(ChunkSource {
            path: path.to_path_buf(),
            f: header.f as usize,
            n: header.n as usize,
        })
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes per record.
    pub fn record_bytes(&self) -> u64 {
        4 * (1 + self.f as u64)
    }

    /// Open a private file handle for span reads (each reader thread
    /// keeps its own cursor).
    pub fn open_file(&self) -> io::Result<File> {
        File::open(&self.path)
    }

    /// Read the raw bytes of `count` records starting at `slot` through
    /// `file` (a handle from [`ChunkSource::open_file`]).
    pub fn read_span(&self, file: &mut File, slot: usize, count: usize) -> io::Result<Vec<u8>> {
        assert!(
            slot + count <= self.n,
            "span {slot}+{count} out of bounds (n={})",
            self.n
        );
        let rec = self.record_bytes();
        file.seek(SeekFrom::Start(HEADER_LEN + slot as u64 * rec))?;
        let mut buf = vec![0u8; count * rec as usize];
        file.read_exact(&mut buf)?;
        Ok(buf)
    }
}

/// Decode record `k` of a span buffer into `row`, returning the label.
pub fn decode_row_into(buf: &[u8], k: usize, f: usize, row: &mut [f32]) -> f32 {
    debug_assert_eq!(row.len(), f);
    let rec = 4 * (1 + f);
    let at = k * rec;
    let label = f32::from_le_bytes([buf[at], buf[at + 1], buf[at + 2], buf[at + 3]]);
    for (j, r) in row.iter_mut().enumerate() {
        let o = at + 4 + j * 4;
        *r = f32::from_le_bytes([buf[o], buf[o + 1], buf[o + 2], buf[o + 3]]);
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("sparrow_chunkfmt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_spans() {
        let path = tmpfile("rt.spch");
        let mut w = ChunkWriter::create(&path, 3).unwrap();
        for i in 0..10 {
            let row = [i as f32, (i * 2) as f32, (i * 3) as f32];
            w.write_row(if i % 2 == 0 { 1.0 } else { -1.0 }, &row).unwrap();
        }
        assert_eq!(w.finish().unwrap(), 10);

        let src = ChunkSource::open_spill(&path).unwrap();
        assert_eq!((src.n, src.f), (10, 3));
        let mut file = src.open_file().unwrap();
        let buf = src.read_span(&mut file, 4, 3).unwrap();
        let mut row = [0f32; 3];
        let label = decode_row_into(&buf, 1, 3, &mut row);
        assert_eq!(label, -1.0); // record 5
        assert_eq!(row, [5.0, 10.0, 15.0]);
    }

    #[test]
    fn base_store_is_a_chunk_source() {
        use crate::data::{DataBlock, DiskStore};
        let path = tmpfile("base.sprw");
        let mut b = DataBlock::empty(2);
        for i in 0..6 {
            b.push(&[i as f32, -(i as f32)], 1.0);
        }
        DiskStore::write(&path, &b).unwrap();

        let src = ChunkSource::open_base(&path).unwrap();
        assert_eq!((src.n, src.f), (6, 2));
        let mut file = src.open_file().unwrap();
        let buf = src.read_span(&mut file, 5, 1).unwrap();
        let mut row = [0f32; 2];
        decode_row_into(&buf, 0, 2, &mut row);
        assert_eq!(row, [5.0, -5.0]);
    }

    #[test]
    fn wrong_magic_rejected_both_ways() {
        let path = tmpfile("cross.spch");
        let mut w = ChunkWriter::create(&path, 1).unwrap();
        w.write_row(1.0, &[0.0]).unwrap();
        w.finish().unwrap();
        // a spill file is not a base store and vice versa
        assert!(ChunkSource::open_base(&path).is_err());
        let base = tmpfile("cross.sprw");
        use crate::data::{DataBlock, DiskStore};
        DiskStore::write(&base, &DataBlock::empty(1)).unwrap();
        assert!(ChunkSource::open_spill(&base).is_err());
    }
}
