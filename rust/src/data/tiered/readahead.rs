//! Asynchronous chunk prefetch behind the build pass.
//!
//! The build pass knows its entire read schedule up front (the survivor
//! spans computed by the exactness-preserving draw — see [`super::draw`]),
//! so prefetch is a straight-line producer: one thread walks the schedule
//! in serving order and pushes raw span buffers into a bounded channel
//! (`readahead_depth` chunks). The consumer counts a **hit** when the
//! next chunk is already buffered and a **miss** when it has to wait —
//! the `readahead_hit` / `readahead_miss` counters surfaced by the admin
//! `metrics.snapshot`.
//!
//! Cancellation mirrors the builder's epoch-invalidation discipline: the
//! consumer flips an atomic flag (on model adoption the whole build pass
//! aborts), drains the channel so a blocked send completes, and joins.
//! Dropping a [`Readahead`] mid-schedule is therefore always safe and
//! prompt.

use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::chunkfmt::ChunkSource;

/// One prefetch request: a contiguous slot span of one source file.
#[derive(Debug, Clone, Copy)]
pub struct ReadReq {
    /// index into the source list handed to [`Readahead::spawn`]
    pub source: usize,
    /// first record slot of the span
    pub slot: usize,
    /// records in the span
    pub count: usize,
}

/// Handle to the prefetch thread; yields span buffers in schedule order.
pub struct Readahead {
    rx: Receiver<io::Result<Vec<u8>>>,
    cancel: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    hits: u64,
    misses: u64,
}

impl Readahead {
    /// Start prefetching `schedule` (requests indexing into `sources`),
    /// keeping at most `depth` chunks buffered ahead of the consumer.
    pub fn spawn(
        sources: Vec<ChunkSource>,
        schedule: Vec<ReadReq>,
        depth: usize,
    ) -> io::Result<Readahead> {
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<io::Result<Vec<u8>>>(depth.max(1));
        let tcancel = Arc::clone(&cancel);
        let thread = std::thread::Builder::new()
            .name("readahead".into())
            .spawn(move || {
                let mut files: Vec<Option<std::fs::File>> =
                    sources.iter().map(|_| None).collect();
                for req in schedule {
                    if tcancel.load(Ordering::Relaxed) {
                        return;
                    }
                    let src = &sources[req.source];
                    let res = (|| {
                        if files[req.source].is_none() {
                            files[req.source] = Some(src.open_file()?);
                        }
                        src.read_span(files[req.source].as_mut().unwrap(), req.slot, req.count)
                    })();
                    let failed = res.is_err();
                    // send failure = consumer gone; either way stop after
                    // surfacing the first I/O error
                    if tx.send(res).is_err() || failed {
                        return;
                    }
                }
            })?;
        Ok(Readahead {
            rx,
            cancel,
            thread: Some(thread),
            hits: 0,
            misses: 0,
        })
    }

    /// Next span buffer in schedule order (blocking), with hit/miss
    /// accounting.
    pub fn next(&mut self) -> io::Result<Vec<u8>> {
        match self.rx.try_recv() {
            Ok(res) => {
                self.hits += 1;
                res
            }
            Err(TryRecvError::Empty) => {
                self.misses += 1;
                match self.rx.recv() {
                    Ok(res) => res,
                    Err(_) => Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "readahead thread ended before the schedule",
                    )),
                }
            }
            Err(TryRecvError::Disconnected) => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "readahead thread ended before the schedule",
            )),
        }
    }

    /// Chunks that were already buffered when asked for.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Chunks the consumer had to wait for.
    pub fn misses(&self) -> u64 {
        self.misses
    }
}

impl Drop for Readahead {
    fn drop(&mut self) {
        self.cancel.store(true, Ordering::Relaxed);
        // unblock a producer stuck on a full channel, then join
        while self.rx.recv().is_ok() {}
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tiered::chunkfmt::{decode_row_into, ChunkWriter};

    fn chunk_file(name: &str, n: usize) -> ChunkSource {
        let dir = std::env::temp_dir().join("sparrow_readahead_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut w = ChunkWriter::create(&path, 2).unwrap();
        for i in 0..n {
            w.write_row(1.0, &[i as f32, (i * i) as f32]).unwrap();
        }
        w.finish().unwrap();
        ChunkSource::open_spill(&path).unwrap()
    }

    #[test]
    fn yields_schedule_in_order() {
        let src = chunk_file("order.spch", 20);
        let schedule = vec![
            ReadReq { source: 0, slot: 10, count: 4 },
            ReadReq { source: 0, slot: 0, count: 2 },
            ReadReq { source: 0, slot: 17, count: 3 },
        ];
        let mut ra = Readahead::spawn(vec![src], schedule.clone(), 2).unwrap();
        let mut row = [0f32; 2];
        for req in &schedule {
            let buf = ra.next().unwrap();
            assert_eq!(buf.len(), req.count * 12);
            decode_row_into(&buf, 0, 2, &mut row);
            assert_eq!(row[0] as usize, req.slot);
        }
        assert_eq!(ra.hits() + ra.misses(), 3);
    }

    #[test]
    fn buffered_chunks_count_as_hits() {
        let src = chunk_file("hits.spch", 8);
        let schedule: Vec<ReadReq> = (0..4)
            .map(|k| ReadReq { source: 0, slot: k * 2, count: 2 })
            .collect();
        let mut ra = Readahead::spawn(vec![src], schedule, 8).unwrap();
        // give the producer time to fill the (deep) buffer
        std::thread::sleep(std::time::Duration::from_millis(200));
        for _ in 0..4 {
            ra.next().unwrap();
        }
        assert!(ra.hits() >= 3, "hits={} misses={}", ra.hits(), ra.misses());
    }

    #[test]
    fn drop_mid_schedule_cancels_promptly() {
        let src = chunk_file("cancel.spch", 1000);
        // shallow channel: the producer will block on send
        let schedule: Vec<ReadReq> = (0..500)
            .map(|k| ReadReq { source: 0, slot: k * 2, count: 2 })
            .collect();
        let mut ra = Readahead::spawn(vec![src], schedule, 1).unwrap();
        let _ = ra.next().unwrap();
        drop(ra); // must not hang
    }

    #[test]
    fn missing_file_surfaces_error() {
        let src = chunk_file("gone.spch", 4);
        std::fs::remove_file(src.path()).unwrap();
        let schedule = vec![ReadReq { source: 0, slot: 0, count: 2 }];
        let mut ra = Readahead::spawn(vec![src], schedule, 1).unwrap();
        assert!(ra.next().is_err());
    }
}
