//! [`TieredStore`]: the chunk-file-backed store behind the out-of-core
//! build pass.
//!
//! Data placement (see [`super::layout`]): each example belongs to one
//! weight-stratum **group**; a group is either memory-resident (a
//! [`DataBlock`] in slot order) or spilled (a chunk file in slot order,
//! [`super::chunkfmt`]). The initial layout is a single group backed by
//! the base `.sprw` file itself — opening a tiered store copies nothing.
//! Commits re-certify the per-example weight ceilings; when enough
//! examples have migrated strata the store re-partitions (a sequential
//! merge pass that rewrites resident blocks and spill files).
//!
//! The build pass ([`TieredStore::build_pass`]) is where the tentpole
//! properties live:
//!
//! 1. survivor spans for every spilled chunk are computed **up front**
//!    from the certified ceilings (`keep`), so certainly-rejected
//!    examples are never read;
//! 2. a [`super::readahead`] thread prefetches those spans while the
//!    resident (heavy) groups are being served, hiding disk latency
//!    behind compute;
//! 3. examples stream out of raw chunk buffers one decoded `f32` row at
//!    a time — no spilled group is ever materialized whole.
//!
//! The store never decides acceptance itself: `keep` and `visit` belong
//! to the sampler (see `sampler::build_tiered`), keeping the strata
//! invariant of [`crate::data::strata`] — placement affects cost, never
//! contents.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::binfmt::Reader;
use crate::data::strata::NUM_STRATA;
use crate::data::tiered::chunkfmt::{decode_row_into, ChunkSource, ChunkWriter};
use crate::data::tiered::draw::{ceiling_value, drift_bound, exp_bump, exp_ceiling, stratum_of_exp};
use crate::data::tiered::layout::TierPlan;
use crate::data::tiered::readahead::{ReadReq, Readahead};
use crate::data::tiered::{TieredConfig, TieredCounters};
use crate::data::DataBlock;
use crate::model::StrongRule;

/// Sentinel for "not observed by the in-flight build".
const EXP_UNSEEN: i16 = i16::MIN;

/// Distinguishes concurrently-opened stores' spill directories.
static WORKDIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Statistics of the last completed (or aborted) build pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct PassStats {
    /// did the pass run to completion (false = invalidated)?
    pub completed: bool,
    /// examples served to the sampler (resident + disk)
    pub rows_visited: u64,
    /// examples decoded from disk chunks
    pub rows_read_disk: u64,
    /// examples skipped with zero bytes served (certified rejected)
    pub rows_skipped: u64,
    /// chunk bytes fetched (includes span slack around survivors)
    pub bytes_read: u64,
}

enum GroupData {
    /// resident rows, slot order
    Mem(DataBlock),
    /// spilled rows (or the base store for the initial layout), slot order
    File(ChunkSource),
}

struct Group {
    stratum: u8,
    /// global example index per slot, ascending
    rows: Vec<u32>,
    data: GroupData,
}

/// Chunk-file-backed tiered store with certified per-example weight
/// ceilings. See the module docs for the layout and the build-pass
/// contract.
pub struct TieredStore {
    base: ChunkSource,
    workdir: PathBuf,
    cfg: TieredConfig,
    n: usize,
    f: usize,
    /// pinned prefix for the sampler's deterministic probe
    probe: DataBlock,
    /// certified: `w_anchor(example i) ≤ 2^ceil_exp[i]`
    ceil_exp: Vec<i16>,
    /// the model the ceilings certify against
    anchor: StrongRule,
    /// serving order: resident groups first, then spilled, heaviest first
    groups: Vec<Group>,
    layout_gen: u64,
    resident_rows: usize,
    pending_exp: Vec<i16>,
    building: bool,
    last_pass: PassStats,
    counters: TieredCounters,
}

impl TieredStore {
    /// Open the base store at `path`. No data is copied: the initial
    /// layout is one group backed by the base file (or one resident
    /// block, when the whole store fits the memory budget).
    pub fn open(path: &Path, cfg: TieredConfig) -> io::Result<TieredStore> {
        let base = ChunkSource::open_base(path)?;
        let n = base.n;
        let f = base.f;
        let record_bytes = base.record_bytes();

        let pin = cfg.probe_rows.min(n);
        let probe = if pin > 0 {
            Reader::open(path)?.read_block(pin, false)?
        } else {
            DataBlock::empty(f)
        };

        let name = path
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "store".into());
        let workdir = path.with_file_name(format!(
            "{name}.tiered.{}.{}",
            std::process::id(),
            WORKDIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&workdir)?;

        // every example starts certified at weight 1 under the empty
        // model: exp(−y·0) = 1 ≤ 2^0 exactly
        let e0 = exp_ceiling(1.0);
        let ceil_exp = vec![e0; n];
        let mut groups = Vec::new();
        let mut resident_rows = 0;
        if n > 0 {
            let stratum = stratum_of_exp(e0);
            let budget = cfg
                .memory_budget
                .saturating_sub(probe.n as u64 * record_bytes);
            let rows: Vec<u32> = (0..n as u32).collect();
            let data = if (n as u64) * record_bytes <= budget {
                resident_rows = n;
                GroupData::Mem(Reader::open(path)?.read_block(n, false)?)
            } else {
                GroupData::File(base.clone())
            };
            groups.push(Group {
                stratum,
                rows,
                data,
            });
        }

        Ok(TieredStore {
            base,
            workdir,
            cfg,
            n,
            f,
            probe,
            ceil_exp,
            anchor: StrongRule::new(),
            groups,
            layout_gen: 0,
            resident_rows,
            pending_exp: Vec::new(),
            building: false,
            last_pass: PassStats::default(),
            counters: TieredCounters::default(),
        })
    }

    /// Number of examples in the store.
    pub fn len(&self) -> usize {
        self.n
    }

    /// `true` when the store holds no examples.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of features per example.
    pub fn num_features(&self) -> usize {
        self.f
    }

    /// The model the certified ceilings hold against (the last committed
    /// build's model; empty at open).
    pub fn anchor(&self) -> &StrongRule {
        &self.anchor
    }

    /// Certified weight ceiling of example `gi` under the anchor model.
    pub fn ceiling(&self, gi: usize) -> f64 {
        ceiling_value(self.ceil_exp[gi])
    }

    /// Fraction of examples currently memory-resident.
    pub fn resident_fraction(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.resident_rows as f64 / self.n as f64
    }

    /// Activity counters (monotone; the worker logs deltas).
    pub fn counters(&self) -> TieredCounters {
        self.counters
    }

    /// Statistics of the most recent build pass.
    pub fn last_pass(&self) -> PassStats {
        self.last_pass
    }

    /// The deterministic probe prefix: records `0..probe_n` in store
    /// order, exactly what the in-memory pass reads first. Served from
    /// the pinned prefix when it covers `probe_n`, else from the base
    /// file.
    pub fn probe_block(&self, probe_n: usize) -> io::Result<DataBlock> {
        let take = probe_n.min(self.n);
        if take <= self.probe.n {
            let mut b = DataBlock::empty(self.f);
            for i in 0..take {
                b.push(self.probe.row(i), self.probe.label(i));
            }
            return Ok(b);
        }
        Reader::open(self.base.path())?.read_block(take, false)
    }

    /// Begin a build pass: open the in-flight ceiling buffer. Mirrors
    /// [`crate::data::StratifiedStore::begin_build`] — only
    /// [`TieredStore::commit_build`] makes observations visible.
    pub fn begin_build(&mut self) {
        assert!(!self.building, "begin_build while building");
        self.pending_exp = vec![EXP_UNSEEN; self.n];
        self.building = true;
        self.last_pass = PassStats::default();
    }

    /// One exactness-preserving pass over every example, heaviest strata
    /// first (resident groups, then spilled groups behind readahead).
    ///
    /// * `keep(gi, ceiling)` — must return `false` **only** when the
    ///   caller can prove example `gi` is rejected given that its fresh
    ///   weight is at most `ceiling · e^d` for its drift allowance `d`
    ///   (see [`super::draw`]); such examples are never read.
    /// * `visit(gi, label, row)` — called for every kept example, returns
    ///   the fresh weight (recorded into the in-flight ceiling buffer).
    /// * `invalidated()` — polled between chunks; `true` aborts the pass
    ///   (the caller should then [`TieredStore::abort_build`]).
    ///
    /// Returns `Ok(true)` on completion, `Ok(false)` when invalidated.
    pub fn build_pass(
        &mut self,
        keep: &mut dyn FnMut(usize, f64) -> bool,
        visit: &mut dyn FnMut(usize, f32, &[f32]) -> f64,
        invalidated: &mut dyn FnMut() -> bool,
    ) -> io::Result<bool> {
        assert!(self.building, "build_pass outside begin_build/commit");
        let chunk_rows = self.cfg.chunk_rows.max(1);

        // ---- plan spilled survivors up front (no I/O: ceilings + coins
        // are in memory) and start the readahead behind them ------------
        let mut sources: Vec<ChunkSource> = Vec::new();
        let mut schedule: Vec<ReadReq> = Vec::new();
        // per request: (group index, span start slot, surviving slots)
        let mut spans: Vec<(usize, usize, Vec<u32>)> = Vec::new();
        for (g_idx, group) in self.groups.iter().enumerate() {
            let src = match &group.data {
                GroupData::File(src) => src,
                GroupData::Mem(_) => continue,
            };
            let src_idx = sources.len();
            sources.push(src.clone());
            let slots = group.rows.len();
            let mut chunk_start = 0;
            while chunk_start < slots {
                let chunk_end = (chunk_start + chunk_rows).min(slots);
                let surv: Vec<u32> = (chunk_start..chunk_end)
                    .filter(|&slot| {
                        let gi = group.rows[slot] as usize;
                        keep(gi, ceiling_value(self.ceil_exp[gi]))
                    })
                    .map(|slot| slot as u32)
                    .collect();
                let skipped = (chunk_end - chunk_start - surv.len()) as u64;
                self.counters.rows_skipped += skipped;
                self.last_pass.rows_skipped += skipped;
                if !surv.is_empty() {
                    let lo = surv[0] as usize;
                    let hi = *surv.last().unwrap() as usize;
                    schedule.push(ReadReq {
                        source: src_idx,
                        slot: lo,
                        count: hi - lo + 1,
                    });
                    spans.push((g_idx, lo, surv));
                }
                chunk_start = chunk_end;
            }
        }
        let mut ra = if schedule.is_empty() {
            None
        } else {
            Some(Readahead::spawn(
                sources,
                schedule,
                self.cfg.readahead_depth,
            )?)
        };

        // ---- serve resident (heavy) groups while the readahead warms ---
        for g_idx in 0..self.groups.len() {
            let group = &self.groups[g_idx];
            let block = match &group.data {
                GroupData::Mem(b) => b,
                GroupData::File(_) => continue,
            };
            for slot in 0..group.rows.len() {
                if slot % chunk_rows == 0 && invalidated() {
                    // keep the prefetch counters, then drop `ra` (which
                    // cancels and joins the thread)
                    if let Some(r) = &ra {
                        self.counters.readahead_hits += r.hits();
                        self.counters.readahead_misses += r.misses();
                    }
                    return Ok(false);
                }
                let gi = group.rows[slot] as usize;
                if keep(gi, ceiling_value(self.ceil_exp[gi])) {
                    let w = visit(gi, block.label(slot), block.row(slot));
                    self.pending_exp[gi] = exp_ceiling(w);
                    self.last_pass.rows_visited += 1;
                } else {
                    self.counters.rows_skipped += 1;
                    self.last_pass.rows_skipped += 1;
                }
            }
        }

        // ---- consume the prefetched spilled spans ----------------------
        if let Some(mut r) = ra.take() {
            let mut row = vec![0f32; self.f];
            for (g_idx, lo, surv) in &spans {
                if invalidated() {
                    self.counters.readahead_hits += r.hits();
                    self.counters.readahead_misses += r.misses();
                    return Ok(false);
                }
                let buf = match r.next() {
                    Ok(b) => b,
                    Err(e) => {
                        self.counters.readahead_hits += r.hits();
                        self.counters.readahead_misses += r.misses();
                        return Err(e);
                    }
                };
                self.counters.bytes_read += buf.len() as u64;
                self.last_pass.bytes_read += buf.len() as u64;
                let group = &self.groups[*g_idx];
                for &slot in surv {
                    let gi = group.rows[slot as usize] as usize;
                    let label = decode_row_into(&buf, slot as usize - lo, self.f, &mut row);
                    let w = visit(gi, label, &row);
                    self.pending_exp[gi] = exp_ceiling(w);
                    self.counters.rows_read += 1;
                    self.last_pass.rows_read_disk += 1;
                    self.last_pass.rows_visited += 1;
                }
            }
            self.counters.readahead_hits += r.hits();
            self.counters.readahead_misses += r.misses();
        }
        self.last_pass.completed = true;
        Ok(true)
    }

    /// Commit the in-flight build: install exact ceilings for visited
    /// examples, inflate unvisited ones by the drift allowance of `model`
    /// vs the old anchor, re-anchor on `model`, and re-partition when the
    /// layout has drifted past the configured threshold.
    pub fn commit_build(&mut self, model: &StrongRule) -> io::Result<()> {
        assert!(self.building);
        let bump = exp_bump(drift_bound(model, &self.anchor));
        let pending = std::mem::take(&mut self.pending_exp);
        for (e, &p) in self.ceil_exp.iter_mut().zip(&pending) {
            *e = if p == EXP_UNSEEN {
                e.saturating_add(bump)
            } else {
                p
            };
        }
        self.anchor = model.clone();
        self.building = false;

        if self.n > 0 {
            let mut drift = 0usize;
            for group in &self.groups {
                for &gi in &group.rows {
                    if stratum_of_exp(self.ceil_exp[gi as usize]) != group.stratum {
                        drift += 1;
                    }
                }
            }
            if drift as f64 / self.n as f64 > self.cfg.relayout_threshold {
                self.relayout()?;
            }
        }
        Ok(())
    }

    /// Abort the in-flight build: the committed ceilings, anchor, and
    /// layout are untouched.
    pub fn abort_build(&mut self) {
        self.pending_exp = Vec::new();
        self.building = false;
    }

    /// Re-partition every example into its current stratum: one
    /// sequential merge pass over the old groups, writing fresh resident
    /// blocks and spill chunk files per [`TierPlan`].
    fn relayout(&mut self) -> io::Result<()> {
        let record_bytes = self.base.record_bytes();
        let mut counts = [0usize; NUM_STRATA];
        for &e in &self.ceil_exp {
            counts[stratum_of_exp(e) as usize] += 1;
        }
        let budget = self
            .cfg
            .memory_budget
            .saturating_sub(self.probe.n as u64 * record_bytes);
        let plan = TierPlan::plan(&counts, record_bytes, budget);

        enum Dest {
            Mem(DataBlock, Vec<u32>),
            File(ChunkWriter, Vec<u32>, PathBuf),
        }
        let mut dest_of = [usize::MAX; NUM_STRATA];
        let mut dests: Vec<(u8, Dest)> = Vec::with_capacity(plan.order.len());
        for (i, (&stratum, &resident)) in plan.order.iter().zip(&plan.resident).enumerate() {
            dest_of[stratum as usize] = i;
            let d = if resident {
                Dest::Mem(DataBlock::empty(self.f), Vec::new())
            } else {
                let path = self
                    .workdir
                    .join(format!("s{stratum:02}_g{}.spch", self.layout_gen + 1));
                Dest::File(ChunkWriter::create(&path, self.f as u32)?, Vec::new(), path)
            };
            dests.push((stratum, d));
        }

        // sequential merge in ascending global order: each old group's
        // rows are ascending and the groups partition 0..n, so exactly
        // one cursor matches each gi
        let mut cursors = vec![0usize; self.groups.len()];
        let mut readers: Vec<Option<SeqReader>> = Vec::with_capacity(self.groups.len());
        for g in &self.groups {
            readers.push(match &g.data {
                GroupData::File(src) => Some(SeqReader::new(src.clone(), self.cfg.chunk_rows.max(1))?),
                GroupData::Mem(_) => None,
            });
        }
        let mut row = vec![0f32; self.f];
        for gi in 0..self.n as u32 {
            let mut src_g = usize::MAX;
            for (k, grp) in self.groups.iter().enumerate() {
                let c = cursors[k];
                if c < grp.rows.len() && grp.rows[c] == gi {
                    src_g = k;
                    break;
                }
            }
            debug_assert_ne!(src_g, usize::MAX, "groups must partition 0..n");
            let slot = cursors[src_g];
            cursors[src_g] += 1;
            let label = match &self.groups[src_g].data {
                GroupData::Mem(b) => {
                    row.copy_from_slice(b.row(slot));
                    b.label(slot)
                }
                GroupData::File(_) => {
                    readers[src_g].as_mut().unwrap().row(slot, &mut row)?
                }
            };
            let di = dest_of[stratum_of_exp(self.ceil_exp[gi as usize]) as usize];
            match &mut dests[di].1 {
                Dest::Mem(block, rows) => {
                    block.push(&row, label);
                    rows.push(gi);
                }
                Dest::File(w, rows, _) => {
                    w.write_row(label, &row)?;
                    rows.push(gi);
                    self.counters.spilled_rows += 1;
                    self.counters.spill_bytes += record_bytes;
                }
            }
        }
        drop(readers);

        // install the new layout (resident groups first, each half
        // heaviest-first), then drop the old generation's spill files
        let old_paths: Vec<PathBuf> = self
            .groups
            .iter()
            .filter_map(|g| match &g.data {
                GroupData::File(src) if src.path().starts_with(&self.workdir) => {
                    Some(src.path().to_path_buf())
                }
                _ => None,
            })
            .collect();
        let mut resident_groups = Vec::new();
        let mut spilled_groups = Vec::new();
        self.resident_rows = 0;
        for (stratum, dest) in dests {
            match dest {
                Dest::Mem(block, rows) => {
                    self.resident_rows += rows.len();
                    resident_groups.push(Group {
                        stratum,
                        rows,
                        data: GroupData::Mem(block),
                    });
                }
                Dest::File(w, rows, path) => {
                    w.finish()?;
                    spilled_groups.push(Group {
                        stratum,
                        rows,
                        data: GroupData::File(ChunkSource::open_spill(&path)?),
                    });
                }
            }
        }
        resident_groups.extend(spilled_groups);
        self.groups = resident_groups;
        self.layout_gen += 1;
        self.counters.relayouts += 1;
        for p in old_paths {
            let _ = std::fs::remove_file(p);
        }
        Ok(())
    }
}

impl Drop for TieredStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.workdir);
    }
}

/// Ascending sequential slot reader over one chunk source (re-partition
/// merge cursor): buffers `chunk_rows` records at a time.
struct SeqReader {
    src: ChunkSource,
    file: std::fs::File,
    buf: Vec<u8>,
    buf_start: usize,
    buf_rows: usize,
    chunk_rows: usize,
}

impl SeqReader {
    fn new(src: ChunkSource, chunk_rows: usize) -> io::Result<SeqReader> {
        let file = src.open_file()?;
        Ok(SeqReader {
            src,
            file,
            buf: Vec::new(),
            buf_start: 0,
            buf_rows: 0,
            chunk_rows,
        })
    }

    fn row(&mut self, slot: usize, row: &mut [f32]) -> io::Result<f32> {
        if slot < self.buf_start || slot >= self.buf_start + self.buf_rows {
            let count = self.chunk_rows.min(self.src.n - slot);
            self.buf = self.src.read_span(&mut self.file, slot, count)?;
            self.buf_start = slot;
            self.buf_rows = count;
        }
        Ok(decode_row_into(
            &self.buf,
            slot - self.buf_start,
            self.src.f,
            row,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DiskStore;

    fn store_path(name: &str, n: usize, f: usize) -> PathBuf {
        let dir = std::env::temp_dir().join("sparrow_tiered_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut b = DataBlock::empty(f);
        for i in 0..n {
            let row: Vec<f32> = (0..f).map(|j| (i * f + j) as f32).collect();
            b.push(&row, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        DiskStore::write(&path, &b).unwrap();
        path
    }

    fn tiny_cfg(budget: u64) -> TieredConfig {
        TieredConfig {
            memory_budget: budget,
            chunk_rows: 16,
            probe_rows: 8,
            readahead_depth: 2,
            relayout_threshold: 0.25,
        }
    }

    /// Full pass keeping everything, weights from `wf`.
    fn full_pass(s: &mut TieredStore, wf: impl Fn(usize) -> f64) -> Vec<(usize, f32, Vec<f32>)> {
        let mut seen = Vec::new();
        s.begin_build();
        let ok = s
            .build_pass(
                &mut |_, _| true,
                &mut |gi, label, row| {
                    seen.push((gi, label, row.to_vec()));
                    wf(gi)
                },
                &mut || false,
            )
            .unwrap();
        assert!(ok);
        seen
    }

    #[test]
    fn open_copies_nothing_and_serves_every_row() {
        let path = store_path("serve.sprw", 100, 3);
        // budget far below the data: single spilled group backed by base
        let mut s = TieredStore::open(&path, tiny_cfg(64)).unwrap();
        assert_eq!(s.len(), 100);
        assert_eq!(s.resident_fraction(), 0.0);
        let mut seen = full_pass(&mut s, |_| 1.0);
        s.commit_build(&StrongRule::new()).unwrap();
        seen.sort_by_key(|e| e.0);
        assert_eq!(seen.len(), 100);
        for (gi, label, row) in seen {
            assert_eq!(label, if gi % 2 == 0 { 1.0 } else { -1.0 });
            assert_eq!(row[0], (gi * 3) as f32);
        }
    }

    #[test]
    fn small_store_goes_fully_resident() {
        let path = store_path("resident.sprw", 50, 2);
        let mut s = TieredStore::open(&path, tiny_cfg(1 << 20)).unwrap();
        assert_eq!(s.resident_fraction(), 1.0);
        let seen = full_pass(&mut s, |_| 1.0);
        s.commit_build(&StrongRule::new()).unwrap();
        assert_eq!(seen.len(), 50);
    }

    #[test]
    fn certified_skips_never_visit() {
        let path = store_path("skip.sprw", 80, 2);
        let mut s = TieredStore::open(&path, tiny_cfg(64)).unwrap();
        // first build: everything weight 1 except evens at 4.0
        full_pass(&mut s, |gi| if gi % 2 == 0 { 4.0 } else { 1.0 });
        s.commit_build(&StrongRule::new()).unwrap();
        // second build: skip everything with ceiling ≤ 2 (the odds)
        let mut visited = Vec::new();
        s.begin_build();
        let ok = s
            .build_pass(
                &mut |_, ceiling| ceiling > 2.0,
                &mut |gi, _, _| {
                    visited.push(gi);
                    1.0
                },
                &mut || false,
            )
            .unwrap();
        assert!(ok);
        s.commit_build(&StrongRule::new()).unwrap();
        visited.sort();
        let evens: Vec<usize> = (0..80).filter(|g| g % 2 == 0).collect();
        assert_eq!(visited, evens);
        assert!(s.last_pass().rows_skipped >= 40);
        assert!(s.counters().rows_skipped >= 40);
    }

    #[test]
    fn commit_installs_ceilings_and_bumps_unseen() {
        let path = store_path("ceil.sprw", 40, 2);
        let mut s = TieredStore::open(&path, tiny_cfg(64)).unwrap();
        assert_eq!(s.ceiling(0), 1.0); // weight 1 under the empty anchor
        full_pass(&mut s, |gi| if gi < 10 { 8.0 } else { 0.25 });
        s.commit_build(&StrongRule::new()).unwrap();
        assert!(s.ceiling(3) >= 8.0);
        assert!(s.ceiling(20) >= 0.25 && s.ceiling(20) <= 1.0);
        // next build skips everything → ceilings grow, never shrink
        let before = s.ceiling(20);
        s.begin_build();
        let ok = s
            .build_pass(&mut |_, _| false, &mut |_, _, _| 1.0, &mut || false)
            .unwrap();
        assert!(ok);
        s.commit_build(&StrongRule::new()).unwrap();
        assert!(s.ceiling(20) >= before);
    }

    #[test]
    fn abort_leaves_no_trace() {
        let path = store_path("abort.sprw", 60, 2);
        let mut s = TieredStore::open(&path, tiny_cfg(64)).unwrap();
        full_pass(&mut s, |_| 1.0);
        s.commit_build(&StrongRule::new()).unwrap();
        let before: Vec<f64> = (0..60).map(|i| s.ceiling(i)).collect();
        // aborted pass observes wild weights — none may stick
        s.begin_build();
        let mut polls = 0;
        let ok = s
            .build_pass(
                &mut |_, _| true,
                &mut |_, _, _| 1e9,
                &mut || {
                    polls += 1;
                    polls > 1
                },
            )
            .unwrap();
        assert!(!ok);
        s.abort_build();
        let after: Vec<f64> = (0..60).map(|i| s.ceiling(i)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn relayout_spills_by_stratum_and_preserves_rows() {
        let path = store_path("relayout.sprw", 90, 2);
        // budget fits ~30 rows (record = 12 bytes) after the probe pin
        let mut s = TieredStore::open(
            &path,
            TieredConfig {
                memory_budget: 12 * 30,
                chunk_rows: 8,
                probe_rows: 0,
                readahead_depth: 2,
                relayout_threshold: 0.25,
            },
        )
        .unwrap();
        // 20 heavy, 70 light → drift from the single initial stratum
        full_pass(&mut s, |gi| if gi < 20 { 64.0 } else { 0.01 });
        s.commit_build(&StrongRule::new()).unwrap();
        let c = s.counters();
        assert_eq!(c.relayouts, 1);
        assert!(c.spilled_rows >= 70, "light tail spilled: {c:?}");
        assert!(s.resident_fraction() > 0.0, "heavy stratum resident");
        // every row still served exactly once, bytes intact
        let mut seen = full_pass(&mut s, |gi| if gi < 20 { 64.0 } else { 0.01 });
        s.commit_build(&StrongRule::new()).unwrap();
        seen.sort_by_key(|e| e.0);
        assert_eq!(seen.len(), 90);
        for (gi, _, row) in seen {
            assert_eq!(row[1], (gi * 2 + 1) as f32);
        }
    }

    #[test]
    fn probe_block_matches_store_prefix() {
        let path = store_path("probe.sprw", 30, 2);
        let s = TieredStore::open(&path, tiny_cfg(64)).unwrap();
        let direct = Reader::open(&path).unwrap().read_block(12, false).unwrap();
        // pinned path (probe_rows = 8) and base-file fallback must agree
        assert_eq!(s.probe_block(5).unwrap(), {
            let mut b = DataBlock::empty(2);
            for i in 0..5 {
                b.push(direct.row(i), direct.label(i));
            }
            b
        });
        assert_eq!(s.probe_block(12).unwrap(), direct);
    }

    #[test]
    fn workdir_removed_on_drop() {
        let path = store_path("cleanup.sprw", 40, 2);
        let wd;
        {
            let mut s = TieredStore::open(&path, tiny_cfg(64)).unwrap();
            wd = s.workdir.clone();
            full_pass(&mut s, |gi| if gi < 20 { 64.0 } else { 0.01 });
            s.commit_build(&StrongRule::new()).unwrap();
            assert!(wd.exists());
        }
        assert!(!wd.exists(), "spill workdir must be cleaned up");
    }

    #[test]
    fn empty_store_builds_trivially() {
        let dir = std::env::temp_dir().join("sparrow_tiered_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.sprw");
        DiskStore::write(&path, &DataBlock::empty(4)).unwrap();
        let mut s = TieredStore::open(&path, tiny_cfg(64)).unwrap();
        assert!(s.is_empty());
        let seen = full_pass(&mut s, |_| 1.0);
        s.commit_build(&StrongRule::new()).unwrap();
        assert!(seen.is_empty());
    }
}
