//! Out-of-core tiered data plane: train on stores far bigger than RAM
//! (DESIGN.md §11).
//!
//! [`crate::data::StratifiedStore`] keeps the whole store's bytes behind a
//! single sequential cursor and *models* the off-memory tier with a
//! token-bucket throttle ([`crate::data::IoThrottle`]) — reads are
//! re-priced, never avoided. This module replaces that simulation with a
//! real tiered layout:
//!
//! * **Tier layout** ([`layout`]): examples are partitioned by weight
//!   stratum (the same `⌊log₂ w⌋` buckets as
//!   [`crate::data::strata::bucket_of`]). The heaviest strata — the
//!   mostly-*accepted* examples — stay memory-resident inside a byte
//!   budget; the light, mostly-rejected tail spills to per-stratum chunk
//!   files ([`chunkfmt`]).
//! * **Exactness-preserving draw** ([`draw`]): the background build's
//!   acceptance coin for example `i` is a pure function of
//!   `(seed, version, attempt, i)` and rejection is monotone in the
//!   example's fresh weight, so a *certified per-example weight ceiling*
//!   lets the store prove "this example will be rejected" **before
//!   reading it**. Per-stratum acceptance survivors are computed up
//!   front; certainly-rejected examples are never read at all — not just
//!   re-priced.
//! * **Readahead** ([`readahead`]): a per-build prefetch thread walks the
//!   survivor chunk schedule ahead of the builder, so the builder consumes
//!   warm buffers while the next chunk is in flight, and aborts with the
//!   same epoch-invalidation discipline as the builder itself.
//!
//! The store tracks [`TieredCounters`] (spills, readahead hits/misses,
//! rows read/skipped); the worker surfaces them through the admin
//! `metrics.snapshot` events (`spill`, `readahead_hit`, `readahead_miss`).
//!
//! The sampler-side pass that drives all of this — and the proof that its
//! output is byte-identical to the in-memory path — lives in
//! [`crate::sampler::build_tiered`].

#![warn(missing_docs)]

pub mod chunkfmt;
pub mod draw;
pub mod layout;
pub mod readahead;
mod store;

pub use store::{PassStats, TieredStore};

/// Configuration for the tiered store.
#[derive(Debug, Clone, Copy)]
pub struct TieredConfig {
    /// Byte budget for memory-resident data (resident strata plus the
    /// pinned probe prefix). The index (a few bytes per example) is not
    /// charged against it.
    pub memory_budget: u64,
    /// Rows per readahead chunk: the granularity of prefetch requests and
    /// of invalidation polling inside a build pass.
    pub chunk_rows: usize,
    /// Rows of the store prefix pinned in memory for the sampler's
    /// deterministic probe (scale calibration). Must cover the sampler's
    /// `probe` setting or probe reads fall back to the base file.
    pub probe_rows: usize,
    /// Chunks the readahead thread may buffer ahead of the builder.
    pub readahead_depth: usize,
    /// Fraction of examples whose stratum may disagree with the layout
    /// before a commit triggers a full re-partition (spill rewrite).
    pub relayout_threshold: f64,
}

impl Default for TieredConfig {
    fn default() -> Self {
        TieredConfig {
            memory_budget: 64 << 20,
            chunk_rows: 1024,
            probe_rows: 4096,
            readahead_depth: 4,
            relayout_threshold: 0.25,
        }
    }
}

/// Monotone activity counters for one [`TieredStore`].
///
/// Deltas between builds feed the `spill` / `readahead_hit` /
/// `readahead_miss` events the worker records (OPERATIONS.md §6).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TieredCounters {
    /// examples written to spill chunk files (re-partitions)
    pub spilled_rows: u64,
    /// bytes written to spill chunk files
    pub spill_bytes: u64,
    /// full re-partitions performed
    pub relayouts: u64,
    /// prefetched chunks that were already buffered when the builder
    /// asked for them
    pub readahead_hits: u64,
    /// chunks the builder had to wait for
    pub readahead_misses: u64,
    /// examples served from disk chunks
    pub rows_read: u64,
    /// examples skipped without any read (certified rejected)
    pub rows_skipped: u64,
    /// bytes read from spill/base chunks during build passes
    pub bytes_read: u64,
}
