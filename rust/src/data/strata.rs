//! Stratified, weight-indexed view of a [`DiskStore`](crate::data::DiskStore)
//! — the read layer behind the background sampler (DESIGN.md §4).
//!
//! The Sampler keeps examples with probability proportional to their
//! boosting weight, so on a trained model most of the records it streams
//! from disk are read only to be *rejected*. This module maintains a
//! per-example **weight stratum** index (buckets keyed on `⌊log₂ w⌋`) from
//! the weights computed during the previous committed build, and marks the
//! heaviest strata — the mostly-*accepted* examples — as **resident**: their
//! bytes are served from memory (the OS page cache keeps them hot) and are
//! therefore not charged against the off-memory tier's I/O throttle. A
//! resample on a skewed weight distribution then pays disk bandwidth only
//! for the light, mostly-rejected tail it still has to visit.
//!
//! Two invariants keep the index honest under the concurrent pipeline:
//!
//! 1. **Contents never depend on the index.** The index influences *cost*
//!    (which bytes are charged) but never *which examples are kept* — the
//!    build pass visits every record and decides acceptance from
//!    per-example seeded coins (see `sampler::background`). A stale or
//!    empty index degrades performance, never correctness.
//! 2. **Only committed builds mutate the index.** Weights observed by an
//!    in-flight build are buffered and applied by [`StratifiedStore::commit_build`];
//!    an invalidated build calls [`StratifiedStore::abort_build`] and leaves
//!    no trace. Thread interleaving can change how *fast* later builds run,
//!    but (per invariant 1) not what they produce.

#![warn(missing_docs)]

use std::io;
use std::path::Path;
use std::time::Duration;

use crate::data::binfmt::Reader;
use crate::data::{DataBlock, IoThrottle};

/// Number of weight strata: buckets cover `w ∈ [2^-16, 2^16)` in powers of
/// two, with underflow/overflow clamped into the end buckets.
pub const NUM_STRATA: usize = 32;

/// Stratum id for weight `w`: `clamp(⌊log₂ w⌋ + NUM_STRATA/2, 0, NUM_STRATA-1)`.
/// Weight 1 (a freshly sampled example) lands in bucket `NUM_STRATA/2`;
/// each step up doubles the weight ceiling.
///
/// Total over every `f64`: NaN and zero/negative weights clamp into the
/// lightest bucket (via the `1e-300` floor), `+∞` into the heaviest. The
/// `min(f64::MAX)` is load-bearing: `(+∞).log2().floor() as i64`
/// saturates to `i64::MAX`, and the `+ NUM_STRATA/2` after it would
/// overflow (a panic in debug builds) without the clamp.
pub fn bucket_of(w: f64) -> u8 {
    let k = w.max(1e-300).min(f64::MAX).log2().floor() as i64 + (NUM_STRATA as i64) / 2;
    k.clamp(0, NUM_STRATA as i64 - 1) as u8
}

/// Configuration for the stratified read layer.
#[derive(Debug, Clone, Copy)]
pub struct StrataConfig {
    /// Residency budget in examples: the heaviest strata are marked
    /// memory-resident up to this many rows. `0` disables residency (every
    /// read is charged to the throttle, as with a plain stream).
    pub resident_rows: usize,
}

impl Default for StrataConfig {
    fn default() -> Self {
        StrataConfig {
            resident_rows: 16_384,
        }
    }
}

/// A [`DiskStore`](crate::data::DiskStore) opened for stratified sequential
/// builds: a cursor for full-store passes plus the committed weight-bucket
/// index and residency set described in the module docs.
pub struct StratifiedStore {
    reader: Reader,
    throttle: IoThrottle,
    cfg: StrataConfig,
    n: usize,
    record_bytes: u64,
    /// committed stratum per example (from the last committed build)
    bucket: Vec<u8>,
    /// committed residency flags (heaviest strata within the budget)
    resident: Vec<bool>,
    resident_count: usize,
    /// total bytes actually charged to the throttle (diagnostics)
    charged_bytes: u64,
    /// in-flight build buffer (applied on commit, dropped on abort)
    pending_bucket: Vec<u8>,
    building: bool,
    cursor: usize,
}

impl StratifiedStore {
    /// Open the store file at `path` with the given throttle (the
    /// off-memory tier model; use [`IoThrottle::unlimited`] for the
    /// in-memory tier, where residency is a no-op by construction).
    ///
    /// The index starts empty-handed: every example in the stratum of
    /// weight 1 (the empty model scores everything 0) and nothing resident.
    pub fn open(
        path: &Path,
        throttle: IoThrottle,
        cfg: StrataConfig,
    ) -> io::Result<StratifiedStore> {
        let reader = Reader::open(path)?;
        let n = reader.header.n as usize;
        let record_bytes = reader.header.record_bytes();
        Ok(StratifiedStore {
            reader,
            throttle,
            cfg,
            n,
            record_bytes,
            bucket: vec![bucket_of(1.0); n],
            resident: vec![false; n],
            resident_count: 0,
            charged_bytes: 0,
            pending_bucket: Vec::new(),
            building: false,
            cursor: 0,
        })
    }

    /// Number of examples in the store.
    pub fn len(&self) -> usize {
        self.n
    }

    /// `true` when the store holds no examples.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of features per example.
    pub fn num_features(&self) -> usize {
        self.reader.header.f as usize
    }

    /// Begin a build pass: rewind the cursor to record 0 and open the
    /// in-flight bucket buffer (pre-filled with the committed assignments,
    /// so records a build never reaches keep their old stratum).
    pub fn begin_build(&mut self) -> io::Result<()> {
        self.reader.rewind()?;
        self.cursor = 0;
        self.pending_bucket = self.bucket.clone();
        self.building = true;
        Ok(())
    }

    /// Read the next sequential block of up to `max_n` records (no wrap —
    /// a build pass visits each record exactly once). Returns the global
    /// index of the block's first record and the block itself.
    ///
    /// Only bytes of non-resident records are charged to the throttle:
    /// resident rows model data the previous build left hot in memory.
    pub fn next_block(&mut self, max_n: usize) -> io::Result<(usize, DataBlock)> {
        assert!(self.building, "next_block outside begin_build/commit");
        let start = self.cursor;
        let block = self.reader.read_block(max_n, false)?;
        self.cursor += block.n;
        let cold = (start..start + block.n)
            .filter(|&i| !self.resident[i])
            .count() as u64;
        let bytes = cold * self.record_bytes;
        self.charged_bytes += bytes;
        self.throttle.consume(bytes);
        Ok((start, block))
    }

    /// Record the freshly computed weight of example `i` for the in-flight
    /// build. Buffered: visible in the index only after
    /// [`StratifiedStore::commit_build`].
    #[inline]
    pub fn note_weight(&mut self, i: usize, w: f64) {
        debug_assert!(self.building);
        self.pending_bucket[i] = bucket_of(w);
    }

    /// Commit the in-flight build: install the buffered bucket assignments
    /// and recompute residency — strata from heaviest to lightest are
    /// marked resident until the `resident_rows` budget is exhausted
    /// (the boundary stratum is taken partially, in index order).
    pub fn commit_build(&mut self) {
        assert!(self.building);
        std::mem::swap(&mut self.bucket, &mut self.pending_bucket);
        self.pending_bucket = Vec::new();
        self.building = false;
        self.rebuild_residency();
    }

    /// Abort the in-flight build, discarding its buffered observations.
    /// The committed index is untouched, so an invalidated build leaves
    /// future builds exactly as it found them.
    pub fn abort_build(&mut self) {
        self.pending_bucket = Vec::new();
        self.building = false;
    }

    fn rebuild_residency(&mut self) {
        let budget = self.cfg.resident_rows;
        self.resident.iter_mut().for_each(|r| *r = false);
        self.resident_count = 0;
        if budget == 0 || self.throttle.is_unlimited() {
            return;
        }
        let mut counts = [0usize; NUM_STRATA];
        for &b in &self.bucket {
            counts[b as usize] += 1;
        }
        // heaviest strata first; stop at the first stratum that would
        // overflow the budget and fill the remainder from it in index order
        let mut remaining = budget;
        let mut full = [false; NUM_STRATA];
        let mut partial: Option<u8> = None;
        for k in (0..NUM_STRATA).rev() {
            if counts[k] == 0 {
                continue;
            }
            if counts[k] <= remaining {
                full[k] = true;
                remaining -= counts[k];
            } else {
                partial = Some(k as u8);
                break;
            }
        }
        for (i, &b) in self.bucket.iter().enumerate() {
            if full[b as usize] || (partial == Some(b) && remaining > 0) {
                if partial == Some(b) && !full[b as usize] {
                    remaining -= 1;
                }
                self.resident[i] = true;
                self.resident_count += 1;
            }
        }
    }

    /// Fraction of the store currently resident (0 when residency is off).
    pub fn resident_fraction(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.resident_count as f64 / self.n as f64
    }

    /// Total bytes charged to the throttle over the store's lifetime.
    pub fn charged_bytes(&self) -> u64 {
        self.charged_bytes
    }

    /// Total time the throttle spent stalled (off-memory tier sleeps).
    pub fn stalled(&self) -> Duration {
        self.throttle.stalled
    }

    /// Committed stratum of example `i` (diagnostics / tests).
    pub fn bucket(&self, i: usize) -> u8 {
        self.bucket[i]
    }

    /// Is example `i` currently resident? (diagnostics / tests)
    pub fn is_resident(&self, i: usize) -> bool {
        self.resident[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DataBlock, DiskStore};

    fn store_path(name: &str, n: usize, f: usize) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparrow_strata_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut b = DataBlock::empty(f);
        for i in 0..n {
            let row: Vec<f32> = (0..f).map(|j| (i * f + j) as f32).collect();
            b.push(&row, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        DiskStore::write(&path, &b).unwrap();
        path
    }

    fn full_pass(s: &mut StratifiedStore, weight: impl Fn(usize) -> f64) {
        s.begin_build().unwrap();
        let mut read = 0;
        while read < s.len() {
            let (start, block) = s.next_block(64).unwrap();
            for k in 0..block.n {
                s.note_weight(start + k, weight(start + k));
            }
            read += block.n;
        }
    }

    #[test]
    fn bucket_math() {
        assert_eq!(bucket_of(1.0) as usize, NUM_STRATA / 2);
        assert_eq!(bucket_of(2.0) as usize, NUM_STRATA / 2 + 1);
        assert_eq!(bucket_of(0.5) as usize, NUM_STRATA / 2 - 1);
        assert_eq!(bucket_of(3.9) as usize, NUM_STRATA / 2 + 1);
        assert_eq!(bucket_of(0.0), 0); // clamped underflow
        assert_eq!(bucket_of(1e30) as usize, NUM_STRATA - 1); // clamped overflow
    }

    #[test]
    fn bucket_of_is_total_over_degenerate_weights() {
        // every representable f64 must map to a valid stratum without
        // panicking — exp() of an extreme score yields ±∞-adjacent
        // weights, and defensive callers may pass NaN or negatives
        let cases = [
            (f64::INFINITY, (NUM_STRATA - 1) as u8), // was an i64 overflow panic
            (f64::MAX, (NUM_STRATA - 1) as u8),
            (f64::NAN, 0),           // NaN.max(1e-300) = 1e-300 → lightest
            (0.0, 0),
            (-0.0, 0),
            (-1.0, 0),
            (f64::NEG_INFINITY, 0),
            (f64::MIN_POSITIVE, 0),  // smallest normal
            (f64::from_bits(1), 0),  // smallest subnormal
            (1e-300, 0),
        ];
        for (w, want) in cases {
            let got = bucket_of(w);
            assert_eq!(got, want, "bucket_of({w:e})");
            assert!((got as usize) < NUM_STRATA);
        }
        // exhaustive sweep over the exponent range, both signs
        for e in -1080..1080 {
            for sign in [1.0, -1.0] {
                let w = sign * 2f64.powi(e.clamp(-1074, 1023));
                assert!((bucket_of(w) as usize) < NUM_STRATA, "w = {w:e}");
            }
        }
    }

    #[test]
    fn note_weight_accepts_degenerate_weights() {
        // the build path must survive whatever exp() produced
        let path = store_path("degenerate.sprw", 8, 2);
        let mut s = StratifiedStore::open(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 4 },
        )
        .unwrap();
        let weird = [
            f64::INFINITY,
            f64::NAN,
            0.0,
            f64::from_bits(1),
            f64::NEG_INFINITY,
            f64::MAX,
            f64::MIN_POSITIVE,
            1.0,
        ];
        full_pass(&mut s, |i| weird[i]);
        s.commit_build();
        // the index committed and every example landed in a real stratum
        for i in 0..8 {
            assert!((s.bucket(i) as usize) < NUM_STRATA);
        }
        assert_eq!(s.bucket(0) as usize, NUM_STRATA - 1); // ∞ → heaviest
        assert_eq!(s.bucket(4), 0); // −∞ → lightest
    }

    #[test]
    fn sequential_blocks_cover_store_once() {
        let path = store_path("cover.sprw", 100, 3);
        let mut s = StratifiedStore::open(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 0 },
        )
        .unwrap();
        s.begin_build().unwrap();
        let mut seen = 0;
        loop {
            let (start, block) = s.next_block(33).unwrap();
            if block.is_empty() {
                break;
            }
            assert_eq!(start, seen);
            seen += block.n;
        }
        assert_eq!(seen, 100); // exactly one pass, no wrap
        s.commit_build();
    }

    #[test]
    fn commit_installs_buckets_abort_discards() {
        let path = store_path("commit.sprw", 50, 2);
        let mut s = StratifiedStore::open(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 0 },
        )
        .unwrap();
        assert_eq!(s.bucket(7) as usize, NUM_STRATA / 2); // initial: weight 1
        full_pass(&mut s, |i| if i < 10 { 8.0 } else { 0.25 });
        s.commit_build();
        assert_eq!(s.bucket(7), bucket_of(8.0));
        assert_eq!(s.bucket(20), bucket_of(0.25));

        // aborted build leaves the committed index untouched
        full_pass(&mut s, |_| 1024.0);
        s.abort_build();
        assert_eq!(s.bucket(7), bucket_of(8.0));
        assert_eq!(s.bucket(20), bucket_of(0.25));
    }

    #[test]
    fn residency_prefers_heavy_strata_within_budget() {
        let path = store_path("resident.sprw", 100, 2);
        // finite throttle so residency is active; generous rate, small reads
        let mut s = StratifiedStore::open(
            &path,
            IoThrottle::new(1e12),
            StrataConfig { resident_rows: 30 },
        )
        .unwrap();
        // 20 heavy, 30 medium, 50 light
        full_pass(&mut s, |i| {
            if i < 20 {
                64.0
            } else if i < 50 {
                2.0
            } else {
                0.01
            }
        });
        s.commit_build();
        // all 20 heavy resident; 10 of the medium stratum (budget partial)
        assert!((0..20).all(|i| s.is_resident(i)));
        let medium_resident = (20..50).filter(|&i| s.is_resident(i)).count();
        assert_eq!(medium_resident, 10);
        assert!((50..100).all(|i| !s.is_resident(i)));
        assert!((s.resident_fraction() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn resident_bytes_not_charged() {
        let path = store_path("charge.sprw", 100, 2);
        let record = 4 * (1 + 2) as u64;
        let mut s = StratifiedStore::open(
            &path,
            IoThrottle::new(1e12),
            StrataConfig { resident_rows: 40 },
        )
        .unwrap();
        // first pass: nothing resident yet → every byte charged
        full_pass(&mut s, |i| if i < 40 { 16.0 } else { 0.1 });
        s.commit_build();
        assert_eq!(s.charged_bytes(), 100 * record);
        // second pass: the 40 heavy rows are resident → only 60 charged
        full_pass(&mut s, |i| if i < 40 { 16.0 } else { 0.1 });
        s.commit_build();
        assert_eq!(s.charged_bytes(), 100 * record + 60 * record);
    }

    #[test]
    fn unlimited_throttle_disables_residency() {
        let path = store_path("unlim.sprw", 40, 2);
        let mut s = StratifiedStore::open(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 1000 },
        )
        .unwrap();
        full_pass(&mut s, |_| 8.0);
        s.commit_build();
        assert_eq!(s.resident_fraction(), 0.0);
    }
}
