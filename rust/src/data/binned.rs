//! Quantized, column-major stripe views for the binned scan engine
//! (DESIGN.md §8).
//!
//! The row engine answers "how many thresholds lie strictly below `x`?"
//! with a per-example linear search over each feature's ascending
//! threshold row — `O(NT)` data-dependent branches per (example, feature)
//! on the hot path. The binned engine answers it **once per sample**: at
//! sample-install time every stripe feature is quantized into a `u8` bin
//! index (the threshold-interval index), and the scan's inner loop becomes
//! a branch-free bucket accumulation `hist[bin[i]] += u[i]`.
//!
//! # Exactness
//!
//! `bin(x)` is defined as `|{t : x > thr[t]}|` — computed by the *same*
//! ascending-row count the row engine runs per example. Therefore
//! `x > thr[t] ⟺ bin(x) > t` holds **exactly** for every value, including
//! values equal to a threshold (bin counts strict exceedances only),
//! duplicated thresholds, and ±∞ (`+∞ → nthr`, `−∞ → 0`). Binning is a
//! lossless reindexing of the stump predicate, not an approximation; see
//! `boosting::edges` for how buckets fold back into edges.
//!
//! # Layout
//!
//! Bins are stored **column-major** — one contiguous `Vec<u8>` region per
//! stripe feature — so the accumulation loop streams each column
//! sequentially (and a batch gather is a per-column `u8` copy, ~4× lighter
//! than the `f32` row copy the scorer already pays).

use crate::data::DataBlock;

/// How to quantize one feature stripe: a copy of the worker's candidate
/// threshold rows restricted to the stripe, in stripe-local order.
///
/// Built from the worker's grid via `CandidateGrid::bin_spec` (the data
/// layer does not depend on `boosting`, so the rows are copied in).
#[derive(Debug, Clone, PartialEq)]
pub struct BinSpec {
    /// global feature range `[start, end)` this spec covers
    pub stripe: (usize, usize),
    /// thresholds per feature
    pub nthr: usize,
    /// `(width × nthr)` row-major, each row ascending — identical values to
    /// the grid rows the row engine compares against
    pub thresholds: Vec<f32>,
}

impl BinSpec {
    /// A spec over `stripe` with `nthr` thresholds per feature.
    ///
    /// Bins take values in `0..=nthr`, so `nthr` must fit alongside the
    /// sentinel-free `u8` range: `nthr <= 255`.
    pub fn new(stripe: (usize, usize), nthr: usize, thresholds: Vec<f32>) -> BinSpec {
        assert!(stripe.0 < stripe.1, "empty stripe {stripe:?}");
        assert!(
            (1..=u8::MAX as usize).contains(&nthr),
            "nthr {nthr} out of the u8 bin range [1, 255]"
        );
        assert_eq!(thresholds.len(), (stripe.1 - stripe.0) * nthr);
        BinSpec {
            stripe,
            nthr,
            thresholds,
        }
    }

    /// Number of features in the stripe.
    pub fn width(&self) -> usize {
        self.stripe.1 - self.stripe.0
    }

    /// FNV-1a fingerprint of the threshold bits — stamped into built
    /// stripes so [`BinnedStripe::matches`] detects a *different grid of
    /// identical shape* (stale bins must never be reused silently).
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &t in &self.thresholds {
            h ^= t.to_bits() as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }

    /// Ascending threshold row of stripe-local feature `c`.
    #[inline]
    pub fn row(&self, c: usize) -> &[f32] {
        &self.thresholds[c * self.nthr..(c + 1) * self.nthr]
    }

    /// Quantize one value of stripe-local feature `c`: the number of
    /// thresholds strictly below `x` — the exact count the row engine
    /// computes per example, so `x > thr[t] ⟺ bin > t`.
    #[inline]
    pub fn bin_value(&self, c: usize, x: f32) -> u8 {
        let thr = self.row(c);
        let mut k = 0usize;
        while k < self.nthr && x > thr[k] {
            k += 1;
        }
        k as u8
    }

    /// Quantize every stripe feature of `block`, column-major.
    pub fn bin_block(&self, block: &DataBlock) -> BinnedStripe {
        assert!(self.stripe.1 <= block.f, "stripe exceeds block width");
        let w = self.width();
        let n = block.n;
        let mut bins = vec![0u8; w * n];
        for i in 0..n {
            let row = block.row(i);
            for c in 0..w {
                bins[c * n + i] = self.bin_value(c, row[self.stripe.0 + c]);
            }
        }
        BinnedStripe {
            stripe: self.stripe,
            nthr: self.nthr,
            grid_fingerprint: self.fingerprint(),
            n,
            bins,
        }
    }
}

/// One sample's quantized feature stripe, column-major: built once per
/// sample (at install time) and reused across every pass and γ-retry over
/// that sample. Weight refreshes and model adoptions never touch it —
/// bins depend only on the features and the (fixed) candidate grid.
#[derive(Debug, Clone, PartialEq)]
pub struct BinnedStripe {
    /// global feature range `[start, end)`
    pub stripe: (usize, usize),
    /// thresholds per feature (bins take values `0..=nthr`)
    pub nthr: usize,
    /// fingerprint of the threshold values the bins were built against
    pub grid_fingerprint: u64,
    /// examples covered
    pub n: usize,
    /// `(width × n)` column-major: `bins[c*n + i]` is example `i`'s bin on
    /// stripe-local feature `c`
    pub bins: Vec<u8>,
}

impl BinnedStripe {
    /// The contiguous bin column of stripe-local feature `c`.
    #[inline]
    pub fn column(&self, c: usize) -> &[u8] {
        &self.bins[c * self.n..(c + 1) * self.n]
    }

    /// Was this stripe built by `spec` over a sample of `n` examples?
    /// Shape AND threshold fingerprint must agree — a different grid of
    /// identical shape forces a rebuild instead of silently reusing bins
    /// quantized against the wrong thresholds.
    pub fn matches(&self, spec: &BinSpec, n: usize) -> bool {
        self.n == n
            && self.stripe == spec.stripe
            && self.nthr == spec.nthr
            && self.grid_fingerprint == spec.fingerprint()
    }
}

/// Column-major bins for ONE scanner batch, gathered from a sample's
/// [`BinnedStripe`] along the batch's (circular) index list. Owned by the
/// scanner's scratch and reused across batches — no per-batch allocation.
#[derive(Debug, Clone, Default)]
pub struct BinnedBatch {
    /// stripe width (features)
    pub width: usize,
    /// batch size (examples)
    pub n: usize,
    /// `(width × n)` column-major
    pub bins: Vec<u8>,
}

impl BinnedBatch {
    /// Refill from `stripe` at the batch indices `idx` (reuses the buffer).
    pub fn gather(&mut self, stripe: &BinnedStripe, idx: &[usize]) {
        self.width = stripe.stripe.1 - stripe.stripe.0;
        self.n = idx.len();
        self.bins.clear();
        self.bins.resize(self.width * self.n, 0);
        for c in 0..self.width {
            let col = stripe.column(c);
            let dst = &mut self.bins[c * self.n..(c + 1) * self.n];
            for (k, &i) in idx.iter().enumerate() {
                dst[k] = col[i];
            }
        }
    }

    /// The contiguous bin column of stripe-local feature `c`.
    #[inline]
    pub fn column(&self, c: usize) -> &[u8] {
        &self.bins[c * self.n..(c + 1) * self.n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, prop_check};
    use crate::util::rng::Rng;

    fn spec_2x3() -> BinSpec {
        // feature 0: thresholds [-1, 0, 1]; feature 1: [0.5, 0.5, 2.0]
        // (duplicated threshold on purpose)
        BinSpec::new((0, 2), 3, vec![-1.0, 0.0, 1.0, 0.5, 0.5, 2.0])
    }

    #[test]
    fn bin_value_is_strict_exceedance_count() {
        let s = spec_2x3();
        assert_eq!(s.bin_value(0, -2.0), 0);
        assert_eq!(s.bin_value(0, -1.0), 0); // equal → not an exceedance
        assert_eq!(s.bin_value(0, -0.5), 1);
        assert_eq!(s.bin_value(0, 0.0), 1);
        assert_eq!(s.bin_value(0, 1.5), 3);
        // duplicated thresholds: crossing the pair jumps by two
        assert_eq!(s.bin_value(1, 0.5), 0);
        assert_eq!(s.bin_value(1, 0.6), 2);
        // infinities land in the extreme bins
        assert_eq!(s.bin_value(0, f32::INFINITY), 3);
        assert_eq!(s.bin_value(0, f32::NEG_INFINITY), 0);
    }

    #[test]
    fn prop_bin_encodes_stump_predicate_exactly() {
        // the exactness claim behind the whole engine:
        // x > thr[t]  ⟺  bin(x) > t, for every (x, t) incl. boundary values
        prop_check("bin ⟺ predicate", 60, |rng| {
            let nthr = gen::size(rng, 1, 8);
            let mut thr: Vec<f32> = (0..nthr).map(|_| rng.gauss() as f32).collect();
            thr.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let spec = BinSpec::new((0, 1), nthr, thr.clone());
            for _ in 0..32 {
                // mix free values with exact threshold hits and infinities
                let x = match rng.below(4) {
                    0 => thr[rng.below(nthr as u64) as usize],
                    1 => {
                        if rng.bernoulli(0.5) {
                            f32::INFINITY
                        } else {
                            f32::NEG_INFINITY
                        }
                    }
                    _ => rng.gauss() as f32,
                };
                let bin = spec.bin_value(0, x);
                for (t, &th) in thr.iter().enumerate() {
                    let pred = x > th;
                    let from_bin = bin as usize > t;
                    if pred != from_bin {
                        return Err(format!(
                            "x={x} thr[{t}]={th}: predicate {pred} vs bin {bin}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bin_block_is_column_major() {
        let s = spec_2x3();
        let block = DataBlock::new(
            3,
            2,
            vec![-2.0, 0.6, 0.5, 0.4, 2.0, 3.0],
            vec![1.0, -1.0, 1.0],
        );
        let bs = s.bin_block(&block);
        assert_eq!(bs.n, 3);
        // feature 0 column: values -2.0, 0.5, 2.0 → bins 0, 1, 3
        assert_eq!(bs.column(0), &[0, 1, 3]);
        // feature 1 column: values 0.6, 0.4, 3.0 → bins 2, 0, 3
        assert_eq!(bs.column(1), &[2, 0, 3]);
    }

    #[test]
    fn matches_checks_shape_identity() {
        let s = spec_2x3();
        let block = DataBlock::new(2, 2, vec![0.0; 4], vec![1.0, -1.0]);
        let bs = s.bin_block(&block);
        assert!(bs.matches(&s, 2));
        assert!(!bs.matches(&s, 3)); // different sample size
        let other = BinSpec::new((0, 2), 2, vec![0.0; 4]);
        assert!(!bs.matches(&other, 2)); // different nthr
        // identical shape, different threshold values → must NOT match
        let same_shape = BinSpec::new((0, 2), 3, vec![-1.0, 0.0, 1.5, 0.5, 0.5, 2.0]);
        assert!(!bs.matches(&same_shape, 2), "stale bins reused across grids");
    }

    #[test]
    fn gather_follows_circular_indices() {
        let s = spec_2x3();
        let block = DataBlock::new(
            4,
            2,
            vec![-2.0, 0.0, 0.5, 0.0, 2.0, 0.0, -0.5, 0.0],
            vec![1.0; 4],
        );
        let bs = s.bin_block(&block);
        let mut b = BinnedBatch::default();
        b.gather(&bs, &[3, 0, 1]); // wrap-around order
        assert_eq!(b.n, 3);
        assert_eq!(b.width, 2);
        // feature 0 values at idx [3,0,1] = [-0.5, -2.0, 0.5] → bins [1,0,1]
        assert_eq!(b.column(0), &[1, 0, 1]);
        // reuse: shrinking gather resizes correctly
        b.gather(&bs, &[2]);
        assert_eq!(b.n, 1);
        assert_eq!(b.column(0), &[3]);
    }

    #[test]
    #[should_panic(expected = "u8 bin range")]
    fn rejects_oversized_nthr() {
        BinSpec::new((0, 1), 256, vec![0.0; 256]);
    }

    fn rng_spec(rng: &mut Rng, width: usize, nthr: usize) -> BinSpec {
        let mut thr = Vec::with_capacity(width * nthr);
        for _ in 0..width {
            let mut row: Vec<f32> = (0..nthr).map(|_| rng.gauss() as f32).collect();
            row.sort_by(|a, b| a.partial_cmp(b).unwrap());
            thr.extend(row);
        }
        BinSpec::new((0, width), nthr, thr)
    }

    #[test]
    fn prop_block_binning_matches_scalar_binning() {
        prop_check("bin_block == bin_value", 20, |rng| {
            let n = gen::size(rng, 1, 40);
            let w = gen::size(rng, 1, 5);
            let nthr = gen::size(rng, 1, 6);
            let spec = rng_spec(rng, w, nthr);
            let block = DataBlock::new(
                n,
                w,
                gen::normal_vec(rng, n * w),
                gen::labels(rng, n, 0.5),
            );
            let bs = spec.bin_block(&block);
            for i in 0..n {
                for c in 0..w {
                    let want = spec.bin_value(c, block.row(i)[c]);
                    if bs.column(c)[i] != want {
                        return Err(format!("({i},{c}): {} vs {want}", bs.column(c)[i]));
                    }
                }
            }
            Ok(())
        });
    }
}
