//! Configuration system: typed configs with file (`key = value` lines) and
//! CLI (`--key value`) overrides.
//!
//! One [`TrainConfig`] drives Sparrow runs; the same knobs parameterize the
//! baselines so Table-1 comparisons share a substrate.

use std::time::Duration;

use crate::network::{BroadcastMode, NetConfig};
use crate::util::cli::Args;

/// Which sequential stopping rule the scanner uses (ablation A1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoppingKind {
    Lil,
    Hoeffding,
    DomingoWatanabe,
    FixedScan,
}

impl StoppingKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "lil" => Ok(StoppingKind::Lil),
            "hoeffding" => Ok(StoppingKind::Hoeffding),
            "dw" | "domingo-watanabe" => Ok(StoppingKind::DomingoWatanabe),
            "fixed" | "fixed-scan" => Ok(StoppingKind::FixedScan),
            _ => Err(format!(
                "unknown stopping rule {s:?} (lil|hoeffding|dw|fixed)"
            )),
        }
    }
}

/// Which selective sampler the Sampler uses (ablation A2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    MinimalVariance,
    Rejection,
    Uniform,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "mvs" | "minimal-variance" => Ok(SamplerKind::MinimalVariance),
            "rejection" => Ok(SamplerKind::Rejection),
            "uniform" => Ok(SamplerKind::Uniform),
            _ => Err(format!("unknown sampler {s:?} (mvs|rejection|uniform)")),
        }
    }
}

/// How the worker drives its Sampler (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerMode {
    /// Paper-faithful: resample on the worker thread; the scanner idles
    /// for the whole pass (the Figure-3/4 plateau).
    Blocking,
    /// Concurrent pipeline: a background thread builds the next sample
    /// against the latest adopted model (stratified store, version-stamped
    /// invalidation) and the scanner flips at a batch boundary with ~zero
    /// stall.
    Background,
}

impl SamplerMode {
    /// Parse a `--sampler-mode` value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "blocking" => Ok(SamplerMode::Blocking),
            "background" | "bg" => Ok(SamplerMode::Background),
            _ => Err(format!(
                "unknown sampler mode {s:?} (blocking|background)"
            )),
        }
    }
}

/// Where the background sampler's weight-indexed store lives (DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreTier {
    /// fully memory-resident stratified store (default)
    Mem,
    /// out-of-core tiered store: heavy strata in memory within
    /// `--memory-budget`, light strata in spill chunk files with
    /// readahead — train on stores much bigger than RAM
    Tiered,
}

impl StoreTier {
    /// Parse a `--store-tier` value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "mem" | "memory" => Ok(StoreTier::Mem),
            "tiered" => Ok(StoreTier::Tiered),
            _ => Err(format!("unknown store tier {s:?} (mem|tiered)")),
        }
    }
}

/// Which CPU scan engine drives the edge accumulation (DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanEngine {
    /// row-major per-example linear threshold search (default; same
    /// numerics as the pre-engine scanner — note the stopping-rule sweep
    /// cadence is amortized for BOTH engines, see `ScannerConfig`)
    Rows,
    /// binned columnar engine: quantized u8 stripe built at sample-install
    /// time, branch-free bucket accumulation, `--scan-threads` sharding
    /// with a thread-count-independent merge order
    Binned,
}

impl ScanEngine {
    /// Parse a `--scan-engine` value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "rows" => Ok(ScanEngine::Rows),
            "binned" => Ok(ScanEngine::Binned),
            _ => Err(format!("unknown scan engine {s:?} (rows|binned)")),
        }
    }
}

/// Whether the binned engine's bucket accumulation runs the lane-widened
/// (SIMD) kernels (DESIGN.md §14). The kernels are bit-identical to the
/// scalar loop by construction, but they only exist in builds with the
/// `simd` cargo feature — see [`simd_compiled`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScanSimd {
    /// best available, silently: avx2 → portable when compiled in,
    /// scalar otherwise (the default — and the default build's off-path
    /// is byte-identical to the pre-SIMD engine)
    Auto,
    /// lane kernels required: a config error when they are compiled out
    /// (never a silent scalar fallback); with the feature compiled in,
    /// always honorable — CPUs without AVX2 run the portable kernel
    On,
    /// scalar loop always, even when the lane kernels are available
    Off,
}

impl ScanSimd {
    /// Parse a `--scan-simd` value.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(ScanSimd::Auto),
            "on" => Ok(ScanSimd::On),
            "off" => Ok(ScanSimd::Off),
            _ => Err(format!("unknown scan-simd mode {s:?} (auto|on|off)")),
        }
    }
}

/// Is this binary built with the `simd` cargo feature (the lane kernels
/// of DESIGN.md §14)? `--scan-simd auto` silently degrades to the scalar
/// loop when false; `--scan-simd on` refuses to.
pub fn simd_compiled() -> bool {
    cfg!(feature = "simd")
}

/// Scanner compute backend (ablation A4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// pure-Rust hot loop
    Native,
    /// AOT scan artifact with the Pallas edge kernel, via PJRT
    XlaPallas,
    /// AOT scan artifact with the pure-jnp edge reduction, via PJRT
    XlaJnp,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" | "xla-pallas" => Ok(Backend::XlaPallas),
            "xla-jnp" => Ok(Backend::XlaJnp),
            _ => Err(format!("unknown backend {s:?} (native|xla-pallas|xla-jnp)")),
        }
    }
}

/// Full training configuration for a Sparrow cluster run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub num_workers: usize,
    /// in-memory sample size per worker (m)
    pub sample_size: usize,
    /// scan batch size (matches the AOT artifact's B when backend = xla)
    pub batch: usize,
    /// candidate thresholds per feature (NT)
    pub nthr: usize,
    /// initial target advantage γ₀ (halved on fruitless passes)
    pub gamma0: f64,
    /// floor for γ — scanning below this gives up the iteration
    pub gamma_min: f64,
    /// resample when n_eff / m drops below this (paper §3)
    pub ess_threshold: f64,
    /// maximum number of weak rules to learn (K)
    pub max_rules: usize,
    /// wall-clock budget for the run
    pub time_limit: Duration,
    /// stop once the *training-sample* loss bound drops below this (0 = off)
    pub target_bound: f64,
    /// stop once measured test exponential loss reaches this (0 = off) —
    /// Table 1's "convergence time to an almost optimal loss"
    pub target_loss: f64,
    pub stopping: StoppingKind,
    /// LIL constant C
    pub stop_c: f64,
    /// total failure budget δ (union-bounded over candidates)
    pub stop_delta: f64,
    pub sampler: SamplerKind,
    /// blocking (paper-faithful) or background (pipelined) sampling
    pub sampler_mode: SamplerMode,
    pub backend: Backend,
    /// rows (default) or binned CPU scan engine (native backend only)
    pub scan_engine: ScanEngine,
    /// worker threads for the binned engine's edge accumulation (results
    /// are identical for every value; 1 = fully inline). Sharding
    /// granularity is fixed 512-example chunks, so threads only engage
    /// when `batch > 512` — pair `--scan-threads N` with `--batch 1024`
    /// or more; at the default batch of 128 the engine's win is the
    /// branch-free single-thread loop, not sharding.
    pub scan_threads: usize,
    /// lane-widened (SIMD) bucket accumulation for the binned engine:
    /// auto (best available, the default), on (required — a config error
    /// when compiled out), off (scalar always). Bit-identical to the
    /// scalar loop in every mode (DESIGN.md §14).
    pub scan_simd: ScanSimd,
    /// disk read bandwidth in bytes/s (0 = unlimited, in-memory tier);
    /// *simulated* — see the quarantine note in `data::throttle`
    pub disk_bandwidth: f64,
    /// where the background sampler's store lives: `mem` (resident) or
    /// `tiered` (out-of-core under `memory_budget`, DESIGN.md §11)
    pub store_tier: StoreTier,
    /// resident-byte budget for `--store-tier tiered` (store rows only;
    /// excludes the sample and scan-side buffers)
    pub memory_budget: u64,
    /// evaluation cadence for the metric series
    pub eval_interval: Duration,
    pub net: NetConfig,
    /// per-worker compute slowdown multipliers (laggard injection)
    pub laggards: Vec<(usize, f64)>,
    /// per-worker crash times (failure injection)
    pub crashes: Vec<(usize, Duration)>,
    pub seed: u64,
    /// directory containing AOT artifacts (xla backends)
    pub artifacts_dir: String,
    /// resume from a checkpoint: every worker starts from this
    /// `(model, certified bound)` instead of the empty model
    pub resume: Option<(crate::model::StrongRule, f64)>,
    /// broadcast dissemination: full (every peer) or gossip fanout
    /// (`k` random peers + TTL-bounded relay, DESIGN.md §12)
    pub broadcast: BroadcastMode,
    /// checkpoint path: the worker atomically rewrites `<path>` +
    /// `<path>.meta` whenever its model version moves, in the same format
    /// `--resume` reads back — a killed worker restarts from its last
    /// committed model instead of scratch
    pub checkpoint: Option<String>,
    /// TCP fabric: idle links heartbeat (`PING`) at this cadence so
    /// half-open peers are detected (DESIGN.md §13)
    pub heartbeat_ms: u64,
    /// TCP fabric: bounded send-queue depth per peer; when full the
    /// oldest frame is dropped (`queue_drop`), which TMSN tolerates
    pub queue_cap: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            num_workers: 1,
            sample_size: 4096,
            batch: 128,
            nthr: 4,
            gamma0: 0.25,
            gamma_min: 0.0005,
            ess_threshold: 0.3,
            max_rules: 128,
            time_limit: Duration::from_secs(60),
            target_bound: 0.0,
            target_loss: 0.0,
            stopping: StoppingKind::Lil,
            stop_c: 0.67,
            stop_delta: 1e-6,
            sampler: SamplerKind::MinimalVariance,
            sampler_mode: SamplerMode::Blocking,
            backend: Backend::Native,
            scan_engine: ScanEngine::Rows,
            scan_threads: 1,
            scan_simd: ScanSimd::Auto,
            disk_bandwidth: 0.0,
            store_tier: StoreTier::Mem,
            memory_budget: 64 << 20,
            eval_interval: Duration::from_millis(250),
            net: NetConfig::default(),
            laggards: Vec::new(),
            crashes: Vec::new(),
            seed: 42,
            artifacts_dir: "artifacts".into(),
            resume: None,
            broadcast: BroadcastMode::Full,
            checkpoint: None,
            heartbeat_ms: 500,
            queue_cap: 1024,
        }
    }
}

impl TrainConfig {
    /// Apply `--key value` CLI overrides (see `sparrow train --help`).
    pub fn apply_args(mut self, args: &Args) -> Result<TrainConfig, String> {
        self.num_workers = args.get_usize("workers", self.num_workers);
        self.sample_size = args.get_usize("sample-size", self.sample_size);
        self.batch = args.get_usize("batch", self.batch);
        self.nthr = args.get_usize("nthr", self.nthr);
        self.gamma0 = args.get_f64("gamma0", self.gamma0);
        self.gamma_min = args.get_f64("gamma-min", self.gamma_min);
        self.ess_threshold = args.get_f64("ess-threshold", self.ess_threshold);
        self.max_rules = args.get_usize("max-rules", self.max_rules);
        self.time_limit = Duration::from_secs_f64(
            args.get_f64("time-limit", self.time_limit.as_secs_f64()),
        );
        self.target_bound = args.get_f64("target-bound", self.target_bound);
        self.target_loss = args.get_f64("target-loss", self.target_loss);
        if let Some(s) = args.get("stopping") {
            self.stopping = StoppingKind::parse(s)?;
        }
        self.stop_c = args.get_f64("stop-c", self.stop_c);
        self.stop_delta = args.get_f64("stop-delta", self.stop_delta);
        if let Some(s) = args.get("sampler") {
            self.sampler = SamplerKind::parse(s)?;
        }
        if let Some(s) = args.get("sampler-mode") {
            self.sampler_mode = SamplerMode::parse(s)?;
        }
        if let Some(s) = args.get("backend") {
            self.backend = Backend::parse(s)?;
        }
        if let Some(s) = args.get("scan-engine") {
            self.scan_engine = ScanEngine::parse(s)?;
        }
        self.scan_threads = args.get_usize("scan-threads", self.scan_threads);
        if let Some(s) = args.get("scan-simd") {
            self.scan_simd = ScanSimd::parse(s)?;
        }
        self.disk_bandwidth = args.get_f64("disk-bandwidth", self.disk_bandwidth);
        if let Some(s) = args.get("store-tier") {
            self.store_tier = StoreTier::parse(s)?;
        }
        self.memory_budget = args.get_u64("memory-budget", self.memory_budget);
        self.eval_interval = Duration::from_secs_f64(
            args.get_f64("eval-interval", self.eval_interval.as_secs_f64()),
        );
        self.seed = args.get_u64("seed", self.seed);
        self.artifacts_dir = args.get_or("artifacts-dir", &self.artifacts_dir);
        if let Some(s) = args.get("broadcast") {
            self.broadcast = BroadcastMode::parse(s)?;
        }
        if let Some(s) = args.get("checkpoint") {
            self.checkpoint = Some(s.to_string());
        }
        self.heartbeat_ms = args.get_u64("heartbeat-ms", self.heartbeat_ms);
        self.queue_cap = args.get_usize("queue-cap", self.queue_cap);
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.num_workers == 0 {
            return Err("workers must be >= 1".into());
        }
        if self.sample_size < 2 {
            return Err("sample-size must be >= 2".into());
        }
        if !(self.gamma0 > 0.0 && self.gamma0 < 0.5) {
            return Err("gamma0 must be in (0, 0.5)".into());
        }
        if !(self.gamma_min > 0.0 && self.gamma_min <= self.gamma0) {
            return Err("gamma-min must be in (0, gamma0]".into());
        }
        if !(self.ess_threshold > 0.0 && self.ess_threshold < 1.0) {
            return Err("ess-threshold must be in (0, 1)".into());
        }
        if self.batch == 0 || self.nthr == 0 || self.max_rules == 0 {
            return Err("batch, nthr and max-rules must be positive".into());
        }
        if self.scan_threads == 0 {
            return Err("scan-threads must be >= 1".into());
        }
        if self.scan_engine == ScanEngine::Binned {
            if self.nthr > u8::MAX as usize {
                return Err("scan-engine binned needs nthr <= 255 (u8 bins)".into());
            }
            if self.backend != Backend::Native {
                return Err("scan-engine binned requires --backend native".into());
            }
        }
        self.validate_scan_simd(simd_compiled())?;
        if self.store_tier == StoreTier::Tiered {
            if self.sampler_mode != SamplerMode::Background {
                return Err(
                    "store-tier tiered requires --sampler-mode background \
                     (the blocking sampler streams the store directly)"
                        .into(),
                );
            }
            if self.disk_bandwidth > 0.0 {
                return Err(
                    "store-tier tiered does real I/O; it cannot be combined with \
                     the simulated --disk-bandwidth throttle"
                        .into(),
                );
            }
            if self.memory_budget == 0 {
                return Err("memory-budget must be positive".into());
            }
        }
        if self.heartbeat_ms == 0 {
            return Err("heartbeat-ms must be >= 1".into());
        }
        if self.queue_cap == 0 {
            return Err("queue-cap must be >= 1".into());
        }
        Ok(())
    }

    /// `--scan-simd` validation against an explicit feature-availability
    /// flag, factored out so the engine × simd × threads matrix is
    /// testable in BOTH build flavors from one build ([`validate`] calls
    /// it with the real [`simd_compiled`]). The single hard rule: `on`
    /// must never silently degrade — if the lane kernels cannot run
    /// (compiled out, or the engine isn't binned), that is a config
    /// error, not a quiet scalar fallback.
    ///
    /// [`validate`]: TrainConfig::validate
    pub fn validate_scan_simd(&self, simd_compiled: bool) -> Result<(), String> {
        match self.scan_simd {
            // auto/off are always valid: auto's contract is "best
            // available, silently"; off is the scalar loop everywhere
            ScanSimd::Auto | ScanSimd::Off => Ok(()),
            ScanSimd::On => {
                if self.scan_engine != ScanEngine::Binned {
                    return Err(
                        "--scan-simd on requires --scan-engine binned \
                         (the row engine has no lane kernels)"
                            .into(),
                    );
                }
                if !simd_compiled {
                    return Err(
                        "--scan-simd on requested but the lane kernels are compiled \
                         out and the scalar loop would run silently; rebuild with \
                         `cargo build --release --features simd`, or use \
                         --scan-simd auto|off"
                            .into(),
                    );
                }
                Ok(())
            }
        }
    }
}

/// Control-plane endpoints for `sparrow serve` (DESIGN.md §10): where the
/// prediction RPC and the admin RPC listen. Port 0 binds an ephemeral
/// port (printed at startup), which is what the tests and the demo
/// script use.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// prediction endpoint (`predict`, `serve.stats`, `model.current`)
    pub serve_addr: String,
    /// admin endpoint (`metrics.snapshot`, config nudges, `fault.inject`,
    /// `shutdown`)
    pub admin_addr: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            serve_addr: "127.0.0.1:7790".into(),
            admin_addr: "127.0.0.1:7791".into(),
        }
    }
}

impl ServeConfig {
    /// Apply `--serve-addr` / `--admin-addr` CLI overrides.
    pub fn apply_args(mut self, args: &Args) -> Result<ServeConfig, String> {
        self.serve_addr = args.get_or("serve-addr", &self.serve_addr);
        self.admin_addr = args.get_or("admin-addr", &self.admin_addr);
        self.validate()?;
        Ok(self)
    }

    /// Both addresses must look like `host:port` and must differ (two
    /// `:0` ephemeral binds are fine — the OS separates them).
    pub fn validate(&self) -> Result<(), String> {
        for (key, addr) in [("serve-addr", &self.serve_addr), ("admin-addr", &self.admin_addr)] {
            if !addr.contains(':') {
                return Err(format!("{key} must be host:port, got {addr:?}"));
            }
        }
        if self.serve_addr == self.admin_addr && !self.serve_addr.ends_with(":0") {
            return Err("serve-addr and admin-addr must differ".into());
        }
        Ok(())
    }
}

/// Workload (dataset) configuration shared by `gen-data`, `train` and the
/// benches.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub train_n: usize,
    pub test_n: usize,
    pub features: usize,
    pub pos_rate: f64,
    pub informative: usize,
    pub signal: f64,
    pub flip_rate: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            train_n: 100_000,
            test_n: 10_000,
            features: 256,
            pos_rate: 0.025,
            informative: 64,
            signal: 0.35,
            flip_rate: 0.05,
            seed: 7,
        }
    }
}

impl WorkloadConfig {
    pub fn apply_args(mut self, args: &Args) -> Result<WorkloadConfig, String> {
        self.train_n = args.get_usize("train-n", self.train_n);
        self.test_n = args.get_usize("test-n", self.test_n);
        self.features = args.get_usize("features", self.features);
        self.pos_rate = args.get_f64("pos-rate", self.pos_rate);
        self.informative = args.get_usize("informative", self.informative);
        self.signal = args.get_f64("signal", self.signal);
        self.flip_rate = args.get_f64("flip-rate", self.flip_rate);
        self.seed = args.get_u64("data-seed", self.seed);
        if self.informative > self.features {
            return Err("informative must be <= features".into());
        }
        Ok(self)
    }

    pub fn synth_config(&self) -> crate::data::SynthConfig {
        crate::data::SynthConfig {
            f: self.features,
            pos_rate: self.pos_rate,
            informative: self.informative,
            signal: self.signal,
            flip_rate: self.flip_rate,
            seed: self.seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn defaults_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let cfg = TrainConfig::default()
            .apply_args(&args(
                "train --workers 4 --gamma0 0.1 --stopping hoeffding \
                 --backend native --sampler rejection",
            ))
            .unwrap();
        assert_eq!(cfg.num_workers, 4);
        assert!((cfg.gamma0 - 0.1).abs() < 1e-12);
        assert_eq!(cfg.stopping, StoppingKind::Hoeffding);
        assert_eq!(cfg.sampler, SamplerKind::Rejection);
    }

    #[test]
    fn invalid_gamma_rejected() {
        assert!(TrainConfig::default()
            .apply_args(&args("train --gamma0 0.7"))
            .is_err());
        assert!(TrainConfig::default()
            .apply_args(&args("train --gamma0 0"))
            .is_err());
    }

    #[test]
    fn invalid_workers_rejected() {
        assert!(TrainConfig::default()
            .apply_args(&args("train --workers 0"))
            .is_err());
    }

    #[test]
    fn fabric_knobs_parse_and_validate() {
        let cfg = TrainConfig::default()
            .apply_args(&args("train --heartbeat-ms 250 --queue-cap 64"))
            .unwrap();
        assert_eq!(cfg.heartbeat_ms, 250);
        assert_eq!(cfg.queue_cap, 64);
        assert!(TrainConfig::default()
            .apply_args(&args("train --heartbeat-ms 0"))
            .is_err());
        assert!(TrainConfig::default()
            .apply_args(&args("train --queue-cap 0"))
            .is_err());
    }

    #[test]
    fn unknown_enum_values_rejected() {
        assert!(TrainConfig::default().apply_args(&args("t --stopping nope")).is_err());
        assert!(TrainConfig::default().apply_args(&args("t --sampler nope")).is_err());
        assert!(TrainConfig::default().apply_args(&args("t --backend nope")).is_err());
        assert!(TrainConfig::default()
            .apply_args(&args("t --sampler-mode nope"))
            .is_err());
    }

    #[test]
    fn enum_parsers() {
        assert_eq!(StoppingKind::parse("fixed").unwrap(), StoppingKind::FixedScan);
        assert_eq!(SamplerKind::parse("mvs").unwrap(), SamplerKind::MinimalVariance);
        assert_eq!(Backend::parse("xla").unwrap(), Backend::XlaPallas);
        assert_eq!(Backend::parse("xla-jnp").unwrap(), Backend::XlaJnp);
        assert_eq!(SamplerMode::parse("bg").unwrap(), SamplerMode::Background);
        assert_eq!(ScanEngine::parse("binned").unwrap(), ScanEngine::Binned);
        assert_eq!(ScanEngine::parse("rows").unwrap(), ScanEngine::Rows);
    }

    #[test]
    fn scan_engine_default_and_override() {
        // the knob must default to rows (the pre-engine numerics)
        let d = TrainConfig::default();
        assert_eq!(d.scan_engine, ScanEngine::Rows);
        assert_eq!(d.scan_threads, 1);
        let cfg = TrainConfig::default()
            .apply_args(&args("train --scan-engine binned --scan-threads 4"))
            .unwrap();
        assert_eq!(cfg.scan_engine, ScanEngine::Binned);
        assert_eq!(cfg.scan_threads, 4);
        assert!(TrainConfig::default()
            .apply_args(&args("t --scan-engine nope"))
            .is_err());
        assert!(TrainConfig::default()
            .apply_args(&args("t --scan-threads 0"))
            .is_err());
        // binned is a native-engine feature: xla backends reject it, and
        // u8 bins bound nthr
        assert!(TrainConfig::default()
            .apply_args(&args("t --scan-engine binned --backend xla-pallas"))
            .is_err());
        assert!(TrainConfig::default()
            .apply_args(&args("t --scan-engine binned --nthr 300"))
            .is_err());
        assert!(TrainConfig::default()
            .apply_args(&args("t --scan-engine rows --nthr 300"))
            .is_ok());
    }

    #[test]
    fn scan_simd_default_parse_and_cli() {
        // defaults to auto — silent best-available, scalar off-path when
        // the feature is compiled out (pre-SIMD behavior, byte for byte)
        assert_eq!(TrainConfig::default().scan_simd, ScanSimd::Auto);
        assert_eq!(ScanSimd::parse("auto").unwrap(), ScanSimd::Auto);
        assert_eq!(ScanSimd::parse("on").unwrap(), ScanSimd::On);
        assert_eq!(ScanSimd::parse("off").unwrap(), ScanSimd::Off);
        assert!(ScanSimd::parse("yes").is_err());
        let cfg = TrainConfig::default()
            .apply_args(&args("train --scan-engine binned --scan-simd off"))
            .unwrap();
        assert_eq!(cfg.scan_simd, ScanSimd::Off);
        assert!(TrainConfig::default()
            .apply_args(&args("t --scan-simd nope"))
            .is_err());
        // `on` through the real CLI path: valid iff this build carries
        // the lane kernels (the compiled-out matrix is pinned below)
        let on = TrainConfig::default().apply_args(&args("t --scan-engine binned --scan-simd on"));
        assert_eq!(on.is_ok(), simd_compiled());
    }

    #[test]
    fn scan_simd_validation_matrix() {
        // engine × simd × threads × feature-availability: exactly two
        // error cells — `on` without the binned engine, and `on` without
        // the compiled lane kernels (the silent-fallback gap)
        for engine in [ScanEngine::Rows, ScanEngine::Binned] {
            for simd in [ScanSimd::Auto, ScanSimd::On, ScanSimd::Off] {
                for threads in [1usize, 4] {
                    for compiled in [false, true] {
                        let cfg = TrainConfig {
                            scan_engine: engine,
                            scan_simd: simd,
                            scan_threads: threads,
                            ..TrainConfig::default()
                        };
                        let want_err = simd == ScanSimd::On
                            && (engine != ScanEngine::Binned || !compiled);
                        let got = cfg.validate_scan_simd(compiled);
                        assert_eq!(
                            got.is_err(),
                            want_err,
                            "engine={engine:?} simd={simd:?} threads={threads} \
                             compiled={compiled}: {got:?}"
                        );
                    }
                }
            }
        }
        // the error messages name the actionable fix
        let on_rows = TrainConfig {
            scan_simd: ScanSimd::On,
            ..TrainConfig::default()
        };
        assert!(on_rows.validate_scan_simd(true).unwrap_err().contains("binned"));
        let on_binned = TrainConfig {
            scan_engine: ScanEngine::Binned,
            scan_simd: ScanSimd::On,
            ..TrainConfig::default()
        };
        assert!(on_binned
            .validate_scan_simd(false)
            .unwrap_err()
            .contains("--features simd"));
    }

    #[test]
    fn store_tier_default_and_override() {
        // the knob must default to the fully-resident store
        assert_eq!(TrainConfig::default().store_tier, StoreTier::Mem);
        assert_eq!(TrainConfig::default().memory_budget, 64 << 20);
        let cfg = TrainConfig::default()
            .apply_args(&args(
                "train --sampler-mode background --store-tier tiered \
                 --memory-budget 1048576",
            ))
            .unwrap();
        assert_eq!(cfg.store_tier, StoreTier::Tiered);
        assert_eq!(cfg.memory_budget, 1 << 20);
        assert_eq!(StoreTier::parse("mem").unwrap(), StoreTier::Mem);
        assert_eq!(StoreTier::parse("tiered").unwrap(), StoreTier::Tiered);
        assert!(StoreTier::parse("nope").is_err());
        // tiered needs the background pipeline...
        assert!(TrainConfig::default()
            .apply_args(&args("t --store-tier tiered"))
            .is_err());
        // ...rejects the simulated throttle (it does real I/O)...
        assert!(TrainConfig::default()
            .apply_args(&args(
                "t --sampler-mode background --store-tier tiered \
                 --disk-bandwidth 1000000"
            ))
            .is_err());
        // ...and needs a positive budget
        assert!(TrainConfig::default()
            .apply_args(&args(
                "t --sampler-mode background --store-tier tiered --memory-budget 0"
            ))
            .is_err());
        // the mem tier ignores both tiered knobs
        assert!(TrainConfig::default()
            .apply_args(&args("t --disk-bandwidth 1000000"))
            .is_ok());
    }

    #[test]
    fn broadcast_and_checkpoint_default_and_override() {
        let d = TrainConfig::default();
        assert_eq!(d.broadcast, BroadcastMode::Full);
        assert!(d.checkpoint.is_none());
        let cfg = TrainConfig::default()
            .apply_args(&args("train --broadcast fanout:4 --checkpoint ckpt/model.txt"))
            .unwrap();
        assert_eq!(cfg.broadcast, BroadcastMode::Fanout { k: 4, ttl: 0 });
        assert_eq!(cfg.checkpoint.as_deref(), Some("ckpt/model.txt"));
        assert_eq!(
            TrainConfig::default()
                .apply_args(&args("train --broadcast fanout"))
                .unwrap()
                .broadcast,
            BroadcastMode::Fanout { k: 3, ttl: 0 }
        );
        assert!(TrainConfig::default()
            .apply_args(&args("t --broadcast nope"))
            .is_err());
        assert!(TrainConfig::default()
            .apply_args(&args("t --broadcast fanout:0"))
            .is_err());
    }

    #[test]
    fn sampler_mode_default_and_override() {
        // the knob must default to the paper-faithful blocking sampler
        assert_eq!(TrainConfig::default().sampler_mode, SamplerMode::Blocking);
        let cfg = TrainConfig::default()
            .apply_args(&args("train --sampler-mode background"))
            .unwrap();
        assert_eq!(cfg.sampler_mode, SamplerMode::Background);
    }

    #[test]
    fn serve_config_defaults_and_overrides() {
        let d = ServeConfig::default();
        d.validate().unwrap();
        assert_ne!(d.serve_addr, d.admin_addr);
        let cfg = ServeConfig::default()
            .apply_args(&args(
                "serve --serve-addr 127.0.0.1:0 --admin-addr 127.0.0.1:0",
            ))
            .unwrap();
        assert_eq!(cfg.serve_addr, "127.0.0.1:0");
        // same concrete address for both endpoints is a config error...
        assert!(ServeConfig::default()
            .apply_args(&args("serve --serve-addr 1.2.3.4:9 --admin-addr 1.2.3.4:9"))
            .is_err());
        // ...as is a port-less address
        assert!(ServeConfig::default()
            .apply_args(&args("serve --admin-addr localhost"))
            .is_err());
    }

    #[test]
    fn workload_overrides_and_validation() {
        let w = WorkloadConfig::default()
            .apply_args(&args("g --train-n 500 --features 32 --informative 8"))
            .unwrap();
        assert_eq!(w.train_n, 500);
        assert_eq!(w.features, 32);
        assert!(WorkloadConfig::default()
            .apply_args(&args("g --features 4 --informative 8"))
            .is_err());
    }
}
