//! The TMSN protocol (§2, §4.2, Alg. 1) — the paper's core contribution.
//!
//! A worker maintains `(H, L)`: its current model and a sound upper bound
//! on the model's loss. When a local search improves the bound by the gap
//! ε, the worker broadcasts the new pair; when a worker *receives* a pair
//! whose bound beats its own, it interrupts its search and adopts it —
//! otherwise it discards the message. That is the whole protocol: no head
//! node, no synchronization, no acknowledgements, and any worker can fail
//! without affecting the others beyond losing its contributions.
//!
//! For boosting the bound is the exponential-loss *potential certificate*:
//! adding a weak rule with certified advantage γ multiplies the training
//! potential bound by `sqrt(1 − 4γ²)` (AdaBoost's per-round Z_t with the
//! optimal α). Certified advantages come from the sequential stopping rule,
//! so the bound is sound with probability ≥ 1 − δ — exactly the "only
//! assumption workers make about incoming messages" (§2).

use crate::model::StrongRule;

/// The "certificate of quality" attached to a broadcast model (§4.2's
/// `z_{t+1}`, Alg. 1's `L`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Certificate {
    /// sound upper bound on the model's exponential-loss potential
    pub loss_bound: f64,
    /// worker that produced this model version
    pub origin: usize,
    /// origin-local sequence number (for lineage/diagnostics)
    pub seq: u64,
}

impl Certificate {
    pub fn initial() -> Certificate {
        Certificate {
            loss_bound: 1.0, // empty model: Z = 1
            origin: usize::MAX,
            seq: 0,
        }
    }
}

/// A broadcast message: the model and its certificate.
#[derive(Debug, Clone)]
pub struct ModelMessage {
    pub model: StrongRule,
    pub cert: Certificate,
}

impl ModelMessage {
    /// Serialized size estimate, used for the fabric's bandwidth model
    /// (stump = feature u32 + threshold f32 + sign i8 + alpha f32 ≈ 13 B,
    /// plus certificate/header overhead).
    pub fn wire_bytes(&self) -> usize {
        32 + 13 * self.model.len()
    }
}

/// Decision on an incoming message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// strictly better bound — interrupt the scanner and adopt
    Accept,
    /// not better — discard
    Reject,
}

/// Per-worker TMSN state machine.
#[derive(Debug, Clone)]
pub struct TmsnState {
    pub model: StrongRule,
    pub cert: Certificate,
    worker_id: usize,
    next_seq: u64,
    /// accepted-message counter (diagnostics)
    pub accepts: u64,
    pub rejects: u64,
}

impl TmsnState {
    pub fn new(worker_id: usize) -> TmsnState {
        TmsnState {
            model: StrongRule::new(),
            cert: Certificate::initial(),
            worker_id,
            next_seq: 1,
            accepts: 0,
            rejects: 0,
        }
    }

    /// Resume from a checkpointed `(model, bound)` pair: the worker starts
    /// as if it had just accepted that model over the broadcast channel.
    pub fn resume(worker_id: usize, model: StrongRule, loss_bound: f64) -> TmsnState {
        assert!(loss_bound.is_finite() && loss_bound >= 0.0);
        TmsnState {
            model,
            cert: Certificate {
                loss_bound,
                origin: worker_id,
                seq: 0,
            },
            worker_id,
            next_seq: 1,
            accepts: 0,
            rejects: 0,
        }
    }

    /// Local improvement: a weak rule with certified advantage γ was added
    /// (the caller already pushed it into `model`). Updates the bound
    /// multiplicatively and stamps a new certificate. Returns the message
    /// to broadcast.
    pub fn local_improvement(&mut self, model: StrongRule, gamma: f64) -> ModelMessage {
        assert!(gamma > 0.0 && gamma < 0.5);
        assert!(
            model.len() > self.model.len(),
            "local improvement must extend the model"
        );
        let factor = (1.0 - 4.0 * gamma * gamma).sqrt();
        self.model = model;
        self.cert = Certificate {
            loss_bound: self.cert.loss_bound * factor,
            origin: self.worker_id,
            seq: self.next_seq,
        };
        self.next_seq += 1;
        ModelMessage {
            model: self.model.clone(),
            cert: self.cert,
        }
    }

    /// Handle an incoming `(H, L)` message (Alg. 1's receive path):
    /// accept iff the incoming bound is *strictly* lower than ours.
    pub fn on_message(&mut self, msg: ModelMessage) -> Verdict {
        if msg.cert.loss_bound < self.cert.loss_bound {
            self.model = msg.model;
            self.cert = msg.cert;
            self.accepts += 1;
            Verdict::Accept
        } else {
            self.rejects += 1;
            Verdict::Reject
        }
    }

    pub fn worker_id(&self) -> usize {
        self.worker_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;
    use crate::util::prop::prop_check;

    fn extend(model: &StrongRule, feature: u32) -> StrongRule {
        let mut m = model.clone();
        m.push(Stump::new(feature, 0.0, 1.0), 0.2);
        m
    }

    #[test]
    fn local_improvement_tightens_bound() {
        let mut s = TmsnState::new(0);
        let msg = s.local_improvement(extend(&s.model.clone(), 1), 0.1);
        assert!(msg.cert.loss_bound < 1.0);
        assert_eq!(msg.cert.origin, 0);
        assert_eq!(msg.cert.seq, 1);
        let b1 = msg.cert.loss_bound;
        let msg2 = s.local_improvement(extend(&s.model.clone(), 2), 0.1);
        assert!(msg2.cert.loss_bound < b1);
        assert_eq!(msg2.cert.seq, 2);
    }

    #[test]
    fn bound_factor_matches_adaboost_z() {
        let mut s = TmsnState::new(0);
        let g = 0.2f64;
        let msg = s.local_improvement(extend(&StrongRule::new(), 0), g);
        assert!((msg.cert.loss_bound - (1.0 - 4.0 * g * g).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn accept_strictly_better_only() {
        let mut a = TmsnState::new(0);
        let mut b = TmsnState::new(1);
        let msg = a.local_improvement(extend(&StrongRule::new(), 0), 0.1);

        // b has the empty model (bound 1.0) → accepts
        assert_eq!(b.on_message(msg.clone()), Verdict::Accept);
        assert_eq!(b.model, a.model);
        assert_eq!(b.cert, a.cert);

        // replaying the same message is now a reject (not strictly better)
        assert_eq!(b.on_message(msg), Verdict::Reject);
        assert_eq!(b.accepts, 1);
        assert_eq!(b.rejects, 1);
    }

    /// A message carrying an arbitrary certificate (bypasses the
    /// `local_improvement` bound arithmetic to probe the verdict rule
    /// directly).
    fn msg_with_bound(loss_bound: f64, origin: usize, seq: u64) -> ModelMessage {
        ModelMessage {
            model: extend(&StrongRule::new(), origin as u32),
            cert: Certificate {
                loss_bound,
                origin,
                seq,
            },
        }
    }

    #[test]
    fn verdict_accept_iff_strictly_better() {
        // Alg. 1 receive path: accept iff the incoming bound is *strictly*
        // lower — strictly better ⇒ Accept; exact tie ⇒ Reject; worse ⇒
        // Reject. Ties must not churn state (no re-adoption loops).
        let mut s = TmsnState::resume(0, extend(&StrongRule::new(), 9), 0.5);

        assert_eq!(s.on_message(msg_with_bound(0.49, 1, 1)), Verdict::Accept);
        assert!((s.cert.loss_bound - 0.49).abs() < 1e-15);

        let model_before = s.model.clone();
        assert_eq!(s.on_message(msg_with_bound(0.49, 2, 1)), Verdict::Reject); // tie
        assert_eq!(s.on_message(msg_with_bound(0.50, 2, 2)), Verdict::Reject); // worse
        assert_eq!(s.on_message(msg_with_bound(9.99, 2, 3)), Verdict::Reject); // much worse
        assert_eq!(s.model, model_before, "rejects must not mutate the model");
        assert!((s.cert.loss_bound - 0.49).abs() < 1e-15);
        assert_eq!(s.accepts, 1);
        assert_eq!(s.rejects, 3);
    }

    #[test]
    fn bound_monotone_across_adopted_messages() {
        // The certificate bound never increases, no matter what mix of
        // better/worse/stale messages arrives in what order — the protocol's
        // progress invariant, checked on the accept path specifically.
        let mut s = TmsnState::new(0);
        let bounds = [0.9, 0.95, 0.6, 0.6, 0.61, 0.3, 0.9, 0.05, 0.049, 0.5];
        let mut prev = s.cert.loss_bound;
        for (seq, &b) in bounds.iter().enumerate() {
            let verdict = s.on_message(msg_with_bound(b, 1, seq as u64));
            assert_eq!(verdict == Verdict::Accept, b < prev, "bound {b} vs {prev}");
            assert!(
                s.cert.loss_bound <= prev,
                "adopted bound increased: {prev} -> {}",
                s.cert.loss_bound
            );
            prev = s.cert.loss_bound;
        }
        assert!((prev - 0.049).abs() < 1e-15);
    }

    #[test]
    fn stale_message_rejected() {
        let mut a = TmsnState::new(0);
        let mut b = TmsnState::new(1);
        let old = a.local_improvement(extend(&StrongRule::new(), 0), 0.05);
        let new = a.local_improvement(extend(&a.model.clone(), 1), 0.05);
        assert_eq!(b.on_message(new), Verdict::Accept);
        assert_eq!(b.on_message(old), Verdict::Reject);
    }

    #[test]
    fn wire_bytes_grows_with_model() {
        let mut s = TmsnState::new(0);
        let m1 = s.local_improvement(extend(&StrongRule::new(), 0), 0.1);
        let m2 = s.local_improvement(extend(&s.model.clone(), 1), 0.1);
        assert!(m2.wire_bytes() > m1.wire_bytes());
    }

    #[test]
    fn prop_bound_monotone_along_accept_chain() {
        // Any interleaving of local improvements and message exchanges
        // keeps every worker's bound non-increasing — the protocol's
        // progress invariant.
        prop_check("bounds monotone under TMSN", 50, |rng| {
            let n = 4;
            let mut workers: Vec<TmsnState> = (0..n).map(TmsnState::new).collect();
            let mut bounds: Vec<f64> = vec![1.0; n];
            let mut inflight: Vec<ModelMessage> = Vec::new();
            for step in 0..60 {
                let w = rng.below(n as u64) as usize;
                if rng.bernoulli(0.5) || inflight.is_empty() {
                    // local improvement with random γ
                    let g = 0.05 + rng.f64() * 0.3;
                    let model = extend(&workers[w].model.clone(), step as u32);
                    let msg = workers[w].local_improvement(model, g);
                    inflight.push(msg);
                } else {
                    // deliver a random in-flight message (arbitrary order!)
                    let k = rng.below(inflight.len() as u64) as usize;
                    let msg = inflight[k].clone();
                    workers[w].on_message(msg);
                }
                let b = workers[w].cert.loss_bound;
                if b > bounds[w] + 1e-12 {
                    return Err(format!("worker {w} bound increased {} -> {b}", bounds[w]));
                }
                bounds[w] = b;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_convergence_after_full_delivery() {
        // Once every broadcast message is delivered to every worker, all
        // workers hold the minimum bound (the §2 convergence claim).
        prop_check("all workers converge to best bound", 30, |rng| {
            let n = 5;
            let mut workers: Vec<TmsnState> = (0..n).map(TmsnState::new).collect();
            let mut all_msgs: Vec<ModelMessage> = Vec::new();
            for step in 0..20 {
                let w = rng.below(n as u64) as usize;
                let g = 0.05 + rng.f64() * 0.3;
                let model = extend(&workers[w].model.clone(), step as u32);
                all_msgs.push(workers[w].local_improvement(model, g));
            }
            let best = all_msgs
                .iter()
                .map(|m| m.cert.loss_bound)
                .fold(f64::INFINITY, f64::min);
            // deliver everything to everyone, in a random order per worker
            for w in workers.iter_mut() {
                let mut order: Vec<usize> = (0..all_msgs.len()).collect();
                rng.shuffle(&mut order);
                for &k in &order {
                    w.on_message(all_msgs[k].clone());
                }
                if (w.cert.loss_bound - best).abs() > 1e-12 && w.cert.loss_bound > best {
                    return Err(format!(
                        "worker {} stuck at {} > best {best}",
                        w.worker_id(),
                        w.cert.loss_bound
                    ));
                }
            }
            Ok(())
        });
    }
}
