//! The TMSN protocol (§2, §4.2, Alg. 1) — the paper's core contribution,
//! as a **payload-generic** protocol layer.
//!
//! A worker maintains a payload `(H, L)`: its current model and a sound
//! certificate of the model's quality. When a local search improves the
//! certificate, the worker broadcasts the new payload; when a worker
//! *receives* a payload whose certificate beats its own, it interrupts its
//! search and adopts it — otherwise it discards the message. That is the
//! whole protocol: no head node, no synchronization, no acknowledgements,
//! and any worker can fail without affecting the others beyond losing its
//! contributions.
//!
//! The paper demonstrates TMSN with boosted trees, but §1/§2 present it as
//! a *general* framework for asynchronous parallel learning. This module
//! is that framework, factored into three pieces:
//!
//! * [`Certified`] — a certificate with a strict partial order
//!   (`better_than`): "the only assumption workers make about incoming
//!   messages" (§2) is that a certificate soundly bounds model quality and
//!   that strictly-better certificates are worth adopting.
//! * [`Payload`] — a broadcastable `(model, certificate)` pair with a wire
//!   encoding; [`Payload::wire_bytes`] is the *real* encoded length, so the
//!   simulated fabric's bandwidth model and the TCP transport agree.
//! * [`Tmsn<P>`] — the per-worker state machine: `local_update` (send
//!   path) and `on_message` (receive path, accept iff *strictly* better).
//!   The certificate is monotone non-worsening under any interleaving.
//!
//! [`Driver<P, L>`] packages the poll/adopt/broadcast loop every workload
//! repeats (drain-the-inbox, interrupt-the-scan, publish-and-log) over any
//! [`Link<P>`] transport.
//!
//! Instantiations:
//! * [`boost`] — the paper's boosting workload: certificate = exponential-
//!   loss potential bound, update factor `sqrt(1 − 4γ²)` (AdaBoost's Z_t).
//! * [`crate::sgd`] — certified asynchronous SGD on a linear model
//!   (certificate = held-out loss), proving the protocol carries
//!   non-boosting learners unchanged.

pub mod boost;

pub use boost::{BoostPayload, LossBoundCert};

use crate::metrics::{EventKind, EventLog};

/// A certificate of model quality with a strict partial order.
///
/// `better_than` must be a strict partial order (irreflexive, transitive):
/// TMSN's verdict rule adopts a payload iff its certificate is strictly
/// better, so ties never churn state and re-broadcast loops are impossible.
/// `origin`/`seq` are lineage metadata (who produced the certified model,
/// and its origin-local version) used for logging and diagnostics.
pub trait Certified: Clone + Send + std::fmt::Debug + 'static {
    /// Certificate of the initial (empty) model.
    fn initial() -> Self;
    /// Strict partial order: does `self` certify a strictly better model?
    fn better_than(&self, other: &Self) -> bool;
    /// Worker that produced this certificate.
    fn origin(&self) -> usize;
    /// Origin-local sequence number (lineage/diagnostics).
    fn seq(&self) -> u64;
    /// Stamp lineage; called by [`Tmsn`] when a payload is committed.
    fn stamp(&mut self, origin: usize, seq: u64);
    /// Scalar rendering for event logs and timelines (for both built-in
    /// workloads: lower = better).
    fn summary(&self) -> f64;
}

/// A broadcastable `(model, certificate)` pair.
pub trait Payload: Clone + Send + 'static {
    type Cert: Certified;

    /// The initial (empty-model) payload every worker starts from.
    fn initial() -> Self;
    fn cert(&self) -> &Self::Cert;
    fn cert_mut(&mut self) -> &mut Self::Cert;
    /// Wire encoding (certificate + model; transport framing excluded).
    fn encode(&self) -> Vec<u8>;
    /// Inverse of [`Payload::encode`]. Must reject malformed input — a bad
    /// peer must never be able to crash a worker.
    fn decode(payload: &[u8]) -> Result<Self, String>;
    /// Serialized size, used by the fabric's bandwidth model. Defaults to
    /// the real encoded length so simulated serialization delays match
    /// what the TCP transport actually ships.
    fn wire_bytes(&self) -> usize {
        self.encode().len()
    }
}

/// The only two operations TMSN needs from a network.
pub trait Link<P: Payload>: Send {
    /// Fire-and-forget broadcast to all peers.
    fn send(&self, msg: P);
    /// Non-blocking poll for the next delivered message.
    fn poll(&self) -> Option<P>;
}

impl<P: Payload> Link<P> for Box<dyn Link<P>> {
    fn send(&self, msg: P) {
        (**self).send(msg)
    }
    fn poll(&self) -> Option<P> {
        (**self).poll()
    }
}

/// Decision on an incoming message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// strictly better certificate — interrupt the search and adopt
    Accept,
    /// not better — discard
    Reject,
}

/// Per-worker TMSN state machine, generic over the payload.
#[derive(Debug, Clone)]
pub struct Tmsn<P: Payload> {
    payload: P,
    worker_id: usize,
    next_seq: u64,
    /// accepted-message counter (diagnostics)
    pub accepts: u64,
    pub rejects: u64,
}

impl<P: Payload> Tmsn<P> {
    pub fn new(worker_id: usize) -> Tmsn<P> {
        Tmsn {
            payload: P::initial(),
            worker_id,
            next_seq: 1,
            accepts: 0,
            rejects: 0,
        }
    }

    /// Resume from a checkpointed payload: the worker starts as if it had
    /// just accepted that payload over the broadcast channel.
    pub fn resume(worker_id: usize, mut payload: P) -> Tmsn<P> {
        payload.cert_mut().stamp(worker_id, 0);
        Tmsn {
            payload,
            worker_id,
            next_seq: 1,
            accepts: 0,
            rejects: 0,
        }
    }

    pub fn payload(&self) -> &P {
        &self.payload
    }

    pub fn cert(&self) -> &P::Cert {
        self.payload.cert()
    }

    /// Local improvement (Alg. 1 send path): commit a payload whose
    /// certificate strictly beats the current one, stamp its lineage, and
    /// return the message to broadcast. Panics if the certificate does not
    /// strictly improve — the protocol's monotonicity invariant.
    pub fn local_update(&mut self, mut payload: P) -> P {
        assert!(
            payload.cert().better_than(self.payload.cert()),
            "local update must strictly improve the certificate"
        );
        payload.cert_mut().stamp(self.worker_id, self.next_seq);
        self.next_seq += 1;
        self.payload = payload.clone();
        payload
    }

    /// Handle an incoming payload (Alg. 1's receive path): accept iff the
    /// incoming certificate is *strictly* better than ours.
    pub fn on_message(&mut self, msg: P) -> Verdict {
        if msg.cert().better_than(self.payload.cert()) {
            self.payload = msg;
            self.accepts += 1;
            Verdict::Accept
        } else {
            self.rejects += 1;
            Verdict::Reject
        }
    }

    pub fn worker_id(&self) -> usize {
        self.worker_id
    }
}

/// The poll/adopt/broadcast loop shared by every TMSN workload.
///
/// Owns the state machine and its transport attachment, and records the
/// protocol's event vocabulary (receive/accept/reject/improve/broadcast)
/// on the shared [`EventLog`] clock. Two receive paths mirror Alg. 1:
///
/// * [`Driver::poll_adopt`] — drain the whole inbox between work units,
///   adopting every strictly-better payload;
/// * [`Driver::poll_interrupt`] + [`Driver::adopt_pending`] — the
///   interrupt-the-scan path: cheap single poll from inside a work unit's
///   inner loop; a strictly-better arrival is parked as pending (so the
///   caller can abandon the scan first) and adopted on the way out.
///   Worse arrivals are logged but not offered to the state machine, so
///   the verdict counters only reflect messages actually considered.
pub struct Driver<P: Payload, L: Link<P>> {
    tmsn: Tmsn<P>,
    link: L,
    log: EventLog,
    pending: Option<P>,
}

impl<P: Payload, L: Link<P>> Driver<P, L> {
    pub fn new(tmsn: Tmsn<P>, link: L, log: EventLog) -> Driver<P, L> {
        Driver {
            tmsn,
            link,
            log,
            pending: None,
        }
    }

    pub fn state(&self) -> &Tmsn<P> {
        &self.tmsn
    }

    pub fn payload(&self) -> &P {
        self.tmsn.payload()
    }

    pub fn cert(&self) -> &P::Cert {
        self.tmsn.cert()
    }

    pub fn worker_id(&self) -> usize {
        self.tmsn.worker_id()
    }

    /// Tear down, returning the final state machine.
    pub fn into_state(self) -> Tmsn<P> {
        self.tmsn
    }

    /// Offer one message to the state machine; on adoption, call
    /// `on_adopt(replaced, adopted)` so the caller can repair any state
    /// derived from the old payload (e.g. cached sample weights).
    fn offer(&mut self, msg: P, on_adopt: &mut dyn FnMut(&P, &P)) -> Verdict {
        let version = Some((msg.cert().origin(), msg.cert().seq()));
        let value = msg.cert().summary();
        let replaced = if msg.cert().better_than(self.tmsn.cert()) {
            Some(self.tmsn.payload().clone())
        } else {
            None
        };
        match self.tmsn.on_message(msg) {
            Verdict::Accept => {
                self.log
                    .record(self.tmsn.worker_id(), EventKind::Accept, version, value);
                on_adopt(&replaced.expect("verdict rule is deterministic"), self.tmsn.payload());
                Verdict::Accept
            }
            Verdict::Reject => {
                self.log
                    .record(self.tmsn.worker_id(), EventKind::Reject, version, value);
                Verdict::Reject
            }
        }
    }

    /// Drain every queued message, adopting each strictly-better payload.
    /// Returns the number adopted.
    pub fn poll_adopt(&mut self, on_adopt: &mut dyn FnMut(&P, &P)) -> usize {
        let mut adopted = 0;
        while let Some(msg) = self.link.poll() {
            if self.offer(msg, on_adopt) == Verdict::Accept {
                adopted += 1;
            }
        }
        adopted
    }

    /// Single poll for the interrupt-the-scan path. If a strictly-better
    /// payload arrived it is parked as pending and `true` is returned: the
    /// caller should abort its work unit and call [`Driver::adopt_pending`].
    /// Worse arrivals are logged (`Receive` + `Reject`) and dropped.
    pub fn poll_interrupt(&mut self) -> bool {
        if let Some(msg) = self.link.poll() {
            let version = Some((msg.cert().origin(), msg.cert().seq()));
            let value = msg.cert().summary();
            self.log
                .record(self.tmsn.worker_id(), EventKind::Receive, version, value);
            if msg.cert().better_than(self.tmsn.cert()) {
                self.pending = Some(msg);
                return true;
            }
            self.log
                .record(self.tmsn.worker_id(), EventKind::Reject, version, value);
        }
        false
    }

    /// Adopt the payload parked by [`Driver::poll_interrupt`], if any.
    pub fn adopt_pending(&mut self, on_adopt: &mut dyn FnMut(&P, &P)) -> bool {
        match self.pending.take() {
            Some(msg) => {
                self.offer(msg, on_adopt);
                true
            }
            None => false,
        }
    }

    /// In-process restart (the admin plane's `fault.inject "restart"`,
    /// DESIGN.md §13): replace the state machine with one resumed from its
    /// own current payload, exactly as if the worker had been killed and
    /// restarted from a checkpoint taken this instant. The certificate is
    /// restamped `(worker_id, 0)` so any of this worker's own pre-restart
    /// broadcasts still in flight strictly beat it (the same catch-up
    /// argument as `--resume`), the pending slot is cleared, and the
    /// verdict counters restart with the new incarnation.
    pub fn rebirth(&mut self) {
        let id = self.tmsn.worker_id();
        let payload = self.tmsn.payload().clone();
        self.pending = None;
        self.tmsn = Tmsn::resume(id, payload);
    }

    /// Commit a local improvement and broadcast it (Alg. 1 send path).
    /// Returns the committed sequence number.
    pub fn publish(&mut self, payload: P) -> u64 {
        let msg = self.tmsn.local_update(payload);
        let id = self.tmsn.worker_id();
        let seq = msg.cert().seq();
        let value = msg.cert().summary();
        self.log
            .record(id, EventKind::LocalImprovement, Some((id, seq)), value);
        self.link.send(msg);
        self.log.record(id, EventKind::Broadcast, Some((id, seq)), value);
        seq
    }
}

/// Minimal workload-agnostic payload shared by the protocol and transport
/// unit tests: a string body plus a lower-is-better scalar certificate.
/// Exists purely to show those layers need nothing from any model family.
#[cfg(test)]
pub(crate) mod testpay {
    use super::{Certified, Payload};

    #[derive(Debug, Clone, PartialEq)]
    pub struct TestCert {
        pub score: f64,
        pub origin: usize,
        pub seq: u64,
    }

    impl Certified for TestCert {
        fn initial() -> TestCert {
            TestCert {
                score: f64::INFINITY,
                origin: usize::MAX,
                seq: 0,
            }
        }
        fn better_than(&self, other: &TestCert) -> bool {
            self.score < other.score
        }
        fn origin(&self) -> usize {
            self.origin
        }
        fn seq(&self) -> u64 {
            self.seq
        }
        fn stamp(&mut self, origin: usize, seq: u64) {
            self.origin = origin;
            self.seq = seq;
        }
        fn summary(&self) -> f64 {
            self.score
        }
    }

    #[derive(Debug, Clone, PartialEq)]
    pub struct TestPayload {
        pub body: String,
        pub cert: TestCert,
    }

    impl TestPayload {
        pub fn scored(body: &str, score: f64) -> TestPayload {
            TestPayload {
                body: body.to_string(),
                cert: TestCert {
                    score,
                    origin: usize::MAX,
                    seq: 0,
                },
            }
        }
    }

    impl Payload for TestPayload {
        type Cert = TestCert;
        fn initial() -> TestPayload {
            TestPayload {
                body: String::new(),
                cert: TestCert::initial(),
            }
        }
        fn cert(&self) -> &TestCert {
            &self.cert
        }
        fn cert_mut(&mut self) -> &mut TestCert {
            &mut self.cert
        }
        fn encode(&self) -> Vec<u8> {
            format!(
                "test {} {} {}\n{}",
                self.cert.score, self.cert.origin, self.cert.seq, self.body
            )
            .into_bytes()
        }
        fn decode(payload: &[u8]) -> Result<TestPayload, String> {
            let text = std::str::from_utf8(payload).map_err(|_| "non-utf8")?;
            let (first, body) = text.split_once('\n').ok_or("missing cert line")?;
            let mut it = first.split_whitespace();
            if it.next() != Some("test") {
                return Err("bad cert line".into());
            }
            let score: f64 = it.next().ok_or("missing score")?.parse().map_err(|_| "bad score")?;
            let origin: usize =
                it.next().ok_or("missing origin")?.parse().map_err(|_| "bad origin")?;
            let seq: u64 = it.next().ok_or("missing seq")?.parse().map_err(|_| "bad seq")?;
            Ok(TestPayload {
                body: body.to_string(),
                cert: TestCert { score, origin, seq },
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testpay::{TestCert, TestPayload};
    use super::*;
    use crate::network::{Fabric, NetConfig};
    use crate::util::prop::prop_check;
    use std::time::Duration;

    fn log() -> EventLog {
        EventLog::new().0
    }

    #[test]
    fn verdict_accept_iff_strictly_better_generic() {
        let mut s = Tmsn::<TestPayload>::resume(0, TestPayload::scored("mine", 0.5));
        assert_eq!(s.on_message(TestPayload::scored("better", 0.49)), Verdict::Accept);
        assert_eq!(s.payload().body, "better");
        let before = s.payload().clone();
        assert_eq!(s.on_message(TestPayload::scored("tie", 0.49)), Verdict::Reject);
        assert_eq!(s.on_message(TestPayload::scored("worse", 0.8)), Verdict::Reject);
        assert_eq!(*s.payload(), before, "rejects must not mutate state");
        assert_eq!((s.accepts, s.rejects), (1, 2));
    }

    #[test]
    fn local_update_stamps_lineage_and_requires_improvement() {
        let mut s = Tmsn::<TestPayload>::new(3);
        let msg = s.local_update(TestPayload::scored("a", 10.0));
        assert_eq!((msg.cert.origin, msg.cert.seq), (3, 1));
        let msg2 = s.local_update(TestPayload::scored("b", 9.0));
        assert_eq!(msg2.cert.seq, 2);
        assert_eq!(s.cert().score, 9.0);
    }

    #[test]
    #[should_panic(expected = "strictly improve")]
    fn local_update_rejects_non_improvement() {
        let mut s = Tmsn::<TestPayload>::new(0);
        s.local_update(TestPayload::scored("a", 5.0));
        s.local_update(TestPayload::scored("b", 5.0)); // tie: not strictly better
    }

    #[test]
    fn prop_cert_monotone_under_any_interleaving() {
        // The generic protocol keeps every worker's certificate monotone
        // non-worsening under arbitrary improvement/delivery interleavings.
        prop_check("generic cert monotone", 50, |rng| {
            let n = 4;
            let mut workers: Vec<Tmsn<TestPayload>> = (0..n).map(Tmsn::new).collect();
            let mut scores = vec![f64::INFINITY; n];
            let mut inflight: Vec<TestPayload> = Vec::new();
            for step in 0..60 {
                let w = rng.below(n as u64) as usize;
                if rng.bernoulli(0.5) || inflight.is_empty() {
                    let cur = workers[w].cert().score;
                    let next = if cur.is_finite() {
                        cur * (0.5 + rng.f64() * 0.49)
                    } else {
                        rng.f64() * 10.0
                    };
                    let p = TestPayload::scored(&format!("{step}"), next);
                    inflight.push(workers[w].local_update(p));
                } else {
                    let k = rng.below(inflight.len() as u64) as usize;
                    workers[w].on_message(inflight[k].clone());
                }
                let s = workers[w].cert().score;
                if s > scores[w] {
                    return Err(format!("worker {w} cert worsened {} -> {s}", scores[w]));
                }
                scores[w] = s;
            }
            Ok(())
        });
    }

    #[test]
    fn driver_publish_adopt_over_fabric() {
        let (fabric, mut eps) = Fabric::<TestPayload>::new(2, NetConfig::ideal());
        let b_ep = eps.pop().unwrap();
        let a_ep = eps.pop().unwrap();
        let mut a = Driver::new(Tmsn::new(0), a_ep, log());
        let mut b = Driver::new(Tmsn::new(1), b_ep, log());

        let seq = a.publish(TestPayload::scored("v1", 1.0));
        assert_eq!(seq, 1);
        let mut adopted = 0;
        for _ in 0..200 {
            adopted += b.poll_adopt(&mut |_, _| {});
            if adopted > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(adopted, 1);
        assert_eq!(b.payload().body, "v1");
        assert_eq!(b.cert().origin, 0);
        fabric.shutdown();
    }

    #[test]
    fn driver_interrupt_path_parks_then_adopts() {
        let (fabric, mut eps) = Fabric::<TestPayload>::new(2, NetConfig::ideal());
        let b_ep = eps.pop().unwrap();
        let a_ep = eps.pop().unwrap();
        let mut a = Driver::new(Tmsn::new(0), a_ep, log());
        let mut b = Driver::new(Tmsn::new(1), b_ep, log());

        a.publish(TestPayload::scored("good", 1.0));
        let mut interrupted = false;
        for _ in 0..200 {
            if b.poll_interrupt() {
                interrupted = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(interrupted, "strictly-better arrival must interrupt");
        // state unchanged until the pending payload is explicitly adopted
        assert_eq!(b.payload().body, "");
        assert!(b.adopt_pending(&mut |_, _| {}));
        assert_eq!(b.payload().body, "good");
        assert!(!b.adopt_pending(&mut |_, _| {}), "pending is consumed");

        // a worse arrival is rejected inline and does not interrupt
        a.publish(TestPayload::scored("better-for-a-only", 0.5));
        b.publish(TestPayload::scored("best", 0.1));
        std::thread::sleep(Duration::from_millis(50));
        assert!(!b.poll_interrupt());
        assert_eq!(b.payload().body, "best");
        fabric.shutdown();
    }

    #[test]
    fn on_adopt_sees_replaced_and_adopted() {
        let (fabric, mut eps) = Fabric::<TestPayload>::new(2, NetConfig::ideal());
        let b_ep = eps.pop().unwrap();
        let a_ep = eps.pop().unwrap();
        let mut a = Driver::new(Tmsn::new(0), a_ep, log());
        let mut b = Driver::new(Tmsn::resume(1, TestPayload::scored("old", 2.0)), b_ep, log());

        a.publish(TestPayload::scored("new", 1.0));
        let mut seen = None;
        for _ in 0..200 {
            b.poll_adopt(&mut |prev, cur| seen = Some((prev.body.clone(), cur.body.clone())));
            if seen.is_some() {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(seen, Some(("old".to_string(), "new".to_string())));
        fabric.shutdown();
    }

    #[test]
    fn wire_bytes_defaults_to_encoded_length() {
        let p = TestPayload::scored("payload-body", 0.25);
        assert_eq!(p.wire_bytes(), p.encode().len());
    }

    #[test]
    fn payload_roundtrip_generic() {
        let p = TestPayload {
            body: "multi\nline body".into(),
            cert: TestCert {
                score: 0.125,
                origin: 7,
                seq: 42,
            },
        };
        assert_eq!(TestPayload::decode(&p.encode()).unwrap(), p);
    }
}
