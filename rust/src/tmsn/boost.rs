//! The boosting instantiation of TMSN — the paper's demonstration
//! workload (§4.2, Alg. 1).
//!
//! The certificate is the exponential-loss *potential bound*: adding a
//! weak rule with certified advantage γ multiplies the training potential
//! bound by `sqrt(1 − 4γ²)` (AdaBoost's per-round Z_t with the optimal α).
//! Certified advantages come from the sequential stopping rule, so the
//! bound is sound with probability ≥ 1 − δ — exactly the "only assumption
//! workers make about incoming messages" (§2).
//!
//! Everything boosting-specific about the protocol lives here; the state
//! machine, driver, and transports ([`crate::tmsn`], [`crate::network`],
//! [`crate::worker::link`]) are payload-generic.

use crate::model::StrongRule;
use crate::tmsn::{Certified, Payload, Tmsn};

/// The "certificate of quality" attached to a broadcast model (§4.2's
/// `z_{t+1}`, Alg. 1's `L`): a sound upper bound on the model's
/// exponential-loss potential. Strictly lower is strictly better.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LossBoundCert {
    /// sound upper bound on the model's exponential-loss potential
    pub loss_bound: f64,
    /// worker that produced this model version
    pub origin: usize,
    /// origin-local sequence number (for lineage/diagnostics)
    pub seq: u64,
}

impl Certified for LossBoundCert {
    fn initial() -> LossBoundCert {
        LossBoundCert {
            loss_bound: 1.0, // empty model: Z = 1
            origin: usize::MAX,
            seq: 0,
        }
    }

    fn better_than(&self, other: &LossBoundCert) -> bool {
        self.loss_bound < other.loss_bound
    }

    fn origin(&self) -> usize {
        self.origin
    }

    fn seq(&self) -> u64 {
        self.seq
    }

    fn stamp(&mut self, origin: usize, seq: u64) {
        self.origin = origin;
        self.seq = seq;
    }

    fn summary(&self) -> f64 {
        self.loss_bound
    }
}

/// A broadcast boosting message: the strong rule and its certificate.
#[derive(Debug, Clone)]
pub struct BoostPayload {
    pub model: StrongRule,
    pub cert: LossBoundCert,
}

impl BoostPayload {
    /// Checkpoint-resume payload: a saved `(model, bound)` pair.
    pub fn resume(model: StrongRule, loss_bound: f64) -> BoostPayload {
        assert!(loss_bound.is_finite() && loss_bound >= 0.0);
        BoostPayload {
            model,
            cert: LossBoundCert {
                loss_bound,
                origin: usize::MAX,
                seq: 0,
            },
        }
    }

    /// The §4.2 bound update: a weak rule with certified advantage γ was
    /// appended to this payload's model (the caller already pushed it into
    /// `model`), multiplying the potential bound by `sqrt(1 − 4γ²)`. The
    /// lineage is stamped later, by [`Tmsn::local_update`].
    pub fn improved(&self, model: StrongRule, gamma: f64) -> BoostPayload {
        assert!(gamma > 0.0 && gamma < 0.5);
        assert!(
            model.len() > self.model.len(),
            "local improvement must extend the model"
        );
        let factor = (1.0 - 4.0 * gamma * gamma).sqrt();
        BoostPayload {
            model,
            cert: LossBoundCert {
                loss_bound: self.cert.loss_bound * factor,
                origin: self.cert.origin,
                seq: self.cert.seq,
            },
        }
    }
}

impl Payload for BoostPayload {
    type Cert = LossBoundCert;

    fn initial() -> BoostPayload {
        BoostPayload {
            model: StrongRule::new(),
            cert: LossBoundCert::initial(),
        }
    }

    fn cert(&self) -> &LossBoundCert {
        &self.cert
    }

    fn cert_mut(&mut self) -> &mut LossBoundCert {
        &mut self.cert
    }

    /// Wire format: certificate line + model text (the payload inside the
    /// TCP framing of [`crate::network::tcp`], and the byte count behind
    /// the fabric's bandwidth model).
    fn encode(&self) -> Vec<u8> {
        let header = format!(
            "cert {} {} {}\n",
            self.cert.loss_bound, self.cert.origin, self.cert.seq
        );
        let body = self.model.to_text();
        [header.as_bytes(), body.as_bytes()].concat()
    }

    fn decode(payload: &[u8]) -> Result<BoostPayload, String> {
        let text = std::str::from_utf8(payload).map_err(|_| "non-utf8 payload")?;
        let (first, rest) = text.split_once('\n').ok_or("missing cert line")?;
        let mut it = first.split_whitespace();
        if it.next() != Some("cert") {
            return Err("bad cert line".into());
        }
        let loss_bound: f64 = it.next().ok_or("missing bound")?.parse().map_err(|_| "bad bound")?;
        let origin: usize = it.next().ok_or("missing origin")?.parse().map_err(|_| "bad origin")?;
        let seq: u64 = it.next().ok_or("missing seq")?.parse().map_err(|_| "bad seq")?;
        if !loss_bound.is_finite() || loss_bound < 0.0 {
            return Err("bound must be finite and non-negative".into());
        }
        let model = StrongRule::from_text(rest)?;
        Ok(BoostPayload {
            model,
            cert: LossBoundCert {
                loss_bound,
                origin,
                seq,
            },
        })
    }
}

impl Tmsn<BoostPayload> {
    /// Local improvement: a weak rule with certified advantage γ was added
    /// (the caller already pushed it into `model`). Updates the bound
    /// multiplicatively and stamps a new certificate. Returns the message
    /// to broadcast.
    pub fn local_improvement(&mut self, model: StrongRule, gamma: f64) -> BoostPayload {
        let payload = self.payload().improved(model, gamma);
        self.local_update(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;
    use crate::tmsn::Verdict;
    use crate::util::prop::prop_check;

    fn extend(model: &StrongRule, feature: u32) -> StrongRule {
        let mut m = model.clone();
        m.push(Stump::new(feature, 0.0, 1.0), 0.2);
        m
    }

    #[test]
    fn local_improvement_tightens_bound() {
        let mut s = Tmsn::<BoostPayload>::new(0);
        let msg = s.local_improvement(extend(&s.payload().model.clone(), 1), 0.1);
        assert!(msg.cert.loss_bound < 1.0);
        assert_eq!(msg.cert.origin, 0);
        assert_eq!(msg.cert.seq, 1);
        let b1 = msg.cert.loss_bound;
        let msg2 = s.local_improvement(extend(&s.payload().model.clone(), 2), 0.1);
        assert!(msg2.cert.loss_bound < b1);
        assert_eq!(msg2.cert.seq, 2);
    }

    #[test]
    fn bound_factor_matches_adaboost_z() {
        let mut s = Tmsn::<BoostPayload>::new(0);
        let g = 0.2f64;
        let msg = s.local_improvement(extend(&StrongRule::new(), 0), g);
        assert!((msg.cert.loss_bound - (1.0 - 4.0 * g * g).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn accept_strictly_better_only() {
        let mut a = Tmsn::<BoostPayload>::new(0);
        let mut b = Tmsn::<BoostPayload>::new(1);
        let msg = a.local_improvement(extend(&StrongRule::new(), 0), 0.1);

        // b has the empty model (bound 1.0) → accepts
        assert_eq!(b.on_message(msg.clone()), Verdict::Accept);
        assert_eq!(b.payload().model, a.payload().model);
        assert_eq!(b.cert(), a.cert());

        // replaying the same message is now a reject (not strictly better)
        assert_eq!(b.on_message(msg), Verdict::Reject);
        assert_eq!(b.accepts, 1);
        assert_eq!(b.rejects, 1);
    }

    /// A message carrying an arbitrary certificate (bypasses the
    /// `local_improvement` bound arithmetic to probe the verdict rule
    /// directly).
    fn msg_with_bound(loss_bound: f64, origin: usize, seq: u64) -> BoostPayload {
        BoostPayload {
            model: extend(&StrongRule::new(), origin as u32),
            cert: LossBoundCert {
                loss_bound,
                origin,
                seq,
            },
        }
    }

    #[test]
    fn verdict_accept_iff_strictly_better() {
        // Alg. 1 receive path: accept iff the incoming bound is *strictly*
        // lower — strictly better ⇒ Accept; exact tie ⇒ Reject; worse ⇒
        // Reject. Ties must not churn state (no re-adoption loops).
        let mut s = Tmsn::resume(0, BoostPayload::resume(extend(&StrongRule::new(), 9), 0.5));

        assert_eq!(s.on_message(msg_with_bound(0.49, 1, 1)), Verdict::Accept);
        assert!((s.cert().loss_bound - 0.49).abs() < 1e-15);

        let model_before = s.payload().model.clone();
        assert_eq!(s.on_message(msg_with_bound(0.49, 2, 1)), Verdict::Reject); // tie
        assert_eq!(s.on_message(msg_with_bound(0.50, 2, 2)), Verdict::Reject); // worse
        assert_eq!(s.on_message(msg_with_bound(9.99, 2, 3)), Verdict::Reject); // much worse
        assert_eq!(s.payload().model, model_before, "rejects must not mutate the model");
        assert!((s.cert().loss_bound - 0.49).abs() < 1e-15);
        assert_eq!(s.accepts, 1);
        assert_eq!(s.rejects, 3);
    }

    #[test]
    fn resume_stamps_worker_lineage() {
        let s = Tmsn::resume(4, BoostPayload::resume(extend(&StrongRule::new(), 1), 0.7));
        assert_eq!(s.cert().origin, 4);
        assert_eq!(s.cert().seq, 0);
        assert!((s.cert().loss_bound - 0.7).abs() < 1e-15);
    }

    #[test]
    fn bound_monotone_across_adopted_messages() {
        // The certificate bound never increases, no matter what mix of
        // better/worse/stale messages arrives in what order — the protocol's
        // progress invariant, checked on the accept path specifically.
        let mut s = Tmsn::<BoostPayload>::new(0);
        let bounds = [0.9, 0.95, 0.6, 0.6, 0.61, 0.3, 0.9, 0.05, 0.049, 0.5];
        let mut prev = s.cert().loss_bound;
        for (seq, &b) in bounds.iter().enumerate() {
            let verdict = s.on_message(msg_with_bound(b, 1, seq as u64));
            assert_eq!(verdict == Verdict::Accept, b < prev, "bound {b} vs {prev}");
            assert!(
                s.cert().loss_bound <= prev,
                "adopted bound increased: {prev} -> {}",
                s.cert().loss_bound
            );
            prev = s.cert().loss_bound;
        }
        assert!((prev - 0.049).abs() < 1e-15);
    }

    #[test]
    fn stale_message_rejected() {
        let mut a = Tmsn::<BoostPayload>::new(0);
        let mut b = Tmsn::<BoostPayload>::new(1);
        let old = a.local_improvement(extend(&StrongRule::new(), 0), 0.05);
        let new = a.local_improvement(extend(&a.payload().model.clone(), 1), 0.05);
        assert_eq!(b.on_message(new), Verdict::Accept);
        assert_eq!(b.on_message(old), Verdict::Reject);
    }

    #[test]
    fn wire_bytes_grows_with_model() {
        let mut s = Tmsn::<BoostPayload>::new(0);
        let m1 = s.local_improvement(extend(&StrongRule::new(), 0), 0.1);
        let m2 = s.local_improvement(extend(&s.payload().model.clone(), 1), 0.1);
        assert!(m2.wire_bytes() > m1.wire_bytes());
    }

    #[test]
    fn wire_bytes_is_the_real_encoded_length() {
        // One wire-size model: the fabric's bandwidth delays are driven by
        // the same byte count the TCP transport actually ships.
        let mut s = Tmsn::<BoostPayload>::new(0);
        let msg = s.local_improvement(extend(&StrongRule::new(), 3), 0.1);
        assert_eq!(msg.wire_bytes(), msg.encode().len());
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut model = StrongRule::new();
        model.push(Stump::new(3, 0.5, 1.0), 0.25);
        let m = BoostPayload {
            model,
            cert: LossBoundCert {
                loss_bound: 0.9,
                origin: 7,
                seq: 5,
            },
        };
        let back = BoostPayload::decode(&m.encode()).unwrap();
        assert_eq!(back.model, m.model);
        assert_eq!(back.cert, m.cert);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(BoostPayload::decode(b"nonsense").is_err());
        assert!(BoostPayload::decode(b"cert abc 0 0\nstrongrule v1 0\n").is_err());
        assert!(BoostPayload::decode(b"cert 0.5 0 0\nnot a model").is_err());
        assert!(BoostPayload::decode(b"cert -0.5 0 0\nstrongrule v1 0\n").is_err());
        assert!(BoostPayload::decode(b"cert inf 0 0\nstrongrule v1 0\n").is_err());
        assert!(BoostPayload::decode(&[0xFF, 0xFE, 0x00]).is_err());
    }

    #[test]
    fn prop_better_than_is_a_strict_partial_order() {
        // The protocol's verdict rule and its no-churn/no-rebroadcast
        // guarantees assume `better_than` is a strict partial order over
        // certificates: irreflexive, asymmetric, transitive — and blind
        // to lineage (origin/seq are diagnostics, not ordering keys).
        prop_check("LossBoundCert strict partial order", 200, |rng| {
            // draw from a small pool so exact ties and chains are common
            let pool = [0.0, 0.049, 0.5, 0.5, 1.0, f64::INFINITY];
            let cert = |rng: &mut crate::util::rng::Rng| LossBoundCert {
                loss_bound: if rng.bernoulli(0.5) {
                    pool[rng.below(pool.len() as u64) as usize]
                } else {
                    rng.f64() * 2.0
                },
                origin: rng.below(8) as usize,
                seq: rng.below(100),
            };
            let certs: Vec<LossBoundCert> = (0..5).map(|_| cert(rng)).collect();
            for a in &certs {
                if a.better_than(a) {
                    return Err(format!("irreflexivity violated: {a:?}"));
                }
                for b in &certs {
                    if a.better_than(b) && b.better_than(a) {
                        return Err(format!("asymmetry violated: {a:?} vs {b:?}"));
                    }
                    // equal bounds with different lineage order neither way
                    if a.loss_bound == b.loss_bound && (a.better_than(b) || b.better_than(a)) {
                        return Err(format!("lineage leaked into the order: {a:?} vs {b:?}"));
                    }
                    for c in &certs {
                        if a.better_than(b) && b.better_than(c) && !a.better_than(c) {
                            return Err(format!("transitivity violated: {a:?} {b:?} {c:?}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_payload_roundtrip() {
        prop_check("boost payload roundtrip", 50, |rng| {
            let mut model = StrongRule::new();
            for _ in 0..rng.below(20) {
                model.push(
                    Stump::new(
                        rng.below(1000) as u32,
                        rng.gauss() as f32,
                        if rng.bernoulli(0.5) { 1.0 } else { -1.0 },
                    ),
                    0.01 + rng.f64() as f32,
                );
            }
            let p = BoostPayload {
                model,
                cert: LossBoundCert {
                    loss_bound: rng.f64(),
                    origin: rng.below(64) as usize,
                    seq: rng.below(1 << 40),
                },
            };
            let back = BoostPayload::decode(&p.encode()).map_err(|e| e.to_string())?;
            if back.model != p.model {
                return Err("model mismatch".into());
            }
            if back.cert != p.cert {
                return Err(format!("cert mismatch: {:?} vs {:?}", back.cert, p.cert));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_bound_monotone_along_accept_chain() {
        // Any interleaving of local improvements and message exchanges
        // keeps every worker's bound non-increasing — the protocol's
        // progress invariant.
        prop_check("bounds monotone under TMSN", 50, |rng| {
            let n = 4;
            let mut workers: Vec<Tmsn<BoostPayload>> = (0..n).map(Tmsn::new).collect();
            let mut bounds: Vec<f64> = vec![1.0; n];
            let mut inflight: Vec<BoostPayload> = Vec::new();
            for step in 0..60 {
                let w = rng.below(n as u64) as usize;
                if rng.bernoulli(0.5) || inflight.is_empty() {
                    // local improvement with random γ
                    let g = 0.05 + rng.f64() * 0.3;
                    let model = extend(&workers[w].payload().model.clone(), step as u32);
                    let msg = workers[w].local_improvement(model, g);
                    inflight.push(msg);
                } else {
                    // deliver a random in-flight message (arbitrary order!)
                    let k = rng.below(inflight.len() as u64) as usize;
                    let msg = inflight[k].clone();
                    workers[w].on_message(msg);
                }
                let b = workers[w].cert().loss_bound;
                if b > bounds[w] + 1e-12 {
                    return Err(format!("worker {w} bound increased {} -> {b}", bounds[w]));
                }
                bounds[w] = b;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_convergence_after_full_delivery() {
        // Once every broadcast message is delivered to every worker, all
        // workers hold the minimum bound (the §2 convergence claim).
        prop_check("all workers converge to best bound", 30, |rng| {
            let n = 5;
            let mut workers: Vec<Tmsn<BoostPayload>> = (0..n).map(Tmsn::new).collect();
            let mut all_msgs: Vec<BoostPayload> = Vec::new();
            for step in 0..20 {
                let w = rng.below(n as u64) as usize;
                let g = 0.05 + rng.f64() * 0.3;
                let model = extend(&workers[w].payload().model.clone(), step as u32);
                all_msgs.push(workers[w].local_improvement(model, g));
            }
            let best = all_msgs
                .iter()
                .map(|m| m.cert.loss_bound)
                .fold(f64::INFINITY, f64::min);
            // deliver everything to everyone, in a random order per worker
            for w in workers.iter_mut() {
                let mut order: Vec<usize> = (0..all_msgs.len()).collect();
                rng.shuffle(&mut order);
                for &k in &order {
                    w.on_message(all_msgs[k].clone());
                }
                if (w.cert().loss_bound - best).abs() > 1e-12 && w.cert().loss_bound > best {
                    return Err(format!(
                        "worker {} stuck at {} > best {best}",
                        w.worker_id(),
                        w.cert().loss_bound
                    ));
                }
            }
            Ok(())
        });
    }
}
