//! # Sparrow-RS
//!
//! Reproduction of *"Tell Me Something New: A New Framework for Asynchronous
//! Parallel Learning"* (Alafate & Freund, 2018): the **TMSN** asynchronous
//! broadcast protocol and the **Sparrow** boosted-tree learner built on it,
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see `DESIGN.md` for the full inventory):
//! - **L3 (this crate)** — the payload-generic TMSN protocol ([`tmsn`]:
//!   `Payload`/`Certified`/`Tmsn`/`Driver`, with boosting instantiated in
//!   [`tmsn::boost`] and a second, SGD workload in [`sgd`]), Sparrow
//!   workers ([`scanner`], [`sampler`], [`worker`]), cluster
//!   [`coordinator`], broadcast [`network`] fabric, disk/memory [`data`]
//!   stores, the [`baselines`] the paper compares against,
//!   [`eval`]/[`metrics`], the deterministic fault-injection
//!   simulator ([`sim`]: virtual-time clock, seeded fault fabric,
//!   scripted crash/laggard/partition scenarios), and the production
//!   control plane ([`admin`]: versioned JSON-RPC endpoint with live
//!   metrics, config nudges, and fault injection; [`serve`]: hot-swap
//!   model serving behind `sparrow serve` — see OPERATIONS.md).
//! - **L2/L1 (python/compile, build-time)** — the JAX scan-batch graph and
//!   the Pallas edge kernel, AOT-lowered to `artifacts/*.hlo.txt` and
//!   executed from [`runtime`] via PJRT. Python never runs at train time.
//!
//! The build is fully offline: the only dependencies (`anyhow`, `xla`) are
//! vendored under `vendor/` — `anyhow` as an API-compatible shim, `xla` as
//! a compile-only stub that errors at runtime (the native backend is the
//! default and needs neither). See `rust/Cargo.toml` for the swap points.

pub mod admin;
pub mod baselines;
pub mod boosting;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod network;
pub mod runtime;
pub mod sampler;
pub mod sampling;
pub mod scanner;
pub mod serve;
pub mod sgd;
pub mod sim;
pub mod stopping;
pub mod tmsn;
pub mod util;
pub mod worker;

pub fn crate_version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
