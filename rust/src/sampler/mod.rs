//! The Sampler (paper §4.1): builds a fresh, effectively-uniform in-memory
//! sample from the disk-resident store by selective sampling.
//!
//! When the Scanner's `n_eff/m` collapses, the worker streams the
//! (pre-permuted) disk store, scores each example under the current model,
//! and keeps it with probability proportional to `w = exp(-y·H(x))`; kept
//! copies enter the new sample with weight 1. The stream is circular: the
//! pass continues until the target sample size is reached (bounded by
//! `max_passes`). The time spent here is the flat plateau visible in the
//! paper's Figures 3-4.
//!
//! Two drive modes share this module (`SamplerMode` in
//! [`crate::config`], spec in DESIGN.md §4):
//!
//! * **Blocking** (default, paper-faithful): [`Sampler::resample`] runs on
//!   the worker thread; the scanner idles for the whole pass — that *is*
//!   the plateau.
//! * **Background**: a [`background::BackgroundSampler`] thread builds the
//!   next sample concurrently against the latest adopted model over a
//!   stratified store ([`crate::data::strata`]), stamps it with the model
//!   version, and hands it over through the double-buffered
//!   [`handle::SampleHandle`]; the scanner flips at a batch boundary with
//!   ~zero stall, and a TMSN adoption mid-build invalidates the in-flight
//!   sample.
//!
//! # Example
//!
//! Blocking resample against the empty model:
//!
//! ```
//! use sparrow::data::synth::SynthGen;
//! use sparrow::data::{IoThrottle, SynthConfig};
//! use sparrow::model::StrongRule;
//! use sparrow::sampler::{Sampler, SamplerConfig};
//! use sparrow::util::rng::Rng;
//!
//! let dir = std::env::temp_dir().join("sparrow_doc_sampler");
//! std::fs::create_dir_all(&dir).unwrap();
//! let path = dir.join("doc.sprw");
//! let synth = SynthConfig { f: 4, pos_rate: 0.4, informative: 2, signal: 1.0,
//!                           flip_rate: 0.0, seed: 1 };
//! let store = SynthGen::new(synth).write_store(&path, 2000).unwrap();
//!
//! let mut sampler = Sampler::new(
//!     store.stream(IoThrottle::unlimited()).unwrap(),
//!     store.len(),
//!     SamplerConfig { target_m: 256, ..SamplerConfig::default() },
//!     Rng::new(7),
//! );
//! let (sample, stats) = sampler.resample(&StrongRule::new()).unwrap();
//! assert_eq!(sample.len(), 256);
//! assert!(stats.read >= 256);
//! ```

#![warn(missing_docs)]

pub mod background;
pub mod handle;
pub mod tiered;

pub use background::{build_once, BackgroundSampler, BuildOutcome};
pub use handle::{BuildStamp, BuiltSample, SampleHandle};
pub use tiered::build_tiered;

use std::time::{Duration, Instant};

use crate::config::SamplerKind;
use crate::data::store::StoreStream;
use crate::data::{DataBlock, SampleSet};
use crate::model::StrongRule;
use crate::sampling::{MinimalVarianceSampler, RejectionSampler, SelectiveSampler, UniformSampler};
use crate::util::rng::Rng;

/// Sampler configuration.
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// target in-memory sample size m
    pub target_m: usize,
    /// which selective-sampling strategy keeps examples (A2 ablation)
    pub kind: SamplerKind,
    /// examples probed to estimate the selection scale
    pub probe: usize,
    /// stop after this many circular passes even if under target
    /// (blocking mode only; a background build is exactly one pass)
    pub max_passes: u32,
    /// disk-read block size
    pub block: usize,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            target_m: 2048,
            kind: SamplerKind::MinimalVariance,
            probe: 2048,
            max_passes: 3,
            block: 1024,
        }
    }
}

/// Outcome statistics of one resampling pass (events + Fig-3 plateaus).
#[derive(Debug, Clone, Copy)]
pub struct SampleStats {
    /// store records read (and scored) during the pass
    pub read: u64,
    /// examples kept into the new sample
    pub kept: usize,
    /// wall-clock time of the pass, throttle stalls included
    pub duration: Duration,
    /// mean example weight estimated by the probe
    pub mean_weight: f64,
}

/// The sampler process: owns the disk stream cursor.
pub struct Sampler {
    stream: StoreStream,
    store_len: usize,
    cfg: SamplerConfig,
    rng: Rng,
}

impl Sampler {
    /// A sampler over `stream` (a circular cursor into a store of
    /// `store_len` examples). The cursor position persists across
    /// [`Sampler::resample`] calls, so successive resamples read
    /// successive regions of the permuted store.
    pub fn new(stream: StoreStream, store_len: usize, cfg: SamplerConfig, rng: Rng) -> Sampler {
        assert!(store_len > 0, "empty store");
        assert!(cfg.target_m >= 1);
        Sampler {
            stream,
            store_len,
            cfg,
            rng,
        }
    }

    /// Build a fresh sample under `model`.
    pub fn resample(&mut self, model: &StrongRule) -> std::io::Result<(SampleSet, SampleStats)> {
        let t0 = Instant::now();
        let m = self.cfg.target_m;

        // Probe: estimate the mean weight to size the selection scale so
        // one full pass yields ≈ m keeps.
        let probe_n = self.cfg.probe.min(self.store_len).max(1);
        let probe = self.stream.next_block(probe_n)?;
        let probe_scored = score_block(model, &probe);
        let mean_w = (probe_scored.iter().map(|&(_, w)| w).sum::<f64>() / probe.n as f64)
            .max(1e-300);
        let scale = mean_w * self.store_len as f64 / m as f64;

        let mut sampler: Box<dyn SelectiveSampler> = match self.cfg.kind {
            SamplerKind::MinimalVariance => {
                Box::new(MinimalVarianceSampler::new(scale, &mut self.rng))
            }
            SamplerKind::Rejection => Box::new(RejectionSampler::new(scale)),
            SamplerKind::Uniform => {
                Box::new(UniformSampler::new((m as f64 / self.store_len as f64).min(1.0)))
            }
        };

        let mut data = DataBlock::empty(probe.f);
        let mut scores = Vec::with_capacity(m);
        let mut weights = Vec::with_capacity(m); // true w (uniform kind)
        let mut read = probe.n as u64;

        // offer the probe block first (its reads shouldn't be wasted)
        offer_block(
            &probe,
            &probe_scored,
            sampler.as_mut(),
            &mut self.rng,
            m,
            &mut data,
            &mut scores,
            &mut weights,
        );

        let budget = self.cfg.max_passes as u64 * self.store_len as u64;
        while data.n < m && read < budget {
            let take = self.cfg.block.min((budget - read) as usize);
            let block = self.stream.next_block(take)?;
            if block.is_empty() {
                break;
            }
            read += block.n as u64;
            let scored = score_block(model, &block);
            offer_block(
                &block,
                &scored,
                sampler.as_mut(),
                &mut self.rng,
                m,
                &mut data,
                &mut scores,
                &mut weights,
            );
        }

        let kept = data.n;
        let stats = SampleStats {
            read,
            kept,
            duration: t0.elapsed(),
            mean_weight: mean_w,
        };
        let sample = if self.cfg.kind == SamplerKind::Uniform {
            SampleSet::with_weights(data, scores, weights, model.len() as u32)
        } else {
            SampleSet::fresh(data, scores, model.len() as u32)
        };
        Ok((sample, stats))
    }

    /// Total time the underlying stream spent throttled (off-memory tier).
    pub fn stalled(&self) -> Duration {
        self.stream.stalled()
    }
}

/// Score a block under `model`, returning per-example `(score, weight)`
/// with `w = exp(-y·H(x))`. Shared by the blocking and background passes.
pub(crate) fn score_block(model: &StrongRule, block: &DataBlock) -> Vec<(f32, f64)> {
    (0..block.n)
        .map(|i| {
            let s = model.score(block.row(i));
            let w = (-(block.label(i) as f64) * s as f64).exp();
            (s, w)
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn offer_block(
    block: &DataBlock,
    scored: &[(f32, f64)],
    sampler: &mut dyn SelectiveSampler,
    rng: &mut Rng,
    m: usize,
    data: &mut DataBlock,
    scores: &mut Vec<f32>,
    weights: &mut Vec<f32>,
) {
    for i in 0..block.n {
        if data.n >= m {
            return;
        }
        let (s, w) = scored[i];
        let copies = sampler.offer(w, rng);
        for _ in 0..copies {
            if data.n >= m {
                return;
            }
            data.push(block.row(i), block.label(i));
            scores.push(s);
            weights.push(w as f32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DiskStore, IoThrottle, SynthConfig};
    use crate::data::synth::SynthGen;
    use crate::model::Stump;

    fn make_store(n: usize, seed: u64) -> DiskStore {
        let dir = std::env::temp_dir().join("sparrow_sampler_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("store_{seed}_{n}.sprw"));
        let cfg = SynthConfig {
            f: 8,
            pos_rate: 0.3,
            informative: 4,
            signal: 1.0,
            flip_rate: 0.0,
            seed,
        };
        SynthGen::new(cfg).write_store(&path, n).unwrap()
    }

    fn sampler_for(store: &DiskStore, kind: SamplerKind, m: usize, seed: u64) -> Sampler {
        Sampler::new(
            store.stream(IoThrottle::unlimited()).unwrap(),
            store.len(),
            SamplerConfig {
                target_m: m,
                kind,
                probe: 256,
                max_passes: 3,
                block: 512,
            },
            Rng::new(seed),
        )
    }

    #[test]
    fn empty_model_yields_near_uniform_sample() {
        let store = make_store(5000, 1);
        let mut s = sampler_for(&store, SamplerKind::MinimalVariance, 1000, 2);
        let (sample, stats) = s.resample(&StrongRule::new()).unwrap();
        assert_eq!(sample.len(), 1000);
        assert!(stats.read <= 3 * 5000);
        // empty model → all weights 1 → n_eff = m
        assert!((sample.n_eff() - 1000.0).abs() < 1e-6);
        // positive rate preserved (weights uniform)
        assert!((sample.data.positive_rate() - 0.3).abs() < 0.06);
    }

    #[test]
    fn trained_model_overselects_hard_examples() {
        let store = make_store(8000, 3);
        // a model confidently right on positives via informative features →
        // use a stump on feature 0 with big alpha; hard examples (wrong
        // side) get upweighted and should be overrepresented
        let mut model = StrongRule::new();
        model.push(Stump::new(0, 0.0, 1.0), 1.5);
        let mut s = sampler_for(&store, SamplerKind::MinimalVariance, 1000, 4);
        let (sample, _) = s.resample(&model).unwrap();
        assert_eq!(sample.len(), 1000);
        // the kept set should skew toward examples the model got wrong:
        // their (pre-sampling) weight was > 1
        let mut hard = 0usize;
        for i in 0..sample.len() {
            let y = sample.data.label(i);
            if y * model.score(sample.data.row(i)) < 0.0 {
                hard += 1;
            }
        }
        // under uniform sampling the wrong-side fraction would equal the
        // model's error rate; weighted sampling multiplies it by ~e^{2α}
        assert!(hard > sample.len() / 4, "hard={hard}");
        // fresh sample resets weights to 1
        assert!((sample.n_eff() - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn rejection_kind_reaches_target() {
        let store = make_store(4000, 5);
        let mut s = sampler_for(&store, SamplerKind::Rejection, 500, 6);
        let (sample, _) = s.resample(&StrongRule::new()).unwrap();
        assert_eq!(sample.len(), 500);
    }

    #[test]
    fn uniform_kind_keeps_true_weights() {
        let store = make_store(4000, 7);
        let mut model = StrongRule::new();
        model.push(Stump::new(1, 0.0, 1.0), 0.9);
        let mut s = sampler_for(&store, SamplerKind::Uniform, 500, 8);
        let (sample, _) = s.resample(&model).unwrap();
        assert!(sample.len() > 300, "len={}", sample.len());
        // uniform sampling does NOT reset weights → n_eff < m
        assert!(sample.n_eff() < sample.len() as f64 * 0.999);
        // weights match exp(-y H)
        for i in 0..sample.len().min(50) {
            let want = (-(sample.data.label(i)) * model.score(sample.data.row(i))).exp();
            assert!((sample.w_last[i] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn pass_budget_bounds_reads() {
        let store = make_store(1000, 9);
        // impossible target (more than the data can ever yield at scale):
        // the pass budget must stop the loop
        let mut s = Sampler::new(
            store.stream(IoThrottle::unlimited()).unwrap(),
            store.len(),
            SamplerConfig {
                target_m: 100_000,
                kind: SamplerKind::Uniform,
                probe: 100,
                max_passes: 2,
                block: 500,
            },
            Rng::new(10),
        );
        let (sample, stats) = s.resample(&StrongRule::new()).unwrap();
        assert!(stats.read <= 2 * 1000 + 500);
        assert!(sample.len() < 100_000);
    }

    #[test]
    fn deterministic_given_seed() {
        let store = make_store(3000, 11);
        let run = |seed| {
            let mut s = sampler_for(&store, SamplerKind::MinimalVariance, 400, seed);
            s.resample(&StrongRule::new()).unwrap().0
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a.data, b.data);
        let c = run(43);
        assert!(a.data != c.data || a.len() != c.len());
    }
}
