//! Double-buffered sample handoff between the background sampler thread
//! and the scanner (DESIGN.md §4: the swap protocol).
//!
//! The handle holds at most one **pending** sample, stamped with the model
//! version (and build attempt) it was built against. The builder publishes
//! into the slot — latest wins, an unclaimed older pending is dropped — and
//! the scanner takes from it at a batch boundary. The take is guarded by
//! the scanner's *current* version: a pending sample stamped with any other
//! version is discarded on sight, which is the consumer half of the
//! invalidation invariant (the swapped-in sample is always one built
//! against the currently-adopted model).
//!
//! The swap itself is a constant-time pointer move under an uncontended
//! mutex (each side holds the lock only to move a `Box`); the `ready` flag
//! is a separate atomic so the scanner's between-batches poll never takes
//! the lock at all.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::data::SampleSet;
use crate::sampler::SampleStats;

/// Identity of one background build: the worker-local model version it was
/// built against, plus a per-version attempt counter (bumped when the same
/// model needs a *different* sample, e.g. after the scanner exhausts one).
///
/// Together with the run seed, the stamp fully determines the accepted
/// sample's contents — see `sampler::background::build_once`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BuildStamp {
    /// worker-local model version (bumped on every adoption and publish)
    pub version: u64,
    /// rebuild counter within one version
    pub attempt: u64,
}

/// A finished background build: the sample, its build statistics, and the
/// stamp identifying the model it was built against.
#[derive(Debug)]
pub struct BuiltSample {
    /// the freshly built in-memory sample
    pub sample: SampleSet,
    /// statistics of the build pass (reads, keeps, duration, mean weight)
    pub stats: SampleStats,
    /// which (version, attempt) this sample realizes
    pub stamp: BuildStamp,
}

struct Shared {
    pending: Mutex<Option<Box<BuiltSample>>>,
    cv: Condvar,
    /// own Arc so interrupt closures can hold the flag without the handle
    ready: Arc<AtomicBool>,
}

/// The scanner ⇄ builder handoff slot. Cheaply cloneable; all clones share
/// the same single pending buffer.
#[derive(Clone)]
pub struct SampleHandle {
    shared: Arc<Shared>,
}

impl Default for SampleHandle {
    fn default() -> Self {
        SampleHandle::new()
    }
}

impl SampleHandle {
    /// Create an empty handle.
    pub fn new() -> SampleHandle {
        SampleHandle {
            shared: Arc::new(Shared {
                pending: Mutex::new(None),
                cv: Condvar::new(),
                ready: Arc::new(AtomicBool::new(false)),
            }),
        }
    }

    /// Builder side: publish a finished sample. Replaces any unclaimed
    /// pending sample (latest wins) and wakes a waiting consumer.
    pub fn publish(&self, built: BuiltSample) {
        let mut slot = self.shared.pending.lock().unwrap();
        *slot = Some(Box::new(built));
        self.shared.ready.store(true, Ordering::Release);
        self.shared.cv.notify_all();
    }

    /// Is a pending sample available? Lock-free; safe to poll from the
    /// scanner's between-batches interrupt check.
    pub fn ready(&self) -> bool {
        self.shared.ready.load(Ordering::Acquire)
    }

    /// A clone of the ready flag for embedding in interrupt closures
    /// (lets the scanner poll without borrowing the handle).
    pub fn ready_flag(&self) -> Arc<AtomicBool> {
        self.shared.ready.clone()
    }

    /// Consumer side: take the pending sample **iff** it was built against
    /// `current_version`. A pending sample with any other version stamp is
    /// discarded (the model moved on while it was in flight) and `None` is
    /// returned.
    pub fn take_if_current(&self, current_version: u64) -> Option<BuiltSample> {
        let mut slot = self.shared.pending.lock().unwrap();
        let taken = match slot.take() {
            Some(b) if b.stamp.version == current_version => Some(*b),
            // stale: drop it (building for the current version is the
            // producer's job; see BackgroundSampler::request)
            _ => None,
        };
        self.shared.ready.store(slot.is_some(), Ordering::Release);
        taken
    }

    /// Block until [`SampleHandle::take_if_current`] succeeds or `give_up`
    /// returns true (checked at least every `tick`). Used only for the
    /// initial fill, when the scanner has no sample to keep working on.
    pub fn wait_take(
        &self,
        current_version: u64,
        tick: Duration,
        mut give_up: impl FnMut() -> bool,
    ) -> Option<BuiltSample> {
        let mut slot = self.shared.pending.lock().unwrap();
        loop {
            match slot.take() {
                Some(b) if b.stamp.version == current_version => {
                    self.shared.ready.store(false, Ordering::Release);
                    return Some(*b);
                }
                Some(_) => {
                    // stale pending: discard and keep waiting
                    self.shared.ready.store(false, Ordering::Release);
                }
                None => {}
            }
            if give_up() {
                return None;
            }
            let (s, _) = self.shared.cv.wait_timeout(slot, tick).unwrap();
            slot = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SampleSet;
    use std::time::Duration as D;

    fn built(version: u64, attempt: u64, n: usize) -> BuiltSample {
        let mut data = crate::data::DataBlock::empty(1);
        for i in 0..n {
            data.push(&[i as f32], 1.0);
        }
        let len = data.n;
        BuiltSample {
            sample: SampleSet::fresh(data, vec![0.0; len], 0),
            stats: SampleStats {
                read: n as u64,
                kept: n,
                duration: D::ZERO,
                mean_weight: 1.0,
            },
            stamp: BuildStamp { version, attempt },
        }
    }

    #[test]
    fn publish_take_roundtrip() {
        let h = SampleHandle::new();
        assert!(!h.ready());
        assert!(h.take_if_current(0).is_none());
        h.publish(built(3, 0, 5));
        assert!(h.ready());
        let b = h.take_if_current(3).expect("matching version");
        assert_eq!(b.stamp, BuildStamp { version: 3, attempt: 0 });
        assert_eq!(b.sample.len(), 5);
        assert!(!h.ready());
    }

    #[test]
    fn stale_pending_discarded() {
        let h = SampleHandle::new();
        h.publish(built(1, 0, 4));
        // consumer has moved on to version 2: the v1 sample must never be
        // installed, and the slot must come back empty
        assert!(h.take_if_current(2).is_none());
        assert!(!h.ready());
        assert!(h.take_if_current(1).is_none(), "discard is permanent");
    }

    #[test]
    fn latest_publish_wins() {
        let h = SampleHandle::new();
        h.publish(built(5, 0, 2));
        h.publish(built(5, 1, 9));
        let b = h.take_if_current(5).unwrap();
        assert_eq!(b.stamp.attempt, 1);
        assert_eq!(b.sample.len(), 9);
        assert!(h.take_if_current(5).is_none(), "slot holds one sample");
    }

    #[test]
    fn adoption_storm_never_installs_stale_and_swaps_only_at_boundaries() {
        // Seeded, sleep-free simulation of an adoption storm: model
        // versions race ahead of the builder, publishes land for current
        // and stale versions alike, and the scanner hits batch boundaries
        // at arbitrary points in between. Invariants under every
        // interleaving:
        //   1. a take only ever returns a sample stamped with the
        //      scanner's *current* version (stale pendings are discarded);
        //   2. mid-batch `ready()` polls never consume or mutate the slot;
        //   3. after any boundary (take attempt) the slot is empty and the
        //      ready flag agrees.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xAD0B);
        let h = SampleHandle::new();
        let mut version = 0u64; // the scanner's current model version
        let mut attempt = 0u64;
        let mut installed = 0u64;
        let mut discarded_probes = 0u64;
        for _ in 0..5_000 {
            match rng.below(4) {
                0 => {
                    // adoption storm: the model moves on (possibly while a
                    // build for the old version sits unclaimed)
                    version += 1;
                    attempt = 0;
                }
                1 => {
                    // builder publishes; sometimes for an already-stale
                    // version (it raced an adoption)
                    let behind = rng.below(3);
                    let v = version.saturating_sub(behind);
                    h.publish(built(v, attempt, 1 + (v % 7) as usize));
                    attempt += 1;
                    assert!(h.ready(), "publish must raise the ready flag");
                }
                2 => {
                    // mid-batch: the scanner peeks the flag (twice — the
                    // poll must be side-effect free)
                    let r1 = h.ready();
                    let r2 = h.ready();
                    assert_eq!(r1, r2, "ready() must not consume");
                }
                _ => {
                    // batch boundary: the only place a swap may land
                    let was_ready = h.ready();
                    match h.take_if_current(version) {
                        Some(b) => {
                            assert!(was_ready, "take succeeded with flag down");
                            assert_eq!(
                                b.stamp.version, version,
                                "a stale build was installed"
                            );
                            installed += 1;
                        }
                        None => {
                            if was_ready {
                                // there was a pending build but it was
                                // stale — it must now be gone for good
                                discarded_probes += 1;
                            }
                        }
                    }
                    assert!(!h.ready(), "slot must be empty after a boundary");
                    assert!(h.take_if_current(version).is_none());
                }
            }
        }
        // the storm must actually exercise both outcomes
        assert!(installed > 100, "installed only {installed} builds");
        assert!(discarded_probes > 100, "discarded only {discarded_probes} stale builds");
    }

    #[test]
    fn wait_take_gives_up() {
        let h = SampleHandle::new();
        let mut polls = 0;
        let got = h.wait_take(0, D::from_millis(1), || {
            polls += 1;
            polls > 2
        });
        assert!(got.is_none());
    }

    #[test]
    fn wait_take_crosses_threads() {
        let h = SampleHandle::new();
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            h2.publish(built(7, 0, 3));
        });
        let b = h.wait_take(7, D::from_millis(5), || false).unwrap();
        assert_eq!(b.stamp.version, 7);
        t.join().unwrap();
    }
}
