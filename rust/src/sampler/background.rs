//! The background sampler: builds the next [`SampleSet`] on its own thread
//! while the Scanner keeps working (DESIGN.md §4).
//!
//! The paper's Figures 3–4 show flat plateaus where every worker stalls
//! while its Sampler rebuilds the in-memory sample. Nothing in TMSN
//! requires that stall: sampling only *reads* the disk store and the
//! adopted model, so it can proceed concurrently with scanning, and a TMSN
//! broadcast interrupts both (the scan between batches, the build between
//! blocks). This module supplies the builder side of that pipeline; the
//! handoff lives in [`super::handle`] and the policy wiring in
//! [`crate::worker`].
//!
//! # Determinism: contents are a pure function of `(seed, stamp, model)`
//!
//! A concurrent build can be aborted at *any* block boundary by an
//! adoption, so sample contents must not depend on where an abort landed.
//! [`build_once`] therefore differs from the blocking sampler's streaming
//! pass in two deliberate ways:
//!
//! 1. **Per-example hash coins.** Instead of one sequential RNG stream
//!    (whose draws shift when the visit order or stop point changes), every
//!    example `i` gets its own RNG seeded from
//!    `(seed, version, attempt, i)`. Acceptance of example `i` depends on
//!    nothing but its own fresh weight and its own coins.
//! 2. **One full pass, no early stop.** The pass visits every record
//!    exactly once and never truncates at the target size `m`; the
//!    selection scale is calibrated (from a deterministic probe prefix) so
//!    the expected kept count is `m`. Kept count therefore varies by a few
//!    percent around `m` — the price of order-independence.
//!
//! Together these make the accepted sample a pure function of
//! `(seed, BuildStamp, model, store)` — byte-identical no matter how many
//! earlier builds were aborted, how the pass was chunked, or what the
//! strata index contained (the index only re-prices I/O; see
//! [`crate::data::strata`]).
//!
//! The blocking sampler ([`super::Sampler`]) is untouched by all of this
//! and remains the paper-faithful default.

use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::SamplerKind;
use crate::data::strata::{StrataConfig, StratifiedStore};
use crate::data::tiered::{TieredConfig, TieredStore};
use crate::data::{BinSpec, DataBlock, IoThrottle, SampleSet};
use crate::metrics::{EventKind, EventLog};
use crate::model::StrongRule;
use crate::sampler::handle::{BuildStamp, BuiltSample, SampleHandle};
use crate::sampler::tiered::build_tiered;
use crate::sampler::{score_block, SampleStats, SamplerConfig};
use crate::util::rng::Rng;

/// Result of one build attempt.
#[derive(Debug)]
pub enum BuildOutcome {
    /// The pass completed; the sample is ready to publish.
    Built {
        /// the freshly built sample
        sample: SampleSet,
        /// build statistics (reads, keeps, duration, mean weight)
        stats: SampleStats,
    },
    /// The invalidation check fired mid-pass (a newer model was adopted);
    /// the in-flight sample was discarded and the strata index untouched.
    Invalidated {
        /// records read before the abort
        read: u64,
    },
}

/// Upper bound on copies of a single example per build (weight-proportional
/// kinds). Purely per-example, so it preserves order-independence.
const MAX_COPIES_PER_EXAMPLE: f64 = 1024.0;

/// RNG key shared by every example coin of one build.
pub(crate) fn coin_key(seed: u64, stamp: BuildStamp) -> u64 {
    seed ^ stamp.version.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ stamp.attempt.wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
}

/// Per-example coin RNG: decorrelated from neighbours by SplitMix seeding.
pub(crate) fn example_rng(key: u64, i: u64) -> Rng {
    Rng::new(key ^ (i + 1).wrapping_mul(0xFF51_AFD7_ED55_8CCD))
}

/// The acceptance coin of example `gi`: the first `f64` its per-example
/// RNG yields — the exact value [`copies_for`]'s Bernoulli consumes. The
/// tiered pass uses it to prove rejections without reading the example
/// (`data::tiered::draw`).
pub(crate) fn first_coin(key: u64, gi: u64) -> f64 {
    example_rng(key, gi).f64()
}

/// Copies kept of example `gi` with fresh weight `w`: the per-example
/// acceptance rule shared by the in-memory and tiered passes. Pure in
/// `(kind, key, scale, uniform_rate, gi, w)`, so visit order never
/// matters. For the weight-proportional kinds `copies = 0` **iff**
/// `scale · first_coin ≥ w` (one copy is unconditional once `w ≥ scale`,
/// and the coin is < 1); for `Uniform` it is `first_coin ≥ uniform_rate`.
pub(crate) fn copies_for(
    kind: SamplerKind,
    key: u64,
    scale: f64,
    uniform_rate: f64,
    gi: u64,
    w: f64,
) -> usize {
    let mut rng = example_rng(key, gi);
    match kind {
        SamplerKind::Uniform => usize::from(rng.bernoulli(uniform_rate)),
        _ => {
            // per-example copy cap: a pure, order-independent guard
            // against a wildly unrepresentative probe scale
            let expect = (w / scale).min(MAX_COPIES_PER_EXAMPLE);
            let base = expect.floor();
            base as usize + usize::from(rng.bernoulli(expect - base))
        }
    }
}

/// Build one sample against `model`, identified by `stamp`.
///
/// Visits every record of `store` exactly once (sequential pass, resident
/// strata not charged to the throttle), computes each example's fresh
/// weight, and keeps `⌊w/c⌋ + Bernoulli(frac)` copies using the example's
/// own seeded coin (`SamplerKind::Uniform` keeps with the flat rate `m/n`
/// and carries true weights, as in the blocking sampler's ablation mode).
///
/// `invalidated` is polled between blocks; returning `true` aborts the
/// build, discards all buffered strata observations, and yields
/// [`BuildOutcome::Invalidated`].
pub fn build_once(
    store: &mut StratifiedStore,
    model: &StrongRule,
    stamp: BuildStamp,
    cfg: &SamplerConfig,
    seed: u64,
    mut invalidated: impl FnMut() -> bool,
) -> io::Result<BuildOutcome> {
    let t0 = Instant::now();
    let n = store.len();
    let f = store.num_features();
    if n == 0 {
        return Ok(BuildOutcome::Built {
            sample: SampleSet::empty(f),
            stats: SampleStats {
                read: 0,
                kept: 0,
                duration: t0.elapsed(),
                mean_weight: 0.0,
            },
        });
    }
    let m = cfg.target_m.max(1);
    let key = coin_key(seed, stamp);
    store.begin_build()?;

    // Probe: the deterministic prefix 0..probe_n estimates the mean weight,
    // sizing the selection scale so the full pass yields ≈ m keeps.
    let probe_n = cfg.probe.min(n).max(1);
    let (probe_start, probe) = store.next_block(probe_n)?;
    debug_assert_eq!(probe_start, 0);
    let probe_scored = score_block(model, &probe);
    let mean_w =
        (probe_scored.iter().map(|&(_, w)| w).sum::<f64>() / probe.n as f64).max(1e-300);
    let scale = mean_w * n as f64 / m as f64;
    let uniform_rate = (m as f64 / n as f64).min(1.0);

    let mut data = DataBlock::empty(probe.f);
    let mut scores = Vec::with_capacity(m);
    let mut weights = Vec::with_capacity(m);
    let mut read = probe.n as u64;
    offer_block(
        cfg.kind,
        key,
        scale,
        uniform_rate,
        0,
        &probe,
        &probe_scored,
        store,
        &mut data,
        &mut scores,
        &mut weights,
    );

    while (read as usize) < n {
        if invalidated() {
            store.abort_build();
            return Ok(BuildOutcome::Invalidated { read });
        }
        let (start, block) = store.next_block(cfg.block.max(1))?;
        if block.is_empty() {
            break;
        }
        let scored = score_block(model, &block);
        read += block.n as u64;
        offer_block(
            cfg.kind,
            key,
            scale,
            uniform_rate,
            start,
            &block,
            &scored,
            store,
            &mut data,
            &mut scores,
            &mut weights,
        );
    }
    store.commit_build();

    let kept = data.n;
    let stats = SampleStats {
        read,
        kept,
        duration: t0.elapsed(),
        mean_weight: mean_w,
    };
    let sample = if cfg.kind == SamplerKind::Uniform {
        SampleSet::with_weights(data, scores, weights, model.len() as u32)
    } else {
        SampleSet::fresh(data, scores, model.len() as u32)
    };
    Ok(BuildOutcome::Built { sample, stats })
}

#[allow(clippy::too_many_arguments)]
fn offer_block(
    kind: SamplerKind,
    key: u64,
    scale: f64,
    uniform_rate: f64,
    start: usize,
    block: &DataBlock,
    scored: &[(f32, f64)],
    store: &mut StratifiedStore,
    data: &mut DataBlock,
    scores: &mut Vec<f32>,
    weights: &mut Vec<f32>,
) {
    for i in 0..block.n {
        let gi = start + i;
        let (s, w) = scored[i];
        store.note_weight(gi, w);
        let copies = copies_for(kind, key, scale, uniform_rate, gi as u64, w);
        for _ in 0..copies {
            data.push(block.row(i), block.label(i));
            scores.push(s);
            weights.push(w as f32);
        }
    }
}

/// Which data plane backs the builder thread: the in-memory stratified
/// store (`--store-tier mem`, the default) or the out-of-core tiered
/// store (`--store-tier tiered`, DESIGN.md §11). Both produce
/// byte-identical samples for equal `(seed, stamp, model, store bytes)`.
pub(crate) enum BuildStore {
    /// whole store behind one sequential cursor, residency simulated
    Mem(StratifiedStore),
    /// chunk-file tiers with certified-skip reads and readahead
    Tiered(Box<TieredStore>),
}

struct Job {
    model: StrongRule,
    stamp: BuildStamp,
}

struct CtrlState {
    job: Option<Job>,
    shutdown: bool,
}

struct Ctrl {
    state: Mutex<CtrlState>,
    cv: Condvar,
    /// bumped (under the state lock) on every post and on shutdown; the
    /// builder polls it between blocks — the invalidation signal
    epoch: AtomicU64,
    /// fatal builder I/O error, surfaced to the worker as a crash
    failed: Mutex<Option<String>>,
}

/// Owner handle for the background sampler thread.
///
/// The worker drives it with four calls:
/// * [`BackgroundSampler::request`] — "I need a (new) sample for model
///   version `v`"; deduplicates while a build for `v` is outstanding, and
///   bumps the attempt counter when a fresh sample of the *same* version
///   is needed (the scanner exhausted the previous one).
/// * [`BackgroundSampler::on_model_change`] — "the adopted model changed";
///   restarts the outstanding build (if any) against the new model. This
///   is the invalidation path: the in-flight pass aborts at its next block
///   boundary.
/// * [`BackgroundSampler::try_install`] — non-blocking take at a batch
///   boundary; returns only samples stamped with the current version.
/// * [`BackgroundSampler::wait_install`] — blocking take for the initial
///   fill, when there is no previous sample to keep scanning.
///
/// Dropping the handle shuts the thread down (it aborts any in-flight
/// build and joins).
///
/// # Example
///
/// ```
/// use sparrow::data::synth::SynthGen;
/// use sparrow::data::{IoThrottle, StrataConfig, SynthConfig};
/// use sparrow::metrics::EventLog;
/// use sparrow::model::StrongRule;
/// use sparrow::sampler::{BackgroundSampler, SamplerConfig};
///
/// let dir = std::env::temp_dir().join("sparrow_doc_bg_sampler");
/// std::fs::create_dir_all(&dir).unwrap();
/// let path = dir.join("doc.sprw");
/// let synth = SynthConfig { f: 4, pos_rate: 0.4, informative: 2, signal: 1.0,
///                           flip_rate: 0.0, seed: 2 };
/// SynthGen::new(synth).write_store(&path, 1000).unwrap();
///
/// let (log, _rx) = EventLog::new();
/// let mut bg = BackgroundSampler::spawn(
///     &path,
///     IoThrottle::unlimited(),
///     StrataConfig::default(),
///     SamplerConfig { target_m: 128, ..SamplerConfig::default() },
///     None, // bin spec — Some(_) prebuilds the binned engine's stripe view
///     7,  // seed — sample contents are a pure function of (seed, stamp, model)
///     0,  // worker id for event logging
///     log,
/// ).unwrap();
///
/// bg.request(0, &StrongRule::new()); // build against model version 0
/// let (sample, stats) = bg.wait_install(0, || false).unwrap().expect("built");
/// assert!(!sample.is_empty());
/// assert_eq!(stats.read, 1000); // one full pass, no truncation
/// ```
pub struct BackgroundSampler {
    ctrl: Arc<Ctrl>,
    handle: SampleHandle,
    thread: Option<JoinHandle<()>>,
    requested: Option<BuildStamp>,
    installed: Option<BuildStamp>,
}

impl BackgroundSampler {
    /// Open `store_path` (with its own reader + throttle, independent of
    /// any scanner-side stream) and start the builder thread.
    ///
    /// With `bin_spec = Some(_)` every committed build also quantizes the
    /// sample's feature stripe (DESIGN.md §8) before publishing, so the
    /// handoff delivers the prebuilt `BinnedStripe` with the sample and
    /// the scanner never bins on the hot path. Binning is a pure function
    /// of (sample, grid), so it does not perturb the determinism contract.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        store_path: &Path,
        throttle: IoThrottle,
        strata: StrataConfig,
        cfg: SamplerConfig,
        bin_spec: Option<BinSpec>,
        seed: u64,
        worker: usize,
        log: EventLog,
    ) -> io::Result<BackgroundSampler> {
        let store = BuildStore::Mem(StratifiedStore::open(store_path, throttle, strata)?);
        Self::spawn_with(store, cfg, bin_spec, seed, worker, log)
    }

    /// Like [`BackgroundSampler::spawn`], but over the out-of-core tiered
    /// store (`--store-tier tiered`): heavy strata memory-resident within
    /// `tiered.memory_budget`, light strata in spill chunk files, builds
    /// skipping certified-rejected examples entirely (DESIGN.md §11).
    /// Sample contents are byte-identical to the `spawn` path for equal
    /// `(seed, stamp, model, store bytes)`.
    pub fn spawn_tiered(
        store_path: &Path,
        tiered: TieredConfig,
        cfg: SamplerConfig,
        bin_spec: Option<BinSpec>,
        seed: u64,
        worker: usize,
        log: EventLog,
    ) -> io::Result<BackgroundSampler> {
        let store = BuildStore::Tiered(Box::new(TieredStore::open(store_path, tiered)?));
        Self::spawn_with(store, cfg, bin_spec, seed, worker, log)
    }

    fn spawn_with(
        mut store: BuildStore,
        cfg: SamplerConfig,
        bin_spec: Option<BinSpec>,
        seed: u64,
        worker: usize,
        log: EventLog,
    ) -> io::Result<BackgroundSampler> {
        let ctrl = Arc::new(Ctrl {
            state: Mutex::new(CtrlState {
                job: None,
                shutdown: false,
            }),
            cv: Condvar::new(),
            epoch: AtomicU64::new(0),
            failed: Mutex::new(None),
        });
        let handle = SampleHandle::new();
        let tctrl = ctrl.clone();
        let thandle = handle.clone();
        let thread = std::thread::Builder::new()
            .name(format!("sampler-{worker}"))
            .spawn(move || {
                builder_loop(
                    &mut store, &tctrl, &thandle, &cfg, &bin_spec, seed, worker, &log,
                )
            })?;
        Ok(BackgroundSampler {
            ctrl,
            handle,
            thread: Some(thread),
            requested: None,
            installed: None,
        })
    }

    fn post(&self, model: StrongRule, stamp: BuildStamp) {
        let mut st = self.ctrl.state.lock().unwrap();
        self.ctrl.epoch.fetch_add(1, Ordering::SeqCst);
        st.job = Some(Job { model, stamp });
        self.ctrl.cv.notify_all();
    }

    /// Ask for a sample built against model `version`. No-op while a build
    /// for this version is already outstanding; a repeat request after the
    /// previous build was installed bumps the attempt counter so the new
    /// sample draws different coins.
    pub fn request(&mut self, version: u64, model: &StrongRule) {
        if let Some(r) = self.requested {
            if r.version == version && self.installed != Some(r) {
                return; // already building exactly this
            }
        }
        let attempt = match self.requested {
            Some(r) if r.version == version => r.attempt + 1,
            _ => 0,
        };
        let stamp = BuildStamp { version, attempt };
        self.requested = Some(stamp);
        self.post(model.clone(), stamp);
    }

    /// The adopted model changed (TMSN adoption or local publish): if a
    /// build is outstanding, restart it against the new model. The
    /// in-flight pass sees the epoch bump at its next block boundary and
    /// discards its partial sample.
    pub fn on_model_change(&mut self, version: u64, model: &StrongRule) {
        if self.requested.is_some() && self.requested != self.installed {
            let stamp = BuildStamp {
                version,
                attempt: 0,
            };
            self.requested = Some(stamp);
            self.post(model.clone(), stamp);
        }
    }

    /// Lock-free "is a pending sample waiting?" flag for interrupt
    /// closures (may be stale-positive for one batch; the versioned take
    /// sorts it out).
    pub fn ready_flag(&self) -> Arc<AtomicBool> {
        self.handle.ready_flag()
    }

    /// The builder's fatal error, if it died (worker treats it as the
    /// same disk-failure crash as a blocking resample error).
    pub fn error(&self) -> Option<String> {
        self.ctrl.failed.lock().unwrap().clone()
    }

    fn fail_err(msg: String) -> io::Error {
        io::Error::new(io::ErrorKind::Other, format!("background sampler: {msg}"))
    }

    /// Non-blocking: install the pending sample iff it was built against
    /// `version` (a stale pending sample is discarded — never installed).
    pub fn try_install(&mut self, version: u64) -> io::Result<Option<(SampleSet, SampleStats)>> {
        if let Some(e) = self.error() {
            return Err(Self::fail_err(e));
        }
        match self.handle.take_if_current(version) {
            Some(b) => {
                self.installed = Some(b.stamp);
                Ok(Some((b.sample, b.stats)))
            }
            None => Ok(None),
        }
    }

    /// Blocking: wait until a sample for `version` lands (the caller must
    /// have [`BackgroundSampler::request`]ed one first) or `give_up`
    /// returns true. Used for the initial fill only — afterwards the
    /// scanner keeps working and flips via [`BackgroundSampler::try_install`].
    pub fn wait_install(
        &mut self,
        version: u64,
        mut give_up: impl FnMut() -> bool,
    ) -> io::Result<Option<(SampleSet, SampleStats)>> {
        let ctrl = self.ctrl.clone();
        let got = self.handle.wait_take(version, Duration::from_millis(10), || {
            give_up() || ctrl.failed.lock().unwrap().is_some()
        });
        if let Some(b) = got {
            self.installed = Some(b.stamp);
            return Ok(Some((b.sample, b.stats)));
        }
        if let Some(e) = self.error() {
            return Err(Self::fail_err(e));
        }
        Ok(None)
    }
}

impl Drop for BackgroundSampler {
    fn drop(&mut self) {
        {
            let mut st = self.ctrl.state.lock().unwrap();
            st.shutdown = true;
            self.ctrl.epoch.fetch_add(1, Ordering::SeqCst);
            self.ctrl.cv.notify_all();
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn builder_loop(
    store: &mut BuildStore,
    ctrl: &Arc<Ctrl>,
    handle: &SampleHandle,
    cfg: &SamplerConfig,
    bin_spec: &Option<BinSpec>,
    seed: u64,
    worker: usize,
    log: &EventLog,
) {
    loop {
        // Take the next job; capture the epoch under the same lock so no
        // post can slip between the take and the snapshot.
        let (job, my_epoch) = {
            let mut st = ctrl.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(j) = st.job.take() {
                    break (j, ctrl.epoch.load(Ordering::SeqCst));
                }
                st = ctrl.cv.wait(st).unwrap();
            }
        };
        log.record(
            worker,
            EventKind::ResampleStart,
            None,
            job.stamp.version as f64,
        );
        let invalidated = || ctrl.epoch.load(Ordering::Relaxed) != my_epoch;
        let outcome = match store {
            BuildStore::Mem(s) => build_once(s, &job.model, job.stamp, cfg, seed, invalidated),
            BuildStore::Tiered(s) => {
                let before = s.counters();
                let out = build_tiered(
                    s,
                    &job.model,
                    job.stamp,
                    cfg,
                    bin_spec.as_ref(),
                    seed,
                    invalidated,
                );
                // surface the tiered data plane's activity as counter
                // deltas (value = delta), mirroring ResampleEnd's
                // value-carrying convention
                let after = s.counters();
                let spilled = after.spilled_rows - before.spilled_rows;
                if spilled > 0 {
                    log.record(worker, EventKind::Spill, None, spilled as f64);
                }
                let hits = after.readahead_hits - before.readahead_hits;
                if hits > 0 {
                    log.record(worker, EventKind::ReadaheadHit, None, hits as f64);
                }
                let misses = after.readahead_misses - before.readahead_misses;
                if misses > 0 {
                    log.record(worker, EventKind::ReadaheadMiss, None, misses as f64);
                }
                out
            }
        };
        match outcome {
            Ok(BuildOutcome::Built { mut sample, stats }) => {
                // commit path: quantize the stripe here, on the builder
                // thread, so the swap hands the scanner a ready view
                if let Some(spec) = bin_spec {
                    sample.ensure_binned(spec);
                }
                log.record(worker, EventKind::ResampleEnd, None, stats.kept as f64);
                handle.publish(BuiltSample {
                    sample,
                    stats,
                    stamp: job.stamp,
                });
            }
            Ok(BuildOutcome::Invalidated { read }) => {
                log.record(worker, EventKind::BuildAbort, None, read as f64);
            }
            Err(e) => {
                *ctrl.failed.lock().unwrap() = Some(e.to_string());
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthGen;
    use crate::data::SynthConfig;
    use crate::model::Stump;

    fn make_store(name: &str, n: usize, seed: u64) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparrow_bg_sampler_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}_{seed}_{n}.sprw"));
        let cfg = SynthConfig {
            f: 6,
            pos_rate: 0.3,
            informative: 3,
            signal: 1.0,
            flip_rate: 0.0,
            seed,
        };
        SynthGen::new(cfg).write_store(&path, n).unwrap();
        path
    }

    fn open(path: &std::path::Path, resident_rows: usize) -> StratifiedStore {
        StratifiedStore::open(
            path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows },
        )
        .unwrap()
    }

    fn cfg(m: usize, block: usize) -> SamplerConfig {
        SamplerConfig {
            target_m: m,
            kind: SamplerKind::MinimalVariance,
            probe: 256,
            max_passes: 1,
            block,
        }
    }

    fn model1() -> StrongRule {
        let mut m = StrongRule::new();
        m.push(Stump::new(0, 0.0, 1.0), 0.8);
        m
    }

    fn built(
        store: &mut StratifiedStore,
        model: &StrongRule,
        stamp: BuildStamp,
        c: &SamplerConfig,
        seed: u64,
    ) -> SampleSet {
        match build_once(store, model, stamp, c, seed, || false).unwrap() {
            BuildOutcome::Built { sample, .. } => sample,
            other => panic!("expected Built, got {other:?}"),
        }
    }

    #[test]
    fn near_target_size_and_fresh_weights() {
        let path = make_store("size", 6000, 1);
        let mut store = open(&path, 0);
        let stamp = BuildStamp {
            version: 0,
            attempt: 0,
        };
        let s = built(&mut store, &StrongRule::new(), stamp, &cfg(1000, 512), 7);
        // scale calibration: expected keeps == m, no truncation → within 15%
        assert!(
            (s.len() as f64 - 1000.0).abs() < 150.0,
            "kept={}",
            s.len()
        );
        // fresh sample: unit weights
        assert!((s.n_eff() - s.len() as f64).abs() < 1e-6);
    }

    #[test]
    fn contents_independent_of_block_size() {
        // the order-independence property behind safe mid-build aborts:
        // chunking the pass differently must not change the sample
        let path = make_store("chunk", 3000, 2);
        let stamp = BuildStamp {
            version: 4,
            attempt: 1,
        };
        let model = model1();
        let a = built(&mut open(&path, 0), &model, stamp, &cfg(400, 32), 9);
        let b = built(&mut open(&path, 0), &model, stamp, &cfg(400, 1024), 9);
        assert_eq!(a.data, b.data);
        assert_eq!(a.score_sample, b.score_sample);
    }

    #[test]
    fn contents_independent_of_residency_state() {
        // the strata index re-prices I/O but must never steer contents:
        // a warm (post-commit, resident strata active) store builds the
        // identical sample. A finite throttle is required for residency to
        // engage at all; make it effectively instant so the test is fast.
        let path = make_store("warm", 3000, 3);
        let stamp = BuildStamp {
            version: 2,
            attempt: 0,
        };
        let model = model1();
        let cold = built(&mut open(&path, 0), &model, stamp, &cfg(400, 256), 11);
        let mut warm_store = StratifiedStore::open(
            &path,
            IoThrottle::new(1e12),
            StrataConfig {
                resident_rows: 1024,
            },
        )
        .unwrap();
        let first = built(&mut warm_store, &model, stamp, &cfg(400, 256), 11);
        assert!(
            warm_store.resident_fraction() > 0.0,
            "residency must engage for the warm build"
        );
        let warm = built(&mut warm_store, &model, stamp, &cfg(400, 256), 11);
        assert_eq!(cold.data, first.data);
        assert_eq!(first.data, warm.data);
    }

    #[test]
    fn stamps_vary_contents() {
        let path = make_store("stamps", 3000, 4);
        let c = cfg(400, 256);
        let m = StrongRule::new();
        let base = built(
            &mut open(&path, 0),
            &m,
            BuildStamp {
                version: 0,
                attempt: 0,
            },
            &c,
            5,
        );
        let next_attempt = built(
            &mut open(&path, 0),
            &m,
            BuildStamp {
                version: 0,
                attempt: 1,
            },
            &c,
            5,
        );
        let next_version = built(
            &mut open(&path, 0),
            &m,
            BuildStamp {
                version: 1,
                attempt: 0,
            },
            &c,
            5,
        );
        assert!(base.data != next_attempt.data);
        assert!(base.data != next_version.data);
    }

    #[test]
    fn invalidation_discards_in_flight_build() {
        let path = make_store("inval", 4000, 5);
        let mut store = open(&path, 0);
        let mut polls = 0;
        let out = build_once(
            &mut store,
            &StrongRule::new(),
            BuildStamp {
                version: 0,
                attempt: 0,
            },
            &cfg(500, 128),
            13,
            || {
                polls += 1;
                polls > 3
            },
        )
        .unwrap();
        match out {
            BuildOutcome::Invalidated { read } => {
                assert!(read < 4000, "aborted early, read={read}");
            }
            other => panic!("expected Invalidated, got {other:?}"),
        }
        // the aborted build left no trace: committed index still pristine
        assert_eq!(
            store.bucket(0) as usize,
            crate::data::strata::NUM_STRATA / 2
        );
        // and a subsequent full build is identical to one on a fresh store
        let stamp = BuildStamp {
            version: 1,
            attempt: 0,
        };
        let after_abort = built(&mut store, &StrongRule::new(), stamp, &cfg(500, 128), 13);
        let fresh = built(
            &mut open(&path, 0),
            &StrongRule::new(),
            stamp,
            &cfg(500, 128),
            13,
        );
        assert_eq!(after_abort.data, fresh.data);
    }

    #[test]
    fn thread_converges_to_latest_version() {
        // the end-to-end invalidation invariant, no sleeps: whatever the
        // interleaving (the v1 build may complete or abort), the sample
        // that installs for v2 is byte-identical to a synchronous build
        // against (seed, {version: 2, attempt: 0}, model_v2).
        let path = make_store("thread", 3000, 6);
        let (log, _rx) = EventLog::new();
        let c = cfg(400, 128);
        let mut bg = BackgroundSampler::spawn(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 0 },
            c.clone(),
            None,
            21,
            0,
            log,
        )
        .unwrap();

        let m0 = StrongRule::new();
        bg.request(0, &m0);
        let (s0, _) = bg.wait_install(0, || false).unwrap().expect("initial fill");
        let sync0 = built(
            &mut open(&path, 0),
            &m0,
            BuildStamp {
                version: 0,
                attempt: 0,
            },
            &c,
            21,
        );
        assert_eq!(s0.data, sync0.data);

        // two rapid model changes: v1 then v2 — v1's build may be aborted
        // mid-flight or complete and be discarded as stale; either way only
        // a v2-stamped sample may install
        let m1 = model1();
        let mut m2 = model1();
        m2.push(Stump::new(1, 0.5, -1.0), 0.4);
        bg.request(1, &m1);
        bg.on_model_change(2, &m2);
        let (s2, _) = bg.wait_install(2, || false).unwrap().expect("v2 sample");
        let sync2 = built(
            &mut open(&path, 0),
            &m2,
            BuildStamp {
                version: 2,
                attempt: 0,
            },
            &c,
            21,
        );
        assert_eq!(s2.data, sync2.data);
        assert_eq!(s2.score_sample, sync2.score_sample);
    }

    #[test]
    fn repeat_request_bumps_attempt() {
        let path = make_store("attempt", 2500, 7);
        let (log, _rx) = EventLog::new();
        let c = cfg(300, 256);
        let mut bg = BackgroundSampler::spawn(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 0 },
            c.clone(),
            None,
            31,
            0,
            log,
        )
        .unwrap();
        let m = StrongRule::new();
        bg.request(0, &m);
        let (a, _) = bg.wait_install(0, || false).unwrap().unwrap();
        bg.request(0, &m); // same version again → attempt 1 → new coins
        let (b, _) = bg.wait_install(0, || false).unwrap().unwrap();
        assert!(a.data != b.data, "attempt bump must redraw the sample");
    }

    #[test]
    fn builder_prebuilds_binned_stripe() {
        // the commit path quantizes on the builder thread: the installed
        // sample already carries the stripe view the scanner will use
        let path = make_store("bins", 2000, 9);
        let (log, _rx) = EventLog::new();
        let spec = BinSpec::new(
            (1, 4),
            3,
            vec![-0.5, 0.0, 0.5, -0.5, 0.0, 0.5, -0.5, 0.0, 0.5],
        );
        let mut bg = BackgroundSampler::spawn(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 0 },
            cfg(300, 256),
            Some(spec.clone()),
            51,
            0,
            log,
        )
        .unwrap();
        bg.request(0, &StrongRule::new());
        let (s, _) = bg.wait_install(0, || false).unwrap().unwrap();
        let built = s.binned.as_ref().expect("bins prebuilt by the builder");
        assert!(built.matches(&spec, s.data.n));
        assert_eq!(built, &spec.bin_block(&s.data));
    }

    #[test]
    fn request_dedupes_while_outstanding() {
        let path = make_store("dedupe", 2000, 8);
        let (log, rx) = EventLog::new();
        let mut bg = BackgroundSampler::spawn(
            &path,
            IoThrottle::unlimited(),
            StrataConfig { resident_rows: 0 },
            cfg(300, 256),
            None,
            41,
            0,
            log,
        )
        .unwrap();
        let m = StrongRule::new();
        bg.request(0, &m);
        bg.request(0, &m); // must not queue a second build
        bg.request(0, &m);
        let _ = bg.wait_install(0, || false).unwrap().unwrap();
        drop(bg); // join the thread so no further events can arrive
        let starts = crate::metrics::drain(&rx)
            .iter()
            .filter(|e| e.kind == EventKind::ResampleStart)
            .count();
        assert_eq!(starts, 1, "duplicate requests must dedupe");
    }
}
