//! The tiered build pass: [`build_tiered`] produces a sample
//! **byte-identical** to [`super::build_once`] while reading only the
//! examples whose acceptance cannot be ruled out up front (DESIGN.md §11).
//!
//! # Why the outputs are identical
//!
//! [`super::build_once`]'s sample is a pure function of
//! `(seed, stamp, model, store)`: example `gi` contributes
//! `copies_for(kind, key, scale, uniform_rate, gi, w)` copies, where the
//! scale comes from a deterministic probe prefix and the coin from the
//! example's own RNG. Nothing depends on visit order. This pass computes
//! the *same* scale from the *same* prefix, draws the *same* per-example
//! coin, and applies the *same* copy rule — it merely declines to read
//! examples whose rejection is already provable from the certified weight
//! ceiling (see [`crate::data::tiered::draw`]): for the
//! weight-proportional kinds `copies = 0 ⟺ scale·u ≥ w`, so
//! `scale·u ≥ ceiling·e^drift ≥ w` is a proof; for
//! [`SamplerKind::Uniform`] acceptance ignores `w` entirely and the
//! survivor set is exact with no ceiling at all. Accepted rows are
//! collected in serving order (heaviest strata first) and emitted in
//! global order, so the output block equals the sequential pass's
//! byte-for-byte.
//!
//! When a [`BinSpec`] is supplied the accepted rows are quantized at
//! visit time — straight out of the chunk buffers — and the column-major
//! stripe is assembled at emission, so the published sample carries the
//! identical `BinnedStripe` that `ensure_binned` would build, without a
//! second pass over the sample.

use std::io;
use std::time::Instant;

use crate::config::SamplerKind;
use crate::data::tiered::draw::drift_bound;
use crate::data::tiered::TieredStore;
use crate::data::{BinSpec, BinnedStripe, DataBlock, SampleSet};
use crate::model::StrongRule;
use crate::sampler::background::{coin_key, copies_for, first_coin, BuildOutcome};
use crate::sampler::handle::BuildStamp;
use crate::sampler::{score_block, SampleStats, SamplerConfig};

/// One accepted example, keyed for global-order emission.
struct Kept {
    gi: u32,
    /// row index into the serving-order accumulator block
    idx: u32,
    s: f32,
    w: f64,
    copies: u32,
}

/// Build one sample against `model` over a [`TieredStore`], identified by
/// `stamp`. Same contract and outcome type as [`super::build_once`]; the
/// contents are byte-identical for equal `(seed, stamp, model, store
/// bytes)`. `invalidated` is polled between chunks; `true` aborts the
/// build and leaves the store's committed state untouched (the caller
/// must still observe the [`BuildOutcome::Invalidated`] return — the
/// store aborts internally).
pub fn build_tiered(
    store: &mut TieredStore,
    model: &StrongRule,
    stamp: BuildStamp,
    cfg: &SamplerConfig,
    bin_spec: Option<&BinSpec>,
    seed: u64,
    mut invalidated: impl FnMut() -> bool,
) -> io::Result<BuildOutcome> {
    let t0 = Instant::now();
    let n = store.len();
    let f = store.num_features();
    if n == 0 {
        return Ok(BuildOutcome::Built {
            sample: SampleSet::empty(f),
            stats: SampleStats {
                read: 0,
                kept: 0,
                duration: t0.elapsed(),
                mean_weight: 0.0,
            },
        });
    }
    let m = cfg.target_m.max(1);
    let key = coin_key(seed, stamp);

    // Probe: the identical deterministic prefix and arithmetic as
    // build_once — the scale must match bit-for-bit.
    let probe_n = cfg.probe.min(n).max(1);
    let probe = store.probe_block(probe_n)?;
    let probe_scored = score_block(model, &probe);
    let mean_w =
        (probe_scored.iter().map(|&(_, w)| w).sum::<f64>() / probe.n as f64).max(1e-300);
    let scale = mean_w * n as f64 / m as f64;
    let uniform_rate = (m as f64 / n as f64).min(1.0);

    // Drift allowance: ceilings certify weights under the store's anchor;
    // `model` may move any weight by at most e^d (safe-side padded).
    let infl = drift_bound(model, store.anchor()).exp();
    let kind = cfg.kind;

    store.begin_build();

    // serving-order accumulators; emission re-sorts by global index
    let mut rows = DataBlock::empty(f);
    let mut kept: Vec<Kept> = Vec::new();
    let width = bin_spec.map_or(0, |s| s.width());
    let mut row_bins: Vec<u8> = Vec::new(); // row-major, parallel to `rows`

    let mut keep = |gi: usize, ceiling: f64| -> bool {
        let u = first_coin(key, gi as u64);
        match kind {
            // acceptance is weight-independent: the survivor set is exact
            SamplerKind::Uniform => u < uniform_rate,
            // read unless rejection is provable from the ceiling
            _ => scale * u < ceiling * infl,
        }
    };
    let mut visit = |gi: usize, label: f32, row: &[f32]| -> f64 {
        let s = model.score(row);
        let w = (-(label as f64) * s as f64).exp();
        let copies = copies_for(kind, key, scale, uniform_rate, gi as u64, w);
        if copies > 0 {
            if let Some(spec) = bin_spec {
                for c in 0..width {
                    row_bins.push(spec.bin_value(c, row[spec.stripe.0 + c]));
                }
            }
            kept.push(Kept {
                gi: gi as u32,
                idx: rows.n as u32,
                s,
                w,
                copies: copies as u32,
            });
            rows.push(row, label);
        }
        w
    };

    let completed = store.build_pass(&mut keep, &mut visit, &mut invalidated)?;
    let pass = store.last_pass();
    let read = probe.n as u64 + pass.rows_visited;
    if !completed {
        store.abort_build();
        return Ok(BuildOutcome::Invalidated { read });
    }
    store.commit_build(model)?;

    // emit in global order — the order build_once pushes in
    kept.sort_by_key(|k| k.gi);
    let mut data = DataBlock::empty(f);
    let mut scores = Vec::with_capacity(m);
    let mut weights = Vec::with_capacity(m);
    let mut bins_emitted: Vec<u8> = Vec::new(); // row-major, emission order
    for k in &kept {
        let idx = k.idx as usize;
        for _ in 0..k.copies {
            data.push(rows.row(idx), rows.label(idx));
            scores.push(k.s);
            weights.push(k.w as f32);
            if width > 0 {
                bins_emitted.extend_from_slice(&row_bins[idx * width..(idx + 1) * width]);
            }
        }
    }

    let kept_n = data.n;
    let stats = SampleStats {
        read,
        kept: kept_n,
        duration: t0.elapsed(),
        mean_weight: mean_w,
    };
    let mut sample = if kind == SamplerKind::Uniform {
        SampleSet::with_weights(data, scores, weights, model.len() as u32)
    } else {
        SampleSet::fresh(data, scores, model.len() as u32)
    };
    if let Some(spec) = bin_spec {
        // transpose the visit-time bins into the column-major stripe —
        // identical values to spec.bin_block(&sample.data)
        let mut bins = vec![0u8; width * kept_n];
        for (i, chunk) in bins_emitted.chunks_exact(width).enumerate() {
            for (c, &b) in chunk.iter().enumerate() {
                bins[c * kept_n + i] = b;
            }
        }
        sample.binned = Some(BinnedStripe {
            stripe: spec.stripe,
            nthr: spec.nthr,
            grid_fingerprint: spec.fingerprint(),
            n: kept_n,
            bins,
        });
    }
    Ok(BuildOutcome::Built { sample, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::strata::{StrataConfig, StratifiedStore};
    use crate::data::synth::SynthGen;
    use crate::data::tiered::TieredConfig;
    use crate::data::{IoThrottle, SynthConfig};
    use crate::model::Stump;
    use crate::sampler::build_once;

    fn make_store(name: &str, n: usize, seed: u64) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparrow_tiered_build_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}_{seed}_{n}.sprw"));
        let cfg = SynthConfig {
            f: 6,
            pos_rate: 0.3,
            informative: 3,
            signal: 1.0,
            flip_rate: 0.0,
            seed,
        };
        SynthGen::new(cfg).write_store(&path, n).unwrap();
        path
    }

    fn cfg(m: usize, kind: SamplerKind) -> SamplerConfig {
        SamplerConfig {
            target_m: m,
            kind,
            probe: 256,
            max_passes: 1,
            block: 128,
        }
    }

    /// A budget far below the store so nearly everything spills.
    fn tiny_tiered(path: &std::path::Path) -> TieredStore {
        TieredStore::open(
            path,
            TieredConfig {
                memory_budget: 2048,
                chunk_rows: 64,
                probe_rows: 0, // exercise the base-file probe fallback
                readahead_depth: 2,
                relayout_threshold: 0.25,
            },
        )
        .unwrap()
    }

    fn mem_build(
        path: &std::path::Path,
        model: &StrongRule,
        stamp: BuildStamp,
        c: &SamplerConfig,
        seed: u64,
    ) -> SampleSet {
        let mut store =
            StratifiedStore::open(path, IoThrottle::unlimited(), StrataConfig { resident_rows: 0 })
                .unwrap();
        match build_once(&mut store, model, stamp, c, seed, || false).unwrap() {
            BuildOutcome::Built { sample, .. } => sample,
            other => panic!("expected Built, got {other:?}"),
        }
    }

    fn tiered_build(
        store: &mut TieredStore,
        model: &StrongRule,
        stamp: BuildStamp,
        c: &SamplerConfig,
        seed: u64,
    ) -> SampleSet {
        match build_tiered(store, model, stamp, c, None, seed, || false).unwrap() {
            BuildOutcome::Built { sample, .. } => sample,
            other => panic!("expected Built, got {other:?}"),
        }
    }

    fn model1() -> StrongRule {
        let mut m = StrongRule::new();
        m.push(Stump::new(0, 0.0, 1.0), 0.8);
        m
    }

    fn model2() -> StrongRule {
        let mut m = model1();
        m.push(Stump::new(1, 0.5, -1.0), 0.4);
        m
    }

    #[test]
    fn byte_identical_to_in_memory_pass_across_model_sequence() {
        // the acceptance gate of the whole tentpole: a spilled tiered
        // store, evolving through a model sequence (empty → extends →
        // extends), emits exactly the samples the in-memory pass does
        let path = make_store("ident", 3000, 1);
        let mut tiered = tiny_tiered(&path);
        let c = cfg(400, SamplerKind::MinimalVariance);
        let seq = [
            (StrongRule::new(), BuildStamp { version: 0, attempt: 0 }),
            (StrongRule::new(), BuildStamp { version: 0, attempt: 1 }),
            (model1(), BuildStamp { version: 1, attempt: 0 }),
            (model2(), BuildStamp { version: 2, attempt: 0 }),
        ];
        for (model, stamp) in &seq {
            let t = tiered_build(&mut tiered, model, *stamp, &c, 9);
            let m = mem_build(&path, model, *stamp, &c, 9);
            assert_eq!(t.data, m.data, "stamp {stamp:?}");
            assert_eq!(t.score_sample, m.score_sample, "stamp {stamp:?}");
        }
        // the later builds must have exercised the certified-skip path
        assert!(
            tiered.counters().rows_skipped > 0,
            "no skips: {:?}",
            tiered.counters()
        );
    }

    #[test]
    fn uniform_kind_identical_with_zero_disk_reads_for_rejects() {
        let path = make_store("uniform", 2500, 2);
        let mut tiered = tiny_tiered(&path);
        let c = cfg(300, SamplerKind::Uniform);
        let stamp = BuildStamp { version: 3, attempt: 0 };
        let model = model1();
        let t = tiered_build(&mut tiered, &model, stamp, &c, 5);
        let m = mem_build(&path, &model, stamp, &c, 5);
        assert_eq!(t.data, m.data);
        assert_eq!(t.w_last, m.w_last); // uniform kind carries true weights
        // uniform acceptance is coin-only: rejected examples cost nothing
        let pass = tiered.last_pass();
        assert_eq!(
            pass.rows_visited + pass.rows_skipped,
            2500,
            "every example decided"
        );
        assert!(pass.rows_skipped > 1500, "{pass:?}");
    }

    #[test]
    fn rejection_kind_identical() {
        let path = make_store("reject", 2000, 3);
        let mut tiered = tiny_tiered(&path);
        let c = cfg(250, SamplerKind::Rejection);
        let stamp = BuildStamp { version: 1, attempt: 2 };
        let t = tiered_build(&mut tiered, &model1(), stamp, &c, 17);
        let m = mem_build(&path, &model1(), stamp, &c, 17);
        assert_eq!(t.data, m.data);
    }

    #[test]
    fn second_build_same_model_reads_less() {
        // after one committed build the ceilings are exact, so a repeat
        // against the same model reads only the actually-accepted rows
        // (plus the Bernoulli boundary cases)
        let path = make_store("skips", 3000, 4);
        let mut tiered = tiny_tiered(&path);
        let c = cfg(300, SamplerKind::MinimalVariance);
        let model = model1();
        tiered_build(&mut tiered, &model, BuildStamp { version: 1, attempt: 0 }, &c, 7);
        let first_read = tiered.last_pass().rows_visited;
        let t = tiered_build(&mut tiered, &model, BuildStamp { version: 1, attempt: 1 }, &c, 7);
        let second = tiered.last_pass();
        assert!(
            second.rows_visited < 3000 / 2,
            "second build should skip most rows: {second:?} (first read {first_read})"
        );
        // and still byte-identical
        let m = mem_build(&path, &model, BuildStamp { version: 1, attempt: 1 }, &c, 7);
        assert_eq!(t.data, m.data);
    }

    #[test]
    fn invalidation_aborts_and_leaves_store_reusable() {
        let path = make_store("inval", 2000, 5);
        let mut tiered = tiny_tiered(&path);
        let c = cfg(250, SamplerKind::MinimalVariance);
        // prime ceilings so both resident/spilled paths exist
        tiered_build(&mut tiered, &StrongRule::new(), BuildStamp { version: 0, attempt: 0 }, &c, 3);
        let mut polls = 0;
        let out = build_tiered(
            &mut tiered,
            &model1(),
            BuildStamp { version: 1, attempt: 0 },
            &c,
            None,
            3,
            || {
                polls += 1;
                polls > 1
            },
        )
        .unwrap();
        assert!(matches!(out, BuildOutcome::Invalidated { .. }), "{out:?}");
        // the aborted build left no trace: the next build matches a
        // build on a freshly-opened tiered store and the memory path
        let stamp = BuildStamp { version: 1, attempt: 0 };
        let after = tiered_build(&mut tiered, &model1(), stamp, &c, 3);
        let mem = mem_build(&path, &model1(), stamp, &c, 3);
        assert_eq!(after.data, mem.data);
    }

    #[test]
    fn prebuilt_stripe_equals_bin_block() {
        let path = make_store("bins", 1500, 6);
        let mut tiered = tiny_tiered(&path);
        let c = cfg(200, SamplerKind::MinimalVariance);
        let spec = BinSpec::new(
            (1, 4),
            3,
            vec![-0.5, 0.0, 0.5, -0.5, 0.0, 0.5, -0.5, 0.0, 0.5],
        );
        let stamp = BuildStamp { version: 2, attempt: 0 };
        let sample = match build_tiered(&mut tiered, &model1(), stamp, &c, Some(&spec), 29, || false)
            .unwrap()
        {
            BuildOutcome::Built { sample, .. } => sample,
            other => panic!("expected Built, got {other:?}"),
        };
        let stripe = sample.binned.as_ref().expect("stripe prebuilt");
        assert!(stripe.matches(&spec, sample.data.n));
        assert_eq!(stripe, &spec.bin_block(&sample.data));
    }

    #[test]
    fn stats_read_counts_probe_and_visits() {
        let path = make_store("stats", 1000, 7);
        let mut tiered = tiny_tiered(&path);
        let c = cfg(100, SamplerKind::MinimalVariance);
        let stamp = BuildStamp { version: 0, attempt: 0 };
        let stats = match build_tiered(&mut tiered, &StrongRule::new(), stamp, &c, None, 11, || false)
            .unwrap()
        {
            BuildOutcome::Built { stats, .. } => stats,
            other => panic!("expected Built, got {other:?}"),
        };
        let pass = tiered.last_pass();
        assert_eq!(stats.read, 256 + pass.rows_visited);
        assert!(stats.read < 1000, "first build already skips: {stats:?}");
    }
}
