//! A Sparrow worker (paper §4, Alg. 1): Scanner + Sampler + TMSN endpoint.
//!
//! The worker is fully autonomous — it never waits for any other machine.
//! Its loop: keep a weighted in-memory sample fresh (resample when
//! `n_eff/m` collapses), scan for a certifiable weak rule, broadcast local
//! improvements, and adopt strictly-better remote models the moment they
//! arrive (interrupting the scan mid-pass). The poll/adopt/broadcast
//! mechanics live in the payload-generic [`crate::tmsn::Driver`]; this
//! module supplies what is boosting-specific: the scan, the sample, and
//! the weight-rebasing that keeps the sample consistent across adoptions.
//!
//! With `SamplerMode::Background` (DESIGN.md §4) the resample runs on a
//! dedicated thread instead of inline: the worker tracks a local **model
//! version** (bumped on every adoption and publish), forwards each change
//! to the [`crate::sampler::BackgroundSampler`] so an in-flight build is
//! invalidated, and swaps a version-matched finished sample in at a batch
//! boundary — the scanner keeps scanning the old sample in the meantime
//! instead of idling through the paper's resample plateau.

pub mod link;
pub mod throttle;

pub use link::NullLink;
pub use throttle::ThrottledBackend;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::admin::{ControlState, Nudge};
use crate::boosting::{alpha_for_advantage, CandidateGrid};
use crate::config::{SamplerMode, ScanEngine, StoreTier, TrainConfig};
use crate::data::{BinSpec, DiskStore, IoThrottle, SampleSet, StrataConfig, TieredConfig};
use crate::metrics::{EventKind, EventLog};
use crate::model::StrongRule;
use crate::sampler::{BackgroundSampler, SampleStats, Sampler, SamplerConfig};
use crate::serve::ModelSlot;
use crate::scanner::{ScanBackend, ScanOutcome, Scanner, ScannerConfig};
use crate::stopping::{DwRule, FixedScan, HoeffdingRule, LilRule, StoppingRule};
use crate::tmsn::{BoostPayload, Driver, Link, Tmsn};
use crate::util::rng::Rng;

/// The worker's control-plane attachment (DESIGN.md §10): gauges and
/// nudges shared with an admin RPC thread, plus the hot-swap slot a
/// serve endpoint reads. `None` everywhere the control plane is off —
/// the training loop then pays nothing.
pub struct ControlPlane {
    /// Gauges (model version, scan progress, stalls) + nudge queue +
    /// fault switches.
    pub state: Arc<ControlState>,
    /// Latest-adopted-model slot for `sparrow serve`.
    pub slot: Arc<ModelSlot>,
}

impl ControlPlane {
    /// Publish a model-version bump to the gauges and the serve slot
    /// (called on every adoption and local improvement).
    fn note_model(&self, version: u64, payload: &BoostPayload) {
        self.state
            .note_model(version, payload.model.len(), payload.cert.loss_bound);
        self.slot
            .publish(payload.model.clone(), version, payload.cert.loss_bound);
    }
}

/// Everything a worker thread needs.
pub struct WorkerParams {
    pub id: usize,
    pub cfg: TrainConfig,
    pub grid: CandidateGrid,
    /// owned feature stripe `[start, end)`
    pub stripe: (usize, usize),
    pub store: DiskStore,
    pub endpoint: Box<dyn Link<BoostPayload>>,
    pub log: EventLog,
    pub stop: Arc<AtomicBool>,
    pub backend: Box<dyn ScanBackend>,
    /// compute slowdown multiplier (1.0 = healthy, >1 = laggard)
    pub laggard: f64,
    /// crash this long after start (failure injection)
    pub crash_after: Option<Duration>,
    pub seed: u64,
    /// control-plane attachment; `None` = no admin/serve endpoints
    pub control: Option<ControlPlane>,
}

/// Final worker state returned to the coordinator.
#[derive(Debug)]
pub struct WorkerResult {
    pub id: usize,
    pub model: StrongRule,
    pub loss_bound: f64,
    pub found: u64,
    pub accepts: u64,
    pub rejects: u64,
    pub resamples: u64,
    pub scanned: u64,
    pub crashed: bool,
}

/// How the worker's sample gets rebuilt: inline (paper-faithful) or on the
/// background pipeline (DESIGN.md §4).
enum SampleSource {
    Blocking(Sampler),
    Background(BackgroundSampler),
}

/// Result for a worker that crashed before its main loop could run (e.g.
/// the background sampler thread failed to spawn).
fn crashed_result(id: usize, cfg: &TrainConfig, log: &EventLog) -> WorkerResult {
    let tmsn: Tmsn<BoostPayload> = match &cfg.resume {
        Some((model, bound)) => Tmsn::resume(id, BoostPayload::resume(model.clone(), *bound)),
        None => Tmsn::new(id),
    };
    log.record(id, EventKind::Finish, None, tmsn.cert().loss_bound);
    WorkerResult {
        id,
        model: tmsn.payload().model.clone(),
        loss_bound: tmsn.cert().loss_bound,
        found: 0,
        accepts: 0,
        rejects: 0,
        resamples: 0,
        scanned: 0,
        crashed: true,
    }
}

/// Atomically persist the worker's current payload as a resumable
/// checkpoint: `<path>` gets the model text, `<path>.meta` the certified
/// bound — the exact files `--resume <path>` reads back. Both writes go
/// through a temp file + rename, so a kill mid-write leaves the previous
/// checkpoint intact. The model lands before the meta; a kill between the
/// two renames leaves a *stale (larger)* bound next to a better model,
/// which is the safe direction — the resumed certificate under-claims.
pub fn write_checkpoint(path: &str, payload: &BoostPayload) -> std::io::Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, payload.model.to_text())?;
    std::fs::rename(&tmp, path)?;
    let meta_tmp = format!("{path}.meta.tmp");
    std::fs::write(&meta_tmp, format!("bound={}\n", payload.cert.loss_bound))?;
    std::fs::rename(&meta_tmp, format!("{path}.meta"))?;
    Ok(())
}

/// Install a freshly built sample into the scanner's seat (shared by the
/// blocking post-resample path and the background swap-at-a-batch-boundary
/// path): replace the sample, ensure its quantized stripe view when the
/// binned engine is active (the background builder prebuilds it, making
/// this a shape check; blocking mode quantizes here — its sample-install
/// time), rewind the scan cursor, count the resample, and emit `event`
/// (`ResampleEnd` for blocking, `SampleSwap` for a background install).
#[allow(clippy::too_many_arguments)]
fn install_sample(
    sample: &mut SampleSet,
    scanner: &mut Scanner,
    resamples: &mut u64,
    log: &EventLog,
    id: usize,
    fresh: SampleSet,
    stats: SampleStats,
    bin_spec: &Option<BinSpec>,
    event: EventKind,
) {
    *sample = fresh;
    if let Some(spec) = bin_spec {
        sample.ensure_binned(spec);
    }
    scanner.reset_cursor();
    *resamples += 1;
    log.record(id, event, None, stats.kept as f64);
}

/// Log a sampler disk failure (treated as a crash — resilience semantics);
/// the caller sets `crashed` and breaks its loop.
fn log_sampler_crash(log: &EventLog, id: usize, e: &dyn std::fmt::Display) {
    log.record(id, EventKind::Crash, None, 0.0);
    eprintln!("worker {id}: sampler I/O error: {e}");
}

/// Build the configured stopping rule, union-bounded over the stripe's
/// candidate count.
pub fn make_stopping_rule(cfg: &TrainConfig, candidates: usize) -> Box<dyn StoppingRule> {
    match cfg.stopping {
        crate::config::StoppingKind::Lil => Box::new(LilRule::with_union_bound(
            cfg.stop_c,
            cfg.stop_delta,
            candidates,
        )),
        crate::config::StoppingKind::Hoeffding => Box::new(HoeffdingRule {
            delta: cfg.stop_delta / candidates.max(1) as f64,
            min_count: 100,
        }),
        crate::config::StoppingKind::DomingoWatanabe => Box::new(DwRule {
            delta: cfg.stop_delta / candidates.max(1) as f64,
            min_count: 100,
        }),
        crate::config::StoppingKind::FixedScan => Box::new(FixedScan),
    }
}

/// Run a worker to completion (blocking; called on its own thread).
pub fn run_worker(params: WorkerParams) -> WorkerResult {
    let WorkerParams {
        id,
        cfg,
        grid,
        stripe,
        store,
        endpoint,
        log,
        stop,
        backend,
        laggard,
        crash_after,
        seed,
        control,
    } = params;
    let start = Instant::now();
    let mut rng = Rng::new(seed ^ (id as u64).wrapping_mul(0x9E37_79B9));

    let candidates = (stripe.1 - stripe.0) * grid.nthr * 2;
    let rule = make_stopping_rule(&cfg, candidates);
    // binned engine: samples carry a quantized stripe view, built at
    // install time (blocking mode inline, background mode on the builder
    // thread) so the scanner never bins on the hot path (DESIGN.md §8)
    let bin_spec: Option<BinSpec> = match cfg.scan_engine {
        ScanEngine::Binned => Some(grid.bin_spec(stripe)),
        ScanEngine::Rows => None,
    };
    let backend: Box<dyn ScanBackend> = if laggard > 1.0 {
        Box::new(ThrottledBackend::new(backend, laggard))
    } else {
        backend
    };
    let mut scanner = Scanner::new(
        grid,
        stripe,
        backend,
        rule,
        ScannerConfig {
            batch: cfg.batch,
            gamma0: cfg.gamma0,
            gamma_min: cfg.gamma_min,
            scan_budget: 0,
            sweep_every: 0,
        },
    );
    let throttle = if cfg.disk_bandwidth > 0.0 {
        IoThrottle::new(cfg.disk_bandwidth)
    } else {
        IoThrottle::unlimited()
    };
    let sampler_cfg = SamplerConfig {
        target_m: cfg.sample_size,
        kind: cfg.sampler,
        probe: cfg.sample_size.min(4096),
        max_passes: 3,
        block: 1024,
    };
    let mut sampler_rng = rng.fork(1);
    let mut source = match cfg.sampler_mode {
        SamplerMode::Blocking => SampleSource::Blocking(Sampler::new(
            store.stream(throttle).expect("open store stream"),
            store.len(),
            sampler_cfg,
            sampler_rng,
        )),
        SamplerMode::Background => {
            let spawned = match cfg.store_tier {
                StoreTier::Mem => BackgroundSampler::spawn(
                    store.path(),
                    throttle,
                    StrataConfig {
                        // keep roughly a few samples' worth of heavy strata hot
                        resident_rows: cfg.sample_size.saturating_mul(4),
                    },
                    sampler_cfg,
                    bin_spec.clone(),
                    sampler_rng.next_u64(),
                    id,
                    log.clone(),
                ),
                // out-of-core: heavy strata resident within the budget,
                // light strata in spill chunks, identical sample bytes
                StoreTier::Tiered => BackgroundSampler::spawn_tiered(
                    store.path(),
                    TieredConfig {
                        memory_budget: cfg.memory_budget,
                        probe_rows: sampler_cfg.probe,
                        ..TieredConfig::default()
                    },
                    sampler_cfg,
                    bin_spec.clone(),
                    sampler_rng.next_u64(),
                    id,
                    log.clone(),
                ),
            };
            match spawned {
                Ok(bg) => SampleSource::Background(bg),
                Err(e) => {
                    log.record(id, EventKind::Crash, None, 0.0);
                    eprintln!("worker {id}: background sampler spawn failed: {e}");
                    return crashed_result(id, &cfg, &log);
                }
            }
        }
    };
    // worker-local model version: bumped on every adoption and publish;
    // stamps background builds so stale in-flight samples are invalidated
    let mut version: u64 = 0;

    let tmsn = match &cfg.resume {
        Some((model, bound)) => {
            // crash-rejoin (DESIGN.md §12): restart from the last
            // committed checkpoint, restamped (id, 0) so any own prior
            // broadcast still in flight beats it and catches us up
            log.record(id, EventKind::Rejoin, None, *bound);
            Tmsn::resume(id, BoostPayload::resume(model.clone(), *bound))
        }
        None => Tmsn::new(id),
    };
    let mut driver = Driver::new(tmsn, endpoint, log.clone());
    if let Some(c) = &control {
        // startup gauges; a resumed checkpoint model reaches the serve
        // slot via `ModelSlot::seed` at the call site (version 0)
        let p = driver.payload();
        c.state.note_model(version, p.model.len(), p.cert.loss_bound);
    }
    let mut sample = SampleSet::empty(store.num_features());
    let mut force_resample = true;
    let mut found = 0u64;
    let mut resamples = 0u64;
    let mut crashed = false;
    let mut prev_gamma_shrinks = 0u64;
    // model version already persisted to cfg.checkpoint (0 = nothing yet)
    let mut ckpt_version: u64 = 0;

    'outer: loop {
        // ---- checkpoint: persist every model-version move ---------------
        if let Some(path) = &cfg.checkpoint {
            if version != ckpt_version {
                match write_checkpoint(path, driver.payload()) {
                    Ok(()) => ckpt_version = version,
                    Err(e) => eprintln!("worker {id}: checkpoint write failed: {e}"),
                }
            }
        }

        // ---- liveness checks -------------------------------------------
        if stop.load(Ordering::Relaxed) || start.elapsed() >= cfg.time_limit {
            break;
        }
        if let Some(t) = crash_after {
            if start.elapsed() >= t {
                log.record(id, EventKind::Crash, None, 0.0);
                crashed = true;
                break;
            }
        }

        // ---- control plane: nudges + on-demand faults (DESIGN.md §10) --
        if let Some(c) = &control {
            for nudge in c.state.drain_nudges() {
                match nudge {
                    Nudge::SetGamma(g) => scanner.set_gamma0(g),
                    Nudge::GammaReset => scanner.set_gamma0(cfg.gamma0),
                    Nudge::SetSweep(s) => scanner.set_sweep_every(s),
                }
            }
            if c.state.crash_requested() {
                log.record(id, EventKind::Crash, None, 0.0);
                crashed = true;
                break;
            }
            if c.state.take_restart() {
                // in-place rebirth (`fault.inject {"fault":"restart"}`):
                // the live analogue of the simulator's crash+rejoin.
                // Persist first (so the restart point is durable), then
                // drop every pending remote payload and restamp the
                // current certified model (id, 0) — any strictly-better
                // broadcast still in flight beats it and catches us up.
                if let Some(path) = &cfg.checkpoint {
                    match write_checkpoint(path, driver.payload()) {
                        Ok(()) => ckpt_version = version,
                        Err(e) => {
                            eprintln!("worker {id}: restart checkpoint write failed: {e}")
                        }
                    }
                }
                driver.rebirth();
                log.record(id, EventKind::Rejoin, None, driver.cert().loss_bound);
                version += 1;
                if let SampleSource::Background(bg) = &mut source {
                    bg.on_model_change(version, &driver.payload().model);
                }
                c.note_model(version, driver.payload());
                force_resample = true;
            }
        }
        if driver.payload().model.len() >= cfg.max_rules
            || (cfg.target_bound > 0.0 && driver.cert().loss_bound <= cfg.target_bound)
        {
            break;
        }

        // ---- inbox (receive path of Alg. 1) ----------------------------
        let adopted = driver.poll_adopt(&mut |prev, cur| {
            rebase_if_foreign(&mut sample, prev, cur);
        });
        if adopted > 0 {
            version += adopted as u64;
            if let SampleSource::Background(bg) = &mut source {
                // invalidate/restart any in-flight build (DESIGN.md §4)
                bg.on_model_change(version, &driver.payload().model);
            }
            if let Some(c) = &control {
                c.note_model(version, driver.payload());
            }
        }

        // ---- background handoff: flip to a finished sample -------------
        if let SampleSource::Background(bg) = &mut source {
            match bg.try_install(version) {
                Ok(Some((s, stats))) => {
                    install_sample(
                        &mut sample,
                        &mut scanner,
                        &mut resamples,
                        &log,
                        id,
                        s,
                        stats,
                        &bin_spec,
                        EventKind::SampleSwap,
                    );
                }
                Ok(None) => {}
                Err(e) => {
                    log_sampler_crash(&log, id, &e);
                    crashed = true;
                    break 'outer;
                }
            }
        }

        // ---- sample freshness (§3 n_eff trigger) ------------------------
        let need_sample = force_resample
            || sample.is_empty()
            || sample.n_eff() / cfg.sample_size as f64 <= cfg.ess_threshold;
        if need_sample {
            match &mut source {
                SampleSource::Blocking(sampler) => {
                    log.record(id, EventKind::ResampleStart, None, sample.n_eff());
                    let model = driver.payload().model.clone();
                    let stall_t0 = Instant::now();
                    let resampled = sampler.resample(&model);
                    if let Some(c) = &control {
                        // the paper's resample plateau, as a live gauge
                        c.state.add_stall(stall_t0.elapsed());
                    }
                    match resampled {
                        Ok((s, stats)) => {
                            install_sample(
                                &mut sample,
                                &mut scanner,
                                &mut resamples,
                                &log,
                                id,
                                s,
                                stats,
                                &bin_spec,
                                EventKind::ResampleEnd,
                            );
                        }
                        Err(e) => {
                            // disk failure: treat as crash (resilience semantics)
                            log_sampler_crash(&log, id, &e);
                            crashed = true;
                            break 'outer;
                        }
                    }
                }
                SampleSource::Background(bg) => {
                    // ask for a build against the current model (deduped
                    // while one is already in flight)
                    bg.request(version, &driver.payload().model);
                    if sample.is_empty() {
                        // initial fill: nothing to scan yet, so this wait
                        // is the only blocking hand-off in background mode
                        let stall_t0 = Instant::now();
                        let install = bg.wait_install(version, || {
                            stop.load(Ordering::Relaxed)
                                || start.elapsed() >= cfg.time_limit
                        });
                        if let Some(c) = &control {
                            c.state.add_stall(stall_t0.elapsed());
                        }
                        match install {
                            Ok(Some((s, stats))) => {
                                install_sample(
                                    &mut sample,
                                    &mut scanner,
                                    &mut resamples,
                                    &log,
                                    id,
                                    s,
                                    stats,
                                    &bin_spec,
                                    EventKind::SampleSwap,
                                );
                            }
                            Ok(None) => break 'outer, // stopped while waiting
                            Err(e) => {
                                log_sampler_crash(&log, id, &e);
                                crashed = true;
                                break 'outer;
                            }
                        }
                    }
                    // else: keep scanning the stale sample until the fresh
                    // one lands — the plateau the pipeline eliminates
                }
            }
            force_resample = false;
            if sample.is_empty() {
                // degenerate store — nothing to learn from
                break;
            }
        }

        // ---- one scanner invocation -------------------------------------
        let model = driver.payload().model.clone();
        let deadline_hit = &stop;
        // a finished background sample also interrupts the pass, so the
        // swap happens at a batch boundary instead of a pass boundary
        let bg_ready = match &source {
            SampleSource::Background(bg) => Some(bg.ready_flag()),
            SampleSource::Blocking(_) => None,
        };
        let pass_t0 = Instant::now();
        let outcome = scanner.run_pass(&mut sample, &model, || {
            deadline_hit.load(Ordering::Relaxed)
                || driver.poll_interrupt()
                || bg_ready.as_ref().map_or(false, |r| r.load(Ordering::Relaxed))
        });
        // surface γ-halving events
        for _ in prev_gamma_shrinks..scanner.gamma_shrinks {
            log.record(id, EventKind::GammaShrink, None, 0.0);
        }
        prev_gamma_shrinks = scanner.gamma_shrinks;
        if let Some(c) = &control {
            c.state.note_scanned(scanner.total_scanned);
            // on-demand laggard (`fault.inject`), applied at pass
            // granularity: idle (factor − 1)× the pass's own elapsed time
            let factor = c.state.laggard();
            if factor > 1.0 {
                std::thread::sleep(pass_t0.elapsed().mul_f64(factor - 1.0));
            }
        }

        match outcome {
            ScanOutcome::Found {
                stump,
                gamma,
                scanned: _,
            } => {
                let mut new_model = driver.payload().model.clone();
                new_model.push(stump, alpha_for_advantage(gamma) as f32);
                driver.publish(driver.payload().improved(new_model, gamma));
                version += 1;
                if let SampleSource::Background(bg) = &mut source {
                    bg.on_model_change(version, &driver.payload().model);
                }
                if let Some(c) = &control {
                    c.note_model(version, driver.payload());
                }
                found += 1;
            }
            ScanOutcome::Exhausted { .. } => {
                // Alg. 2 `Fail` → build a fresh sample
                force_resample = true;
                // In background mode an exhausted sample has nothing
                // certifiable left — don't busy-spin full passes over it
                // (each spamming γ-halvings) while the replacement builds;
                // park on the handoff until the swap, an adoption, or stop.
                if let SampleSource::Background(bg) = &mut source {
                    bg.request(version, &driver.payload().model);
                    let stall_t0 = Instant::now();
                    let install = bg.wait_install(version, || {
                        stop.load(Ordering::Relaxed)
                            || start.elapsed() >= cfg.time_limit
                            || driver.poll_interrupt()
                    });
                    if let Some(c) = &control {
                        c.state.add_stall(stall_t0.elapsed());
                    }
                    match install {
                        Ok(Some((s, stats))) => {
                            install_sample(
                                &mut sample,
                                &mut scanner,
                                &mut resamples,
                                &log,
                                id,
                                s,
                                stats,
                                &bin_spec,
                                EventKind::SampleSwap,
                            );
                            force_resample = false;
                        }
                        Ok(None) => {
                            // gave up: a strictly-better model may be
                            // parked from the poll_interrupt probe above
                            let adopted = driver.adopt_pending(&mut |prev, cur| {
                                rebase_if_foreign(&mut sample, prev, cur);
                            });
                            if adopted {
                                version += 1;
                                bg.on_model_change(version, &driver.payload().model);
                                if let Some(c) = &control {
                                    c.note_model(version, driver.payload());
                                }
                            }
                        }
                        Err(e) => {
                            log_sampler_crash(&log, id, &e);
                            crashed = true;
                            break 'outer;
                        }
                    }
                }
            }
            ScanOutcome::Interrupted { .. } => {
                let adopted = driver.adopt_pending(&mut |prev, cur| {
                    rebase_if_foreign(&mut sample, prev, cur);
                });
                if adopted {
                    version += 1;
                    if let SampleSource::Background(bg) = &mut source {
                        bg.on_model_change(version, &driver.payload().model);
                    }
                    if let Some(c) = &control {
                        c.note_model(version, driver.payload());
                    }
                }
                // stop-flag and sample-ready interrupts fall through to
                // the loop head (where a pending sample is swapped in)
            }
        }
        // tiny jitter so identical workers don't phase-lock in tests
        if laggard > 1.0 {
            std::thread::sleep(Duration::from_micros(rng.below(50)));
        }
    }

    // final checkpoint: the loop may have broken between a version bump
    // and its loop-head persist
    if let Some(path) = &cfg.checkpoint {
        if version != ckpt_version {
            if let Err(e) = write_checkpoint(path, driver.payload()) {
                eprintln!("worker {id}: final checkpoint write failed: {e}");
            }
        }
    }
    log.record(id, EventKind::Finish, None, driver.cert().loss_bound);
    let state = driver.into_state();
    WorkerResult {
        id,
        model: state.payload().model.clone(),
        loss_bound: state.cert().loss_bound,
        found,
        accepts: state.accepts,
        rejects: state.rejects,
        resamples,
        scanned: scanner.total_scanned,
        crashed,
    }
}

/// Adoption hook for [`Driver`]: keep the sample's cached weights
/// consistent with the newly adopted model. If the adopted model extends
/// the replaced one, the per-example incremental state stays valid (suffix
/// update); otherwise the lineage broke and every cached weight is rebased
/// onto the new model from its sample-time reference pair.
fn rebase_if_foreign(sample: &mut SampleSet, prev: &BoostPayload, cur: &BoostPayload) {
    if !cur.model.extends(&prev.model) {
        rebase_sample(sample, &cur.model);
    }
}

/// Recompute cached weights against `model` from the sample-time reference
/// `(w_s, H_s(x))` — exact for any lineage (§4.1's invariant).
pub fn rebase_sample(sample: &mut SampleSet, model: &StrongRule) {
    let len = model.len() as u32;
    for i in 0..sample.len() {
        let score = model.score(sample.data.row(i));
        let y = sample.data.label(i);
        let w = sample.w_sample[i] * (-(y) * (score - sample.score_sample[i])).exp();
        sample.set_weight(i, score, w, len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;

    #[test]
    fn checkpoint_roundtrips_through_the_resume_files() {
        let dir = std::env::temp_dir().join(format!("sparrow-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.txt");
        let path = path.to_str().unwrap();

        let mut model = StrongRule::new();
        model.push(Stump::new(0, 0.5, 1.0), 0.4);
        write_checkpoint(path, &BoostPayload::resume(model.clone(), 0.75)).unwrap();
        let back = StrongRule::from_text(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(back.len(), 1);
        let meta = std::fs::read_to_string(format!("{path}.meta")).unwrap();
        assert!(meta.contains("bound=0.75"), "{meta:?}");

        // a later version replaces both files (rename, never truncate)
        model.push(Stump::new(0, 0.1, -1.0), 0.2);
        write_checkpoint(path, &BoostPayload::resume(model, 0.5)).unwrap();
        let back = StrongRule::from_text(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(back.len(), 2);
        let meta = std::fs::read_to_string(format!("{path}.meta")).unwrap();
        assert!(meta.contains("bound=0.5"), "{meta:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rebase_matches_direct_weights() {
        let mut rng = Rng::new(1);
        let mut block = crate::data::DataBlock::empty(3);
        for _ in 0..50 {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            block.push(
                &[rng.gauss() as f32, rng.gauss() as f32, rng.gauss() as f32],
                y,
            );
        }
        // sampled under a "model A" with per-example scores 0 (fresh)
        let mut sample = SampleSet::fresh(block, vec![0.0; 50], 0);
        // foreign model B
        let mut b = StrongRule::new();
        b.push(Stump::new(0, 0.1, 1.0), 0.4);
        b.push(Stump::new(2, -0.2, -1.0), 0.3);
        rebase_sample(&mut sample, &b);
        for i in 0..50 {
            let want_score = b.score(sample.data.row(i));
            let want_w = (-(sample.data.label(i)) * want_score).exp();
            assert!((sample.score_last[i] - want_score).abs() < 1e-5);
            assert!((sample.w_last[i] - want_w).abs() < 1e-4);
            assert_eq!(sample.model_len_last[i], 2);
        }
    }

    #[test]
    fn rebase_respects_nonzero_sample_reference() {
        // sampled when the model scored the example 0.5 with weight 1
        let mut block = crate::data::DataBlock::empty(1);
        block.push(&[2.0], 1.0);
        let mut sample = SampleSet::fresh(block, vec![0.5], 3);
        let mut b = StrongRule::new();
        b.push(Stump::new(0, 0.0, 1.0), 0.9); // score(x) = 0.9
        rebase_sample(&mut sample, &b);
        // w = 1 * exp(-1 * (0.9 - 0.5))
        assert!((sample.w_last[0] - (-0.4f32).exp()).abs() < 1e-5);
    }

    #[test]
    fn rebase_skipped_when_adopted_model_extends() {
        // extends-lineage adoptions must leave cached weights untouched;
        // the base model must be non-empty (the empty model is a prefix of
        // everything, so any adoption from it is an "extends" adoption)
        let mut block = crate::data::DataBlock::empty(1);
        block.push(&[2.0], 1.0);
        let mut sample = SampleSet::fresh(block, vec![0.0], 0);
        let w_before = sample.w_last[0];

        let mut base_model = StrongRule::new();
        base_model.push(Stump::new(0, 0.5, 1.0), 0.4);
        let base = BoostPayload::resume(base_model, 0.9);
        let mut extended = base.model.clone();
        extended.push(Stump::new(0, 0.0, 1.0), 0.9);
        let cur = BoostPayload::resume(extended, 0.5);
        rebase_if_foreign(&mut sample, &base, &cur);
        assert_eq!(sample.w_last[0], w_before, "suffix lineage: no rebase");

        // a non-extending (foreign) model does trigger the rebase
        let mut foreign = StrongRule::new();
        foreign.push(Stump::new(0, 1.0, -1.0), 0.3);
        let cur = BoostPayload::resume(foreign, 0.4);
        rebase_if_foreign(&mut sample, &base, &cur);
        assert_ne!(sample.w_last[0], w_before);
    }
}
