//! Transport attachments: how a worker joins the broadcast medium.
//!
//! The protocol's transport surface is [`crate::tmsn::Link`] — two
//! operations, fire-and-forget `send` and non-blocking `poll`. This module
//! implements it for every transport, generically over the payload:
//!
//! * [`crate::network::Endpoint<P>`] — the in-process simulated fabric
//!   (coordinator, benches, failure-injection experiments);
//! * [`crate::network::TcpEndpoint<P>`] — the real TCP transport
//!   (`sparrow worker` multi-process mode);
//! * [`NullLink`] — a disconnected link (single-worker runs).

use crate::network::{Endpoint, TcpEndpoint};
use crate::tmsn::{Link, Payload};

impl<P: Payload> Link<P> for Endpoint<P> {
    fn send(&self, msg: P) {
        let bytes = msg.wire_bytes();
        self.broadcast(msg, bytes);
    }

    fn poll(&self) -> Option<P> {
        self.try_recv()
    }
}

impl<P: Payload> Link<P> for TcpEndpoint<P> {
    fn send(&self, msg: P) {
        self.broadcast(&msg);
    }

    fn poll(&self) -> Option<P> {
        self.try_recv()
    }
}

/// A disconnected link (single-worker runs with no peers at all).
pub struct NullLink;

impl<P: Payload> Link<P> for NullLink {
    fn send(&self, _msg: P) {}
    fn poll(&self) -> Option<P> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EventLog;
    use crate::model::StrongRule;
    use crate::network::{Fabric, NetConfig};
    use crate::tmsn::{BoostPayload, Certified, Driver, LossBoundCert, Tmsn};

    fn msg() -> BoostPayload {
        BoostPayload {
            model: StrongRule::new(),
            cert: LossBoundCert::initial(),
        }
    }

    #[test]
    fn null_link_swallows() {
        let l = NullLink;
        Link::<BoostPayload>::send(&l, msg());
        assert!(Link::<BoostPayload>::poll(&l).is_none());
    }

    #[test]
    fn null_link_driver_keeps_local_state() {
        // A worker with no peers behaves exactly like the single-machine
        // learner: publishes go nowhere, polls adopt nothing, and the
        // verdict counters never move.
        let (log, _rx) = EventLog::new();
        let mut d = Driver::new(Tmsn::<BoostPayload>::new(0), NullLink, log);
        let mut model = StrongRule::new();
        model.push(crate::model::Stump::new(0, 0.0, 1.0), 0.2);
        d.publish(d.payload().improved(model, 0.1));
        assert_eq!(d.poll_adopt(&mut |_, _| {}), 0);
        assert!(!d.poll_interrupt());
        assert_eq!((d.state().accepts, d.state().rejects), (0, 0));
        assert!(d.cert().loss_bound < 1.0, "local progress is kept");
    }

    #[test]
    fn fabric_endpoint_roundtrip_through_trait() {
        let (fabric, mut eps) = Fabric::<BoostPayload>::new(2, NetConfig::ideal());
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let link_a: &dyn Link<BoostPayload> = &a;
        link_a.send(msg());
        let mut got = None;
        for _ in 0..100 {
            if let Some(m) = b.poll() {
                got = Some(m);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(got.is_some());
        fabric.shutdown();
    }
}
