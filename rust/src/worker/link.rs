//! Transport abstraction: a worker's attachment to the broadcast medium.
//!
//! Implemented by the in-process simulated fabric
//! ([`crate::network::Endpoint<ModelMessage>`], used by the coordinator,
//! benches, and failure-injection experiments) and by the real TCP
//! transport ([`crate::network::TcpEndpoint`], used by the
//! `sparrow worker` multi-process mode).

use crate::network::{Endpoint, TcpEndpoint};
use crate::tmsn::ModelMessage;

/// The only two operations TMSN needs from a network.
pub trait BroadcastLink: Send {
    /// Fire-and-forget broadcast to all peers.
    fn send(&self, msg: ModelMessage);
    /// Non-blocking poll for the next delivered message.
    fn poll(&self) -> Option<ModelMessage>;
}

impl BroadcastLink for Endpoint<ModelMessage> {
    fn send(&self, msg: ModelMessage) {
        let bytes = msg.wire_bytes();
        self.broadcast(msg, bytes);
    }

    fn poll(&self) -> Option<ModelMessage> {
        self.try_recv()
    }
}

impl BroadcastLink for TcpEndpoint {
    fn send(&self, msg: ModelMessage) {
        self.broadcast(&msg);
    }

    fn poll(&self) -> Option<ModelMessage> {
        self.try_recv()
    }
}

/// A disconnected link (single-worker runs with no peers at all).
pub struct NullLink;

impl BroadcastLink for NullLink {
    fn send(&self, _msg: ModelMessage) {}
    fn poll(&self) -> Option<ModelMessage> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::StrongRule;
    use crate::network::{Fabric, NetConfig};
    use crate::tmsn::Certificate;

    fn msg() -> ModelMessage {
        ModelMessage {
            model: StrongRule::new(),
            cert: Certificate::initial(),
        }
    }

    #[test]
    fn null_link_swallows() {
        let l = NullLink;
        l.send(msg());
        assert!(l.poll().is_none());
    }

    #[test]
    fn fabric_endpoint_roundtrip_through_trait() {
        let (fabric, mut eps) = Fabric::<ModelMessage>::new(2, NetConfig::ideal());
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        let link_a: &dyn BroadcastLink = &a;
        link_a.send(msg());
        let mut got = None;
        for _ in 0..100 {
            if let Some(m) = b.poll() {
                got = Some(m);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(got.is_some());
        fabric.shutdown();
    }
}
