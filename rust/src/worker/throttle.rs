//! Laggard injection: a backend wrapper that slows compute by a factor.
//!
//! The paper's resilience claim (§1, §4): "the overall slowdown resulting
//! from machine slowness or failure is proportional to the fraction of
//! faulty machines". Wrapping a worker's backend with a multiplier `k`
//! makes that worker behave like a machine running k× slower — the E6
//! resilience experiment sweeps this.

use std::sync::Arc;
use std::time::Duration;

use crate::boosting::CandidateGrid;
use crate::data::{BinnedBatch, DataBlock};
use crate::model::StrongRule;
use crate::scanner::{BatchResult, ScanBackend};
use crate::sim::clock::{Clock, RealClock};

/// Wraps a backend, adding `(k - 1)×` the measured batch time as sleep.
///
/// Batch time is measured (and the extra sleep performed) through a
/// [`Clock`], so under a [`crate::sim::SimClock`] a laggard slows down in
/// *virtual* time: wrap a backend whose cost is modeled via `clock.sleep`
/// and the slowdown composes deterministically (DESIGN.md §9).
pub struct ThrottledBackend {
    inner: Box<dyn ScanBackend>,
    factor: f64,
    clock: Arc<dyn Clock>,
}

impl ThrottledBackend {
    pub fn new(inner: Box<dyn ScanBackend>, factor: f64) -> ThrottledBackend {
        ThrottledBackend::with_clock(inner, factor, Arc::new(RealClock))
    }

    /// A laggard wrapper timing itself on `clock`.
    pub fn with_clock(
        inner: Box<dyn ScanBackend>,
        factor: f64,
        clock: Arc<dyn Clock>,
    ) -> ThrottledBackend {
        assert!(factor >= 1.0, "laggard factor must be >= 1");
        ThrottledBackend {
            inner,
            factor,
            clock,
        }
    }
}

impl ScanBackend for ThrottledBackend {
    fn scan_batch_into(
        &mut self,
        block: &DataBlock,
        bins: Option<&BinnedBatch>,
        w_ref: &[f32],
        score_ref: &[f32],
        model_len_ref: &[u32],
        model: &StrongRule,
        grid: &CandidateGrid,
        stripe: (usize, usize),
        out: &mut BatchResult,
    ) {
        let t0 = self.clock.now();
        self.inner.scan_batch_into(
            block, bins, w_ref, score_ref, model_len_ref, model, grid, stripe, out,
        );
        let spent = self.clock.now().saturating_duration_since(t0);
        let extra = spent.mul_f64(self.factor - 1.0);
        if extra > Duration::ZERO {
            self.clock.sleep(extra);
        }
    }

    fn wants_bins(&self) -> bool {
        self.inner.wants_bins()
    }

    fn name(&self) -> &'static str {
        "throttled"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scanner::NativeBackend;
    use crate::util::rng::Rng;
    use std::time::Instant;

    fn work(be: &mut dyn ScanBackend, n: usize) -> Duration {
        let mut rng = Rng::new(1);
        let f = 16;
        let feats: Vec<f32> = (0..n * f).map(|_| rng.gauss() as f32).collect();
        let labels: Vec<f32> = (0..n)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
            .collect();
        let block = DataBlock::new(n, f, feats, labels);
        let grid = CandidateGrid::uniform(f, 4, -1.0, 1.0);
        let model = StrongRule::new();
        let w = vec![1.0f32; n];
        let s = vec![0.0f32; n];
        let l = vec![0u32; n];
        let t0 = Instant::now();
        for _ in 0..10 {
            be.scan_batch(&block, &w, &s, &l, &model, &grid, (0, f));
        }
        t0.elapsed()
    }

    #[test]
    fn throttled_slower_than_native() {
        let mut native = NativeBackend;
        let base = work(&mut native, 512);
        let mut slow = ThrottledBackend::new(Box::new(NativeBackend), 4.0);
        let slowed = work(&mut slow, 512);
        // expect roughly 4x; allow wide margin for scheduling noise
        assert!(
            slowed > base.mul_f64(2.0),
            "base={base:?} slowed={slowed:?}"
        );
    }

    #[test]
    #[should_panic(expected = "laggard factor")]
    fn rejects_speedup_factor() {
        ThrottledBackend::new(Box::new(NativeBackend), 0.5);
    }

    #[test]
    fn virtual_clock_throttles_in_virtual_time() {
        use crate::sim::SimClock;

        /// A backend whose compute cost is *modeled*: each batch advances
        /// the shared clock by 10 ms instead of burning CPU.
        struct Modeled(Arc<SimClock>);
        impl ScanBackend for Modeled {
            fn scan_batch_into(
                &mut self,
                _block: &DataBlock,
                _bins: Option<&BinnedBatch>,
                _w_ref: &[f32],
                _score_ref: &[f32],
                _model_len_ref: &[u32],
                _model: &StrongRule,
                _grid: &CandidateGrid,
                _stripe: (usize, usize),
                _out: &mut BatchResult,
            ) {
                self.0.sleep(Duration::from_millis(10));
            }
            fn wants_bins(&self) -> bool {
                false
            }
            fn name(&self) -> &'static str {
                "modeled"
            }
        }

        let clock = Arc::new(SimClock::new());
        let mut slow =
            ThrottledBackend::with_clock(Box::new(Modeled(clock.clone())), 4.0, clock.clone());
        let block = DataBlock::new(1, 1, vec![0.0], vec![1.0]);
        let grid = CandidateGrid::uniform(1, 1, -1.0, 1.0);
        let model = StrongRule::new();
        let wall = Instant::now();
        slow.scan_batch(&block, &[1.0], &[0.0], &[0], &model, &grid, (0, 1));
        // 10 ms modeled batch × factor 4 = exactly 40 ms of virtual time,
        // and essentially zero wall time
        assert_eq!(clock.now_virtual(), Duration::from_millis(40));
        assert!(wall.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn delegates_wants_bins_to_inner() {
        use crate::scanner::BinnedBackend;
        let rows = ThrottledBackend::new(Box::new(NativeBackend), 2.0);
        assert!(!rows.wants_bins());
        let binned = ThrottledBackend::new(Box::new(BinnedBackend::new(2)), 2.0);
        assert!(binned.wants_bins(), "laggard wrapper must forward bins");
    }
}
