//! Selective (weight-proportional) sampling strategies for the Sampler.
//!
//! The paper samples each example with probability proportional to its
//! weight and assigns the kept copies initial weight 1 (§4.1). It uses
//! *minimal variance sampling* (Kitagawa's systematic resampling [19])
//! "because it produces less variation in the sampled set"; rejection and
//! uniform sampling are provided for the A2 ablation.

use crate::util::rng::Rng;

/// A streaming weighted sampler: offered examples one at a time, returns
/// how many copies to keep (0 or more).
pub trait SelectiveSampler: Send {
    /// Offer an example with weight `w`; how many copies enter the sample?
    fn offer(&mut self, w: f64, rng: &mut Rng) -> usize;

    /// `scale` is the weight mass per kept example (`c` such that an
    /// example of weight `c` is kept exactly once in expectation).
    fn scale(&self) -> f64;

    /// Human-readable strategy name (ablation tables, logs).
    fn name(&self) -> &'static str;
}

/// Kitagawa systematic ("minimal variance") resampling, streamed:
/// accumulate `w/scale` and emit a copy every time the accumulator crosses
/// the next stratum boundary `offset + k`. Copy counts differ from the
/// expectation `w/scale` by strictly less than 1.
#[derive(Debug)]
pub struct MinimalVarianceSampler {
    scale: f64,
    acc: f64,
    emitted: u64,
    offset: f64,
}

impl MinimalVarianceSampler {
    /// `scale` = expected weight mass per kept example. The stratum offset
    /// is drawn once per pass (systematic sampling's single random number).
    pub fn new(scale: f64, rng: &mut Rng) -> MinimalVarianceSampler {
        assert!(scale > 0.0);
        MinimalVarianceSampler {
            scale,
            acc: 0.0,
            emitted: 0,
            offset: rng.f64(),
        }
    }
}

impl SelectiveSampler for MinimalVarianceSampler {
    fn offer(&mut self, w: f64, _rng: &mut Rng) -> usize {
        debug_assert!(w >= 0.0);
        self.acc += w / self.scale;
        let mut copies = 0usize;
        while self.acc > self.offset + self.emitted as f64 {
            self.emitted += 1;
            copies += 1;
        }
        copies
    }

    fn scale(&self) -> f64 {
        self.scale
    }

    fn name(&self) -> &'static str {
        "minimal-variance"
    }
}

/// Classic rejection sampling: keep with probability `min(w/scale, 1)`;
/// weights above `scale` keep `floor(w/scale)` copies plus a Bernoulli
/// remainder so expectation matches minimal-variance exactly.
#[derive(Debug)]
pub struct RejectionSampler {
    scale: f64,
}

impl RejectionSampler {
    /// `scale` = expected weight mass per kept example (as in
    /// [`MinimalVarianceSampler::new`]).
    pub fn new(scale: f64) -> RejectionSampler {
        assert!(scale > 0.0);
        RejectionSampler { scale }
    }
}

impl SelectiveSampler for RejectionSampler {
    fn offer(&mut self, w: f64, rng: &mut Rng) -> usize {
        debug_assert!(w >= 0.0);
        let expect = w / self.scale;
        let base = expect.floor();
        let frac = expect - base;
        base as usize + usize::from(rng.bernoulli(frac))
    }

    fn scale(&self) -> f64 {
        self.scale
    }

    fn name(&self) -> &'static str {
        "rejection"
    }
}

/// Weight-blind uniform sampling at a fixed rate (A2 ablation's strawman —
/// wastes memory on easy examples; kept examples do NOT have uniform
/// weight so the caller must carry w into the sample).
#[derive(Debug)]
pub struct UniformSampler {
    /// flat keep probability per offered example
    pub rate: f64,
}

impl UniformSampler {
    /// Keep every offered example with probability `rate ∈ [0, 1]`.
    pub fn new(rate: f64) -> UniformSampler {
        assert!((0.0..=1.0).contains(&rate));
        UniformSampler { rate }
    }
}

impl SelectiveSampler for UniformSampler {
    fn offer(&mut self, _w: f64, rng: &mut Rng) -> usize {
        usize::from(rng.bernoulli(self.rate))
    }

    fn scale(&self) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, prop_check};

    #[test]
    fn mvs_copy_count_within_one_of_expectation() {
        let mut rng = Rng::new(1);
        let mut s = MinimalVarianceSampler::new(2.0, &mut rng);
        let ws = [0.5, 3.0, 1.0, 6.0, 0.1, 2.0];
        let mut total = 0usize;
        let mut mass = 0.0;
        for &w in &ws {
            total += s.offer(w, &mut rng);
            mass += w;
        }
        let expect = mass / 2.0;
        assert!(
            (total as f64 - expect).abs() < 1.0,
            "total={total} expect={expect}"
        );
    }

    #[test]
    fn mvs_heavy_example_kept_multiple_times() {
        let mut rng = Rng::new(2);
        let mut s = MinimalVarianceSampler::new(1.0, &mut rng);
        let copies = s.offer(5.5, &mut rng);
        assert!(copies == 5 || copies == 6, "copies={copies}");
    }

    #[test]
    fn mvs_zero_weight_never_kept() {
        let mut rng = Rng::new(3);
        let mut s = MinimalVarianceSampler::new(1.0, &mut rng);
        for _ in 0..100 {
            assert_eq!(s.offer(0.0, &mut rng), 0);
        }
    }

    #[test]
    fn prop_mvs_unbiased() {
        prop_check("mvs total ≈ mass/scale across seeds", 30, |rng| {
            let n = gen::size(rng, 50, 500);
            let ws = gen::skewed_weights(rng, n, 5.0);
            let scale = 0.5;
            let mut s = MinimalVarianceSampler::new(scale, rng);
            let mut total = 0usize;
            let mut mass = 0.0f64;
            for &w in &ws {
                total += s.offer(w as f64, rng);
                mass += w as f64;
            }
            let expect = mass / scale;
            if (total as f64 - expect).abs() < 1.0 {
                Ok(())
            } else {
                Err(format!("total={total} expect={expect:.3}"))
            }
        });
    }

    #[test]
    fn rejection_unbiased_in_expectation() {
        let mut rng = Rng::new(4);
        let mut s = RejectionSampler::new(2.0);
        let trials = 20_000;
        let w = 1.3; // expect 0.65/trial
        let total: usize = (0..trials).map(|_| s.offer(w, &mut rng)).sum();
        let rate = total as f64 / trials as f64;
        assert!((rate - 0.65).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn rejection_heavy_weight_multi_copy() {
        let mut rng = Rng::new(5);
        let mut s = RejectionSampler::new(1.0);
        let copies = s.offer(3.7, &mut rng);
        assert!(copies == 3 || copies == 4);
    }

    #[test]
    fn uniform_rate() {
        let mut rng = Rng::new(6);
        let mut s = UniformSampler::new(0.25);
        let total: usize = (0..40_000).map(|_| s.offer(123.0, &mut rng)).sum();
        let rate = total as f64 / 40_000.0;
        assert!((rate - 0.25).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn mvs_variance_lower_than_rejection() {
        // run both over the same weight stream many times; MVS count
        // variance must be (much) smaller
        let ws: Vec<f64> = (0..200).map(|i| 0.5 + (i % 7) as f64 * 0.3).collect();
        let scale = 1.0;
        let mut mv_counts = Vec::new();
        let mut rj_counts = Vec::new();
        for seed in 0..50 {
            let mut rng = Rng::new(seed);
            let mut mv = MinimalVarianceSampler::new(scale, &mut rng);
            let c: usize = ws.iter().map(|&w| mv.offer(w, &mut rng)).sum();
            mv_counts.push(c as f64);
            let mut rng = Rng::new(seed + 1000);
            let mut rj = RejectionSampler::new(scale);
            let c: usize = ws.iter().map(|&w| rj.offer(w, &mut rng)).sum();
            rj_counts.push(c as f64);
        }
        let var = |xs: &[f64]| {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
        };
        assert!(
            var(&mv_counts) < var(&rj_counts),
            "mv={} rj={}",
            var(&mv_counts),
            var(&rj_counts)
        );
    }
}
