//! Weighted sampling substrate (§3 "Effective Sample Size", §4.1 Sampler).

pub mod ess;
pub mod selective;

pub use ess::n_eff;
pub use selective::{MinimalVarianceSampler, RejectionSampler, SelectiveSampler, UniformSampler};
