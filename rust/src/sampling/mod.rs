//! Weighted sampling substrate (§3 "Effective Sample Size", §4.1 Sampler).
//!
//! [`ess::n_eff`] is the resample trigger; the [`selective`] strategies
//! decide which streamed examples a resample keeps. Both drive modes of
//! [`crate::sampler`] (blocking and background) sit on top of this module.

#![warn(missing_docs)]

pub mod ess;
pub mod selective;

pub use ess::n_eff;
pub use selective::{MinimalVarianceSampler, RejectionSampler, SelectiveSampler, UniformSampler};
