//! Effective sample size (paper Eq. 4):
//!
//!   n_eff = (Σ w_i)² / Σ w_i²
//!
//! As boosting skews the in-memory sample's weights, `n_eff` collapses;
//! when `n_eff / m` crosses a threshold the worker resamples from disk.

/// Effective number of examples for (unnormalized) weights.
pub fn n_eff(w: &[f32]) -> f64 {
    let mut s = 0.0f64;
    let mut s2 = 0.0f64;
    for &wi in w {
        let wi = wi as f64;
        s += wi;
        s2 += wi * wi;
    }
    if s2 <= 0.0 {
        0.0
    } else {
        s * s / s2
    }
}

/// Expected fraction of examples kept by weight-proportional selection
/// (§3: `(mean w) / (max w)`).
pub fn expected_keep_fraction(w: &[f32]) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    let max = w.iter().cloned().fold(f32::MIN, f32::max) as f64;
    if max <= 0.0 {
        return 0.0;
    }
    let mean = w.iter().map(|&x| x as f64).sum::<f64>() / w.len() as f64;
    mean / max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, prop_check};

    #[test]
    fn uniform_weights_full_ess() {
        let w = vec![2.5f32; 100];
        assert!((n_eff(&w) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn k_hot_weights_give_k() {
        // k ones and the rest zeros → n_eff = k (the paper's motivating case)
        let mut w = vec![0.0f32; 100];
        for wi in w.iter_mut().take(7) {
            *wi = 1.0;
        }
        assert!((n_eff(&w) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn empty_and_zero() {
        assert_eq!(n_eff(&[]), 0.0);
        assert_eq!(n_eff(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn scale_invariant() {
        let w1 = vec![1.0f32, 2.0, 3.0, 4.0];
        let w2: Vec<f32> = w1.iter().map(|x| x * 7.5).collect();
        assert!((n_eff(&w1) - n_eff(&w2)).abs() < 1e-6);
    }

    #[test]
    fn prop_bounded_by_n() {
        prop_check("1 <= n_eff <= n for positive weights", 50, |rng| {
            let n = gen::size(rng, 1, 500);
            let w = gen::skewed_weights(rng, n, 8.0);
            let e = n_eff(&w);
            if e >= 1.0 - 1e-9 && e <= n as f64 + 1e-9 {
                Ok(())
            } else {
                Err(format!("n_eff={e} out of [1, {n}]"))
            }
        });
    }

    #[test]
    fn keep_fraction() {
        assert!((expected_keep_fraction(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((expected_keep_fraction(&[1.0, 0.0]) - 0.5).abs() < 1e-12);
        assert_eq!(expected_keep_fraction(&[]), 0.0);
    }
}
