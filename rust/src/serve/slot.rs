//! The hot-swap model slot: the serve-side half of the train→serve loop
//! (DESIGN.md §10).
//!
//! A training worker publishes every adopted/improved model into a
//! [`ModelSlot`]; prediction threads read the current model with one
//! short lock and an `Arc` clone, then score entirely lock-free. The
//! publish protocol is the same **latest-wins** rule as the sampler's
//! [`crate::sampler::SampleHandle`]: a publish carrying a version no
//! newer than the installed one is dropped, so no interleaving of an
//! adoption storm can ever roll the served model backwards — served
//! versions are monotone non-decreasing, the invariant the control-plane
//! storm test asserts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::model::StrongRule;

/// An immutable served snapshot: the model plus its provenance.
#[derive(Debug)]
pub struct ServedModel {
    /// The strong rule predictions are scored against.
    pub model: StrongRule,
    /// Worker-local model version (bumped on every adoption/publish).
    pub version: u64,
    /// The certificate bound the model shipped with.
    pub loss_bound: f64,
}

/// Double-buffered latest-wins slot holding the newest adopted model.
pub struct ModelSlot {
    current: Mutex<Arc<ServedModel>>,
    swaps: AtomicU64,
}

impl ModelSlot {
    /// A slot holding the empty model (version 0, bound 1.0).
    pub fn new() -> ModelSlot {
        ModelSlot {
            current: Mutex::new(Arc::new(ServedModel {
                model: StrongRule::new(),
                version: 0,
                loss_bound: 1.0,
            })),
            swaps: AtomicU64::new(0),
        }
    }

    /// Install `model` iff `version` is strictly newer than the installed
    /// one (latest-wins). Returns whether the swap happened. In-flight
    /// predictions keep their `Arc` to the old model — nothing is
    /// invalidated under a reader, so a swap never drops a request.
    pub fn publish(&self, model: StrongRule, version: u64, loss_bound: f64) -> bool {
        let mut cur = self.current.lock().unwrap();
        if version <= cur.version {
            return false; // stale publish from a racing older state
        }
        *cur = Arc::new(ServedModel {
            model,
            version,
            loss_bound,
        });
        self.swaps.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Replace the pristine slot's initial model without consuming a
    /// version — resuming `sparrow serve` from a checkpoint serves the
    /// checkpointed model immediately instead of the empty one. Only
    /// valid before any publish has landed.
    pub fn seed(&self, model: StrongRule, loss_bound: f64) {
        let mut cur = self.current.lock().unwrap();
        assert_eq!(
            self.swaps.load(Ordering::Relaxed),
            0,
            "seed after a publish already landed"
        );
        *cur = Arc::new(ServedModel {
            model,
            version: 0,
            loss_bound,
        });
    }

    /// The current served model (cheap: one lock + `Arc` clone).
    pub fn current(&self) -> Arc<ServedModel> {
        Arc::clone(&self.current.lock().unwrap())
    }

    /// Version of the currently served model.
    pub fn version(&self) -> u64 {
        self.current.lock().unwrap().version
    }

    /// How many swaps have been installed over the slot's lifetime.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }
}

impl Default for ModelSlot {
    fn default() -> Self {
        ModelSlot::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;

    fn model_of_len(n: usize) -> StrongRule {
        let mut m = StrongRule::new();
        for i in 0..n {
            m.push(Stump::new(i as u32, 0.0, 1.0), 0.1);
        }
        m
    }

    #[test]
    fn publish_installs_and_stale_is_dropped() {
        let slot = ModelSlot::new();
        assert_eq!(slot.version(), 0);
        assert!(slot.publish(model_of_len(1), 1, 0.9));
        assert!(slot.publish(model_of_len(3), 3, 0.7));
        // older and same-version publishes lose
        assert!(!slot.publish(model_of_len(2), 2, 0.8));
        assert!(!slot.publish(model_of_len(3), 3, 0.7));
        let cur = slot.current();
        assert_eq!(cur.version, 3);
        assert_eq!(cur.model.len(), 3);
        assert_eq!(slot.swaps(), 2);
    }

    #[test]
    fn seed_installs_without_a_version() {
        let slot = ModelSlot::new();
        slot.seed(model_of_len(4), 0.7);
        let cur = slot.current();
        assert_eq!((cur.version, cur.model.len()), (0, 4));
        assert_eq!(slot.swaps(), 0);
        // version 1 still beats the seed (seed is "version 0 content")
        assert!(slot.publish(model_of_len(5), 1, 0.6));
        assert_eq!(slot.current().version, 1);
    }

    #[test]
    fn readers_keep_old_model_across_swap() {
        let slot = ModelSlot::new();
        slot.publish(model_of_len(1), 1, 0.9);
        let held = slot.current();
        slot.publish(model_of_len(5), 5, 0.5);
        // the in-flight reader's snapshot is untouched
        assert_eq!(held.version, 1);
        assert_eq!(held.model.len(), 1);
        assert_eq!(slot.current().version, 5);
    }

    #[test]
    fn adoption_storm_served_version_monotone() {
        // Seeded storm in the SampleHandle test style: racing publishers
        // fire interleaved stale and fresh versions while a reader spins;
        // the reader must never observe a version decrease, and the slot
        // must end on the global maximum.
        use std::sync::atomic::AtomicBool;
        let slot = Arc::new(ModelSlot::new());
        let done = Arc::new(AtomicBool::new(false));

        let reader = {
            let slot = Arc::clone(&slot);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut last = 0u64;
                let mut observed = 0u64;
                while !done.load(Ordering::Relaxed) {
                    let cur = slot.current();
                    assert!(
                        cur.version >= last,
                        "served version went backwards: {} -> {}",
                        last,
                        cur.version
                    );
                    // provenance stays consistent under the swap
                    assert_eq!(cur.model.len() as u64, cur.version);
                    last = cur.version;
                    observed += 1;
                }
                observed
            })
        };

        let publishers: Vec<_> = (0..4)
            .map(|p| {
                let slot = Arc::clone(&slot);
                std::thread::spawn(move || {
                    // each publisher walks its own arithmetic progression,
                    // so threads constantly race stale versions at the slot
                    for step in 0..200u64 {
                        let v = step * 4 + p + 1;
                        slot.publish(model_of_len(v as usize), v, 1.0 / v as f64);
                    }
                })
            })
            .collect();
        for h in publishers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
        let observed = reader.join().unwrap();
        assert!(observed > 0);
        assert_eq!(slot.version(), 800);
        assert!(slot.swaps() <= 800, "swaps can never exceed distinct versions");
    }
}
