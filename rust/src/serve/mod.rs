//! Model serving: answer prediction requests from the latest adopted
//! strong model while training continues (DESIGN.md §10, `sparrow serve`).
//!
//! The serve endpoint is a second [`crate::admin::RpcServer`] instance —
//! same framing, same envelope, different handler — bound next to the
//! worker's admin endpoint. Predictions read the model through a
//! [`ModelSlot`] hot-swap: an adoption storm replaces the served model
//! between requests without dropping or blocking any in-flight request.
//!
//! # Example
//!
//! ```
//! use std::sync::Arc;
//! use sparrow::model::{StrongRule, Stump};
//! use sparrow::serve::{ModelSlot, ServeHandler};
//! use sparrow::admin::RpcHandler;
//! use sparrow::util::json::Json;
//!
//! let slot = Arc::new(ModelSlot::new());
//! let mut m = StrongRule::new();
//! m.push(Stump::new(0, 0.0, 1.0), 0.5);
//! slot.publish(m, 1, 0.8);
//!
//! let handler = ServeHandler::new(Arc::clone(&slot));
//! let params = Json::parse(r#"{"row":[2.5]}"#).unwrap();
//! let r = handler.handle("predict", &params).unwrap();
//! assert_eq!(r.get("label").and_then(Json::as_f64), Some(1.0));
//! assert_eq!(r.get("model_version").and_then(Json::as_u64), Some(1));
//! ```

#![warn(missing_docs)]

pub mod slot;

pub use slot::{ModelSlot, ServedModel};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::admin::{RpcError, RpcHandler, PROTO_VERSION};
use crate::util::json::Json;

/// The prediction endpoint: serves every method in
/// [`crate::admin::SERVE_METHODS`] from a shared [`ModelSlot`].
pub struct ServeHandler {
    slot: Arc<ModelSlot>,
    requests: AtomicU64,
    predictions: AtomicU64,
}

impl ServeHandler {
    /// A serve endpoint answering from `slot`.
    pub fn new(slot: Arc<ModelSlot>) -> ServeHandler {
        ServeHandler {
            slot,
            requests: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
        }
    }

    fn predict(&self, params: &Json) -> Result<Json, RpcError> {
        let row_json = params
            .get("row")
            .and_then(Json::as_arr)
            .ok_or_else(|| RpcError::invalid_params("expected {\"row\": [number, ...]}"))?;
        let mut row = Vec::with_capacity(row_json.len());
        for v in row_json {
            row.push(
                v.as_f64()
                    .ok_or_else(|| RpcError::invalid_params("row entries must be numbers"))?
                    as f32,
            );
        }
        // one lock + Arc clone, then score lock-free: a concurrent swap
        // cannot touch this snapshot
        let served = self.slot.current();
        let needed = served
            .model
            .stumps()
            .iter()
            .map(|s| s.feature as usize + 1)
            .max()
            .unwrap_or(0);
        if row.len() < needed {
            return Err(RpcError::invalid_params(format!(
                "row has {} features, model needs {needed}",
                row.len()
            )));
        }
        let score = served.model.score(&row);
        self.predictions.fetch_add(1, Ordering::Relaxed);
        let mut o = Json::obj();
        o.set("score", score as f64)
            .set("label", if score >= 0.0 { 1.0 } else { -1.0 })
            .set("model_version", served.version as f64);
        Ok(o)
    }

    fn stats(&self) -> Json {
        let cur = self.slot.current();
        let mut o = Json::obj();
        o.set("requests", self.requests.load(Ordering::Relaxed) as f64)
            .set(
                "predictions",
                self.predictions.load(Ordering::Relaxed) as f64,
            )
            .set("swaps", self.slot.swaps() as f64)
            .set("model_version", cur.version as f64);
        o
    }
}

impl RpcHandler for ServeHandler {
    fn handle(&self, method: &str, params: &Json) -> Result<Json, RpcError> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        match method {
            "ping" => {
                let mut o = Json::obj();
                o.set("pong", true).set("proto", PROTO_VERSION as f64);
                Ok(o)
            }
            "predict" => self.predict(params),
            "serve.stats" => Ok(self.stats()),
            "model.current" => {
                let cur = self.slot.current();
                let mut o = Json::obj();
                o.set("version", cur.version as f64)
                    .set("len", cur.model.len() as f64)
                    .set("loss_bound", cur.loss_bound);
                Ok(o)
            }
            other => Err(RpcError::method_not_found(other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admin::SERVE_METHODS;
    use crate::model::{StrongRule, Stump};

    fn handler_with_model() -> ServeHandler {
        let slot = Arc::new(ModelSlot::new());
        let mut m = StrongRule::new();
        m.push(Stump::new(0, 0.0, 1.0), 0.5); // +1 above 0 on feature 0
        m.push(Stump::new(2, 1.0, -1.0), 0.25); // -1 above 1 on feature 2
        slot.publish(m, 7, 0.6);
        ServeHandler::new(slot)
    }

    fn params(text: &str) -> Json {
        Json::parse(text).unwrap()
    }

    #[test]
    fn every_listed_method_is_handled() {
        let h = handler_with_model();
        for m in SERVE_METHODS {
            let p = match *m {
                "predict" => params(r#"{"row":[1,0,0]}"#),
                _ => Json::Null,
            };
            match h.handle(m, &p) {
                Ok(_) => {}
                Err(e) => panic!("{m}: {e:?}"),
            }
        }
        assert_eq!(h.handle("nope", &Json::Null).unwrap_err().code, -32601);
    }

    #[test]
    fn predict_scores_against_served_model() {
        let h = handler_with_model();
        // f0 = 2 > 0 → +0.5; f2 = 0 ≤ 1 → stump2 predicts +1 · -1 sign
        // below → +0.25: total score 0.75 → label +1
        let r = h.handle("predict", &params(r#"{"row":[2,0,0]}"#)).unwrap();
        assert_eq!(r.get("label").and_then(Json::as_f64), Some(1.0));
        assert!((r.get("score").and_then(Json::as_f64).unwrap() - 0.75).abs() < 1e-6);
        assert_eq!(r.get("model_version").and_then(Json::as_u64), Some(7));
        // f0 = -2 ≤ 0 → -0.5; f2 = 5 > 1 → -0.25: score -0.75 → label -1
        let r = h.handle("predict", &params(r#"{"row":[-2,0,5]}"#)).unwrap();
        assert_eq!(r.get("label").and_then(Json::as_f64), Some(-1.0));
    }

    #[test]
    fn predict_validates_row() {
        let h = handler_with_model();
        for bad in [
            r#"{}"#,
            r#"{"row":"x"}"#,
            r#"{"row":[1,"a",3]}"#,
            r#"{"row":[1]}"#, // model needs features 0..=2
        ] {
            let err = h.handle("predict", &params(bad)).unwrap_err();
            assert_eq!(err.code, -32602, "{bad}");
        }
    }

    #[test]
    fn empty_model_predicts_default_label() {
        let h = ServeHandler::new(Arc::new(ModelSlot::new()));
        let r = h.handle("predict", &params(r#"{"row":[]}"#)).unwrap();
        assert_eq!(r.get("score").and_then(Json::as_f64), Some(0.0));
        assert_eq!(r.get("label").and_then(Json::as_f64), Some(1.0));
        assert_eq!(r.get("model_version").and_then(Json::as_u64), Some(0));
    }

    #[test]
    fn stats_count_requests_and_swaps() {
        let h = handler_with_model();
        h.handle("ping", &Json::Null).unwrap();
        h.handle("predict", &params(r#"{"row":[1,0,0]}"#)).unwrap();
        let _ = h.handle("predict", &params(r#"{}"#)); // invalid → counted request, not prediction
        let r = h.handle("serve.stats", &Json::Null).unwrap();
        assert_eq!(r.get("requests").and_then(Json::as_u64), Some(4));
        assert_eq!(r.get("predictions").and_then(Json::as_u64), Some(1));
        assert_eq!(r.get("swaps").and_then(Json::as_u64), Some(1));
        assert_eq!(r.get("model_version").and_then(Json::as_u64), Some(7));
    }
}
