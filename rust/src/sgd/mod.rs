//! Certified asynchronous SGD — the second TMSN workload.
//!
//! The paper presents TMSN as a *general* framework for asynchronous
//! parallel learning (§1, §2); boosting is only the demonstration. This
//! module proves the generality claim on our own stack: a linear model
//! trained by logistic-loss SGD rides the identical protocol and fabric —
//! [`crate::tmsn::Tmsn`], [`crate::tmsn::Driver`], [`crate::network`] —
//! with **zero boosting types anywhere**.
//!
//! The workload maps onto the protocol like this:
//!
//! * **payload** = the weight vector;
//! * **certificate** = the model's loss on a *shared held-out set* that
//!   every worker derives deterministically from the run seed. Any worker
//!   can re-evaluate an incoming payload, so the bound is sound and the
//!   "tell me something new" rule applies verbatim: broadcast only when
//!   your held-out loss strictly undercuts the best certified loss you
//!   know of (by the gap ε), adopt only strictly-better certificates.
//! * **local search** = a chunk of SGD steps on the worker's private data
//!   shard, polling the inbox mid-chunk (the interrupt-the-scan path).
//!
//! Resilience is therefore a property of the protocol, not of boosting:
//! the cluster runner injects laggards and crashes exactly like the
//! boosting coordinator does, and survivors keep making certified
//! progress (see `examples/async_sgd.rs` and the tests below).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::synth::SynthGen;
use crate::data::{DataBlock, SynthConfig};
use crate::metrics::{events, Event, EventKind, EventLog};
use crate::network::{Endpoint, Fabric, NetConfig};
use crate::tmsn::{Certified, Driver, Payload, Tmsn};

/// Certificate: logistic loss on the shared held-out set. Strictly lower
/// is strictly better; the initial (no-certificate) state is `+inf` so the
/// first finite evaluation always certifies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SgdCert {
    /// mean logistic loss of the payload's weights on the held-out set
    pub loss: f64,
    pub origin: usize,
    pub seq: u64,
}

impl Certified for SgdCert {
    fn initial() -> SgdCert {
        SgdCert {
            loss: f64::INFINITY,
            origin: usize::MAX,
            seq: 0,
        }
    }

    fn better_than(&self, other: &SgdCert) -> bool {
        self.loss < other.loss
    }

    fn origin(&self) -> usize {
        self.origin
    }

    fn seq(&self) -> u64 {
        self.seq
    }

    fn stamp(&mut self, origin: usize, seq: u64) {
        self.origin = origin;
        self.seq = seq;
    }

    fn summary(&self) -> f64 {
        self.loss
    }
}

/// A broadcast SGD message: the linear model's weights plus certificate.
#[derive(Debug, Clone, PartialEq)]
pub struct SgdPayload {
    pub w: Vec<f32>,
    pub cert: SgdCert,
}

impl SgdPayload {
    /// Payload for freshly evaluated weights (lineage stamped on commit).
    pub fn certified(w: Vec<f32>, loss: f64) -> SgdPayload {
        assert!(loss.is_finite() && loss >= 0.0);
        SgdPayload {
            w,
            cert: SgdCert {
                loss,
                origin: usize::MAX,
                seq: 0,
            },
        }
    }
}

impl Payload for SgdPayload {
    type Cert = SgdCert;

    fn initial() -> SgdPayload {
        SgdPayload {
            w: Vec::new(), // empty = the zero model in any dimension
            cert: SgdCert::initial(),
        }
    }

    fn cert(&self) -> &SgdCert {
        &self.cert
    }

    fn cert_mut(&mut self) -> &mut SgdCert {
        &mut self.cert
    }

    fn encode(&self) -> Vec<u8> {
        let mut out = format!(
            "sgdcert {} {} {}\nlinear v1 {}\n",
            self.cert.loss,
            self.cert.origin,
            self.cert.seq,
            self.w.len()
        );
        for v in &self.w {
            out.push_str(&format!("{v}\n"));
        }
        out.into_bytes()
    }

    fn decode(payload: &[u8]) -> Result<SgdPayload, String> {
        let text = std::str::from_utf8(payload).map_err(|_| "non-utf8 payload")?;
        let mut lines = text.lines();
        let cert_line = lines.next().ok_or("empty payload")?;
        let mut it = cert_line.split_whitespace();
        if it.next() != Some("sgdcert") {
            return Err("bad cert line".into());
        }
        let loss: f64 = it.next().ok_or("missing loss")?.parse().map_err(|_| "bad loss")?;
        let origin: usize = it.next().ok_or("missing origin")?.parse().map_err(|_| "bad origin")?;
        let seq: u64 = it.next().ok_or("missing seq")?.parse().map_err(|_| "bad seq")?;
        if loss.is_nan() || loss < 0.0 {
            return Err("loss must be non-negative".into());
        }
        let header = lines.next().ok_or("missing model header")?;
        let mut hp = header.split_whitespace();
        if hp.next() != Some("linear") || hp.next() != Some("v1") {
            return Err("bad model header".into());
        }
        let n: usize = hp.next().ok_or("missing count")?.parse().map_err(|_| "bad count")?;
        // never trust a wire-supplied count for allocation: each weight
        // line needs at least 2 payload bytes, so cap the hint there (the
        // read loop below still errors on truncation)
        let mut w = Vec::with_capacity(n.min(payload.len() / 2));
        for _ in 0..n {
            let v: f32 = lines
                .next()
                .ok_or("truncated weights")?
                .trim()
                .parse()
                .map_err(|_| "bad weight")?;
            if !v.is_finite() {
                return Err("weights must be finite".into());
            }
            w.push(v);
        }
        Ok(SgdPayload {
            w,
            cert: SgdCert { loss, origin, seq },
        })
    }
}

/// `w·x` over however many weights the payload carries (the initial empty
/// payload scores 0 everywhere).
fn dot(w: &[f32], x: &[f32]) -> f32 {
    w.iter().zip(x).map(|(a, b)| a * b).sum()
}

/// Numerically stable `ln(1 + e^z)`.
fn log1p_exp(z: f64) -> f64 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// One slice of plain logistic-loss SGD on `shard`, walking `cursor`
/// through the shard cyclically: `w += lr · y · σ(−y·w·x) · x` per step.
///
/// This is the local-search unit shared by the threaded cluster worker
/// ([`train_sgd_cluster`]) and the virtual-time simulator
/// ([`crate::sim::SgdSimWorker`]) — both drive the identical arithmetic,
/// so sim-validated convergence transfers to the threaded runner.
pub fn sgd_steps(w: &mut [f32], shard: &DataBlock, lr: f32, cursor: &mut usize, steps: usize) {
    assert!(!shard.is_empty(), "empty training shard");
    for _ in 0..steps {
        let i = *cursor % shard.n;
        *cursor = cursor.wrapping_add(1);
        let x = shard.row(i);
        let y = shard.label(i);
        let g = 1.0 / (1.0 + ((y * dot(w, x)) as f64).exp());
        let scale = lr * y * g as f32;
        for (wj, xj) in w.iter_mut().zip(x) {
            *wj += scale * xj;
        }
    }
}

/// Mean logistic loss of `w` on `data` (labels in {-1, +1}).
pub fn logistic_loss(w: &[f32], data: &DataBlock) -> f64 {
    assert!(!data.is_empty(), "empty evaluation set");
    let mut total = 0.0f64;
    for i in 0..data.n {
        let margin = data.label(i) as f64 * dot(w, data.row(i)) as f64;
        total += log1p_exp(-margin);
    }
    total / data.n as f64
}

/// Configuration for the async-SGD cluster.
#[derive(Debug, Clone)]
pub struct SgdConfig {
    pub workers: usize,
    /// training examples in each worker's private shard
    pub shard_n: usize,
    /// shared held-out set size (the certificate's evaluation set)
    pub valid_n: usize,
    pub lr: f32,
    /// SGD steps per local search chunk (between certificate evaluations)
    pub steps_per_chunk: usize,
    /// inbox poll cadence inside a chunk (the interrupt-the-scan path)
    pub poll_every: usize,
    /// max chunks per worker
    pub chunks: usize,
    /// ε gap: broadcast only if held-out loss undercuts the certified
    /// bound by at least this ("tell me something *new*")
    pub min_gain: f64,
    pub time_limit: Duration,
    /// per-worker compute slowdown multipliers (failure injection)
    pub laggards: Vec<(usize, f64)>,
    /// per-worker crash times (failure injection)
    pub crashes: Vec<(usize, Duration)>,
    pub synth: SynthConfig,
    pub net: NetConfig,
    pub seed: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig {
            workers: 4,
            shard_n: 4_000,
            valid_n: 1_000,
            lr: 0.05,
            steps_per_chunk: 200,
            poll_every: 16,
            chunks: 200,
            min_gain: 1e-3,
            time_limit: Duration::from_secs(30),
            laggards: Vec::new(),
            crashes: Vec::new(),
            synth: SynthConfig {
                f: 16,
                pos_rate: 0.3,
                informative: 8,
                signal: 0.8,
                flip_rate: 0.02,
                seed: 0x5D6D,
            },
            net: NetConfig::default(),
            seed: 7,
        }
    }
}

/// Final per-worker state.
#[derive(Debug)]
pub struct SgdWorkerResult {
    pub id: usize,
    /// certified held-out loss the worker ended with
    pub loss: f64,
    /// the certified payload held at shutdown (folded into the outcome's
    /// `best` in case its broadcast was lost on the observer link)
    pub payload: SgdPayload,
    pub steps: u64,
    pub published: u64,
    pub accepts: u64,
    pub rejects: u64,
    pub crashed: bool,
}

/// Everything an async-SGD cluster run produces.
#[derive(Debug)]
pub struct SgdOutcome {
    /// best certified payload observed on the wire (or held at shutdown)
    pub best: SgdPayload,
    /// the observer's certified-bound trajectory: strictly decreasing by
    /// construction (only strictly-better certificates are recorded)
    pub bound_series: Vec<(Duration, f64)>,
    pub workers: Vec<SgdWorkerResult>,
    pub events: Vec<Event>,
    /// (sent, delivered, dropped) fabric counters
    pub net: (u64, u64, u64),
    pub elapsed: Duration,
}

struct SgdWorkerParams {
    id: usize,
    cfg: SgdConfig,
    shard: DataBlock,
    valid: Arc<DataBlock>,
    endpoint: Endpoint<SgdPayload>,
    log: EventLog,
    stop: Arc<AtomicBool>,
    laggard: f64,
    crash_after: Option<Duration>,
}

/// One asynchronous SGD worker: local chunks of descent on its private
/// shard, certificate evaluations on the shared held-out set, and the
/// generic [`Driver`] for every protocol interaction.
fn run_sgd_worker(params: SgdWorkerParams) -> SgdWorkerResult {
    let SgdWorkerParams {
        id,
        cfg,
        shard,
        valid,
        endpoint,
        log,
        stop,
        laggard,
        crash_after,
    } = params;
    let start = Instant::now();
    let f = cfg.synth.f;
    let mut driver = Driver::new(Tmsn::<SgdPayload>::new(id), endpoint, log.clone());

    // local scratch weights: certified state + uncertified local progress
    let mut w = vec![0.0f32; f];
    let mut steps = 0u64;
    let mut published = 0u64;
    let mut crashed = false;
    let mut cursor = id * 31; // decorrelate shard walk across workers

    let resync = |w: &mut Vec<f32>, adopted: &SgdPayload| {
        w.clear();
        w.extend_from_slice(&adopted.w);
        w.resize(f, 0.0);
    };

    'outer: for _chunk in 0..cfg.chunks {
        // ---- liveness checks -------------------------------------------
        if stop.load(Ordering::Relaxed) || start.elapsed() >= cfg.time_limit {
            break;
        }
        if let Some(t) = crash_after {
            if start.elapsed() >= t {
                log.record(id, EventKind::Crash, None, 0.0);
                crashed = true;
                break;
            }
        }

        // ---- inbox (receive path of Alg. 1) ----------------------------
        driver.poll_adopt(&mut |_prev, cur| resync(&mut w, cur));

        // ---- one local search chunk ------------------------------------
        let chunk_start = Instant::now();
        let mut interrupted = false;
        let mut done = 0;
        while done < cfg.steps_per_chunk {
            let slice = cfg.poll_every.min(cfg.steps_per_chunk - done);
            sgd_steps(&mut w, &shard, cfg.lr, &mut cursor, slice);
            steps += slice as u64;
            done += slice;
            // interrupt-the-scan: a strictly-better certificate abandons
            // the chunk (local uncertified progress is discarded, exactly
            // like the boosting scanner abandons a pass); only full
            // poll_every slices poll — a ragged final slice runs through
            // to the certify step, as it always has
            if done % cfg.poll_every == 0 && driver.poll_interrupt() {
                driver.adopt_pending(&mut |_prev, cur| resync(&mut w, cur));
                interrupted = true;
                break;
            }
            if stop.load(Ordering::Relaxed) {
                break 'outer;
            }
        }
        // laggard injection: a slow machine takes proportionally longer
        // per chunk of the same work
        if laggard > 1.0 {
            std::thread::sleep(chunk_start.elapsed().mul_f64(laggard - 1.0));
        }
        if interrupted {
            continue;
        }

        // ---- certify & broadcast (send path of Alg. 1) ------------------
        let loss = logistic_loss(&w, &valid);
        if loss.is_finite() && loss < driver.cert().loss - cfg.min_gain {
            driver.publish(SgdPayload::certified(w.clone(), loss));
            published += 1;
        }
    }

    log.record(id, EventKind::Finish, None, driver.cert().loss);
    let state = driver.into_state();
    SgdWorkerResult {
        id,
        loss: state.cert().loss,
        payload: state.payload().clone(),
        steps,
        published,
        accepts: state.accepts,
        rejects: state.rejects,
        crashed,
    }
}

/// Run an async-SGD cluster on the simulated fabric: `workers` threads,
/// one passive observer endpoint, laggard/crash injection — the same
/// harness shape as the boosting coordinator, over the same protocol.
pub fn train_sgd_cluster(cfg: &SgdConfig) -> SgdOutcome {
    assert!(cfg.workers >= 1);
    assert!(cfg.shard_n >= 1 && cfg.valid_n >= 1);
    assert!(cfg.steps_per_chunk >= 1 && cfg.poll_every >= 1);
    let t0 = Instant::now();

    // Private shards + the shared held-out set, all from one deterministic
    // stream: shards are disjoint, and every worker could re-derive the
    // held-out set from the seed (what makes the certificate verifiable).
    let mut gen = SynthGen::new(cfg.synth.clone());
    let shards: Vec<DataBlock> = (0..cfg.workers).map(|_| gen.next_block(cfg.shard_n)).collect();
    let valid = Arc::new(gen.next_block(cfg.valid_n));

    let net = NetConfig {
        seed: cfg.seed ^ 0x56D,
        ..cfg.net.clone()
    };
    let (fabric, mut endpoints) = Fabric::<SgdPayload>::new(cfg.workers + 1, net);
    let observer = endpoints.pop().expect("observer endpoint");
    let (log, event_rx) = EventLog::new();
    let stop = Arc::new(AtomicBool::new(false));

    let mut handles = Vec::new();
    for (id, (endpoint, shard)) in endpoints.into_iter().zip(shards).enumerate() {
        let params = SgdWorkerParams {
            id,
            cfg: cfg.clone(),
            shard,
            valid: Arc::clone(&valid),
            endpoint,
            log: log.clone(),
            stop: Arc::clone(&stop),
            laggard: cfg
                .laggards
                .iter()
                .find(|(w, _)| *w == id)
                .map(|(_, k)| *k)
                .unwrap_or(1.0),
            crash_after: cfg.crashes.iter().find(|(w, _)| *w == id).map(|(_, t)| *t),
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("sgd-worker-{id}"))
                .spawn(move || run_sgd_worker(params))
                .expect("spawn sgd worker"),
        );
    }

    // Passive observation: track the best certificate on the wire.
    let mut best = SgdPayload::initial();
    let mut bound_series: Vec<(Duration, f64)> = Vec::new();
    loop {
        while let Some(msg) = observer.try_recv() {
            if msg.cert.better_than(&best.cert) {
                bound_series.push((t0.elapsed(), msg.cert.loss));
                best = msg;
            }
        }
        if t0.elapsed() >= cfg.time_limit {
            stop.store(true, Ordering::Relaxed);
        }
        if handles.iter().all(|h| h.is_finished()) {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }

    let workers: Vec<SgdWorkerResult> = handles
        .into_iter()
        .map(|h| h.join().expect("sgd worker panicked"))
        .collect();

    // Fold in anything the observer's last poll missed, plus the workers'
    // final certified payloads — a lossy net may have dropped the best
    // broadcast on the observer's own link.
    while let Some(msg) = observer.try_recv() {
        if msg.cert.better_than(&best.cert) {
            bound_series.push((t0.elapsed(), msg.cert.loss));
            best = msg;
        }
    }
    for w in &workers {
        if w.payload.cert.better_than(&best.cert) {
            bound_series.push((t0.elapsed(), w.payload.cert.loss));
            best = w.payload.clone();
        }
    }

    let net_stats = fabric.stats.snapshot();
    fabric.shutdown();
    SgdOutcome {
        best,
        bound_series,
        workers,
        events: events::drain(&event_rx),
        net: net_stats,
        elapsed: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn payload_roundtrip() {
        let p = SgdPayload {
            w: vec![0.5, -1.25, 0.0, 3.5e-3],
            cert: SgdCert {
                loss: 0.42,
                origin: 3,
                seq: 17,
            },
        };
        assert_eq!(SgdPayload::decode(&p.encode()).unwrap(), p);
        // the initial payload (infinite loss, no weights) round-trips too
        let init = SgdPayload::initial();
        assert_eq!(SgdPayload::decode(&init.encode()).unwrap(), init);
    }

    #[test]
    fn prop_payload_roundtrip() {
        prop_check("sgd payload roundtrip", 50, |rng| {
            let n = rng.below(64) as usize;
            let p = SgdPayload {
                w: (0..n).map(|_| rng.gauss() as f32).collect(),
                cert: SgdCert {
                    loss: rng.f64() * 2.0,
                    origin: rng.below(64) as usize,
                    seq: rng.below(1 << 40),
                },
            };
            let back = SgdPayload::decode(&p.encode()).map_err(|e| e.to_string())?;
            if back != p {
                return Err(format!("{back:?} != {p:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(SgdPayload::decode(b"nonsense").is_err());
        assert!(SgdPayload::decode(b"sgdcert abc 0 0\nlinear v1 0\n").is_err());
        assert!(SgdPayload::decode(b"sgdcert -1 0 0\nlinear v1 0\n").is_err());
        assert!(SgdPayload::decode(b"sgdcert NaN 0 0\nlinear v1 0\n").is_err());
        assert!(SgdPayload::decode(b"sgdcert 0.5 0 0\nlinear v1 2\n1.0\n").is_err());
        assert!(SgdPayload::decode(b"sgdcert 0.5 0 0\nlinear v1 1\ninf\n").is_err());
        assert!(SgdPayload::decode(&[0xFF, 0xFE, 0x00]).is_err());
    }

    #[test]
    fn sgd_steps_is_deterministic_and_improves() {
        let mut gen = SynthGen::new(SynthConfig {
            f: 8,
            pos_rate: 0.4,
            informative: 4,
            signal: 1.0,
            flip_rate: 0.0,
            seed: 11,
        });
        let shard = gen.next_block(500);
        let mut w1 = vec![0.0f32; 8];
        let mut w2 = vec![0.0f32; 8];
        let (mut c1, mut c2) = (0usize, 0usize);
        sgd_steps(&mut w1, &shard, 0.1, &mut c1, 400);
        sgd_steps(&mut w2, &shard, 0.1, &mut c2, 400);
        assert_eq!(w1, w2, "same shard + cursor must be bitwise identical");
        assert_eq!((c1, c2), (400, 400));
        // slicing the chunk (the worker's poll cadence) changes nothing
        let mut w3 = vec![0.0f32; 8];
        let mut c3 = 0usize;
        for _ in 0..25 {
            sgd_steps(&mut w3, &shard, 0.1, &mut c3, 16);
        }
        assert_eq!(w1, w3, "poll-slicing must not change the arithmetic");
        assert!(logistic_loss(&w1, &shard) < logistic_loss(&vec![0.0f32; 8], &shard));
    }

    #[test]
    fn logistic_loss_zero_model_is_ln2() {
        let mut d = DataBlock::empty(2);
        d.push(&[1.0, 0.0], 1.0);
        d.push(&[0.0, 1.0], -1.0);
        let loss = logistic_loss(&[0.0, 0.0], &d);
        assert!((loss - std::f64::consts::LN_2).abs() < 1e-12);
        // a model aligned with the labels beats the zero model
        let good = logistic_loss(&[2.0, -2.0], &d);
        assert!(good < loss);
    }

    #[test]
    fn sgd_cluster_converges_with_laggard_and_crash() {
        // The acceptance scenario at test scale: ≥4 workers, one laggard,
        // one crash, generic Driver end to end — the certified bound must
        // strictly decrease and end below the zero-model loss.
        let cfg = SgdConfig {
            workers: 4,
            shard_n: 1_500,
            valid_n: 600,
            steps_per_chunk: 100,
            // enough chunks that the cluster is still running when the
            // crash deadline arrives (the deadline is checked per chunk)
            chunks: 5_000,
            time_limit: Duration::from_secs(20),
            laggards: vec![(1, 4.0)],
            crashes: vec![(2, Duration::from_millis(3))],
            net: NetConfig {
                seed: 1,
                ..NetConfig::default()
            },
            ..SgdConfig::default()
        };
        let out = train_sgd_cluster(&cfg);

        assert!(out.workers[2].crashed, "crash injection must fire");
        assert!(
            out.events.iter().any(|e| e.kind == EventKind::Crash),
            "crash event recorded"
        );
        assert!(!out.bound_series.is_empty(), "no certified improvement");
        assert!(
            out.bound_series.windows(2).all(|p| p[1].1 < p[0].1),
            "certified bound must strictly decrease: {:?}",
            out.bound_series
        );
        let final_loss = out.best.cert.loss;
        assert!(
            final_loss < std::f64::consts::LN_2,
            "certified loss {final_loss} not below the zero model"
        );
        // the protocol did its job: someone adopted someone else's model
        let (sent, delivered, _) = out.net;
        assert!(sent > 0 && delivered > 0);
        let survivors_accepts: u64 = out.workers.iter().map(|w| w.accepts).sum();
        assert!(survivors_accepts > 0, "no adoption happened");
    }

    #[test]
    fn sgd_single_worker_needs_no_peers() {
        let cfg = SgdConfig {
            workers: 1,
            shard_n: 1_000,
            valid_n: 400,
            steps_per_chunk: 100,
            chunks: 20,
            time_limit: Duration::from_secs(10),
            ..SgdConfig::default()
        };
        let out = train_sgd_cluster(&cfg);
        assert!(out.best.cert.loss < std::f64::consts::LN_2);
        assert_eq!(out.workers[0].accepts, 0, "no peers, nothing to adopt");
        assert!(out.workers[0].published > 0);
    }
}
