//! Artifact manifest parsing (`artifacts/manifest.txt`, written by
//! `python/compile/aot.py`). Whitespace-separated `key=value` lines.

use std::path::{Path, PathBuf};

/// One AOT-lowered HLO module and its fixed shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// "scan" (Pallas edge kernel), "scanjnp" (pure-jnp edges), "predict"
    pub kind: String,
    pub file: String,
    pub batch: usize,
    pub features: usize,
    pub tmax: usize,
    pub nthr: usize,
}

/// The parsed manifest plus its directory (for resolving file paths).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!(
                "cannot read {} ({e}) — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let mut specs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut spec = ArtifactSpec {
                kind: String::new(),
                file: String::new(),
                batch: 0,
                features: 0,
                tmax: 0,
                nthr: 0,
            };
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .ok_or_else(|| format!("manifest line {}: bad token {tok:?}", lineno + 1))?;
                let parse_usize = |v: &str| {
                    v.parse::<usize>()
                        .map_err(|_| format!("line {}: bad {k}={v}", lineno + 1))
                };
                match k {
                    "kind" => spec.kind = v.to_string(),
                    "file" => spec.file = v.to_string(),
                    "batch" => spec.batch = parse_usize(v)?,
                    "features" => spec.features = parse_usize(v)?,
                    "tmax" => spec.tmax = parse_usize(v)?,
                    "nthr" => spec.nthr = parse_usize(v)?,
                    _ => {} // forward-compatible: ignore unknown keys
                }
            }
            if spec.kind.is_empty() || spec.file.is_empty() {
                return Err(format!("manifest line {}: missing kind/file", lineno + 1));
            }
            specs.push(spec);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            specs,
        })
    }

    /// Find a scan artifact matching the workload shape.
    pub fn find_scan(
        &self,
        pallas: bool,
        features: usize,
        nthr: usize,
    ) -> Result<&ArtifactSpec, String> {
        let kind = if pallas { "scan" } else { "scanjnp" };
        self.specs
            .iter()
            .find(|s| s.kind == kind && s.features == features && s.nthr == nthr)
            .ok_or_else(|| {
                let have: Vec<String> = self
                    .specs
                    .iter()
                    .filter(|s| s.kind == kind)
                    .map(|s| format!("(F={}, NT={})", s.features, s.nthr))
                    .collect();
                format!(
                    "no {kind} artifact for F={features}, NT={nthr}; available: {} — \
                     add the config to python/compile/aot.py (--configs) and re-run `make artifacts`",
                    have.join(", ")
                )
            })
    }

    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
kind=scan file=scan_b128_f32_t16_n4.hlo.txt batch=128 features=32 tmax=16 nthr=4
kind=scanjnp file=scanjnp_b128_f32_t16_n4.hlo.txt batch=128 features=32 tmax=16 nthr=4
kind=predict file=predict_b128_f32_t16.hlo.txt batch=128 features=32 tmax=16 nthr=0
";

    #[test]
    fn parses_specs() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.specs.len(), 3);
        assert_eq!(m.specs[0].kind, "scan");
        assert_eq!(m.specs[0].batch, 128);
        assert_eq!(m.specs[2].nthr, 0);
    }

    #[test]
    fn find_scan_matches_shape() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let s = m.find_scan(true, 32, 4).unwrap();
        assert_eq!(s.kind, "scan");
        let s = m.find_scan(false, 32, 4).unwrap();
        assert_eq!(s.kind, "scanjnp");
        assert!(m.find_scan(true, 64, 4).is_err());
        let err = m.find_scan(true, 64, 4).unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/t"), "kind scan").is_err());
        assert!(Manifest::parse(Path::new("/t"), "file=x.hlo").is_err());
        assert!(Manifest::parse(Path::new("/t"), "kind=scan file=x batch=abc").is_err());
    }

    #[test]
    fn path_resolution() {
        let m = Manifest::parse(Path::new("/tmp/art"), SAMPLE).unwrap();
        assert_eq!(
            m.path_of(&m.specs[0]),
            PathBuf::from("/tmp/art/scan_b128_f32_t16_n4.hlo.txt")
        );
    }
}
