//! PJRT runtime — load AOT-compiled HLO artifacts and run them from the
//! scanner hot path.
//!
//! Bridge contract (see `python/compile/aot.py` and DESIGN.md §6):
//! * interchange is HLO **text** (`HloModuleProto::from_text_file`); the
//!   text parser reassigns instruction ids, avoiding the 64-bit-id protos
//!   that xla_extension 0.5.1 rejects;
//! * the scan module takes 9 parameters
//!   `x(B,F) y(B) w_s(B) score_s(B) onehot(F,T) thr(T) sign(T) alpha(T)
//!   grid_thr(F,NT)` and returns the tuple
//!   `(scores(B), w(B), edges(F,NT), sumw, sumw2)`;
//! * Python never runs at train time — the artifacts are compiled once by
//!   `make artifacts`.

pub mod artifacts;

pub use artifacts::{ArtifactSpec, Manifest};

use crate::boosting::{CandidateGrid, EdgeMatrix};
use crate::config::{simd_compiled, Backend, ScanEngine, ScanSimd, TrainConfig};
use crate::data::{BinnedBatch, DataBlock};
use crate::model::StrongRule;
use crate::scanner::{BatchResult, BinnedBackend, NativeBackend, ScanBackend};

/// A compiled scan executable bound to a PJRT CPU client.
pub struct XlaScanBackend {
    exe: xla::PjRtLoadedExecutable,
    name: &'static str,
    batch: usize,
    features: usize,
    tmax: usize,
    nthr: usize,
    /// grid literal cache — the candidate grid is fixed per scanner
    grid_cache: Option<(Vec<f32>, xla::Literal)>,
    /// padded-model literal cache (§Perf): the model changes only between
    /// boosting iterations, so the four model literals — including the
    /// F×T one-hot selector, the largest input — are reused across the
    /// many batches of a scan pass. Keyed by an *exact copy* of the model
    /// (stumps + alphas compare), never a hash, so a cache hit can never
    /// produce wrong numerics.
    model_cache: Option<ModelCache>,
    /// scratch input buffers reused across batches
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
    ws_buf: Vec<f32>,
    ss_buf: Vec<f32>,
}

struct ModelCache {
    key: StrongRule,
    onehot: xla::Literal,
    thr: xla::Literal,
    sign: xla::Literal,
    alpha: xla::Literal,
}

// SAFETY: the backend is owned and used by exactly one worker thread at a
// time (Box<dyn ScanBackend> moved into the thread); XLA's TfrtCpuClient
// itself is thread-safe. The xla crate just doesn't declare Send on its
// pointer wrappers.
unsafe impl Send for XlaScanBackend {}

impl XlaScanBackend {
    /// Compile the artifact described by `spec` on a fresh CPU client.
    pub fn load(
        manifest: &Manifest,
        spec: &ArtifactSpec,
        pallas: bool,
    ) -> anyhow::Result<XlaScanBackend> {
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(manifest.path_of(spec))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(XlaScanBackend {
            exe,
            name: if pallas { "xla-pallas" } else { "xla-jnp" },
            batch: spec.batch,
            features: spec.features,
            tmax: spec.tmax,
            nthr: spec.nthr,
            grid_cache: None,
            model_cache: None,
            x_buf: vec![0.0; spec.batch * spec.features],
            y_buf: vec![0.0; spec.batch],
            ws_buf: vec![0.0; spec.batch],
            ss_buf: vec![0.0; spec.batch],
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    fn literal_2d(data: &[f32], d0: usize, d1: usize) -> anyhow::Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(&[d0 as i64, d1 as i64])?)
    }
}

impl ScanBackend for XlaScanBackend {
    fn scan_batch_into(
        &mut self,
        block: &DataBlock,
        _bins: Option<&BinnedBatch>, // PJRT path has its own layout
        w_ref: &[f32],
        score_ref: &[f32],
        _model_len_ref: &[u32], // XLA path always full-scores (fixed shape)
        model: &StrongRule,
        grid: &CandidateGrid,
        _stripe: (usize, usize), // full grid computed; scanner filters
        out: &mut BatchResult,
    ) {
        let n = block.n;
        assert!(n <= self.batch, "batch {} exceeds artifact B={}", n, self.batch);
        assert_eq!(block.f, self.features, "feature width mismatch");
        assert_eq!(grid.nthr, self.nthr, "nthr mismatch");
        assert!(
            model.len() <= self.tmax,
            "model length {} exceeds artifact tmax {}",
            model.len(),
            self.tmax
        );

        // ---- pack + pad inputs (padded rows get w_ref = 0 → contribute
        //      nothing to edges or the stopping scalars) ------------------
        self.x_buf[..n * self.features].copy_from_slice(&block.features);
        self.x_buf[n * self.features..].fill(0.0);
        self.y_buf[..n].copy_from_slice(&block.labels);
        self.y_buf[n..].fill(1.0);
        self.ws_buf[..n].copy_from_slice(w_ref);
        self.ws_buf[n..].fill(0.0);
        self.ss_buf[..n].copy_from_slice(score_ref);
        self.ss_buf[n..].fill(0.0);

        let mut run = || -> anyhow::Result<(Vec<f32>, Vec<f32>, EdgeMatrix)> {
            let x = Self::literal_2d(&self.x_buf, self.batch, self.features)?;
            let y = xla::Literal::vec1(&self.y_buf);
            let w_s = xla::Literal::vec1(&self.ws_buf);
            let score_s = xla::Literal::vec1(&self.ss_buf);
            // §Perf: rebuild the model literals only when the model
            // actually changed (exact structural compare — see ModelCache)
            if self
                .model_cache
                .as_ref()
                .map_or(true, |c| &c.key != model)
            {
                let pm = model.to_padded_arrays(self.features, self.tmax);
                self.model_cache = Some(ModelCache {
                    key: model.clone(),
                    onehot: Self::literal_2d(&pm.onehot, self.features, self.tmax)?,
                    thr: xla::Literal::vec1(&pm.thr),
                    sign: xla::Literal::vec1(&pm.sign),
                    alpha: xla::Literal::vec1(&pm.alpha),
                });
            }
            if self
                .grid_cache
                .as_ref()
                .map_or(true, |(g, _)| g != &grid.thresholds)
            {
                self.grid_cache = Some((
                    grid.thresholds.clone(),
                    Self::literal_2d(&grid.thresholds, self.features, self.nthr)?,
                ));
            }
            let mc = self.model_cache.as_ref().unwrap();
            let grid_lit = &self.grid_cache.as_ref().unwrap().1;

            let args: [&xla::Literal; 9] = [
                &x, &y, &w_s, &score_s, &mc.onehot, &mc.thr, &mc.sign, &mc.alpha, grid_lit,
            ];
            let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            anyhow::ensure!(parts.len() == 5, "expected 5 outputs, got {}", parts.len());
            let scores: Vec<f32> = parts[0].to_vec()?;
            let weights: Vec<f32> = parts[1].to_vec()?;
            let edges_f32: Vec<f32> = parts[2].to_vec()?;
            let sumw: f32 = parts[3].get_first_element()?;
            let sumw2: f32 = parts[4].get_first_element()?;

            let mut edges = EdgeMatrix::zeros(self.features, self.nthr);
            for (e, &v) in edges.edges.iter_mut().zip(&edges_f32) {
                *e = v as f64;
            }
            edges.sum_w = sumw as f64;
            edges.sum_w2 = sumw2 as f64;
            edges.count = n as u64;
            Ok((scores, weights, edges))
        };
        let (scores, weights, edges) = run().expect("PJRT execution failed");
        out.scores.clear();
        out.scores.extend_from_slice(&scores[..n]);
        out.weights.clear();
        out.weights.extend_from_slice(&weights[..n]);
        out.edges.merge(&edges);
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

/// Config-driven backend factory used by the coordinator / CLI / benches.
pub fn make_backend(cfg: &TrainConfig, features: usize) -> anyhow::Result<Box<dyn ScanBackend>> {
    match cfg.backend {
        Backend::Native => match cfg.scan_engine {
            ScanEngine::Rows => Ok(Box::new(NativeBackend)),
            ScanEngine::Binned => {
                // resolve --scan-simd against this build (DESIGN.md §14):
                // auto = lane kernels iff compiled in; on = required
                // (validate() already rejects it when compiled out — the
                // ensure below is the factory-level backstop for callers
                // that skip validation); off = scalar always
                let lanes = match cfg.scan_simd {
                    ScanSimd::Off => false,
                    ScanSimd::Auto => simd_compiled(),
                    ScanSimd::On => {
                        anyhow::ensure!(
                            simd_compiled(),
                            "--scan-simd on requires a build with --features simd"
                        );
                        true
                    }
                };
                Ok(Box::new(BinnedBackend::with_simd(cfg.scan_threads, lanes)))
            }
        },
        Backend::XlaPallas | Backend::XlaJnp => {
            anyhow::ensure!(
                cfg.scan_engine == ScanEngine::Rows,
                "--scan-engine binned requires --backend native"
            );
            let pallas = cfg.backend == Backend::XlaPallas;
            let manifest = Manifest::load(std::path::Path::new(&cfg.artifacts_dir))
                .map_err(anyhow::Error::msg)?;
            let spec = manifest
                .find_scan(pallas, features, cfg.nthr)
                .map_err(anyhow::Error::msg)?;
            anyhow::ensure!(
                cfg.batch == spec.batch,
                "config batch {} must equal artifact batch {} (set --batch {})",
                cfg.batch,
                spec.batch,
                spec.batch
            );
            anyhow::ensure!(
                cfg.max_rules <= spec.tmax,
                "max-rules {} exceeds artifact tmax {}",
                cfg.max_rules,
                spec.tmax
            );
            Ok(Box::new(XlaScanBackend::load(&manifest, spec, pallas)?))
        }
    }
}

#[cfg(test)]
mod tests {
    // Execution tests live in rust/tests/runtime_roundtrip.rs (they need
    // `make artifacts` to have run); manifest parsing is covered in
    // artifacts.rs.
    use super::*;

    #[test]
    fn make_backend_selects_scan_engine() {
        let rows = TrainConfig::default();
        assert_eq!(make_backend(&rows, 8).unwrap().name(), "native");
        let binned = TrainConfig {
            scan_engine: ScanEngine::Binned,
            scan_threads: 4,
            ..TrainConfig::default()
        };
        let be = make_backend(&binned, 8).unwrap();
        assert_eq!(be.name(), "binned");
        assert!(be.wants_bins());
    }

    #[test]
    fn make_backend_resolves_scan_simd() {
        // off → always buildable (scalar); auto → always buildable (best
        // available); on → buildable exactly when the lane kernels are in
        // this build
        for simd in [ScanSimd::Off, ScanSimd::Auto] {
            let cfg = TrainConfig {
                scan_engine: ScanEngine::Binned,
                scan_simd: simd,
                ..TrainConfig::default()
            };
            assert_eq!(make_backend(&cfg, 8).unwrap().name(), "binned");
        }
        let on = TrainConfig {
            scan_engine: ScanEngine::Binned,
            scan_simd: ScanSimd::On,
            ..TrainConfig::default()
        };
        let got = make_backend(&on, 8);
        if simd_compiled() {
            assert_eq!(got.unwrap().name(), "binned");
        } else {
            let err = got.unwrap_err().to_string();
            assert!(err.contains("--features simd"), "unexpected error: {err}");
        }
    }

    #[test]
    fn make_backend_rejects_binned_on_xla() {
        let cfg = TrainConfig {
            backend: Backend::XlaPallas,
            scan_engine: ScanEngine::Binned,
            ..TrainConfig::default()
        };
        let err = make_backend(&cfg, 8).unwrap_err().to_string();
        assert!(err.contains("native"), "unexpected error: {err}");
    }
}
