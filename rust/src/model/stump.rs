//! Decision stumps — the weak rules W of the paper's evaluation
//! (§5: "we restrict our trees to one level, so-called decision stumps").

/// A threshold stump `h(x) = sign * (2·[x[feature] > threshold] − 1)`.
///
/// `sign = +1` predicts +1 above the threshold; `sign = -1` inverts the
/// polarity, so the candidate set is closed under negation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stump {
    pub feature: u32,
    pub threshold: f32,
    pub sign: f32,
}

impl Stump {
    pub fn new(feature: u32, threshold: f32, sign: f32) -> Stump {
        assert!(sign == 1.0 || sign == -1.0, "sign must be ±1");
        Stump {
            feature,
            threshold,
            sign,
        }
    }

    /// Predict in {-1.0, +1.0}.
    #[inline]
    pub fn predict(&self, row: &[f32]) -> f32 {
        let above = row[self.feature as usize] > self.threshold;
        if above {
            self.sign
        } else {
            -self.sign
        }
    }

    /// The stump with opposite polarity (whose edge is the negation).
    pub fn negated(&self) -> Stump {
        Stump {
            sign: -self.sign,
            ..*self
        }
    }
}

impl std::fmt::Display for Stump {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "h(x[{}] > {:.4}){}",
            self.feature,
            self.threshold,
            if self.sign > 0.0 { "" } else { " (neg)" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predicts_by_threshold() {
        let h = Stump::new(1, 0.5, 1.0);
        assert_eq!(h.predict(&[9.0, 0.6]), 1.0);
        assert_eq!(h.predict(&[9.0, 0.5]), -1.0); // strict >
        assert_eq!(h.predict(&[9.0, 0.4]), -1.0);
    }

    #[test]
    fn negative_polarity() {
        let h = Stump::new(0, 0.0, -1.0);
        assert_eq!(h.predict(&[1.0]), -1.0);
        assert_eq!(h.predict(&[-1.0]), 1.0);
    }

    #[test]
    fn negated_flips_all_predictions() {
        let h = Stump::new(0, 0.25, 1.0);
        let n = h.negated();
        for x in [-1.0f32, 0.0, 0.25, 0.3, 2.0] {
            assert_eq!(h.predict(&[x]), -n.predict(&[x]));
        }
    }

    #[test]
    #[should_panic(expected = "sign must be ±1")]
    fn invalid_sign_rejected() {
        Stump::new(0, 0.0, 0.5);
    }
}
