//! Boosted-stump model: weak rules, strong rules, serialization.

pub mod stump;
pub mod strong;
pub mod tree;

pub use strong::StrongRule;
pub use stump::Stump;
pub use tree::{DecisionTree, TreeEnsemble};
