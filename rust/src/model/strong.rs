//! Strong rules: weighted stump ensembles, grown append-only.
//!
//! Append-only growth is what makes the paper's incremental update cheap:
//! "H_l" (the model last used to weight an example) is identified by its
//! *length*, and refreshing a weight only evaluates the new suffix.

use crate::model::Stump;

/// `H(x) = sign( Σ_t alpha_t · h_t(x) )`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StrongRule {
    stumps: Vec<Stump>,
    alphas: Vec<f32>,
}

impl StrongRule {
    pub fn new() -> StrongRule {
        StrongRule::default()
    }

    pub fn len(&self) -> usize {
        self.stumps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stumps.is_empty()
    }

    pub fn stumps(&self) -> &[Stump] {
        &self.stumps
    }

    pub fn alphas(&self) -> &[f32] {
        &self.alphas
    }

    /// Append a weak rule with vote weight `alpha`.
    pub fn push(&mut self, stump: Stump, alpha: f32) {
        assert!(alpha.is_finite() && alpha > 0.0, "alpha must be positive");
        self.stumps.push(stump);
        self.alphas.push(alpha);
    }

    /// Raw margin score `Σ alpha_t h_t(x)`.
    pub fn score(&self, row: &[f32]) -> f32 {
        self.score_suffix(row, 0)
    }

    /// Score contribution of stumps `from..len` only — the incremental
    /// update path (§4.1): caller caches the score under the first `from`
    /// stumps and adds this delta.
    pub fn score_suffix(&self, row: &[f32], from: usize) -> f32 {
        let mut s = 0.0f32;
        for (h, &a) in self.stumps[from..].iter().zip(&self.alphas[from..]) {
            s += a * h.predict(row);
        }
        s
    }

    /// Classify in {-1.0, +1.0} (ties → +1, irrelevant in practice).
    pub fn predict(&self, row: &[f32]) -> f32 {
        if self.score(row) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Whether `prefix` is a prefix of `self` (same stumps & alphas).
    /// Used by the TMSN accept path to decide if an incoming model extends
    /// the local one (cheap adoption) or replaces it (full re-weight).
    pub fn extends(&self, prefix: &StrongRule) -> bool {
        prefix.len() <= self.len()
            && prefix.stumps == self.stumps[..prefix.len()]
            && prefix.alphas == self.alphas[..prefix.len()]
    }

    // ---- serialization (compact text lines; no serde offline) ----

    /// `T` lines of `feature threshold sign alpha`, preceded by a count.
    pub fn to_text(&self) -> String {
        let mut out = format!("strongrule v1 {}\n", self.len());
        for (h, a) in self.stumps.iter().zip(&self.alphas) {
            out.push_str(&format!(
                "{} {} {} {}\n",
                h.feature, h.threshold, h.sign as i32, a
            ));
        }
        out
    }

    pub fn from_text(text: &str) -> Result<StrongRule, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty model text")?;
        let mut parts = header.split_whitespace();
        if parts.next() != Some("strongrule") || parts.next() != Some("v1") {
            return Err("bad model header".into());
        }
        let t: usize = parts
            .next()
            .ok_or("missing count")?
            .parse()
            .map_err(|_| "bad count")?;
        let mut model = StrongRule::new();
        for _ in 0..t {
            let line = lines.next().ok_or("truncated model text")?;
            let mut it = line.split_whitespace();
            let feature: u32 = it
                .next()
                .ok_or("missing feature")?
                .parse()
                .map_err(|_| "bad feature")?;
            let threshold: f32 = it
                .next()
                .ok_or("missing threshold")?
                .parse()
                .map_err(|_| "bad threshold")?;
            let sign: f32 = it.next().ok_or("missing sign")?.parse().map_err(|_| "bad sign")?;
            let alpha: f32 = it.next().ok_or("missing alpha")?.parse().map_err(|_| "bad alpha")?;
            if sign != 1.0 && sign != -1.0 {
                return Err(format!("sign must be ±1, got {sign}"));
            }
            if !(alpha.is_finite() && alpha > 0.0) {
                return Err(format!("alpha must be positive and finite, got {alpha}"));
            }
            if !threshold.is_finite() {
                return Err("threshold must be finite".into());
            }
            model.push(Stump::new(feature, threshold, sign), alpha);
        }
        Ok(model)
    }

    /// Padded arrays for the AOT scan-batch graph (L2 inputs):
    /// `(feat_onehot (F,T) row-major, thr (T,), sign (T,), alpha (T,))`.
    /// Slots `>= len` carry `alpha = 0` and contribute nothing.
    pub fn to_padded_arrays(&self, f: usize, tmax: usize) -> PaddedModel {
        assert!(
            self.len() <= tmax,
            "model length {} exceeds tmax {tmax}",
            self.len()
        );
        let mut onehot = vec![0f32; f * tmax];
        let mut thr = vec![0f32; tmax];
        let mut sign = vec![1f32; tmax];
        let mut alpha = vec![0f32; tmax];
        for (t, (h, &a)) in self.stumps.iter().zip(&self.alphas).enumerate() {
            assert!((h.feature as usize) < f, "feature out of range");
            onehot[h.feature as usize * tmax + t] = 1.0;
            thr[t] = h.threshold;
            sign[t] = h.sign;
            alpha[t] = a;
        }
        PaddedModel {
            onehot,
            thr,
            sign,
            alpha,
            f,
            tmax,
        }
    }
}

/// Fixed-shape model arrays for the PJRT scan executable.
#[derive(Debug, Clone)]
pub struct PaddedModel {
    /// (F, T) row-major one-hot feature selector
    pub onehot: Vec<f32>,
    pub thr: Vec<f32>,
    pub sign: Vec<f32>,
    pub alpha: Vec<f32>,
    pub f: usize,
    pub tmax: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model2() -> StrongRule {
        let mut m = StrongRule::new();
        m.push(Stump::new(0, 0.0, 1.0), 0.5);
        m.push(Stump::new(1, 1.0, -1.0), 0.25);
        m
    }

    #[test]
    fn empty_scores_zero() {
        let m = StrongRule::new();
        assert_eq!(m.score(&[1.0, 2.0]), 0.0);
        assert_eq!(m.predict(&[1.0, 2.0]), 1.0);
    }

    #[test]
    fn score_accumulates() {
        let m = model2();
        // x = [1, 0]: h0 = +1 (1>0), h1 = -1*(2*(0>1)-1) = +1
        assert!((m.score(&[1.0, 0.0]) - 0.75).abs() < 1e-6);
        // x = [-1, 2]: h0 = -1, h1 = -1
        assert!((m.score(&[-1.0, 2.0]) + 0.75).abs() < 1e-6);
    }

    #[test]
    fn suffix_equals_full_minus_prefix() {
        let m = model2();
        let row = [0.5f32, 0.5];
        let full = m.score(&row);
        let prefix = {
            let mut p = StrongRule::new();
            p.push(m.stumps()[0], m.alphas()[0]);
            p.score(&row)
        };
        assert!((m.score_suffix(&row, 1) - (full - prefix)).abs() < 1e-6);
        assert_eq!(m.score_suffix(&row, 2), 0.0);
    }

    #[test]
    fn extends_prefix() {
        let m = model2();
        let mut p = StrongRule::new();
        p.push(m.stumps()[0], m.alphas()[0]);
        assert!(m.extends(&p));
        assert!(m.extends(&m));
        assert!(!p.extends(&m));
        let mut other = StrongRule::new();
        other.push(Stump::new(5, 0.0, 1.0), 0.5);
        assert!(!m.extends(&other));
    }

    #[test]
    fn text_roundtrip() {
        let m = model2();
        let t = m.to_text();
        let back = StrongRule::from_text(&t).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn text_roundtrip_empty() {
        let m = StrongRule::new();
        assert_eq!(StrongRule::from_text(&m.to_text()).unwrap(), m);
    }

    #[test]
    fn from_text_rejects_garbage() {
        assert!(StrongRule::from_text("nope").is_err());
        assert!(StrongRule::from_text("strongrule v1 2\n1 2 1 0.5\n").is_err());
    }

    #[test]
    fn padded_arrays_match_scoring() {
        let m = model2();
        let pm = m.to_padded_arrays(3, 4);
        // emulate the L2 math: xsel = x @ onehot; pred = sign*(2*(xsel>thr)-1)
        let x = [0.5f32, 2.0, -1.0];
        let mut score = 0.0f32;
        for t in 0..pm.tmax {
            let mut xsel = 0.0f32;
            for f in 0..pm.f {
                xsel += x[f] * pm.onehot[f * pm.tmax + t];
            }
            let pred = pm.sign[t] * (2.0 * ((xsel > pm.thr[t]) as i32 as f32) - 1.0);
            score += pm.alpha[t] * pred;
        }
        assert!((score - m.score(&x)).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "exceeds tmax")]
    fn padded_arrays_checks_capacity() {
        model2().to_padded_arrays(3, 1);
    }

    #[test]
    #[should_panic(expected = "alpha must be positive")]
    fn push_rejects_bad_alpha() {
        StrongRule::new().push(Stump::new(0, 0.0, 1.0), 0.0);
    }
}
