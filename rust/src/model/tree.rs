//! Multi-level decision trees — the paper's stated future work (§5:
//! "We plan to extend the algorithm to boosting full trees").
//!
//! Trees here are binary-output weak rules `h(x) ∈ {-1, +1}` (leaf = sign
//! of the weighted label mass), built greedily by maximizing the weighted
//! edge at every node — depth 1 degenerates exactly to the [`Stump`]
//! candidates the rest of the system certifies.

use crate::boosting::{edges_native, CandidateGrid};
use crate::data::DataBlock;
use crate::model::Stump;

/// Flattened tree: internal nodes route by `x[feature] > threshold`
/// (right when true); leaves carry a ±1 prediction.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    Split {
        feature: u32,
        threshold: f32,
        /// index of the child for `x <= threshold`
        left: usize,
        /// index of the child for `x > threshold`
        right: usize,
    },
    Leaf {
        value: f32,
    },
}

/// A decision tree weak rule.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionTree {
    /// nodes[0] is the root
    pub nodes: Vec<Node>,
}

impl DecisionTree {
    /// A single leaf (constant rule).
    pub fn leaf(value: f32) -> DecisionTree {
        DecisionTree {
            nodes: vec![Node::Leaf { value }],
        }
    }

    /// A depth-1 tree equivalent to `stump`.
    pub fn from_stump(stump: Stump) -> DecisionTree {
        DecisionTree {
            nodes: vec![
                Node::Split {
                    feature: stump.feature,
                    threshold: stump.threshold,
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: -stump.sign },
                Node::Leaf { value: stump.sign },
            ],
        }
    }

    #[inline]
    pub fn predict(&self, row: &[f32]) -> f32 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if row[*feature as usize] > *threshold {
                        *right
                    } else {
                        *left
                    };
                }
                Node::Leaf { value } => return *value,
            }
        }
    }

    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + walk(nodes, *left).max(walk(nodes, *right)),
            }
        }
        walk(&self.nodes, 0)
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }

    /// Greedy fit: at each node pick the candidate stump with the largest
    /// |weighted edge| on the node's examples; recurse to `depth`.
    ///
    /// `idx` carries the example subset; leaves predict the sign of the
    /// weighted label mass (ties → +1).
    pub fn fit(
        block: &DataBlock,
        w: &[f32],
        grid: &CandidateGrid,
        depth: usize,
    ) -> DecisionTree {
        assert_eq!(block.n, w.len());
        let idx: Vec<usize> = (0..block.n).collect();
        let mut nodes = Vec::new();
        Self::fit_node(block, w, grid, depth, &idx, &mut nodes);
        DecisionTree { nodes }
    }

    fn weighted_leaf(block: &DataBlock, w: &[f32], idx: &[usize]) -> Node {
        let mass: f64 = idx
            .iter()
            .map(|&i| w[i] as f64 * block.label(i) as f64)
            .sum();
        Node::Leaf {
            value: if mass >= 0.0 { 1.0 } else { -1.0 },
        }
    }

    /// Returns the index of the subtree root appended to `nodes`.
    fn fit_node(
        block: &DataBlock,
        w: &[f32],
        grid: &CandidateGrid,
        depth: usize,
        idx: &[usize],
        nodes: &mut Vec<Node>,
    ) -> usize {
        if depth == 0 || idx.len() < 2 {
            nodes.push(Self::weighted_leaf(block, w, idx));
            return nodes.len() - 1;
        }
        // edges on this node's subset
        let sub = block.select(idx);
        let sub_w: Vec<f32> = idx.iter().map(|&i| w[i]).collect();
        let m = edges_native(&sub, &sub_w, grid);
        let (bf, bt, edge) = m.best();
        if edge.abs() <= 1e-12 {
            nodes.push(Self::weighted_leaf(block, w, idx));
            return nodes.len() - 1;
        }
        let threshold = grid.row(bf)[bt];
        let (mut li, mut ri) = (Vec::new(), Vec::new());
        for &i in idx {
            if block.row(i)[bf] > threshold {
                ri.push(i);
            } else {
                li.push(i);
            }
        }
        if li.is_empty() || ri.is_empty() {
            nodes.push(Self::weighted_leaf(block, w, idx));
            return nodes.len() - 1;
        }
        let me = nodes.len();
        nodes.push(Node::Leaf { value: 0.0 }); // placeholder, patched below
        let left = Self::fit_node(block, w, grid, depth - 1, &li, nodes);
        let right = Self::fit_node(block, w, grid, depth - 1, &ri, nodes);
        nodes[me] = Node::Split {
            feature: bf as u32,
            threshold,
            left,
            right,
        };
        me
    }
}

/// A boosted ensemble of trees: `H(x) = Σ alpha_t · tree_t(x)`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TreeEnsemble {
    pub trees: Vec<DecisionTree>,
    pub alphas: Vec<f32>,
}

impl TreeEnsemble {
    pub fn new() -> TreeEnsemble {
        TreeEnsemble::default()
    }

    pub fn len(&self) -> usize {
        self.trees.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trees.is_empty()
    }

    pub fn push(&mut self, tree: DecisionTree, alpha: f32) {
        assert!(alpha.is_finite() && alpha > 0.0);
        self.trees.push(tree);
        self.alphas.push(alpha);
    }

    pub fn score(&self, row: &[f32]) -> f32 {
        self.trees
            .iter()
            .zip(&self.alphas)
            .map(|(t, &a)| a * t.predict(row))
            .sum()
    }

    pub fn predict(&self, row: &[f32]) -> f32 {
        if self.score(row) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Text serialization:
    /// `treeensemble v1 <T>` then per tree `tree <alpha> <nodes>` followed
    /// by node lines `s <feat> <thr> <l> <r>` / `l <value>`.
    pub fn to_text(&self) -> String {
        let mut out = format!("treeensemble v1 {}\n", self.len());
        for (t, a) in self.trees.iter().zip(&self.alphas) {
            out.push_str(&format!("tree {} {}\n", a, t.nodes.len()));
            for n in &t.nodes {
                match n {
                    Node::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => out.push_str(&format!("s {feature} {threshold} {left} {right}\n")),
                    Node::Leaf { value } => out.push_str(&format!("l {value}\n")),
                }
            }
        }
        out
    }

    pub fn from_text(text: &str) -> Result<TreeEnsemble, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty")?;
        let mut hp = header.split_whitespace();
        if hp.next() != Some("treeensemble") || hp.next() != Some("v1") {
            return Err("bad ensemble header".into());
        }
        let count: usize = hp.next().ok_or("missing count")?.parse().map_err(|_| "bad count")?;
        let mut ens = TreeEnsemble::new();
        for _ in 0..count {
            let th = lines.next().ok_or("truncated (tree header)")?;
            let mut tp = th.split_whitespace();
            if tp.next() != Some("tree") {
                return Err("bad tree header".into());
            }
            let alpha: f32 = tp.next().ok_or("missing alpha")?.parse().map_err(|_| "bad alpha")?;
            let n_nodes: usize = tp
                .next()
                .ok_or("missing nodes")?
                .parse()
                .map_err(|_| "bad nodes")?;
            if !(alpha.is_finite() && alpha > 0.0) {
                return Err("alpha must be positive".into());
            }
            let mut nodes = Vec::with_capacity(n_nodes);
            for _ in 0..n_nodes {
                let line = lines.next().ok_or("truncated (node)")?;
                let mut it = line.split_whitespace();
                match it.next() {
                    Some("s") => {
                        let feature: u32 = it.next().ok_or("f")?.parse().map_err(|_| "bad feat")?;
                        let threshold: f32 = it.next().ok_or("t")?.parse().map_err(|_| "bad thr")?;
                        let left: usize = it.next().ok_or("l")?.parse().map_err(|_| "bad left")?;
                        let right: usize = it.next().ok_or("r")?.parse().map_err(|_| "bad right")?;
                        if left >= n_nodes || right >= n_nodes {
                            return Err("child index out of range".into());
                        }
                        nodes.push(Node::Split {
                            feature,
                            threshold,
                            left,
                            right,
                        });
                    }
                    Some("l") => {
                        let value: f32 = it.next().ok_or("v")?.parse().map_err(|_| "bad value")?;
                        if value != 1.0 && value != -1.0 {
                            return Err("leaf must be ±1".into());
                        }
                        nodes.push(Node::Leaf { value });
                    }
                    _ => return Err("bad node line".into()),
                }
            }
            ens.push(DecisionTree { nodes }, alpha);
        }
        Ok(ens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// XOR data: y = sign(x0 · x1) — no single stump has an edge, but a
    /// depth-2 tree separates it perfectly.
    fn xor_block(n: usize, seed: u64) -> DataBlock {
        let mut rng = Rng::new(seed);
        let mut b = DataBlock::empty(2);
        for _ in 0..n {
            let x0 = rng.gauss() as f32;
            let x1 = rng.gauss() as f32;
            let y = if x0 * x1 > 0.0 { 1.0 } else { -1.0 };
            b.push(&[x0, x1], y);
        }
        b
    }

    #[test]
    fn stump_tree_equivalence() {
        let stump = Stump::new(1, 0.25, -1.0);
        let tree = DecisionTree::from_stump(stump);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let row = [rng.gauss() as f32, rng.gauss() as f32];
            assert_eq!(tree.predict(&row), stump.predict(&row));
        }
        assert_eq!(tree.depth(), 1);
        assert_eq!(tree.num_leaves(), 2);
    }

    #[test]
    fn depth2_solves_xor() {
        let block = xor_block(2000, 2);
        let w = vec![1.0f32; block.n];
        // single candidate threshold at 0 per feature: on pure XOR every
        // root split has edge ≈ 0, so a wider grid makes greedy pick an
        // arbitrary off-center split (a classic greedy-tree blind spot);
        // the centered grid lets depth-2 realize the concept exactly
        let grid = CandidateGrid::uniform(2, 1, -1.0, 1.0);
        // depth 1 is a coin flip on XOR
        let d1 = DecisionTree::fit(&block, &w, &CandidateGrid::uniform(2, 3, -1.0, 1.0), 1);
        let acc1 = (0..block.n)
            .filter(|&i| d1.predict(block.row(i)) == block.label(i))
            .count() as f64
            / block.n as f64;
        assert!(acc1 < 0.62, "depth-1 should fail on XOR, acc={acc1}");
        // depth 2 separates
        let d2 = DecisionTree::fit(&block, &w, &grid, 2);
        let acc2 = (0..block.n)
            .filter(|&i| d2.predict(block.row(i)) == block.label(i))
            .count() as f64
            / block.n as f64;
        assert!(acc2 > 0.9, "depth-2 should solve XOR, acc={acc2}");
        assert!(d2.depth() <= 2);
    }

    #[test]
    fn leaf_tree_constant() {
        let t = DecisionTree::leaf(-1.0);
        assert_eq!(t.predict(&[0.0, 0.0]), -1.0);
        assert_eq!(t.depth(), 0);
    }

    #[test]
    fn fit_respects_depth_zero() {
        let block = xor_block(100, 3);
        let w = vec![1.0f32; block.n];
        let grid = CandidateGrid::uniform(2, 2, -1.0, 1.0);
        let t = DecisionTree::fit(&block, &w, &grid, 0);
        assert_eq!(t.num_leaves(), 1);
    }

    #[test]
    fn ensemble_scoring_and_roundtrip() {
        let block = xor_block(500, 4);
        let w = vec![1.0f32; block.n];
        let grid = CandidateGrid::uniform(2, 3, -1.0, 1.0);
        let mut ens = TreeEnsemble::new();
        ens.push(DecisionTree::fit(&block, &w, &grid, 2), 0.7);
        ens.push(DecisionTree::from_stump(Stump::new(0, 0.0, 1.0)), 0.3);
        let text = ens.to_text();
        let back = TreeEnsemble::from_text(&text).unwrap();
        assert_eq!(back, ens);
        for i in 0..20 {
            let row = block.row(i);
            assert!((back.score(row) - ens.score(row)).abs() < 1e-6);
        }
    }

    #[test]
    fn from_text_rejects_garbage() {
        assert!(TreeEnsemble::from_text("nope").is_err());
        assert!(TreeEnsemble::from_text("treeensemble v1 1\ntree 0.5 1\ns 0 0.0 9 9\n").is_err());
        assert!(TreeEnsemble::from_text("treeensemble v1 1\ntree 0.5 1\nl 0.5\n").is_err());
        assert!(TreeEnsemble::from_text("treeensemble v1 1\ntree -1 1\nl 1\n").is_err());
    }

    #[test]
    fn weighted_fit_prefers_upweighted_region() {
        // all weight on the x0 > 0 half: the root split must discriminate
        // labels *within that half* well
        let mut rng = Rng::new(5);
        let mut b = DataBlock::empty(2);
        let mut w = Vec::new();
        for _ in 0..2000 {
            let x0 = rng.gauss() as f32;
            let x1 = rng.gauss() as f32;
            // label: on the heavy half it's sign(x1); elsewhere it's noise
            let y = if x0 > 0.0 {
                if x1 > 0.0 {
                    1.0
                } else {
                    -1.0
                }
            } else if rng.bernoulli(0.5) {
                1.0
            } else {
                -1.0
            };
            b.push(&[x0, x1], y);
            w.push(if x0 > 0.0 { 1.0 } else { 0.001 });
        }
        let grid = CandidateGrid::uniform(2, 3, -1.0, 1.0);
        let t = DecisionTree::fit(&b, &w, &grid, 1);
        // weighted accuracy on the heavy half must be high
        let (mut good, mut total) = (0.0f64, 0.0f64);
        for i in 0..b.n {
            if b.row(i)[0] > 0.0 {
                total += 1.0;
                if t.predict(b.row(i)) == b.label(i) {
                    good += 1.0;
                }
            }
        }
        assert!(good / total > 0.85, "weighted fit ignored the heavy region");
    }
}
