//! Stopping-rule implementations.

use crate::stopping::CandidateStats;

/// A sequential test over a candidate's running statistics.
pub trait StoppingRule: Send + Sync {
    /// Does the rule fire for a candidate at target advantage `gamma`?
    ///
    /// Firing asserts: with probability ≥ 1−δ the candidate's true
    /// advantage is at least `gamma` (when `deviation > 0`).
    fn fires(&self, stats: &CandidateStats, gamma: f64) -> bool;

    /// The current confidence-bound radius (for diagnostics/plots).
    fn bound(&self, stats: &CandidateStats) -> f64;

    fn name(&self) -> &'static str;
}

/// The paper's rule: Balsubramani's finite-time law-of-the-iterated-
/// logarithm martingale concentration (Theorem 1 / Alg. 2 StoppingRule):
///
/// fire when  |M| > C · sqrt( V · ( loglog(V / |M|) + log(1/δ) ) )
///
/// with `M = m − 2γW` and `V = Σw²`. `C` is the universal constant of the
/// theorem (theory gives ~O(1); the original Sparrow release shipped a
/// practical C < 1, default here 0.67) and δ the per-candidate failure
/// probability.
#[derive(Debug, Clone)]
pub struct LilRule {
    pub c: f64,
    pub delta: f64,
    /// minimum examples before the asymptotics are trusted (CLT floor;
    /// §3 assumes n ≳ 100)
    pub min_count: u64,
}

impl Default for LilRule {
    fn default() -> Self {
        LilRule {
            c: 0.67,
            delta: 1e-6,
            min_count: 100,
        }
    }
}

impl LilRule {
    pub fn new(c: f64, delta: f64) -> LilRule {
        assert!(c > 0.0 && delta > 0.0 && delta < 1.0);
        LilRule {
            c,
            delta,
            ..LilRule::default()
        }
    }

    /// Split a global failure budget across `k` simultaneous candidates
    /// (union bound over the worker's candidate stripe).
    pub fn with_union_bound(c: f64, delta_total: f64, k: usize) -> LilRule {
        LilRule::new(c, delta_total / k.max(1) as f64)
    }
}

impl StoppingRule for LilRule {
    fn fires(&self, stats: &CandidateStats, gamma: f64) -> bool {
        if stats.count < self.min_count || stats.sum_w2 <= 0.0 {
            return false;
        }
        let m = stats.deviation(gamma);
        // Only a *positive* deviation certifies advantage ≥ γ. (The paper
        // takes |M|; the negative side certifies the negated candidate,
        // which appears separately in our candidate set.)
        m > self.bound(stats)
    }

    fn bound(&self, stats: &CandidateStats) -> f64 {
        let v = stats.sum_w2.max(1e-300);
        // loglog term, floored: log log max(V/|M|, e^e) keeps the argument
        // of both logs above 1 without branching on M = 0.
        let m_abs = stats.deviation(0.0).abs().max(1e-300);
        let ratio = (v / m_abs).max(std::f64::consts::E.powf(std::f64::consts::E));
        let ll = ratio.ln().ln();
        self.c * (v * (ll + (1.0 / self.delta).ln())).sqrt()
    }

    fn name(&self) -> &'static str {
        "lil"
    }
}

/// Naive Hoeffding-style rule (A1 ablation): treats the weighted sum as a
/// sub-Gaussian with variance proxy V and *fixed* horizon — pointwise valid
/// but not anytime-valid, and looser in the adaptive setting because it
/// must be re-unioned over every prefix in practice. We apply the standard
/// correction δ' = δ / count² (union over stopping times).
#[derive(Debug, Clone)]
pub struct HoeffdingRule {
    pub delta: f64,
    pub min_count: u64,
}

impl Default for HoeffdingRule {
    fn default() -> Self {
        HoeffdingRule {
            delta: 1e-6,
            min_count: 100,
        }
    }
}

impl StoppingRule for HoeffdingRule {
    fn fires(&self, stats: &CandidateStats, gamma: f64) -> bool {
        if stats.count < self.min_count || stats.sum_w2 <= 0.0 {
            return false;
        }
        stats.deviation(gamma) > self.bound(stats)
    }

    fn bound(&self, stats: &CandidateStats) -> f64 {
        let delta_t = self.delta / ((stats.count as f64).powi(2)).max(1.0);
        (2.0 * stats.sum_w2 * (1.0 / delta_t).ln()).sqrt()
    }

    fn name(&self) -> &'static str {
        "hoeffding"
    }
}

/// No early stopping (the classic full-scan boosting baseline): the rule
/// never fires; the caller scans the entire sample and picks the best
/// empirical candidate.
#[derive(Debug, Clone, Default)]
pub struct FixedScan;

impl StoppingRule for FixedScan {
    fn fires(&self, _stats: &CandidateStats, _gamma: f64) -> bool {
        false
    }

    fn bound(&self, _stats: &CandidateStats) -> f64 {
        f64::INFINITY
    }

    fn name(&self) -> &'static str {
        "fixed-scan"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, prop_check};

    fn stats_from(us: &[f64]) -> CandidateStats {
        let mut s = CandidateStats::new();
        for &u in us {
            s.m += u;
            s.sum_w += u.abs();
            s.sum_w2 += u * u;
            s.count += 1;
        }
        s
    }

    #[test]
    fn fires_on_strong_signal() {
        // all-correct candidate: m grows linearly, bound grows like sqrt
        let us = vec![1.0; 2000];
        let s = stats_from(&us);
        let rule = LilRule::default();
        assert!(rule.fires(&s, 0.1));
    }

    #[test]
    fn does_not_fire_below_min_count() {
        let us = vec![1.0; 50];
        let s = stats_from(&us);
        assert!(!LilRule::default().fires(&s, 0.1));
    }

    #[test]
    fn does_not_fire_on_noise() {
        // alternating ±1: m stays ~0
        let us: Vec<f64> = (0..5000).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let s = stats_from(&us);
        assert!(!LilRule::default().fires(&s, 0.05));
        assert!(!HoeffdingRule::default().fires(&s, 0.05));
    }

    #[test]
    fn prop_no_false_fire_under_null() {
        // under the null (true edge 0), firing at γ=0.1 over 2000 draws
        // should be (very) rare: test 50 seeds, allow none (δ=1e-6).
        prop_check("lil sound under null", 50, |rng| {
            let mut s = CandidateStats::new();
            let rule = LilRule::default();
            for _ in 0..2000 {
                let w = (-rng.f64() * 2.0).exp();
                let u = if rng.bernoulli(0.5) { w } else { -w };
                s.m += u;
                s.sum_w += w;
                s.sum_w2 += w * w;
                s.count += 1;
                if rule.fires(&s, 0.1) {
                    return Err(format!("false fire at count={}", s.count));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fires_eventually_with_true_edge() {
        prop_check("lil powers up on real edges", 20, |rng| {
            let mut s = CandidateStats::new();
            let rule = LilRule::default();
            // true correlation 0.5 (advantage 0.25) vs target γ = 0.1
            for _ in 0..20_000u64 {
                let u = if rng.bernoulli(0.75) { 1.0 } else { -1.0 };
                s.m += u;
                s.sum_w += 1.0;
                s.sum_w2 += 1.0;
                s.count += 1;
                if rule.fires(&s, 0.1) {
                    if s.count < 100 {
                        return Err("fired before min_count".into());
                    }
                    return Ok(());
                }
            }
            Err("never fired on a strong edge".into())
        });
    }

    #[test]
    fn lil_tighter_than_hoeffding() {
        // the LIL bound should (eventually) be tighter → earlier stopping
        let us = vec![1.0; 10_000];
        let s = stats_from(&us);
        let lil = LilRule::default().bound(&s);
        let hoef = HoeffdingRule::default().bound(&s);
        assert!(lil < hoef, "lil={lil} hoeffding={hoef}");
    }

    #[test]
    fn fixed_scan_never_fires() {
        let us = vec![1.0; 100_000];
        let s = stats_from(&us);
        assert!(!FixedScan.fires(&s, 0.0001));
        assert_eq!(FixedScan.bound(&s), f64::INFINITY);
    }

    #[test]
    fn union_bound_divides_delta() {
        let r = LilRule::with_union_bound(0.67, 1e-3, 100);
        assert!((r.delta - 1e-5).abs() < 1e-18);
    }

    #[test]
    fn negative_deviation_never_fires() {
        // strong *negative* edge must not certify the positive candidate
        let us = vec![-1.0; 5000];
        let s = stats_from(&us);
        assert!(!LilRule::default().fires(&s, 0.1));
    }

    #[test]
    fn prop_bound_monotone_in_v() {
        prop_check("bound grows with V", 30, |rng| {
            let base = gen::size(rng, 200, 5000) as f64;
            let s1 = CandidateStats {
                m: 0.0,
                sum_w: base,
                sum_w2: base,
                count: base as u64,
            };
            let s2 = CandidateStats {
                sum_w2: base * 2.0,
                ..s1
            };
            let rule = LilRule::default();
            if rule.bound(&s2) > rule.bound(&s1) {
                Ok(())
            } else {
                Err(format!("bound not monotone at V={base}"))
            }
        });
    }
}
