//! Domingo–Watanabe adaptive-sampling stopping rule.
//!
//! §3 cites Domingo & Watanabe [14] (and Bradley & Schapire's FilterBoost
//! [13]) as the prior early-stopping approaches Sparrow deliberately
//! departs from. This implements the DW-style rule for the A1 ablation:
//! a time-peeled Hoeffding test — at "time" t (here: accumulated variance
//! V), the deviation must clear `sqrt(2 V ln(t(t+1)/δ))`, the union bound
//! over all stopping times. Valid anytime, but the `log t` inflation grows
//! forever, whereas the LIL bound's `log log` is exponentially tighter —
//! which is exactly the paper's reason for choosing [15].

use crate::stopping::{CandidateStats, StoppingRule};

/// Domingo–Watanabe peeled-Hoeffding sequential test.
#[derive(Debug, Clone)]
pub struct DwRule {
    pub delta: f64,
    pub min_count: u64,
}

impl Default for DwRule {
    fn default() -> Self {
        DwRule {
            delta: 1e-6,
            min_count: 100,
        }
    }
}

impl StoppingRule for DwRule {
    fn fires(&self, stats: &CandidateStats, gamma: f64) -> bool {
        if stats.count < self.min_count || stats.sum_w2 <= 0.0 {
            return false;
        }
        stats.deviation(gamma) > self.bound(stats)
    }

    fn bound(&self, stats: &CandidateStats) -> f64 {
        let t = stats.count as f64;
        let v = stats.sum_w2.max(1e-300);
        (2.0 * v * ((t * (t + 1.0)) / self.delta).ln()).sqrt()
    }

    fn name(&self) -> &'static str {
        "domingo-watanabe"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stopping::LilRule;
    use crate::util::prop::prop_check;

    fn stats_n(n: u64, corr: f64) -> CandidateStats {
        CandidateStats {
            m: corr * n as f64,
            sum_w: n as f64,
            sum_w2: n as f64,
            count: n,
        }
    }

    #[test]
    fn fires_on_strong_signal() {
        let rule = DwRule::default();
        assert!(rule.fires(&stats_n(5000, 0.5), 0.1));
    }

    #[test]
    fn respects_min_count() {
        assert!(!DwRule::default().fires(&stats_n(50, 1.0), 0.1));
    }

    #[test]
    fn looser_than_lil_asymptotically() {
        // log t vs log log t: by n = 1e6 the DW bound must be strictly wider
        let s = stats_n(1_000_000, 0.0);
        let dw = DwRule::default().bound(&s);
        let lil = LilRule::default().bound(&s);
        assert!(dw > lil, "dw={dw} lil={lil}");
    }

    #[test]
    fn prop_sound_under_null() {
        prop_check("dw sound under null", 30, |rng| {
            let mut s = CandidateStats::default();
            let rule = DwRule::default();
            for _ in 0..2000 {
                let w = (-rng.f64() * 2.0).exp();
                let u = if rng.bernoulli(0.5) { w } else { -w };
                s.m += u;
                s.sum_w += w;
                s.sum_w2 += w * w;
                s.count += 1;
                if rule.fires(&s, 0.1) {
                    return Err(format!("false fire at {}", s.count));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bound_monotone_in_count() {
        let rule = DwRule::default();
        assert!(rule.bound(&stats_n(10_000, 0.0)) > rule.bound(&stats_n(1_000, 0.0)));
    }
}
