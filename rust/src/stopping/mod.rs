//! Sequential stopping rules (§3 "Sequential Analysis and Early Stopping").
//!
//! A stopping rule watches a candidate's running statistics and fires when
//! the candidate's true edge exceeds the target γ with high probability.
//! The paper's rule is the finite-time iterated-logarithm martingale bound
//! of Balsubramani [15] (Theorem 1); a naive Hoeffding rule and a
//! fixed-scan (no early stopping) rule are provided for the A1 ablation.

pub mod dw;
pub mod lil;

pub use dw::DwRule;
pub use lil::{FixedScan, HoeffdingRule, LilRule, StoppingRule};

/// Running statistics for one candidate weak rule (Alg. 2 state).
#[derive(Debug, Clone, Copy, Default)]
pub struct CandidateStats {
    /// Σ w·y·h(x) — the candidate's unnormalized empirical edge  (m[h])
    pub m: f64,
    /// Σ |w| over scanned examples                                (W)
    pub sum_w: f64,
    /// Σ w² over scanned examples                                 (V)
    pub sum_w2: f64,
    /// number of examples scanned
    pub count: u64,
}

impl CandidateStats {
    pub fn new() -> CandidateStats {
        CandidateStats::default()
    }

    /// Martingale deviation from the target edge: `M = m − 2γ·W`
    /// (positive when the candidate looks better than target γ).
    #[inline]
    pub fn deviation(&self, gamma: f64) -> f64 {
        self.m - 2.0 * gamma * self.sum_w
    }

    /// Normalized empirical correlation `m / W ∈ [-1, 1]`.
    pub fn correlation(&self) -> f64 {
        if self.sum_w <= 0.0 {
            0.0
        } else {
            self.m / self.sum_w
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deviation_centered_at_target() {
        let s = CandidateStats {
            m: 10.0,
            sum_w: 20.0,
            sum_w2: 5.0,
            count: 20,
        };
        // corr = 0.5, advantage = 0.25; target γ = 0.25 ⇒ deviation 0
        assert!((s.deviation(0.25)).abs() < 1e-12);
        assert!(s.deviation(0.2) > 0.0);
        assert!(s.deviation(0.3) < 0.0);
    }

    #[test]
    fn correlation_empty_is_zero() {
        assert_eq!(CandidateStats::new().correlation(), 0.0);
    }
}
