//! `sparrow` — CLI for the TMSN/Sparrow reproduction.
//!
//! Subcommands:
//!   gen-data   synthesize (or convert) a disk-resident training store
//!   train      run a Sparrow cluster (TMSN) on a store
//!   baseline   run a Table-1 baseline (fullscan | goss | bulksync)
//!   eval       evaluate a saved model on a test store
//!   serve      train + answer predictions from the latest adopted model
//!   rpc        call a worker's admin (or serve) JSON-RPC endpoint
//!
//! `sparrow <cmd> --help` lists the knobs for each subcommand.

use std::path::{Path, PathBuf};
use std::time::Duration;

use sparrow::baselines::{
    train_bulk_sync, train_fullscan, train_goss, BulkSyncConfig, DataSource, FullScanConfig,
    GossConfig, StopConditions,
};
use sparrow::config::{TrainConfig, WorkloadConfig};
use sparrow::coordinator::train_cluster;
use sparrow::data::synth::SynthGen;
use sparrow::data::{libsvm, DiskStore};
use sparrow::eval::{auprc, exp_loss, test_error};
use sparrow::metrics::events::to_jsonl;
use sparrow::model::StrongRule;
use sparrow::util::cli::Args;

const USAGE: &str = "\
sparrow — 'Tell Me Something New' asynchronous parallel boosting

USAGE: sparrow <COMMAND> [--key value ...]

COMMANDS
  gen-data   --out train.sprw [--test-out test.sprw] [--train-n N] [--test-n N]
             [--features F] [--pos-rate P] [--informative K] [--signal S]
             [--flip-rate P] [--data-seed S] [--libsvm in.svm]
  train      --data train.sprw --test test.sprw [--workers N] [--sample-size M]
             [--gamma0 G] [--ess-threshold T] [--max-rules K] [--time-limit SECS]
             [--target-loss L] [--stopping lil|hoeffding|fixed]
             [--sampler mvs|rejection|uniform] [--sampler-mode blocking|background]
             [--backend native|xla-pallas|xla-jnp]
             [--scan-engine rows|binned] [--scan-threads N] [--scan-simd auto|on|off]
             [--store-tier mem|tiered] [--memory-budget BYTES]
             [--batch B] [--nthr NT] [--disk-bandwidth BYTES/S] [--seed S]
             [--out-dir DIR]
  baseline   --algo fullscan|goss|bulksync --data train.sprw --test test.sprw
             [--max-rules K] [--time-limit SECS] [--target-loss L]
             [--disk-bandwidth BYTES/S] [--in-memory] [--workers N] [--out-dir DIR]
  eval       --model model.txt --test test.sprw
  worker     one TMSN worker process over real TCP:
             --data train.sprw --worker-id I --workers N --listen ADDR
             [--peers addr1,addr2,...] [--seed-peers addr] [--pex]
             [--advertise ADDR] [--admin ADDR] --out model.txt
             [--broadcast full|fanout[:K]] [--checkpoint PATH]
             [--heartbeat-ms MS] [--queue-cap N]
             [--resume PATH [--resume-bound B]] [train knobs as above]
             (--seed-peers joins via peer exchange — no static peer list;
             --pex makes a seed node answer discovery; --advertise sets
             the announced dial-back address, e.g. behind a proxy)
  serve      a worker that also answers predictions from the latest
             adopted model (hot-swapped on every adoption, see
             OPERATIONS.md): --data train.sprw [--serve-addr ADDR]
             [--admin-addr ADDR] [--resume model.txt] [--out model.txt]
             [--exit-after-train] [worker knobs as above]
  rpc        one admin/serve RPC call, response envelope on stdout:
             --addr HOST:PORT --method NAME [--params JSON]
             (methods: ping, metrics.snapshot, model.current, peers.list,
             config.set_gamma, config.gamma_reset, config.set_sweep,
             fault.inject, shutdown; serve: predict, serve.stats)
  launch     spawn N local `worker` processes wired over TCP:
             --data train.sprw --test test.sprw --workers N --out-dir DIR
             [train knobs as above]
  sim        deterministic fault-injection scenarios in virtual time:
             [--workload boost|sgd]
             [--scenario calm|crash|laggard|partition|churn|join|churn_large|all]
             [--seed S] [--workers N] [--horizon SECS] [--drop P] [--dup P]
             [--reorder P] [--mode full|fanout[:K]] [--trace] [--minimize]
             (exit 1 on any TMSN invariant violation; --minimize delta-debugs
             a failing run to its minimal byte-identical repro)
";

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("gen-data") => cmd_gen_data(&args),
        Some("train") => cmd_train(&args),
        Some("baseline") => cmd_baseline(&args),
        Some("eval") => cmd_eval(&args),
        Some("worker") => cmd_worker(&args),
        Some("serve") => cmd_serve(&args),
        Some("rpc") => cmd_rpc(&args),
        Some("launch") => cmd_launch(&args),
        Some("sim") => cmd_sim(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(anyhow::anyhow!("unknown command {other:?}\n{USAGE}")),
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e:#}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn load_test_block(path: &str) -> anyhow::Result<sparrow::data::DataBlock> {
    Ok(DiskStore::open(Path::new(path))?.read_all()?)
}

fn out_dir(args: &Args) -> anyhow::Result<Option<PathBuf>> {
    match args.get("out-dir") {
        None => Ok(None),
        Some(d) => {
            let p = PathBuf::from(d);
            std::fs::create_dir_all(&p)?;
            Ok(Some(p))
        }
    }
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out is required"))?
        .to_string();
    if let Some(svm) = args.get("libsvm") {
        let features = args.get_usize("features", 0);
        let block = libsvm::read_file(Path::new(svm), features)?;
        let store = DiskStore::write(Path::new(&out), &block)?;
        println!(
            "converted {} -> {} ({} examples, {} features)",
            svm,
            out,
            store.len(),
            store.num_features()
        );
        args.finish().map_err(anyhow::Error::msg)?;
        return Ok(());
    }
    let w = WorkloadConfig::default()
        .apply_args(args)
        .map_err(anyhow::Error::msg)?;
    let mut gen = SynthGen::new(w.synth_config());
    let store = gen.write_store(Path::new(&out), w.train_n)?;
    println!(
        "wrote {} ({} examples, {} features, {:.1} MB)",
        out,
        store.len(),
        store.num_features(),
        store.data_bytes() as f64 / 1e6
    );
    if let Some(test_out) = args.get("test-out") {
        let test_store = gen.write_store(Path::new(test_out), w.test_n)?;
        println!("wrote {} ({} examples)", test_out, test_store.len());
    }
    args.finish().map_err(anyhow::Error::msg)?;
    Ok(())
}

/// Checkpoint resume: `--resume model.txt [--resume-bound B]` (the bound
/// defaults to the value recorded in `model.txt.meta`). Shared by `train`,
/// `serve` and `worker` — the files are exactly what `--checkpoint` writes,
/// so a killed worker restarts with `--resume <its own checkpoint>`.
fn apply_resume(args: &Args, cfg: &mut TrainConfig) -> anyhow::Result<()> {
    if let Some(resume_path) = args.get("resume") {
        let model = StrongRule::from_text(&std::fs::read_to_string(resume_path)?)
            .map_err(anyhow::Error::msg)?;
        let bound = match args.get("resume-bound") {
            Some(v) => v.parse::<f64>().map_err(|_| anyhow::anyhow!("bad --resume-bound"))?,
            None => {
                let meta = std::fs::read_to_string(format!("{resume_path}.meta"))
                    .map_err(|_| anyhow::anyhow!(
                        "--resume needs {resume_path}.meta (or pass --resume-bound)"
                    ))?;
                meta.split_whitespace()
                    .find_map(|t| t.strip_prefix("bound="))
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow::anyhow!("no bound= in {resume_path}.meta"))?
            }
        };
        println!("resuming from {resume_path} ({} rules, bound {bound:.4})", model.len());
        cfg.resume = Some((model, bound));
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let data = args
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("--data is required"))?
        .to_string();
    let test_path = args
        .get("test")
        .ok_or_else(|| anyhow::anyhow!("--test is required"))?
        .to_string();
    let mut cfg = TrainConfig::default()
        .apply_args(args)
        .map_err(anyhow::Error::msg)?;
    apply_resume(args, &mut cfg)?;
    let out = out_dir(args)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let test = load_test_block(&test_path)?;
    let store = DiskStore::open(Path::new(&data))?;
    let features = store.num_features();
    let cfg2 = cfg.clone();
    let outcome = train_cluster(&cfg, Path::new(&data), &test, "sparrow", &move |_| {
        sparrow::runtime::make_backend(&cfg2, features)
    })?;

    println!(
        "trained {} rules in {:.2}s  (bound {:.4})",
        outcome.model.len(),
        outcome.elapsed.as_secs_f64(),
        outcome.loss_bound
    );
    let final_point = outcome.series.points.last().expect("series");
    println!(
        "test exp-loss {:.4}  auprc {:.4}",
        final_point.exp_loss, final_point.auprc
    );
    let (sent, delivered, dropped) = outcome.net;
    println!("net: {sent} broadcasts, {delivered} delivered, {dropped} dropped");
    for w in &outcome.workers {
        println!(
            "  worker {}: found {} accepted {} rejected {} resamples {} scanned {}{}",
            w.id,
            w.found,
            w.accepts,
            w.rejects,
            w.resamples,
            w.scanned,
            if w.crashed { " [crashed]" } else { "" }
        );
    }
    if let Some(dir) = out {
        std::fs::write(dir.join("model.txt"), outcome.model.to_text())?;
        std::fs::write(
            dir.join("model.txt.meta"),
            format!("bound={}\n", outcome.loss_bound),
        )?;
        std::fs::write(dir.join("series.csv"), outcome.series.to_csv())?;
        std::fs::write(dir.join("events.jsonl"), to_jsonl(&outcome.events))?;
        std::fs::write(dir.join("timeline.txt"), outcome.timeline(100))?;
        println!("artifacts written to {}", dir.display());
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> anyhow::Result<()> {
    let algo = args.get_or("algo", "fullscan");
    let data = args
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("--data is required"))?
        .to_string();
    let test_path = args
        .get("test")
        .ok_or_else(|| anyhow::anyhow!("--test is required"))?
        .to_string();
    let stop = StopConditions {
        max_rules: args.get_usize("max-rules", 128),
        time_limit: Duration::from_secs_f64(args.get_f64("time-limit", 60.0)),
        target_loss: args.get_f64("target-loss", 0.0),
        eval_interval: Duration::from_secs_f64(args.get_f64("eval-interval", 0.25)),
    };
    let bandwidth = args.get_f64("disk-bandwidth", 0.0);
    let in_memory = args.has_flag("in-memory");
    let workers = args.get_usize("workers", 4);
    let args_depth = args.get_usize("depth", 2);
    let out = out_dir(args)?;
    let out_dir_v = out.clone();
    args.finish().map_err(anyhow::Error::msg)?;

    let test = load_test_block(&test_path)?;
    let source = if in_memory {
        DataSource::memory(DiskStore::open(Path::new(&data))?.read_all()?)
    } else {
        DataSource::disk(Path::new(&data), bandwidth)?
    };
    let outcome = match algo.as_str() {
        "fullscan" => train_fullscan(
            &source,
            &test,
            &FullScanConfig {
                stop,
                ..FullScanConfig::default()
            },
            "fullscan",
        )?,
        "goss" => train_goss(
            &source,
            &test,
            &GossConfig {
                stop,
                ..GossConfig::default()
            },
            "goss",
        )?,
        "bulksync" => {
            let train = DiskStore::open(Path::new(&data))?.read_all()?;
            train_bulk_sync(
                &train,
                &test,
                &BulkSyncConfig {
                    workers,
                    stop,
                    ..BulkSyncConfig::default()
                },
                "bulksync",
            )
        }
        "tree" => {
            // multi-level trees (paper §5 future work) — separate model
            // family, reported here and returned via its own outcome
            let depth = args_depth;
            let out = sparrow::baselines::train_tree_boost(
                &source,
                &test,
                &sparrow::baselines::TreeBoostConfig {
                    depth,
                    stop,
                    ..sparrow::baselines::TreeBoostConfig::default()
                },
                "tree",
            )?;
            let p = out.series.points.last().expect("series");
            println!(
                "tree(depth={depth}): {} trees, test exp-loss {:.4}, auprc {:.4}, {:.2}s",
                out.model.len(),
                p.exp_loss,
                p.auprc,
                p.elapsed.as_secs_f64()
            );
            if let Some(dir) = out_dir_v {
                std::fs::write(dir.join("tree_model.txt"), out.model.to_text())?;
                std::fs::write(dir.join("tree_series.csv"), out.series.to_csv())?;
            }
            return Ok(());
        }
        other => anyhow::bail!("unknown --algo {other:?} (fullscan|goss|bulksync|tree)"),
    };
    let p = outcome.series.points.last().expect("series");
    println!(
        "{algo}: {} rules, test exp-loss {:.4}, auprc {:.4}, {:.2}s",
        outcome.model.len(),
        p.exp_loss,
        p.auprc,
        p.elapsed.as_secs_f64()
    );
    if let Some(dir) = out {
        std::fs::write(dir.join(format!("{algo}_model.txt")), outcome.model.to_text())?;
        std::fs::write(dir.join(format!("{algo}_series.csv")), outcome.series.to_csv())?;
        println!("artifacts written to {}", dir.display());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let model_path = args
        .get("model")
        .ok_or_else(|| anyhow::anyhow!("--model is required"))?
        .to_string();
    let test_path = args
        .get("test")
        .ok_or_else(|| anyhow::anyhow!("--test is required"))?
        .to_string();
    args.finish().map_err(anyhow::Error::msg)?;
    let model =
        StrongRule::from_text(&std::fs::read_to_string(&model_path)?).map_err(anyhow::Error::msg)?;
    let test = load_test_block(&test_path)?;
    let sc = sparrow::eval::metrics::scores(&model, &test);
    println!(
        "model: {} rules\nexp-loss: {:.6}\nauprc: {:.6}\n0/1 error: {:.6}",
        model.len(),
        exp_loss(&model, &test),
        auprc(&sc, &test.labels),
        test_error(&model, &test)
    );
    Ok(())
}

/// One TMSN worker process attached to the real TCP transport.
///
/// All workers must be launched with the same `--data`, `--workers` and
/// `--nthr` so they derive the identical candidate grid (pilot quantiles
/// are deterministic) and consistent feature stripes.
/// Wire the self-healing fabric (DESIGN.md §13) onto a freshly bound
/// endpoint: tuning from the config knobs, peer exchange for seed-node
/// discovery, and the initial dials — the static `--peers` list plus the
/// `--seed-peers` discovery seeds.
fn wire_fabric<P: sparrow::tmsn::Payload>(
    endpoint: &sparrow::network::TcpEndpoint<P>,
    cfg: &TrainConfig,
    worker_id: usize,
    peers: &str,
    seed_peers: &str,
    pex: bool,
    advertise: Option<&str>,
) -> anyhow::Result<()> {
    use sparrow::network::TcpTuning;
    endpoint.tune(TcpTuning {
        heartbeat: Duration::from_millis(cfg.heartbeat_ms),
        queue_cap: cfg.queue_cap,
        ..TcpTuning::default()
    });
    // peer exchange is on for a joiner (--seed-peers), a seed node
    // (--pex), or any endpoint announcing a non-bind address
    // (--advertise, e.g. when fronted by a chaos proxy)
    if pex || !seed_peers.is_empty() || advertise.is_some() {
        match advertise {
            Some(a) => endpoint.enable_pex_as(a),
            None => endpoint.enable_pex(),
        }
    }
    for peer in peers.split(',').filter(|p| !p.is_empty()) {
        endpoint.connect(peer)?;
        println!("worker {worker_id} connected to {peer}");
    }
    for seed in seed_peers.split(',').filter(|p| !p.is_empty()) {
        endpoint.connect(seed)?;
        println!("worker {worker_id} joining swarm via seed {seed}");
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    use sparrow::admin::{AdminHandler, ControlState, RpcServer};
    use sparrow::boosting::grid::partition_features;
    use sparrow::boosting::CandidateGrid;
    use sparrow::data::IoThrottle;
    use sparrow::metrics::EventLog;
    use sparrow::network::TcpEndpoint;
    use sparrow::serve::ModelSlot;
    use sparrow::tmsn::BoostPayload;
    use sparrow::worker::{run_worker, ControlPlane, WorkerParams};
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    let data = args
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("--data is required"))?
        .to_string();
    let worker_id = args.get_usize("worker-id", 0);
    let listen = args.get_or("listen", "127.0.0.1:0");
    let peers = args.get_or("peers", "");
    let seed_peers = args.get_or("seed-peers", "");
    let pex = args.has_flag("pex");
    let advertise = args.get("advertise").map(str::to_string);
    let admin_addr = args.get("admin").map(str::to_string);
    let out = args.get("out").map(str::to_string);
    let mut cfg = TrainConfig::default()
        .apply_args(args)
        .map_err(anyhow::Error::msg)?;
    apply_resume(args, &mut cfg)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let store = DiskStore::open(Path::new(&data))?;
    let features = store.num_features();
    anyhow::ensure!(worker_id < cfg.num_workers, "--worker-id out of range");

    // deterministic shared grid: pilot = first 4096 records (same file on
    // every worker → same grid)
    let pilot = store
        .stream(IoThrottle::unlimited())?
        .next_block(4096.min(store.len()))?;
    let grid = CandidateGrid::from_quantiles(&pilot, cfg.nthr);
    let stripe = partition_features(features, cfg.num_workers)[worker_id];

    let endpoint: TcpEndpoint<BoostPayload> = TcpEndpoint::bind(&listen)?;
    println!("worker {worker_id} listening on {}", endpoint.local_addr());
    wire_fabric(
        &endpoint,
        &cfg,
        worker_id,
        &peers,
        &seed_peers,
        pex,
        advertise.as_deref(),
    )?;
    // gossip mode is a cluster-wide dialect: every worker must be launched
    // with the same --broadcast value (DESIGN.md §12)
    endpoint.enable_fanout(
        cfg.broadcast,
        cfg.num_workers,
        cfg.seed ^ 0xFA_0 ^ worker_id as u64,
    );

    let (mut log, _event_rx) = EventLog::new();
    let stop = Arc::new(AtomicBool::new(false));
    // --admin ADDR: publish gauges into a ControlState and answer the
    // operator's JSON-RPC on a side thread (OPERATIONS.md)
    let control = match admin_addr {
        Some(addr) => {
            let state = Arc::new(ControlState::new());
            // `peers.list` + the snapshot's peers object read the live table
            state.set_peer_source(endpoint.peer_table_handle());
            log = log.with_counters(Arc::clone(&state.counters));
            let admin = RpcServer::bind(
                &addr,
                Arc::new(AdminHandler::new(worker_id, Arc::clone(&state), Arc::clone(&stop))),
            )?;
            println!("worker {worker_id} admin rpc on {}", admin.local_addr());
            Some(ControlPlane {
                state,
                slot: Arc::new(ModelSlot::new()),
            })
        }
        None => None,
    };
    // gossip relays show up in the metrics feed as `forward` events;
    // the fabric's own lifecycle (peer_up/peer_down/reconnect/queue_drop)
    // feeds the same log
    endpoint.fanout_event_log(log.clone(), worker_id);
    endpoint.event_log(log.clone(), worker_id);
    let cfg2 = cfg.clone();
    let result = run_worker(WorkerParams {
        id: worker_id,
        cfg: cfg.clone(),
        grid,
        stripe,
        store,
        endpoint: Box::new(endpoint),
        log,
        stop,
        backend: sparrow::runtime::make_backend(&cfg2, features)?,
        laggard: 1.0,
        crash_after: None,
        seed: cfg.seed ^ worker_id as u64,
        control,
    });

    println!(
        "worker {worker_id} done: {} rules, bound {:.4}, found {}, accepted {}",
        result.model.len(),
        result.loss_bound,
        result.found,
        result.accepts
    );
    if let Some(out) = out {
        std::fs::write(&out, result.model.to_text())?;
        std::fs::write(
            format!("{out}.meta"),
            format!(
                "bound={} found={} accepts={} rejects={} resamples={} scanned={}\n",
                result.loss_bound,
                result.found,
                result.accepts,
                result.rejects,
                result.resamples,
                result.scanned
            ),
        )?;
    }
    Ok(())
}

/// `sparrow serve`: one worker process that also answers prediction
/// requests from the latest adopted strong model (DESIGN.md §10).
///
/// Both RPC endpoints come up before training starts: the serve endpoint
/// (`predict`, `serve.stats`, …) reads a hot-swap `ModelSlot` that the
/// training loop publishes every adoption into — an adoption storm swaps
/// the served model between requests without dropping any — and the
/// admin endpoint answers `metrics.snapshot`, config nudges, fault
/// injection and `shutdown`. After training finishes the process keeps
/// serving the final model until an admin `shutdown` arrives (suppress
/// with `--exit-after-train`).
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use sparrow::admin::{AdminHandler, ControlState, RpcServer};
    use sparrow::boosting::grid::partition_features;
    use sparrow::boosting::CandidateGrid;
    use sparrow::config::ServeConfig;
    use sparrow::data::IoThrottle;
    use sparrow::metrics::EventLog;
    use sparrow::network::TcpEndpoint;
    use sparrow::serve::{ModelSlot, ServeHandler};
    use sparrow::tmsn::BoostPayload;
    use sparrow::worker::{run_worker, ControlPlane, WorkerParams};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let data = args
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("--data is required"))?
        .to_string();
    let worker_id = args.get_usize("worker-id", 0);
    let listen = args.get_or("listen", "127.0.0.1:0");
    let peers = args.get_or("peers", "");
    let seed_peers = args.get_or("seed-peers", "");
    let pex = args.has_flag("pex");
    let advertise = args.get("advertise").map(str::to_string);
    let out = args.get("out").map(str::to_string);
    let exit_after_train = args.has_flag("exit-after-train");
    let serve_cfg = ServeConfig::default()
        .apply_args(args)
        .map_err(anyhow::Error::msg)?;
    let mut cfg = TrainConfig::default()
        .apply_args(args)
        .map_err(anyhow::Error::msg)?;
    apply_resume(args, &mut cfg)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let store = DiskStore::open(Path::new(&data))?;
    let features = store.num_features();
    anyhow::ensure!(worker_id < cfg.num_workers, "--worker-id out of range");
    let pilot = store
        .stream(IoThrottle::unlimited())?
        .next_block(4096.min(store.len()))?;
    let grid = CandidateGrid::from_quantiles(&pilot, cfg.nthr);
    let stripe = partition_features(features, cfg.num_workers)[worker_id];

    let endpoint: TcpEndpoint<BoostPayload> = TcpEndpoint::bind(&listen)?;
    if cfg.num_workers > 1 {
        println!("worker {worker_id} listening on {}", endpoint.local_addr());
    }
    wire_fabric(
        &endpoint,
        &cfg,
        worker_id,
        &peers,
        &seed_peers,
        pex,
        advertise.as_deref(),
    )?;
    endpoint.enable_fanout(
        cfg.broadcast,
        cfg.num_workers,
        cfg.seed ^ 0xFA_0 ^ worker_id as u64,
    );

    let stop = Arc::new(AtomicBool::new(false));
    let state = Arc::new(ControlState::new());
    state.set_peer_source(endpoint.peer_table_handle());
    let slot = Arc::new(ModelSlot::new());
    if let Some((model, bound)) = &cfg.resume {
        // serve the checkpoint immediately instead of the empty model;
        // the seed stays version 0, so the first adoption still wins
        slot.seed(model.clone(), *bound);
    }
    let admin = RpcServer::bind(
        &serve_cfg.admin_addr,
        Arc::new(AdminHandler::new(worker_id, Arc::clone(&state), Arc::clone(&stop))),
    )?;
    let serve = RpcServer::bind(
        &serve_cfg.serve_addr,
        Arc::new(ServeHandler::new(Arc::clone(&slot))),
    )?;
    println!(
        "worker {worker_id} serving predictions on {} (admin rpc {})",
        serve.local_addr(),
        admin.local_addr()
    );

    let (log, _event_rx) = EventLog::new();
    let log = log.with_counters(Arc::clone(&state.counters));
    endpoint.fanout_event_log(log.clone(), worker_id);
    endpoint.event_log(log.clone(), worker_id);
    let cfg2 = cfg.clone();
    let result = run_worker(WorkerParams {
        id: worker_id,
        cfg: cfg.clone(),
        grid,
        stripe,
        store,
        endpoint: Box::new(endpoint),
        log,
        stop: Arc::clone(&stop),
        backend: sparrow::runtime::make_backend(&cfg2, features)?,
        laggard: 1.0,
        crash_after: None,
        seed: cfg.seed ^ worker_id as u64,
        control: Some(ControlPlane {
            state,
            slot: Arc::clone(&slot),
        }),
    });

    println!(
        "training done: {} rules, bound {:.4} — serving model v{}",
        result.model.len(),
        result.loss_bound,
        slot.version()
    );
    if let Some(out) = out {
        std::fs::write(&out, result.model.to_text())?;
        std::fs::write(format!("{out}.meta"), format!("bound={}\n", result.loss_bound))?;
        println!("model written to {out}");
    }
    if !exit_after_train && !stop.load(Ordering::Relaxed) {
        println!(
            "serving until shutdown: sparrow rpc --addr {} --method shutdown",
            admin.local_addr()
        );
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    Ok(())
}

/// One admin/serve RPC round trip; the full response envelope goes to
/// stdout. The exit code is nonzero when the endpoint returned a typed
/// error, so shell scripts can gate on it.
fn cmd_rpc(args: &Args) -> anyhow::Result<()> {
    use sparrow::admin::RpcClient;
    use sparrow::util::json::Json;

    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("--addr is required"))?
        .to_string();
    let method = args
        .get("method")
        .ok_or_else(|| anyhow::anyhow!("--method is required"))?
        .to_string();
    let params = match args.get("params") {
        Some(p) => Json::parse(p).map_err(|e| anyhow::anyhow!("bad --params: {e}"))?,
        None => Json::Null,
    };
    args.finish().map_err(anyhow::Error::msg)?;

    let mut client = RpcClient::connect(&addr)?;
    let reply = client.call(&method, params)?;
    println!("{}", reply.to_string());
    if let Some(err) = reply.get("error") {
        let code = err.get("code").and_then(Json::as_f64).unwrap_or(0.0);
        anyhow::bail!("rpc error {code}");
    }
    Ok(())
}

/// Run the deterministic fault-injection simulator (DESIGN.md §9): the
/// real TMSN state machine over a seeded virtual-time wire, with scripted
/// crash/laggard/partition schedules. Exits non-zero if any scenario
/// violates a TMSN invariant.
fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    use sparrow::sim::{
        preset, run_scenario, sgd_sim_fixture, BoostSimWorker, EdgeFaults, SgdSimWorker,
        SimConfig, SimNetConfig, PRESETS,
    };
    use sparrow::tmsn::BoostPayload;
    use std::sync::Arc;

    let workload = args.get_or("workload", "boost");
    let scenario_arg = args.get_or("scenario", "all");
    let seed = args.get_u64("seed", 1);
    let workers = args.get_usize("workers", 5);
    let horizon = Duration::from_secs_f64(args.get_f64("horizon", 1.5));
    let mode = sparrow::network::BroadcastMode::parse(&args.get_or("mode", "full"))
        .map_err(anyhow::Error::msg)?;
    let net = SimNetConfig {
        edge: EdgeFaults::lossy(
            args.get_f64("drop", 0.0),
            args.get_f64("dup", 0.0),
            args.get_f64("reorder", 0.0),
        ),
        mode,
        ..SimNetConfig::default()
    };
    let show_trace = args.has_flag("trace");
    let do_minimize = args.has_flag("minimize");
    args.finish().map_err(anyhow::Error::msg)?;

    let names: Vec<String> = if scenario_arg == "all" {
        PRESETS.iter().map(|s| s.to_string()).collect()
    } else {
        vec![scenario_arg.clone()]
    };

    fn summarize<P: sparrow::tmsn::Payload>(
        name: &str,
        workload: &str,
        seed: u64,
        r: &sparrow::sim::SimReport<P>,
    ) {
        use sparrow::tmsn::{Certified, Payload};
        println!(
            "[{workload}/{name}] seed={seed} vtime={:.3}s best={:.6} \
             net: {} broadcast / {} delivered / {} dropped / {} blocked",
            r.virtual_elapsed.as_secs_f64(),
            r.best.cert().summary(),
            r.net.broadcasts,
            r.net.delivered,
            r.net.dropped,
            r.net.partition_blocked,
        );
        for w in &r.workers {
            println!(
                "  w{}: steps={} published={} accepts={} rejects={} cert={:.6}{}{}",
                w.id,
                w.steps,
                w.published,
                w.accepts,
                w.rejects,
                w.final_summary,
                if w.alive { "" } else { " [down]" },
                if w.restarts > 0 { " [restarted]" } else { "" },
            );
        }
        for v in &r.violations {
            println!("  VIOLATION: {v}");
        }
    }

    let mut violations = 0usize;
    for name in &names {
        let scenario = preset(name, workers)
            .ok_or_else(|| anyhow::anyhow!("unknown --scenario {name:?} (try: {PRESETS:?})"))?;
        let cfg = SimConfig {
            workers,
            seed,
            net: net.clone(),
            scenario,
            horizon,
            ..SimConfig::default()
        };
        match workload.as_str() {
            "boost" => {
                let r =
                    run_scenario(&cfg, |id, inc| BoostSimWorker::for_run(seed, id, inc));
                summarize::<BoostPayload>(name, &workload, seed, &r);
                violations += r.violations.len();
                if show_trace {
                    print!("{}", r.trace);
                }
                if do_minimize && !r.violations.is_empty() {
                    let spawn = |id: usize, inc: u64| BoostSimWorker::for_run(seed, id, inc);
                    let failing =
                        |r: &sparrow::sim::SimReport<BoostPayload>| !r.violations.is_empty();
                    if let Some(m) = sparrow::sim::minimize(&cfg, &spawn, &failing) {
                        println!(
                            "minimized repro ({} probes): {} workers, horizon {:.3}s, \
                             {} scenario event(s)",
                            m.probes,
                            m.cfg.workers,
                            m.cfg.horizon.as_secs_f64(),
                            m.cfg.scenario.len(),
                        );
                        for (t, e) in m.cfg.scenario.events() {
                            println!("  {:>8.3}s {}", t.as_secs_f64(), e.describe());
                        }
                        for v in &m.violations {
                            println!("  VIOLATION: {v}");
                        }
                        print!("{}", m.trace);
                    }
                }
            }
            "sgd" => {
                let (shards, valid) = sgd_sim_fixture(seed, workers);
                let r = run_scenario(&cfg, |id, _inc| {
                    SgdSimWorker::new(id, Arc::clone(&shards[id]), Arc::clone(&valid))
                });
                summarize(name, &workload, seed, &r);
                violations += r.violations.len();
                if show_trace {
                    print!("{}", r.trace);
                }
            }
            other => anyhow::bail!("unknown --workload {other:?} (boost|sgd)"),
        }
    }
    anyhow::ensure!(violations == 0, "{violations} TMSN invariant violation(s)");
    Ok(())
}

/// Spawn a local multi-process TMSN cluster over TCP.
fn cmd_launch(args: &Args) -> anyhow::Result<()> {
    let data = args
        .get("data")
        .ok_or_else(|| anyhow::anyhow!("--data is required"))?
        .to_string();
    let test_path = args.get("test").map(str::to_string);
    let workers = args.get_usize("workers", 2);
    let base_port = args.get_usize("base-port", 17760);
    let out = out_dir(args)?.ok_or_else(|| anyhow::anyhow!("--out-dir is required"))?;
    // knobs forwarded verbatim to the children
    let forward: Vec<String> = [
        "sample-size",
        "gamma0",
        "max-rules",
        "time-limit",
        "nthr",
        "batch",
        "backend",
        "stopping",
        "sampler",
        "sampler-mode",
        "scan-engine",
        "scan-threads",
        "scan-simd",
        "store-tier",
        "memory-budget",
        "disk-bandwidth",
        "seed",
        "artifacts-dir",
        "broadcast",
        "heartbeat-ms",
        "queue-cap",
    ]
    .iter()
    .filter_map(|k| args.get(k).map(|v| vec![format!("--{k}"), v.to_string()]))
    .flatten()
    .collect();
    args.finish().map_err(anyhow::Error::msg)?;

    let exe = std::env::current_exe()?;
    let addrs: Vec<String> = (0..workers)
        .map(|i| format!("127.0.0.1:{}", base_port + i))
        .collect();
    let mut children = Vec::new();
    for i in 0..workers {
        let peers: Vec<String> = addrs
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != i)
            .map(|(_, a)| a.clone())
            .collect();
        let model_out = out.join(format!("worker_{i}.model.txt"));
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .args(["--data", &data])
            .args(["--worker-id", &i.to_string()])
            .args(["--workers", &workers.to_string()])
            .args(["--listen", &addrs[i]])
            .args(["--peers", &peers.join(",")])
            .args(["--out", model_out.to_str().unwrap()])
            .args(&forward);
        children.push((i, cmd.spawn()?));
    }
    let mut best: Option<(f64, PathBuf)> = None;
    for (i, mut child) in children {
        let status = child.wait()?;
        anyhow::ensure!(status.success(), "worker {i} failed: {status}");
        let meta_path = out.join(format!("worker_{i}.model.txt.meta"));
        let meta = std::fs::read_to_string(&meta_path).unwrap_or_default();
        let bound: f64 = meta
            .split_whitespace()
            .find_map(|t| t.strip_prefix("bound="))
            .and_then(|v| v.parse().ok())
            .unwrap_or(f64::INFINITY);
        println!("worker {i}: bound {bound:.4}");
        let model_path = out.join(format!("worker_{i}.model.txt"));
        if best.as_ref().map_or(true, |(b, _)| bound < *b) {
            best = Some((bound, model_path));
        }
    }
    let (bound, best_path) = best.ok_or_else(|| anyhow::anyhow!("no workers finished"))?;
    std::fs::copy(&best_path, out.join("model.txt"))?;
    println!(
        "best model: {} (bound {bound:.4}) -> {}",
        best_path.display(),
        out.join("model.txt").display()
    );
    if let Some(test_path) = test_path {
        let model = StrongRule::from_text(&std::fs::read_to_string(out.join("model.txt"))?)
            .map_err(anyhow::Error::msg)?;
        let test = load_test_block(&test_path)?;
        let sc = sparrow::eval::metrics::scores(&model, &test);
        println!(
            "test exp-loss {:.4}  auprc {:.4}",
            sparrow::eval::exp_loss_scores(&sc, &test.labels),
            auprc(&sc, &test.labels)
        );
    }
    Ok(())
}
