//! Client side of the control-plane RPC: one connection, sequential
//! request/response calls (the `sparrow rpc` subcommand and the
//! integration tests are built on this).

use std::io::{self, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::admin::proto::{RpcRequest, PROTO_VERSION};
use crate::network::tcp::{frame_bytes, read_frame};
use crate::util::json::Json;

/// A blocking RPC client over one TCP connection.
pub struct RpcClient {
    stream: TcpStream,
    next_id: u64,
}

impl RpcClient {
    /// Dial an RPC endpoint, retrying briefly so bring-up order doesn't
    /// matter (same policy as the broadcast transport's `connect`).
    pub fn connect(addr: &str) -> io::Result<RpcClient> {
        let mut last_err = io::Error::new(io::ErrorKind::Other, "no attempt");
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    return Ok(RpcClient {
                        stream: s,
                        next_id: 1,
                    });
                }
                Err(e) => {
                    last_err = e;
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
        Err(last_err)
    }

    /// One call; returns the full response envelope
    /// (`{"v":…,"id":…,"result":…}` or `{"v":…,"id":…,"error":…}`).
    pub fn call(&mut self, method: &str, params: Json) -> io::Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        let req = RpcRequest {
            id,
            method: method.to_string(),
            params,
        };
        let body = req.to_json().to_string();
        self.stream.write_all(&frame_bytes(body.as_bytes()))?;
        let raw = read_frame(&mut self.stream)?.ok_or_else(|| {
            io::Error::new(io::ErrorKind::UnexpectedEof, "endpoint closed mid-call")
        })?;
        let text = std::str::from_utf8(&raw)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 response"))?;
        let v = Json::parse(text)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
        if v.get("v").and_then(Json::as_u64) != Some(PROTO_VERSION) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "response protocol version mismatch",
            ));
        }
        if v.get("id").and_then(Json::as_u64) != Some(id) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "response id does not match request",
            ));
        }
        Ok(v)
    }

    /// One call, unwrapped: the `result` object on success, a rendered
    /// `"rpc error <code>: <message>"` string on a typed error.
    pub fn call_ok(&mut self, method: &str, params: Json) -> Result<Json, String> {
        let envelope = self.call(method, params).map_err(|e| e.to_string())?;
        if let Some(err) = envelope.get("error") {
            let code = err.get("code").and_then(Json::as_f64).unwrap_or(0.0);
            let msg = err.get("message").and_then(Json::as_str).unwrap_or("?");
            return Err(format!("rpc error {code}: {msg}"));
        }
        envelope
            .get("result")
            .cloned()
            .ok_or_else(|| "response carried neither result nor error".to_string())
    }
}
