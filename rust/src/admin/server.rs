//! Generic JSON-RPC server over the TMSN TCP framing (DESIGN.md §10).
//!
//! One [`RpcServer`] serves one [`RpcHandler`] from a lightweight
//! detached acceptor thread (the same pattern as
//! [`crate::network::TcpEndpoint`]): each connection gets its own thread
//! that loops frame → [`dispatch`] → frame, so a connection can issue
//! many requests. The admin endpoint and the serve (prediction) endpoint
//! are both instances of this server with different handlers.
//!
//! [`dispatch`] is the socket-free core — bytes in, response bytes out —
//! which is what the golden-schema tests drive directly.

use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;

use crate::admin::proto::{response_err, response_ok, RpcError, RpcRequest};
use crate::network::tcp::{frame_bytes, read_frame};
use crate::util::json::Json;

/// A method dispatcher: the admin endpoint and the serve endpoint each
/// implement this once.
pub trait RpcHandler: Send + Sync + 'static {
    /// Execute `method` with `params`, returning the `result` object or a
    /// typed error. Envelope concerns (version, id, framing) are handled
    /// by the server.
    fn handle(&self, method: &str, params: &Json) -> Result<Json, RpcError>;
}

/// Turn one raw request frame into one response frame body (the JSON
/// bytes, unframed). Never fails: every malformed input becomes a typed
/// error envelope with id 0.
pub fn dispatch(handler: &dyn RpcHandler, raw: &[u8]) -> Vec<u8> {
    let reply = match std::str::from_utf8(raw)
        .map_err(|_| RpcError::parse_error("request is not UTF-8"))
        .and_then(|text| Json::parse(text).map_err(RpcError::parse_error))
    {
        Ok(v) => match RpcRequest::from_json(&v) {
            Ok(req) => match handler.handle(&req.method, &req.params) {
                Ok(result) => response_ok(req.id, result),
                Err(e) => response_err(req.id, &e),
            },
            Err(e) => {
                // best-effort id echo for malformed envelopes
                let id = v.get("id").and_then(Json::as_u64).unwrap_or(0);
                response_err(id, &e)
            }
        },
        Err(e) => response_err(0, &e),
    };
    reply.to_string().into_bytes()
}

/// A listening RPC endpoint; accepting and serving happen on detached
/// threads (dropping the server does not tear down in-flight
/// connections — workers live until process exit, like the broadcast
/// transport).
pub struct RpcServer {
    local_addr: SocketAddr,
}

impl RpcServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and serve `handler`.
    pub fn bind(addr: &str, handler: Arc<dyn RpcHandler>) -> io::Result<RpcServer> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        std::thread::Builder::new()
            .name(format!("rpc-accept-{local_addr}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { break };
                    let handler = Arc::clone(&handler);
                    std::thread::spawn(move || serve_conn(stream, handler));
                }
            })?;
        Ok(RpcServer { local_addr })
    }

    /// The bound address (useful after binding port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }
}

fn serve_conn(mut stream: TcpStream, handler: Arc<dyn RpcHandler>) {
    stream.set_nodelay(true).ok();
    loop {
        match read_frame(&mut stream) {
            Ok(Some(raw)) => {
                let reply = dispatch(handler.as_ref(), &raw);
                if stream.write_all(&frame_bytes(&reply)).is_err() {
                    return;
                }
            }
            // clean close or corrupt framing: drop the connection, never
            // the worker (same resilience stance as the broadcast path)
            Ok(None) | Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admin::client::RpcClient;

    /// Echoes params for "echo", errors for "boom", rejects the rest.
    struct EchoHandler;

    impl RpcHandler for EchoHandler {
        fn handle(&self, method: &str, params: &Json) -> Result<Json, RpcError> {
            match method {
                "echo" => Ok(params.clone()),
                "boom" => Err(RpcError::internal("kaboom")),
                other => Err(RpcError::method_not_found(other)),
            }
        }
    }

    #[test]
    fn dispatch_success_envelope() {
        let out = dispatch(&EchoHandler, br#"{"v":1,"id":3,"method":"echo","params":[1,2]}"#);
        assert_eq!(
            String::from_utf8(out).unwrap(),
            r#"{"id":3,"result":[1,2],"v":1}"#
        );
    }

    #[test]
    fn dispatch_typed_errors() {
        // handler error
        let out = dispatch(&EchoHandler, br#"{"v":1,"id":4,"method":"boom"}"#);
        let v = Json::parse(std::str::from_utf8(&out).unwrap()).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_f64),
            Some(-32603.0)
        );
        // unknown method
        let out = dispatch(&EchoHandler, br#"{"v":1,"id":4,"method":"nope"}"#);
        assert!(String::from_utf8(out).unwrap().contains("-32601"));
        // non-JSON
        let out = dispatch(&EchoHandler, b"not json at all");
        assert!(String::from_utf8(out).unwrap().contains("-32700"));
        // non-UTF8
        let out = dispatch(&EchoHandler, &[0xFF, 0xFE]);
        assert!(String::from_utf8(out).unwrap().contains("-32700"));
        // bad envelope still echoes the id it could salvage
        let out = dispatch(&EchoHandler, br#"{"v":1,"id":9}"#);
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains(r#""id":9"#) && s.contains("-32600"), "{s}");
        // version mismatch
        let out = dispatch(&EchoHandler, br#"{"v":9,"id":1,"method":"echo"}"#);
        assert!(String::from_utf8(out).unwrap().contains("-32002"));
    }

    #[test]
    fn server_round_trips_over_tcp() {
        let server = RpcServer::bind("127.0.0.1:0", Arc::new(EchoHandler)).unwrap();
        let mut client = RpcClient::connect(&server.local_addr().to_string()).unwrap();
        // several calls down one connection
        for i in 0..3 {
            let mut params = Json::obj();
            params.set("n", i as f64);
            let result = client.call_ok("echo", params).unwrap();
            assert_eq!(result.get("n").and_then(Json::as_u64), Some(i));
        }
        // typed error surfaces client-side
        let err = client.call_ok("nope", Json::Null).unwrap_err();
        assert!(err.contains("-32601"), "{err}");
        // the connection survives an error reply
        assert!(client.call_ok("echo", Json::Bool(true)).is_ok());
    }

    #[test]
    fn two_clients_served_concurrently() {
        let server = RpcServer::bind("127.0.0.1:0", Arc::new(EchoHandler)).unwrap();
        let addr = server.local_addr().to_string();
        let handles: Vec<_> = (0..2)
            .map(|t| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = RpcClient::connect(&addr).unwrap();
                    for i in 0..10u64 {
                        let got = c.call_ok("echo", Json::Num((t * 100 + i) as f64)).unwrap();
                        assert_eq!(got.as_u64(), Some(t * 100 + i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
