//! The admin control plane: a versioned JSON-RPC endpoint on every worker
//! (DESIGN.md §10, OPERATIONS.md for the operator's manual).
//!
//! The paper's pitch — no head node, resilient, asynchronous — only
//! matters in production if an operator can *see* and *steer* a live
//! swarm. This module adds exactly that, without touching the training
//! hot path: the worker publishes gauges into a shared [`ControlState`]
//! and drains a nudge queue at its loop head; a lightweight
//! [`RpcServer`] thread answers operator requests from that shared state.
//!
//! - [`proto`] — the wire envelope, typed error codes, and the canonical
//!   method lists (`ADMIN_METHODS`, `SERVE_METHODS`).
//! - [`server`] — the framing/dispatch loop, generic over [`RpcHandler`]
//!   (the serve endpoint reuses it).
//! - [`client`] — the blocking client behind `sparrow rpc`.
//! - [`state`] — gauges, live counters, nudges, fault switches.
//! - [`AdminHandler`] — the worker admin methods themselves.
//!
//! # Example
//!
//! ```
//! use std::sync::atomic::AtomicBool;
//! use std::sync::Arc;
//! use sparrow::admin::{AdminHandler, ControlState, RpcClient, RpcServer};
//! use sparrow::util::json::Json;
//!
//! let state = Arc::new(ControlState::new());
//! let stop = Arc::new(AtomicBool::new(false));
//! let handler = Arc::new(AdminHandler::new(0, Arc::clone(&state), Arc::clone(&stop)));
//! let server = RpcServer::bind("127.0.0.1:0", handler).unwrap();
//! let mut client = RpcClient::connect(&server.local_addr().to_string()).unwrap();
//! let pong = client.call_ok("ping", Json::Null).unwrap();
//! assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
//! ```

#![warn(missing_docs)]

pub mod client;
pub mod proto;
pub mod server;
pub mod state;

pub use client::RpcClient;
pub use proto::{RpcError, RpcRequest, ADMIN_METHODS, PROTO_VERSION, SERVE_METHODS};
pub use server::{dispatch, RpcHandler, RpcServer};
pub use state::{ChaosCtl, ControlState, Nudge, PeerSource};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::network::chaos::ChaosFault;
use crate::util::json::Json;

/// The worker admin endpoint: serves every method in
/// [`proto::ADMIN_METHODS`] from the shared [`ControlState`] plus the
/// worker's stop flag.
pub struct AdminHandler {
    worker: usize,
    state: Arc<ControlState>,
    stop: Arc<AtomicBool>,
}

impl AdminHandler {
    /// An admin endpoint for worker `worker` steering `state`;
    /// `shutdown` sets `stop`, which the worker's liveness check honors.
    pub fn new(worker: usize, state: Arc<ControlState>, stop: Arc<AtomicBool>) -> AdminHandler {
        AdminHandler {
            worker,
            state,
            stop,
        }
    }

    fn set_gamma(&self, params: &Json) -> Result<Json, RpcError> {
        let gamma = params
            .get("gamma")
            .and_then(Json::as_f64)
            .ok_or_else(|| RpcError::invalid_params("expected {\"gamma\": number}"))?;
        if !(gamma > 0.0 && gamma < 0.5) {
            return Err(RpcError::invalid_params(format!(
                "gamma must be in (0, 0.5), got {gamma}"
            )));
        }
        self.state.push_nudge(Nudge::SetGamma(gamma));
        let mut o = Json::obj();
        o.set("ok", true).set("gamma", gamma);
        Ok(o)
    }

    fn set_sweep(&self, params: &Json) -> Result<Json, RpcError> {
        let every = params
            .get("every")
            .and_then(Json::as_u64)
            .ok_or_else(|| RpcError::invalid_params("expected {\"every\": integer >= 0}"))?;
        self.state.push_nudge(Nudge::SetSweep(every as usize));
        let mut o = Json::obj();
        o.set("ok", true).set("every", every as f64);
        Ok(o)
    }

    fn fault_inject(&self, params: &Json) -> Result<Json, RpcError> {
        let fault = params
            .get("fault")
            .and_then(Json::as_str)
            .ok_or_else(|| RpcError::invalid_params("expected {\"fault\": string}"))?;
        let mut o = Json::obj();
        o.set("ok", true).set("fault", fault);
        match fault {
            "crash" => {
                self.state.request_crash();
                Ok(o)
            }
            "laggard" => {
                let factor = params
                    .get("factor")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| RpcError::invalid_params("laggard needs {\"factor\": number}"))?;
                if !(factor >= 1.0 && factor.is_finite()) {
                    return Err(RpcError::invalid_params(format!(
                        "factor must be >= 1, got {factor}"
                    )));
                }
                self.state.set_laggard(factor);
                o.set("factor", factor);
                Ok(o)
            }
            "heal" => {
                // heal is total: compute slowdown back to 1.0 AND every
                // chaos fault cleared (when a fabric is attached)
                self.state.set_laggard(1.0);
                if let Some(ctl) = self.state.chaos() {
                    ctl.rules.clear_all();
                }
                Ok(o)
            }
            "partition" => {
                let ctl = self.state.chaos().ok_or_else(|| {
                    RpcError::unsupported(
                        "fault \"partition\" needs a chaos fabric attached to this worker \
                         (front its links with chaos proxies, or use `sparrow sim`)",
                    )
                })?;
                // blackhole every registered edge; optional {"ms": N}
                // auto-heals after N milliseconds
                let ms = params.get("ms").and_then(Json::as_u64);
                for edge in &ctl.edges {
                    match ms {
                        Some(ms) => ctl.rules.set_for(
                            edge,
                            ChaosFault::Blackhole,
                            Duration::from_millis(ms),
                        ),
                        None => ctl.rules.set(edge, ChaosFault::Blackhole),
                    }
                }
                o.set("edges", ctl.edges.len() as u64);
                if let Some(ms) = ms {
                    o.set("ms", ms);
                }
                Ok(o)
            }
            "restart" => {
                // in-place rebirth at the worker's next loop head: the
                // live analogue of the simulator's crash+rejoin
                self.state.request_restart();
                Ok(o)
            }
            other => Err(RpcError::invalid_params(format!(
                "unknown fault \"{other}\" (crash, laggard, heal, partition, restart)"
            ))),
        }
    }
}

impl RpcHandler for AdminHandler {
    fn handle(&self, method: &str, params: &Json) -> Result<Json, RpcError> {
        match method {
            "ping" => {
                let mut o = Json::obj();
                o.set("pong", true)
                    .set("proto", PROTO_VERSION as f64)
                    .set("worker", self.worker as f64);
                Ok(o)
            }
            "metrics.snapshot" => Ok(self.state.snapshot_json()),
            "model.current" => Ok(self.state.model_json()),
            "peers.list" => Ok(self.state.peers_json()),
            "config.set_gamma" => self.set_gamma(params),
            "config.gamma_reset" => {
                self.state.push_nudge(Nudge::GammaReset);
                let mut o = Json::obj();
                o.set("ok", true);
                Ok(o)
            }
            "config.set_sweep" => self.set_sweep(params),
            "fault.inject" => self.fault_inject(params),
            "shutdown" => {
                self.stop.store(true, Ordering::Relaxed);
                let mut o = Json::obj();
                o.set("ok", true).set("stopping", true);
                Ok(o)
            }
            other => Err(RpcError::method_not_found(other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn handler() -> (AdminHandler, Arc<ControlState>, Arc<AtomicBool>) {
        let state = Arc::new(ControlState::new());
        let stop = Arc::new(AtomicBool::new(false));
        (
            AdminHandler::new(3, Arc::clone(&state), Arc::clone(&stop)),
            state,
            stop,
        )
    }

    fn params(text: &str) -> Json {
        Json::parse(text).unwrap()
    }

    #[test]
    fn every_listed_method_is_handled() {
        // ADMIN_METHODS is the contract: each entry must dispatch to a
        // real handler arm (not MethodNotFound), with minimal params
        let (h, _, _) = handler();
        for m in ADMIN_METHODS {
            let p = match *m {
                "config.set_gamma" => params(r#"{"gamma":0.1}"#),
                "config.set_sweep" => params(r#"{"every":2}"#),
                "fault.inject" => params(r#"{"fault":"heal"}"#),
                _ => Json::Null,
            };
            match h.handle(m, &p) {
                Ok(_) => {}
                Err(e) => panic!("{m}: {e:?}"),
            }
        }
        // and an unknown method is typed -32601
        assert_eq!(h.handle("nope", &Json::Null).unwrap_err().code, -32601);
    }

    #[test]
    fn nudge_methods_queue_nudges() {
        let (h, state, _) = handler();
        h.handle("config.set_gamma", &params(r#"{"gamma":0.2}"#)).unwrap();
        h.handle("config.gamma_reset", &Json::Null).unwrap();
        h.handle("config.set_sweep", &params(r#"{"every":5}"#)).unwrap();
        assert_eq!(
            state.drain_nudges(),
            vec![Nudge::SetGamma(0.2), Nudge::GammaReset, Nudge::SetSweep(5)]
        );
    }

    #[test]
    fn gamma_bounds_enforced() {
        let (h, state, _) = handler();
        for bad in [r#"{"gamma":0}"#, r#"{"gamma":0.5}"#, r#"{"gamma":-1}"#, r#"{}"#] {
            let err = h.handle("config.set_gamma", &params(bad)).unwrap_err();
            assert_eq!(err.code, -32602, "{bad}");
        }
        assert!(state.drain_nudges().is_empty(), "bad params queued a nudge");
    }

    #[test]
    fn fault_vocabulary() {
        let (h, state, _) = handler();
        h.handle("fault.inject", &params(r#"{"fault":"laggard","factor":4}"#)).unwrap();
        assert_eq!(state.laggard(), 4.0);
        h.handle("fault.inject", &params(r#"{"fault":"heal"}"#)).unwrap();
        assert_eq!(state.laggard(), 1.0);
        h.handle("fault.inject", &params(r#"{"fault":"crash"}"#)).unwrap();
        assert!(state.crash_requested());
        // partition with no chaos fabric attached is typed Unsupported,
        // not InvalidParams — the vocabulary is known, the capability is
        // missing
        let err = h
            .handle("fault.inject", &params(r#"{"fault":"partition"}"#))
            .unwrap_err();
        assert_eq!(err.code, -32001);
        // restart needs no fabric: it's an in-process rebirth
        h.handle("fault.inject", &params(r#"{"fault":"restart"}"#)).unwrap();
        assert!(state.take_restart());
        let err = h
            .handle("fault.inject", &params(r#"{"fault":"gremlins"}"#))
            .unwrap_err();
        assert_eq!(err.code, -32602);
        // laggard without factor / bad factor rejected
        for bad in [r#"{"fault":"laggard"}"#, r#"{"fault":"laggard","factor":0.5}"#] {
            assert_eq!(h.handle("fault.inject", &params(bad)).unwrap_err().code, -32602);
        }
    }

    #[test]
    fn partition_blackholes_edges_and_heal_clears() {
        use crate::network::chaos::ChaosRules;
        let (h, state, _) = handler();
        state.set_chaos(ChaosCtl {
            rules: ChaosRules::new(11),
            edges: vec!["w0->w1".into(), "w1->w0".into()],
        });
        let r = h
            .handle("fault.inject", &params(r#"{"fault":"partition"}"#))
            .unwrap();
        assert_eq!(r.get("edges").and_then(Json::as_u64), Some(2));
        let rules = &state.chaos().unwrap().rules;
        assert!(matches!(rules.active("w0->w1"), Some(ChaosFault::Blackhole)));
        assert!(matches!(rules.active("w1->w0"), Some(ChaosFault::Blackhole)));
        // heal clears every chaos fault along with the laggard factor
        state.set_laggard(2.0);
        h.handle("fault.inject", &params(r#"{"fault":"heal"}"#)).unwrap();
        assert!(rules.active("w0->w1").is_none());
        assert_eq!(state.laggard(), 1.0);
        // timed partition carries its duration in the reply
        let r = h
            .handle("fault.inject", &params(r#"{"fault":"partition","ms":50}"#))
            .unwrap();
        assert_eq!(r.get("ms").and_then(Json::as_u64), Some(50));
        assert!(matches!(rules.active("w0->w1"), Some(ChaosFault::Blackhole)));
        std::thread::sleep(Duration::from_millis(80));
        assert!(rules.active("w0->w1").is_none(), "timed fault never healed");
    }

    #[test]
    fn peers_list_serves_the_live_table() {
        use crate::network::tcp::PeerInfo;
        let (h, state, _) = handler();
        // no source attached: valid, empty
        let r = h.handle("peers.list", &Json::Null).unwrap();
        assert_eq!(r.get("total").and_then(Json::as_u64), Some(0));
        state.set_peer_source(Arc::new(|| {
            vec![PeerInfo {
                addr: "127.0.0.1:9000".into(),
                up: true,
                queue_len: 2,
                last_seen_ms: 40,
                reconnects: 0,
                drops: 0,
            }]
        }));
        let r = h.handle("peers.list", &Json::Null).unwrap();
        assert_eq!(r.get("up").and_then(Json::as_u64), Some(1));
        let rows = r.get("peers").and_then(Json::as_arr).unwrap();
        assert_eq!(
            rows[0].get("addr").and_then(Json::as_str),
            Some("127.0.0.1:9000")
        );
    }

    #[test]
    fn shutdown_sets_stop_flag() {
        let (h, _, stop) = handler();
        assert!(!stop.load(Ordering::Relaxed));
        let r = h.handle("shutdown", &Json::Null).unwrap();
        assert_eq!(r.get("stopping").and_then(Json::as_bool), Some(true));
        assert!(stop.load(Ordering::Relaxed));
    }
}
