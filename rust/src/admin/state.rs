//! Shared control-plane state: the gauges a worker publishes and the
//! nudge queue an operator writes (DESIGN.md §10).
//!
//! One [`ControlState`] sits between a worker thread and its admin RPC
//! thread. The worker is the only writer of the gauges (model version,
//! scan progress, sampler stalls) and the only consumer of the nudge
//! queue; the admin thread reads gauges and counters for
//! `metrics.snapshot` and pushes [`Nudge`]s for the config methods. All
//! gauges are atomics — a snapshot never blocks the training loop.
//!
//! Event *counters* live in [`LiveCounters`] and are fed by the worker's
//! [`crate::metrics::EventLog`] (bump-after-send), so a snapshot's counts
//! are always ≤ what a later drain of the event log shows — the
//! consistency contract the control-plane storm test pins down.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::{EventKind, LiveCounters};
use crate::network::chaos::ChaosRules;
use crate::network::tcp::PeerInfo;
use crate::sim::clock::{Clock, RealClock};
use crate::util::json::Json;

/// A payload-type-free view of the worker's live peer table (from
/// [`crate::network::TcpEndpoint::peer_table_handle`]).
pub type PeerSource = Arc<dyn Fn() -> Vec<PeerInfo> + Send + Sync>;

/// The fabric's fault-injection handle: the chaos rules table shared with
/// the proxies fronting this worker, plus the directed-edge names the
/// admin plane may partition.
#[derive(Clone)]
pub struct ChaosCtl {
    /// shared fault table (every attached proxy consults it per frame)
    pub rules: Arc<ChaosRules>,
    /// edge names `fault.inject {"fault":"partition"}` applies to
    pub edges: Vec<String>,
}

/// A deferred config change, applied by the worker at its loop head.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Nudge {
    /// Override the scanner's per-invocation starting target γ₀
    /// (`config.set_gamma`).
    SetGamma(f64),
    /// Restore γ₀ to the `TrainConfig` value (`config.gamma_reset`).
    GammaReset,
    /// Override the stopping-rule sweep cadence; 0 = auto
    /// (`config.set_sweep`).
    SetSweep(usize),
}

/// Gauges + nudge queue + fault switches shared between one worker and
/// its admin endpoint.
pub struct ControlState {
    epoch: Instant,
    clock: Arc<dyn Clock>,
    /// Live per-[`EventKind`] counters; attach to the worker's log with
    /// [`crate::metrics::EventLog::with_counters`].
    pub counters: Arc<LiveCounters>,
    model_version: AtomicU64,
    model_len: AtomicU64,
    loss_bound_bits: AtomicU64,
    scanned: AtomicU64,
    stall_nanos: AtomicU64,
    nudges: Mutex<Vec<Nudge>>,
    laggard_bits: AtomicU64,
    crash_requested: AtomicBool,
    restart_requested: AtomicBool,
    peer_source: Mutex<Option<PeerSource>>,
    chaos: Mutex<Option<ChaosCtl>>,
}

impl ControlState {
    /// Fresh state on the wall clock (empty model, bound 1.0).
    pub fn new() -> ControlState {
        ControlState::with_clock(Arc::new(RealClock))
    }

    /// Fresh state whose uptime is measured on `clock` — a
    /// [`crate::sim::SimClock`] makes snapshots fully deterministic (the
    /// golden-schema fixtures rely on this).
    pub fn with_clock(clock: Arc<dyn Clock>) -> ControlState {
        ControlState {
            epoch: clock.now(),
            clock,
            counters: Arc::new(LiveCounters::new()),
            model_version: AtomicU64::new(0),
            model_len: AtomicU64::new(0),
            loss_bound_bits: AtomicU64::new(1.0f64.to_bits()),
            scanned: AtomicU64::new(0),
            stall_nanos: AtomicU64::new(0),
            nudges: Mutex::new(Vec::new()),
            laggard_bits: AtomicU64::new(1.0f64.to_bits()),
            crash_requested: AtomicBool::new(false),
            restart_requested: AtomicBool::new(false),
            peer_source: Mutex::new(None),
            chaos: Mutex::new(None),
        }
    }

    // ---- worker-side writes ------------------------------------------

    /// Publish the worker's current model gauges (on every version bump).
    pub fn note_model(&self, version: u64, len: usize, loss_bound: f64) {
        self.model_version.store(version, Ordering::Relaxed);
        self.model_len.store(len as u64, Ordering::Relaxed);
        self.loss_bound_bits
            .store(loss_bound.to_bits(), Ordering::Relaxed);
    }

    /// Publish the scanner's lifetime examples-scanned total.
    pub fn note_scanned(&self, total: u64) {
        self.scanned.store(total, Ordering::Relaxed);
    }

    /// Add time the worker spent blocked waiting for a sample (the
    /// blocking resample, or the background pipeline's initial fill /
    /// exhausted-sample park).
    pub fn add_stall(&self, d: Duration) {
        self.stall_nanos
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Drain every queued nudge, oldest first (worker loop head).
    pub fn drain_nudges(&self) -> Vec<Nudge> {
        std::mem::take(&mut *self.nudges.lock().unwrap())
    }

    // ---- admin-side writes -------------------------------------------

    /// Queue a config nudge for the worker's next loop iteration.
    pub fn push_nudge(&self, n: Nudge) {
        self.nudges.lock().unwrap().push(n);
    }

    /// Ask the worker to crash at its next liveness check
    /// (`fault.inject {"fault":"crash"}` — the live analogue of the
    /// simulator's `ScenarioEvent::Crash`).
    pub fn request_crash(&self) {
        self.crash_requested.store(true, Ordering::Relaxed);
    }

    /// Has a crash been requested?
    pub fn crash_requested(&self) -> bool {
        self.crash_requested.load(Ordering::Relaxed)
    }

    /// Ask the worker to restart in place at its next loop head
    /// (`fault.inject {"fault":"restart"}`): persist a checkpoint if
    /// configured, drop every pending remote payload, and rejoin the
    /// protocol from the current certified model via
    /// [`crate::tmsn::Driver::rebirth`].
    pub fn request_restart(&self) {
        self.restart_requested.store(true, Ordering::Relaxed);
    }

    /// Consume a pending restart request (worker loop head). Returns
    /// `true` at most once per [`ControlState::request_restart`] call.
    pub fn take_restart(&self) -> bool {
        self.restart_requested.swap(false, Ordering::Relaxed)
    }

    /// Attach the live peer table (the endpoint's
    /// [`crate::network::TcpEndpoint::peer_table_handle`]); feeds
    /// `peers.list` and the `peers` object in `metrics.snapshot`.
    pub fn set_peer_source(&self, src: PeerSource) {
        *self.peer_source.lock().unwrap() = Some(src);
    }

    /// Attach the fabric's chaos handle, enabling real-path
    /// `fault.inject {"fault":"partition"}`.
    pub fn set_chaos(&self, ctl: ChaosCtl) {
        *self.chaos.lock().unwrap() = Some(ctl);
    }

    /// The chaos handle, if one was attached.
    pub fn chaos(&self) -> Option<ChaosCtl> {
        self.chaos.lock().unwrap().clone()
    }

    /// Set the live compute-slowdown factor (≥ 1; 1.0 heals). Applied at
    /// pass granularity: after each scan pass the worker idles
    /// `(factor − 1) ×` the pass's elapsed time.
    pub fn set_laggard(&self, factor: f64) {
        self.laggard_bits.store(factor.to_bits(), Ordering::Relaxed);
    }

    /// Current compute-slowdown factor.
    pub fn laggard(&self) -> f64 {
        f64::from_bits(self.laggard_bits.load(Ordering::Relaxed))
    }

    // ---- reads -------------------------------------------------------

    /// `(version, len, loss_bound)` of the worker's current model.
    pub fn model(&self) -> (u64, u64, f64) {
        (
            self.model_version.load(Ordering::Relaxed),
            self.model_len.load(Ordering::Relaxed),
            f64::from_bits(self.loss_bound_bits.load(Ordering::Relaxed)),
        )
    }

    /// The `model.current` RPC result object.
    pub fn model_json(&self) -> Json {
        let (version, len, bound) = self.model();
        let mut o = Json::obj();
        o.set("version", version as f64)
            .set("len", len as f64)
            .set("loss_bound", bound);
        o
    }

    /// The current peer table, or empty when no source is attached.
    pub fn peers(&self) -> Vec<PeerInfo> {
        match &*self.peer_source.lock().unwrap() {
            Some(src) => src(),
            None => Vec::new(),
        }
    }

    /// The `peers.list` RPC result object: one row per known peer
    /// (up/down, send-queue depth, last-heartbeat age, reconnect and
    /// queue-drop totals), plus up/total summary counts.
    pub fn peers_json(&self) -> Json {
        let peers = self.peers();
        let up = peers.iter().filter(|p| p.up).count();
        let rows: Vec<Json> = peers
            .iter()
            .map(|p| {
                let mut row = Json::obj();
                row.set("addr", p.addr.as_str())
                    .set("up", p.up)
                    .set("queue", p.queue_len as u64)
                    .set("last_seen_ms", p.last_seen_ms)
                    .set("reconnects", p.reconnects)
                    .set("drops", p.drops);
                row
            })
            .collect();
        let mut o = Json::obj();
        o.set("peers", rows).set("total", peers.len() as u64).set("up", up as u64);
        o
    }

    /// The `metrics.snapshot` RPC result object: uptime, model gauges,
    /// scan throughput, sampler stalls/aborts, and one counter per event
    /// kind. Keys are stable (BTreeMap ordering) — the wire format is
    /// pinned by the golden-schema tests.
    pub fn snapshot_json(&self) -> Json {
        let uptime = self.clock.now().saturating_duration_since(self.epoch);
        let scanned = self.scanned.load(Ordering::Relaxed);
        let scan_per_s = if uptime.as_secs_f64() > 0.0 {
            scanned as f64 / uptime.as_secs_f64()
        } else {
            0.0
        };
        let mut events = Json::obj();
        for (name, count) in self.counters.snapshot() {
            events.set(name, count as f64);
        }
        let mut sampler = Json::obj();
        sampler
            .set(
                "stall_ms",
                self.stall_nanos.load(Ordering::Relaxed) as f64 / 1e6,
            )
            .set(
                "build_aborts",
                self.counters.get(EventKind::BuildAbort) as f64,
            )
            .set("swaps", self.counters.get(EventKind::SampleSwap) as f64);
        let peer_rows = self.peers();
        let up = peer_rows.iter().filter(|p| p.up).count();
        let mut peers = Json::obj();
        peers
            .set("up", up as u64)
            .set("down", (peer_rows.len() - up) as u64);
        let mut o = Json::obj();
        o.set("uptime_s", uptime.as_secs_f64())
            .set("model", self.model_json())
            .set("scanned", scanned as f64)
            .set("scan_per_s", scan_per_s)
            .set("sampler", sampler)
            .set("laggard", self.laggard())
            .set("peers", peers)
            .set("events", events);
        o
    }
}

impl Default for ControlState {
    fn default() -> Self {
        ControlState::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimClock;

    #[test]
    fn gauges_roundtrip() {
        let s = ControlState::new();
        assert_eq!(s.model(), (0, 0, 1.0));
        s.note_model(3, 7, 0.25);
        assert_eq!(s.model(), (3, 7, 0.25));
        s.note_scanned(1000);
        let snap = s.snapshot_json();
        assert_eq!(snap.get("scanned").and_then(Json::as_u64), Some(1000));
        assert_eq!(
            snap.get("model").and_then(|m| m.get("version")).and_then(Json::as_u64),
            Some(3)
        );
    }

    #[test]
    fn nudges_fifo_and_drain_empties() {
        let s = ControlState::new();
        s.push_nudge(Nudge::SetGamma(0.1));
        s.push_nudge(Nudge::GammaReset);
        s.push_nudge(Nudge::SetSweep(4));
        assert_eq!(
            s.drain_nudges(),
            vec![Nudge::SetGamma(0.1), Nudge::GammaReset, Nudge::SetSweep(4)]
        );
        assert!(s.drain_nudges().is_empty());
    }

    #[test]
    fn fault_switches() {
        let s = ControlState::new();
        assert!(!s.crash_requested());
        assert_eq!(s.laggard(), 1.0);
        s.set_laggard(3.5);
        assert_eq!(s.laggard(), 3.5);
        s.set_laggard(1.0); // heal
        assert_eq!(s.laggard(), 1.0);
        s.request_crash();
        assert!(s.crash_requested());
        // restart is one-shot
        assert!(!s.take_restart());
        s.request_restart();
        assert!(s.take_restart());
        assert!(!s.take_restart());
    }

    fn fake_peers() -> Vec<PeerInfo> {
        vec![
            PeerInfo {
                addr: "127.0.0.1:7701".into(),
                up: true,
                queue_len: 3,
                last_seen_ms: 150,
                reconnects: 1,
                drops: 0,
            },
            PeerInfo {
                addr: "127.0.0.1:7702".into(),
                up: false,
                queue_len: 17,
                last_seen_ms: 4200,
                reconnects: 6,
                drops: 12,
            },
        ]
    }

    #[test]
    fn peer_source_feeds_list_and_snapshot() {
        let s = ControlState::new();
        // without a source: empty list, zero summary
        let empty = s.peers_json();
        assert_eq!(empty.get("total").and_then(Json::as_u64), Some(0));
        let snap = s.snapshot_json();
        let p = snap.get("peers").unwrap();
        assert_eq!(p.get("up").and_then(Json::as_u64), Some(0));
        assert_eq!(p.get("down").and_then(Json::as_u64), Some(0));

        s.set_peer_source(Arc::new(fake_peers));
        let list = s.peers_json();
        assert_eq!(list.get("total").and_then(Json::as_u64), Some(2));
        assert_eq!(list.get("up").and_then(Json::as_u64), Some(1));
        let rows = list.get("peers").and_then(Json::as_arr).unwrap();
        let first = &rows[0];
        assert_eq!(
            first.get("addr").and_then(Json::as_str),
            Some("127.0.0.1:7701")
        );
        assert_eq!(first.get("queue").and_then(Json::as_u64), Some(3));
        let second = &rows[1];
        assert_eq!(second.get("reconnects").and_then(Json::as_u64), Some(6));
        assert_eq!(second.get("drops").and_then(Json::as_u64), Some(12));

        let snap = s.snapshot_json();
        let p = snap.get("peers").unwrap();
        assert_eq!(p.get("up").and_then(Json::as_u64), Some(1));
        assert_eq!(p.get("down").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn chaos_handle_attaches() {
        let s = ControlState::new();
        assert!(s.chaos().is_none());
        s.set_chaos(ChaosCtl {
            rules: ChaosRules::new(7),
            edges: vec!["a->b".into(), "b->a".into()],
        });
        let ctl = s.chaos().unwrap();
        assert_eq!(ctl.edges.len(), 2);
        assert!(ctl.rules.active("a->b").is_none());
    }

    #[test]
    fn snapshot_counts_every_event_kind() {
        let s = ControlState::new();
        let snap = s.snapshot_json();
        let events = snap.get("events").unwrap();
        for k in EventKind::ALL {
            assert_eq!(
                events.get(k.as_str()).and_then(Json::as_u64),
                Some(0),
                "missing {}",
                k.as_str()
            );
        }
    }

    #[test]
    fn virtual_clock_snapshot_is_deterministic() {
        let clock = Arc::new(SimClock::new());
        let s = ControlState::with_clock(clock.clone());
        s.note_scanned(500);
        clock.advance(Duration::from_secs(2));
        let snap = s.snapshot_json();
        assert_eq!(snap.get("uptime_s").and_then(Json::as_f64), Some(2.0));
        assert_eq!(snap.get("scan_per_s").and_then(Json::as_f64), Some(250.0));
        // zero uptime divides safely
        let s2 = ControlState::with_clock(Arc::new(SimClock::new()));
        assert_eq!(
            s2.snapshot_json().get("scan_per_s").and_then(Json::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn stall_accumulates() {
        let s = ControlState::new();
        s.add_stall(Duration::from_millis(3));
        s.add_stall(Duration::from_millis(4));
        let snap = s.snapshot_json();
        let ms = snap
            .get("sampler")
            .and_then(|x| x.get("stall_ms"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((ms - 7.0).abs() < 1e-9, "{ms}");
    }
}
