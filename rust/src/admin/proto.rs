//! Wire protocol for the control plane: a versioned JSON-RPC envelope with
//! typed error codes (DESIGN.md §10).
//!
//! Every request and response is one JSON object inside one TCP frame
//! (the same magic + length framing the broadcast transport uses, see
//! [`crate::network::tcp`]). Requests carry a protocol version so a v2
//! operator tool talking to a v1 worker fails loudly with
//! [`RpcError::version_mismatch`] instead of mis-parsing.
//!
//! Request:  `{"v":1,"id":7,"method":"metrics.snapshot","params":{...}}`
//! Response: `{"v":1,"id":7,"result":{...}}`
//!       or  `{"v":1,"id":7,"error":{"code":-32601,"message":"..."}}`
//!
//! The golden-schema tests under `rust/tests/golden/admin_rpc/` pin this
//! format byte-for-byte; OPERATIONS.md documents every method.

use crate::util::json::Json;

/// Control-plane protocol version carried in every envelope.
pub const PROTO_VERSION: u64 = 1;

/// Every method the admin endpoint serves, in OPERATIONS.md order. The
/// doc-coverage check (`scripts/check_ops_doc.sh`) diffs the manual
/// against this list, so adding a method without documenting it fails CI.
pub const ADMIN_METHODS: &[&str] = &[
    "ping",
    "metrics.snapshot",
    "model.current",
    "peers.list",
    "config.set_gamma",
    "config.gamma_reset",
    "config.set_sweep",
    "fault.inject",
    "shutdown",
];

/// Every method the serve (prediction) endpoint serves.
pub const SERVE_METHODS: &[&str] = &["ping", "predict", "serve.stats", "model.current"];

/// Typed RPC failure: a JSON-RPC-style numeric code plus a human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RpcError {
    /// Numeric error code (see the constructors for the vocabulary).
    pub code: i64,
    /// Human-readable explanation.
    pub message: String,
}

impl RpcError {
    /// `-32700` — request frame was not valid JSON.
    pub fn parse_error(detail: impl Into<String>) -> RpcError {
        RpcError {
            code: -32700,
            message: format!("parse error: {}", detail.into()),
        }
    }

    /// `-32600` — JSON was valid but not a well-formed request envelope.
    pub fn invalid_request(detail: impl Into<String>) -> RpcError {
        RpcError {
            code: -32600,
            message: format!("invalid request: {}", detail.into()),
        }
    }

    /// `-32601` — the method is not one this endpoint serves.
    pub fn method_not_found(method: &str) -> RpcError {
        RpcError {
            code: -32601,
            message: format!("method not found: {method}"),
        }
    }

    /// `-32602` — the method exists but `params` is missing/ill-typed.
    pub fn invalid_params(detail: impl Into<String>) -> RpcError {
        RpcError {
            code: -32602,
            message: format!("invalid params: {}", detail.into()),
        }
    }

    /// `-32603` — the handler failed internally.
    pub fn internal(detail: impl Into<String>) -> RpcError {
        RpcError {
            code: -32603,
            message: format!("internal error: {}", detail.into()),
        }
    }

    /// `-32001` — the request is understood but this endpoint cannot do it
    /// (e.g. `fault.inject` with a sim-only fault on a live worker).
    pub fn unsupported(detail: impl Into<String>) -> RpcError {
        RpcError {
            code: -32001,
            message: format!("unsupported: {}", detail.into()),
        }
    }

    /// `-32002` — the envelope's `v` is not [`PROTO_VERSION`].
    pub fn version_mismatch(got: &Json) -> RpcError {
        RpcError {
            code: -32002,
            message: format!(
                "version mismatch: endpoint speaks v{PROTO_VERSION}, request carried {}",
                got.to_string()
            ),
        }
    }
}

/// A parsed request envelope.
#[derive(Debug, Clone)]
pub struct RpcRequest {
    /// Caller-chosen correlation id, echoed in the response.
    pub id: u64,
    /// Method name, e.g. `"metrics.snapshot"`.
    pub method: String,
    /// Method parameters (`Json::Null` when omitted).
    pub params: Json,
}

impl RpcRequest {
    /// Validate a decoded JSON value as a v-[`PROTO_VERSION`] envelope.
    pub fn from_json(v: &Json) -> Result<RpcRequest, RpcError> {
        if !matches!(v, Json::Obj(_)) {
            return Err(RpcError::invalid_request("not a JSON object"));
        }
        let ver = v.get("v").ok_or_else(|| {
            RpcError::invalid_request("missing protocol version field \"v\"")
        })?;
        if ver.as_u64() != Some(PROTO_VERSION) {
            return Err(RpcError::version_mismatch(ver));
        }
        let id = v
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| RpcError::invalid_request("missing or non-integer \"id\""))?;
        let method = v
            .get("method")
            .and_then(Json::as_str)
            .ok_or_else(|| RpcError::invalid_request("missing or non-string \"method\""))?
            .to_string();
        let params = v.get("params").cloned().unwrap_or(Json::Null);
        Ok(RpcRequest { id, method, params })
    }

    /// Build a request envelope (client side).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("v", PROTO_VERSION as f64)
            .set("id", self.id as f64)
            .set("method", self.method.as_str());
        if !self.params.is_null() {
            o.set("params", self.params.clone());
        }
        o
    }
}

/// A success response envelope: `{"v":1,"id":id,"result":result}`.
pub fn response_ok(id: u64, result: Json) -> Json {
    let mut o = Json::obj();
    o.set("v", PROTO_VERSION as f64)
        .set("id", id as f64)
        .set("result", result);
    o
}

/// An error response envelope:
/// `{"v":1,"id":id,"error":{"code":…,"message":…}}`.
pub fn response_err(id: u64, err: &RpcError) -> Json {
    let mut e = Json::obj();
    e.set("code", err.code as f64)
        .set("message", err.message.as_str());
    let mut o = Json::obj();
    o.set("v", PROTO_VERSION as f64)
        .set("id", id as f64)
        .set("error", e);
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let mut params = Json::obj();
        params.set("gamma", 0.1);
        let req = RpcRequest {
            id: 9,
            method: "config.set_gamma".into(),
            params,
        };
        let wire = req.to_json().to_string();
        let back = RpcRequest::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.id, 9);
        assert_eq!(back.method, "config.set_gamma");
        assert_eq!(back.params.get("gamma").and_then(Json::as_f64), Some(0.1));
    }

    #[test]
    fn missing_version_rejected() {
        let v = Json::parse(r#"{"id":1,"method":"ping"}"#).unwrap();
        let err = RpcRequest::from_json(&v).unwrap_err();
        assert_eq!(err.code, -32600);
    }

    #[test]
    fn wrong_version_is_version_mismatch() {
        let v = Json::parse(r#"{"v":2,"id":1,"method":"ping"}"#).unwrap();
        let err = RpcRequest::from_json(&v).unwrap_err();
        assert_eq!(err.code, -32002);
        assert!(err.message.contains("v1"), "{}", err.message);
    }

    #[test]
    fn missing_id_or_method_rejected() {
        for bad in [
            r#"{"v":1,"method":"ping"}"#,
            r#"{"v":1,"id":1}"#,
            r#"{"v":1,"id":"x","method":"ping"}"#,
            r#"{"v":1,"id":1,"method":7}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            let err = RpcRequest::from_json(&v).unwrap_err();
            assert_eq!(err.code, -32600, "{bad}");
        }
    }

    #[test]
    fn params_default_to_null() {
        let v = Json::parse(r#"{"v":1,"id":1,"method":"ping"}"#).unwrap();
        let req = RpcRequest::from_json(&v).unwrap();
        assert!(req.params.is_null());
    }

    #[test]
    fn response_envelopes_echo_id() {
        let ok = response_ok(5, Json::Bool(true)).to_string();
        assert_eq!(ok, r#"{"id":5,"result":true,"v":1}"#);
        let err = response_err(6, &RpcError::method_not_found("nope")).to_string();
        assert!(err.contains(r#""id":6"#), "{err}");
        assert!(err.contains(r#""code":-32601"#), "{err}");
    }

    #[test]
    fn error_codes_distinct() {
        let codes = [
            RpcError::parse_error("x").code,
            RpcError::invalid_request("x").code,
            RpcError::method_not_found("x").code,
            RpcError::invalid_params("x").code,
            RpcError::internal("x").code,
            RpcError::unsupported("x").code,
            RpcError::version_mismatch(&Json::Num(2.0)).code,
        ];
        let mut sorted = codes.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), codes.len());
    }

    #[test]
    fn method_lists_sane() {
        assert!(ADMIN_METHODS.contains(&"metrics.snapshot"));
        assert!(SERVE_METHODS.contains(&"predict"));
        for list in [ADMIN_METHODS, SERVE_METHODS] {
            let mut names = list.to_vec();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), list.len(), "duplicate method name");
        }
    }
}
