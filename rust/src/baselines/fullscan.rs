//! Full-scan approximate-greedy boosting — the "XGBoost" configuration of
//! Table 1 (exponential loss, depth-1 trees, quantile candidate grid,
//! every iteration scans every example).

use std::time::Instant;

use crate::baselines::{DataSource, StopConditions, TimedEvaluator};
use crate::boosting::{
    alpha::{alpha_for_correlation, clamp_correlation},
    edges::accumulate_edges,
    CandidateGrid, EdgeMatrix,
};
use crate::data::DataBlock;
use crate::eval::MetricSeries;
use crate::model::{StrongRule, Stump};

/// Configuration of the full-scan booster.
#[derive(Debug, Clone)]
pub struct FullScanConfig {
    pub nthr: usize,
    pub stop: StopConditions,
    /// clamp on the per-iteration normalized correlation (keeps alphas
    /// finite on separable data, mirroring XGBoost's eta/regularization)
    pub max_corr: f64,
    /// chunk size for passes
    pub chunk: usize,
}

impl Default for FullScanConfig {
    fn default() -> Self {
        FullScanConfig {
            nthr: 4,
            stop: StopConditions::default(),
            max_corr: 0.8,
            chunk: 4096,
        }
    }
}

/// Train result shared by the baseline trainers.
#[derive(Debug)]
pub struct BaselineOutcome {
    pub model: StrongRule,
    pub series: MetricSeries,
    pub iterations: usize,
}

/// Run the full-scan booster.
///
/// Scores are cached per example across iterations (incremental update —
/// both XGBoost and LightGBM do this; §4.1 notes Sparrow must work harder
/// for the same effect because it scans fractions).
pub fn train_fullscan(
    source: &DataSource,
    test: &DataBlock,
    cfg: &FullScanConfig,
    label: &str,
) -> std::io::Result<BaselineOutcome> {
    let n = source.len();
    let f = source.num_features();
    assert!(n > 0, "empty training set");
    let pilot = source.pilot(4096.min(n))?;
    let grid = CandidateGrid::from_quantiles(&pilot, cfg.nthr);

    let mut model = StrongRule::new();
    let mut scores = vec![0f32; n];
    let mut evaluator = TimedEvaluator::new(test, cfg.stop.eval_interval, label);
    let t0 = Instant::now();
    evaluator.force_eval(&model);

    let mut iterations = 0usize;
    while iterations < cfg.stop.max_rules && t0.elapsed() < cfg.stop.time_limit {
        // one full pass: weights from cached scores, accumulate edges
        let mut accum = EdgeMatrix::zeros(f, cfg.nthr);
        let mut w_chunk: Vec<f32> = Vec::new();
        source.for_each_block(cfg.chunk, |block, off| {
            w_chunk.clear();
            for i in 0..block.n {
                w_chunk.push((-(block.label(i)) * scores[off + i]).exp());
            }
            accumulate_edges(block, &w_chunk, &grid, &mut accum);
        })?;

        let (bf, bt, edge) = accum.best();
        if accum.sum_w <= 0.0 || edge.abs() <= 0.0 {
            break; // fully separated / degenerate
        }
        let corr = clamp_correlation(edge / accum.sum_w, cfg.max_corr);
        if corr.abs() < 1e-9 {
            break;
        }
        let sign = if corr >= 0.0 { 1.0 } else { -1.0 };
        let stump = Stump::new(bf as u32, grid.row(bf)[bt], sign as f32);
        let alpha = alpha_for_correlation(corr.abs()) as f32;
        model.push(stump, alpha);
        iterations += 1;

        // incremental score refresh (second cheap pass)
        source.for_each_block(cfg.chunk, |block, off| {
            for i in 0..block.n {
                scores[off + i] += alpha * stump.predict(block.row(i));
            }
        })?;

        if let Some(loss) = evaluator.maybe_eval(&model) {
            if cfg.stop.target_loss > 0.0 && loss <= cfg.stop.target_loss {
                break;
            }
        }
    }
    evaluator.force_eval(&model);
    Ok(BaselineOutcome {
        model,
        series: evaluator.series,
        iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthGen;
    use crate::data::{DiskStore, SynthConfig};
    use crate::eval::exp_loss;
    use std::time::Duration;

    fn synth(n: usize, seed: u64) -> DataBlock {
        SynthGen::new(SynthConfig {
            f: 8,
            pos_rate: 0.4,
            informative: 4,
            signal: 0.9,
            flip_rate: 0.02,
            seed,
        })
        .next_block(n)
    }

    fn quick_cfg(rules: usize) -> FullScanConfig {
        FullScanConfig {
            stop: StopConditions {
                max_rules: rules,
                time_limit: Duration::from_secs(30),
                target_loss: 0.0,
                eval_interval: Duration::ZERO,
            },
            ..FullScanConfig::default()
        }
    }

    #[test]
    fn loss_decreases_monotonically_in_train() {
        let train = synth(5000, 1);
        let test = synth(1000, 2);
        let src = DataSource::memory(train.clone());
        let out = train_fullscan(&src, &test, &quick_cfg(10), "fs").unwrap();
        assert_eq!(out.iterations, 10);
        assert_eq!(out.model.len(), 10);
        // training loss must drop vs empty model (AdaBoost guarantee)
        let l = exp_loss(&out.model, &train);
        assert!(l < 0.95, "train loss {l}");
        // series recorded and non-increasing at endpoints
        let first = out.series.points.first().unwrap().exp_loss;
        let last = out.series.points.last().unwrap().exp_loss;
        assert!(last < first);
    }

    #[test]
    fn disk_source_gives_same_model_as_memory() {
        let train = synth(2000, 3);
        let dir = std::env::temp_dir().join("sparrow_fullscan_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fs.sprw");
        DiskStore::write(&path, &train).unwrap();
        let test = synth(500, 4);

        let m1 = train_fullscan(&DataSource::memory(train), &test, &quick_cfg(5), "m").unwrap();
        let m2 = train_fullscan(&DataSource::disk(&path, 0.0).unwrap(), &test, &quick_cfg(5), "d")
            .unwrap();
        assert_eq!(m1.model, m2.model, "memory and disk paths must agree");
    }

    #[test]
    fn throttled_disk_is_slower() {
        let train = synth(3000, 5);
        let dir = std::env::temp_dir().join("sparrow_fullscan_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fs_throttle.sprw");
        DiskStore::write(&path, &train).unwrap();
        let test = synth(300, 6);

        let t0 = Instant::now();
        train_fullscan(&DataSource::disk(&path, 0.0).unwrap(), &test, &quick_cfg(3), "fast")
            .unwrap();
        let fast = t0.elapsed();

        // ~108KB/pass at 200 KB/s ≈ 0.5 s/pass × 2 passes × 3 iters
        let t0 = Instant::now();
        train_fullscan(
            &DataSource::disk(&path, 200.0 * 1024.0).unwrap(),
            &test,
            &quick_cfg(3),
            "slow",
        )
        .unwrap();
        let slow = t0.elapsed();
        assert!(slow > fast * 2, "fast={fast:?} slow={slow:?}");
    }

    #[test]
    fn target_loss_stops_early() {
        // evaluate against the training data itself: AdaBoost's training
        // potential is guaranteed to fall, so the target must fire
        let train = synth(5000, 7);
        let mut cfg = quick_cfg(1000);
        cfg.stop.target_loss = 0.95;
        let out =
            train_fullscan(&DataSource::memory(train.clone()), &train, &cfg, "tl").unwrap();
        assert!(out.iterations < 1000, "ran {} iterations", out.iterations);
    }
}
