//! Data source abstraction for the baselines' per-iteration full passes —
//! the in-memory vs off-memory tiers of Table 1.

use std::io;
use std::path::Path;

use crate::data::{DataBlock, DiskStore, IoThrottle};

/// Where a full-scan trainer reads its examples from each iteration.
pub enum DataSource {
    /// Whole training set resident in memory (x1e tier).
    Memory(DataBlock),
    /// Streamed from disk every pass, throttled to `bandwidth` B/s
    /// (r3 tier; 0 = unthrottled). The throttle persists across passes —
    /// every re-read pays for its bytes.
    Disk {
        store: DiskStore,
        throttle: std::cell::RefCell<IoThrottle>,
        block: usize,
    },
}

impl DataSource {
    pub fn memory(block: DataBlock) -> DataSource {
        DataSource::Memory(block)
    }

    pub fn disk(path: &Path, bandwidth: f64) -> io::Result<DataSource> {
        let throttle = if bandwidth > 0.0 {
            IoThrottle::new(bandwidth)
        } else {
            IoThrottle::unlimited()
        };
        Ok(DataSource::Disk {
            store: DiskStore::open(path)?,
            throttle: std::cell::RefCell::new(throttle),
            block: 4096,
        })
    }

    pub fn len(&self) -> usize {
        match self {
            DataSource::Memory(b) => b.n,
            DataSource::Disk { store, .. } => store.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_features(&self) -> usize {
        match self {
            DataSource::Memory(b) => b.f,
            DataSource::Disk { store, .. } => store.num_features(),
        }
    }

    /// One full pass: call `f(block, row_offset)` over consecutive chunks.
    /// The disk variant re-reads (and re-pays for) the bytes every pass.
    pub fn for_each_block(
        &self,
        chunk: usize,
        mut f: impl FnMut(&DataBlock, usize),
    ) -> io::Result<()> {
        match self {
            DataSource::Memory(data) => {
                let mut off = 0;
                while off < data.n {
                    let take = chunk.min(data.n - off);
                    // borrow a sub-block without copying labels/features?
                    // DataBlock is contiguous: build a cheap view-copy.
                    let sub = DataBlock::new(
                        take,
                        data.f,
                        data.features[off * data.f..(off + take) * data.f].to_vec(),
                        data.labels[off..off + take].to_vec(),
                    );
                    f(&sub, off);
                    off += take;
                }
                Ok(())
            }
            DataSource::Disk {
                store,
                throttle,
                block,
            } => {
                let mut stream = store.stream(IoThrottle::unlimited())?;
                let record_bytes = store.header.record_bytes();
                let mut off = 0usize;
                let n = store.len();
                let chunk = chunk.min(*block);
                while off < n {
                    let take = chunk.min(n - off);
                    let b = stream.next_block(take)?;
                    if b.is_empty() {
                        break;
                    }
                    throttle.borrow_mut().consume(b.n as u64 * record_bytes);
                    f(&b, off);
                    off += b.n;
                }
                Ok(())
            }
        }
    }

    /// A pilot block for grid construction.
    pub fn pilot(&self, n: usize) -> io::Result<DataBlock> {
        match self {
            DataSource::Memory(b) => {
                let take = n.min(b.n);
                Ok(DataBlock::new(
                    take,
                    b.f,
                    b.features[..take * b.f].to_vec(),
                    b.labels[..take].to_vec(),
                ))
            }
            DataSource::Disk { store, .. } => {
                let mut stream = store.stream(IoThrottle::unlimited())?;
                stream.next_block(n.min(store.len()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthGen;
    use crate::data::SynthConfig;

    fn synth(n: usize) -> DataBlock {
        SynthGen::new(SynthConfig {
            f: 4,
            pos_rate: 0.5,
            informative: 2,
            signal: 1.0,
            flip_rate: 0.0,
            seed: 3,
        })
        .next_block(n)
    }

    #[test]
    fn memory_pass_covers_all_rows() {
        let data = synth(1000);
        let src = DataSource::memory(data.clone());
        let mut seen = 0usize;
        src.for_each_block(256, |b, off| {
            assert_eq!(off, seen);
            seen += b.n;
        })
        .unwrap();
        assert_eq!(seen, 1000);
    }

    #[test]
    fn disk_pass_matches_memory() {
        let data = synth(500);
        let dir = std::env::temp_dir().join("sparrow_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("src.sprw");
        DiskStore::write(&path, &data).unwrap();
        let src = DataSource::disk(&path, 0.0).unwrap();
        assert_eq!(src.len(), 500);
        let mut collected = DataBlock::empty(4);
        src.for_each_block(128, |b, _| collected.extend(b)).unwrap();
        assert_eq!(collected, data);
    }

    #[test]
    fn pilot_returns_prefix() {
        let data = synth(300);
        let src = DataSource::memory(data.clone());
        let p = src.pilot(100).unwrap();
        assert_eq!(p.n, 100);
        assert_eq!(p.row(5), data.row(5));
    }
}
