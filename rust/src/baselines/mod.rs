//! Baseline boosted-stump trainers (paper §5).
//!
//! The paper compares Sparrow against XGBoost (approximate greedy) and
//! LightGBM (GOSS), each in an in-memory and an off-memory (disk) tier.
//! Rather than linking the C++ binaries, the same *algorithmic
//! configurations* are implemented on the identical Rust substrate
//! (DESIGN.md §3): all trainers share the candidate grid, the edge
//! computation, the exponential loss, and the evaluation cadence, so the
//! Table-1 comparison isolates the algorithmic differences the paper is
//! about — full-scan vs GOSS subsampling vs TMSN early-stopping — plus the
//! §1 bulk-synchronous strawman.

pub mod bulk_sync;
pub mod fullscan;
pub mod goss;
pub mod source;
pub mod tree_boost;

pub use bulk_sync::{train_bulk_sync, BulkSyncConfig};
pub use fullscan::{train_fullscan, FullScanConfig};
pub use goss::{train_goss, GossConfig};
pub use source::DataSource;
pub use tree_boost::{train_tree_boost, TreeBoostConfig};

use std::time::{Duration, Instant};

use crate::data::DataBlock;
use crate::eval::{auprc, exp_loss_scores, MetricPoint, MetricSeries};
use crate::model::StrongRule;

/// Shared stop conditions for baseline trainers.
#[derive(Debug, Clone)]
pub struct StopConditions {
    pub max_rules: usize,
    pub time_limit: Duration,
    /// stop when test exp-loss reaches this (0 = off)
    pub target_loss: f64,
    /// held-out evaluation cadence (ZERO = evaluate every iteration)
    pub eval_interval: Duration,
}

impl Default for StopConditions {
    fn default() -> Self {
        StopConditions {
            max_rules: 128,
            time_limit: Duration::from_secs(60),
            target_loss: 0.0,
            eval_interval: Duration::from_millis(250),
        }
    }
}

/// Periodic held-out evaluation shared by every trainer (identical cadence
/// keeps the Fig-3/4 series comparable).
pub struct TimedEvaluator<'a> {
    test: &'a DataBlock,
    interval: Duration,
    start: Instant,
    next: Instant,
    pub series: MetricSeries,
}

impl<'a> TimedEvaluator<'a> {
    pub fn new(test: &'a DataBlock, interval: Duration, label: &str) -> TimedEvaluator<'a> {
        let now = Instant::now();
        TimedEvaluator {
            test,
            interval,
            start: now,
            next: now,
            series: MetricSeries::new(label),
        }
    }

    /// Evaluate if the cadence says so; returns the fresh loss when it did.
    pub fn maybe_eval(&mut self, model: &StrongRule) -> Option<f64> {
        if Instant::now() < self.next {
            return None;
        }
        Some(self.force_eval(model))
    }

    /// Unconditional evaluation point.
    pub fn force_eval(&mut self, model: &StrongRule) -> f64 {
        let sc = crate::eval::metrics::scores(model, self.test);
        self.record(&sc, model.len() as u64)
    }

    /// Cadenced evaluation from caller-maintained test scores (used by
    /// model families other than [`StrongRule`], e.g. tree ensembles).
    pub fn maybe_eval_scores(&mut self, scores: &[f32], iterations: u64) -> Option<f64> {
        if Instant::now() < self.next {
            return None;
        }
        Some(self.force_eval_scores(scores, iterations))
    }

    pub fn force_eval_scores(&mut self, scores: &[f32], iterations: u64) -> f64 {
        let sc = scores.to_vec();
        self.record(&sc, iterations)
    }

    fn record(&mut self, sc: &[f32], iterations: u64) -> f64 {
        self.next = Instant::now() + self.interval;
        let point = MetricPoint {
            elapsed: self.start.elapsed(),
            iterations,
            exp_loss: exp_loss_scores(sc, &self.test.labels),
            auprc: auprc(sc, &self.test.labels),
        };
        self.series.push(point);
        point.exp_loss
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;

    #[test]
    fn evaluator_respects_cadence() {
        let mut d = DataBlock::empty(1);
        d.push(&[1.0], 1.0);
        d.push(&[-1.0], -1.0);
        let mut ev = TimedEvaluator::new(&d, Duration::from_secs(100), "x");
        let model = StrongRule::new();
        assert!(ev.maybe_eval(&model).is_some()); // first is immediate
        assert!(ev.maybe_eval(&model).is_none()); // within interval
        ev.force_eval(&model);
        assert_eq!(ev.series.points.len(), 2);
    }

    #[test]
    fn evaluator_tracks_improvement() {
        let mut d = DataBlock::empty(1);
        for i in 0..20 {
            let y = if i % 2 == 0 { 1.0 } else { -1.0 };
            d.push(&[y], y);
        }
        let mut ev = TimedEvaluator::new(&d, Duration::ZERO, "x");
        let mut m = StrongRule::new();
        let l0 = ev.force_eval(&m);
        m.push(Stump::new(0, 0.0, 1.0), 1.0);
        let l1 = ev.force_eval(&m);
        assert!(l1 < l0);
    }
}
