//! GOSS boosting — the "LightGBM" configuration of Table 1.
//!
//! Gradient-based One-Side Sampling: keep the `a` fraction of examples
//! with the largest gradient magnitudes, uniformly sample a `b` fraction
//! of the rest, and up-weight the sampled small-gradient examples by
//! `(1 - a) / b` so the edge estimates stay unbiased. For exponential
//! loss the gradient magnitude *is* the boosting weight `w = exp(-y H(x))`,
//! so GOSS keeps the hardest examples exactly.

use std::time::Instant;

use crate::baselines::fullscan::BaselineOutcome;
use crate::baselines::{DataSource, StopConditions, TimedEvaluator};
use crate::boosting::{
    alpha::{alpha_for_correlation, clamp_correlation},
    edges::accumulate_edges,
    CandidateGrid, EdgeMatrix,
};
use crate::data::DataBlock;
use crate::model::{StrongRule, Stump};
use crate::util::rng::Rng;

/// GOSS configuration (LightGBM defaults: a = 0.2, b = 0.1).
#[derive(Debug, Clone)]
pub struct GossConfig {
    pub nthr: usize,
    pub top_rate: f64,
    pub other_rate: f64,
    pub stop: StopConditions,
    pub max_corr: f64,
    pub chunk: usize,
    pub seed: u64,
}

impl Default for GossConfig {
    fn default() -> Self {
        GossConfig {
            nthr: 4,
            top_rate: 0.2,
            other_rate: 0.1,
            stop: StopConditions::default(),
            max_corr: 0.8,
            chunk: 4096,
            seed: 0x6055,
        }
    }
}

/// Run the GOSS booster.
pub fn train_goss(
    source: &DataSource,
    test: &DataBlock,
    cfg: &GossConfig,
    label: &str,
) -> std::io::Result<BaselineOutcome> {
    assert!(cfg.top_rate > 0.0 && cfg.top_rate < 1.0);
    assert!(cfg.other_rate > 0.0 && cfg.top_rate + cfg.other_rate <= 1.0);
    let n = source.len();
    let f = source.num_features();
    assert!(n > 0, "empty training set");
    let pilot = source.pilot(4096.min(n))?;
    let grid = CandidateGrid::from_quantiles(&pilot, cfg.nthr);

    let mut rng = Rng::new(cfg.seed);
    let mut model = StrongRule::new();
    let mut scores = vec![0f32; n];
    let mut weights = vec![1f32; n];
    let mut evaluator =
        TimedEvaluator::new(test, cfg.stop.eval_interval, label);
    let t0 = Instant::now();
    evaluator.force_eval(&model);

    let top_k = ((n as f64) * cfg.top_rate).ceil() as usize;
    let amplify = ((1.0 - cfg.top_rate) / cfg.other_rate) as f32;

    let mut iterations = 0usize;
    while iterations < cfg.stop.max_rules && t0.elapsed() < cfg.stop.time_limit {
        // GOSS selection from cached weights: threshold = k-th largest |w|
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            weights[b as usize]
                .partial_cmp(&weights[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut selected = vec![false; n];
        let mut sel_weight = vec![0f32; n];
        for &i in &order[..top_k.min(n)] {
            selected[i as usize] = true;
            sel_weight[i as usize] = weights[i as usize];
        }
        for &i in &order[top_k.min(n)..] {
            if rng.bernoulli(cfg.other_rate) {
                selected[i as usize] = true;
                sel_weight[i as usize] = weights[i as usize] * amplify;
            }
        }

        // edge pass over the selected subset only (the GOSS saving: the
        // histogram/edge work shrinks to a+b of the data, but the pass
        // still reads everything — matching LightGBM's disk behaviour)
        let mut accum = EdgeMatrix::zeros(f, cfg.nthr);
        let mut sub = DataBlock::empty(f);
        let mut sub_w: Vec<f32> = Vec::new();
        source.for_each_block(cfg.chunk, |block, off| {
            sub.n = 0;
            sub.features.clear();
            sub.labels.clear();
            sub_w.clear();
            for i in 0..block.n {
                if selected[off + i] {
                    sub.push(block.row(i), block.label(i));
                    sub_w.push(sel_weight[off + i]);
                }
            }
            if sub.n > 0 {
                accumulate_edges(&sub, &sub_w, &grid, &mut accum);
            }
        })?;

        let (bf, bt, edge) = accum.best();
        if accum.sum_w <= 0.0 || edge.abs() <= 0.0 {
            break;
        }
        let corr = clamp_correlation(edge / accum.sum_w, cfg.max_corr);
        if corr.abs() < 1e-9 {
            break;
        }
        let sign = if corr >= 0.0 { 1.0 } else { -1.0 };
        let stump = Stump::new(bf as u32, grid.row(bf)[bt], sign as f32);
        let alpha = alpha_for_correlation(corr.abs()) as f32;
        model.push(stump, alpha);
        iterations += 1;

        // full-pass incremental refresh of scores & weights
        source.for_each_block(cfg.chunk, |block, off| {
            for i in 0..block.n {
                let s = scores[off + i] + alpha * stump.predict(block.row(i));
                scores[off + i] = s;
                weights[off + i] = (-(block.label(i)) * s).exp();
            }
        })?;

        if let Some(loss) = evaluator.maybe_eval(&model) {
            if cfg.stop.target_loss > 0.0 && loss <= cfg.stop.target_loss {
                break;
            }
        }
    }
    evaluator.force_eval(&model);
    Ok(BaselineOutcome {
        model,
        series: evaluator.series,
        iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthGen;
    use crate::data::SynthConfig;
    use crate::eval::exp_loss;
    use std::time::Duration;

    fn synth(n: usize, seed: u64) -> DataBlock {
        SynthGen::new(SynthConfig {
            f: 8,
            pos_rate: 0.4,
            informative: 4,
            signal: 0.9,
            flip_rate: 0.02,
            seed,
        })
        .next_block(n)
    }

    fn quick_cfg(rules: usize) -> GossConfig {
        GossConfig {
            stop: StopConditions {
                max_rules: rules,
                time_limit: Duration::from_secs(30),
                target_loss: 0.0,
                eval_interval: Duration::ZERO,
            },
            ..GossConfig::default()
        }
    }

    #[test]
    fn learns_and_reduces_loss() {
        let train = synth(5000, 1);
        let test = synth(1000, 2);
        let out = train_goss(&DataSource::memory(train.clone()), &test, &quick_cfg(10), "g")
            .unwrap();
        assert_eq!(out.model.len(), 10);
        assert!(exp_loss(&out.model, &train) < 0.95);
    }

    #[test]
    fn comparable_to_fullscan_on_easy_data() {
        use crate::baselines::fullscan::{train_fullscan, FullScanConfig};
        let train = synth(6000, 3);
        let test = synth(1500, 4);
        let g = train_goss(&DataSource::memory(train.clone()), &test, &quick_cfg(15), "g")
            .unwrap();
        let fs_cfg = FullScanConfig {
            stop: StopConditions {
                max_rules: 15,
                time_limit: Duration::from_secs(30),
                target_loss: 0.0,
                eval_interval: Duration::ZERO,
            },
            ..FullScanConfig::default()
        };
        let f = train_fullscan(&DataSource::memory(train.clone()), &test, &fs_cfg, "f").unwrap();
        let gl = exp_loss(&g.model, &train);
        let fl = exp_loss(&f.model, &train);
        // GOSS is an approximation: within a modest factor of full scan
        assert!(gl < fl * 1.5 + 0.05, "goss={gl} full={fl}");
    }

    #[test]
    fn selection_rates_respected() {
        // indirectly: degenerate rates must be rejected
        let train = synth(100, 5);
        let test = synth(50, 6);
        let mut cfg = quick_cfg(1);
        cfg.top_rate = 0.0;
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            train_goss(&DataSource::memory(train), &test, &cfg, "bad")
        }));
        assert!(r.is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let train = synth(3000, 7);
        let test = synth(300, 8);
        let a = train_goss(&DataSource::memory(train.clone()), &test, &quick_cfg(5), "a")
            .unwrap();
        let b = train_goss(&DataSource::memory(train), &test, &quick_cfg(5), "b").unwrap();
        assert_eq!(a.model, b.model);
    }
}
