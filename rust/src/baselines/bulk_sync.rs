//! Bulk-synchronous parallel boosting — the §1 strawman TMSN replaces.
//!
//! Valiant's BSP model applied to feature-parallel boosting: each of `p`
//! workers owns a feature stripe; every iteration, all workers scan the
//! whole dataset for their stripe's best candidate, then a **barrier**
//! gathers the per-stripe winners at a master, which appends the global
//! best and broadcasts the new model before the next iteration may start.
//!
//! The fast workers wait for the slowest at every barrier — with a laggard
//! injected, the *whole cluster* runs at the laggard's pace (contrast with
//! TMSN in `benches/resilience.rs`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::baselines::fullscan::BaselineOutcome;
use crate::baselines::{StopConditions, TimedEvaluator};
use crate::boosting::{
    alpha::{alpha_for_correlation, clamp_correlation},
    edges::accumulate_edges_stripe,
    grid::partition_features,
    CandidateGrid, EdgeMatrix,
};
use crate::data::DataBlock;
use crate::model::{StrongRule, Stump};

/// Bulk-synchronous trainer configuration.
#[derive(Debug, Clone)]
pub struct BulkSyncConfig {
    pub workers: usize,
    pub nthr: usize,
    pub stop: StopConditions,
    pub max_corr: f64,
    /// per-worker compute slowdown multipliers (laggard injection)
    pub laggards: Vec<(usize, f64)>,
    /// synchronization overhead charged at every barrier (models the
    /// master round-trip the paper's §1 attributes BSP's stalls to)
    pub sync_overhead: Duration,
}

impl Default for BulkSyncConfig {
    fn default() -> Self {
        BulkSyncConfig {
            workers: 4,
            nthr: 4,
            stop: StopConditions::default(),
            max_corr: 0.8,
            laggards: Vec::new(),
            sync_overhead: Duration::from_micros(500),
        }
    }
}

/// Per-iteration result a worker reports at the barrier.
#[derive(Debug, Clone, Copy, Default)]
struct StripeBest {
    feature: usize,
    t: usize,
    edge: f64,
    sum_w: f64,
}

/// Run bulk-synchronous feature-parallel boosting (in-memory data,
/// replicated to every worker as in the paper's setup).
pub fn train_bulk_sync(
    train: &DataBlock,
    test: &DataBlock,
    cfg: &BulkSyncConfig,
    label: &str,
) -> BaselineOutcome {
    assert!(cfg.workers >= 1);
    assert!(train.n > 0);
    let f = train.f;
    let grid = Arc::new(CandidateGrid::from_quantiles(
        &train.select(&(0..train.n.min(4096)).collect::<Vec<_>>()),
        cfg.nthr,
    ));
    let stripes = partition_features(f, cfg.workers);

    let model = Arc::new(Mutex::new(StrongRule::new()));
    let scores = Arc::new(Mutex::new(vec![0f32; train.n]));
    let barrier = Arc::new(Barrier::new(cfg.workers + 1)); // workers + master
    let bests: Arc<Mutex<Vec<StripeBest>>> =
        Arc::new(Mutex::new(vec![StripeBest::default(); cfg.workers]));
    let done = Arc::new(AtomicBool::new(false));
    let train = Arc::new(train.clone());

    let mut handles = Vec::new();
    for (wid, stripe) in stripes.iter().copied().enumerate() {
        let grid = Arc::clone(&grid);
        let scores = Arc::clone(&scores);
        let barrier = Arc::clone(&barrier);
        let bests = Arc::clone(&bests);
        let done = Arc::clone(&done);
        let train = Arc::clone(&train);
        let laggard = cfg
            .laggards
            .iter()
            .find(|(w, _)| *w == wid)
            .map(|(_, k)| *k)
            .unwrap_or(1.0);
        handles.push(std::thread::spawn(move || {
            let mut w = vec![0f32; train.n];
            loop {
                barrier.wait(); // iteration start
                if done.load(Ordering::Relaxed) {
                    return;
                }
                let t0 = Instant::now();
                {
                    let sc = scores.lock().unwrap();
                    for i in 0..train.n {
                        w[i] = (-(train.label(i)) * sc[i]).exp();
                    }
                }
                let mut accum = EdgeMatrix::zeros(f, grid.nthr);
                accumulate_edges_stripe(&train, &w, &grid, stripe, &mut accum);
                let mut best = StripeBest {
                    sum_w: accum.sum_w,
                    ..StripeBest::default()
                };
                for fi in stripe.0..stripe.1 {
                    for t in 0..grid.nthr {
                        let e = accum.edge(fi, t);
                        if e.abs() > best.edge.abs() {
                            best = StripeBest {
                                feature: fi,
                                t,
                                edge: e,
                                sum_w: accum.sum_w,
                            };
                        }
                    }
                }
                // laggard: pretend this worker's scan took k× longer
                if laggard > 1.0 {
                    std::thread::sleep(t0.elapsed().mul_f64(laggard - 1.0));
                }
                bests.lock().unwrap()[wid] = best;
                barrier.wait(); // results ready — master reduces
            }
        }));
    }

    let mut evaluator = TimedEvaluator::new(test, cfg.stop.eval_interval, label);
    {
        let m = model.lock().unwrap();
        evaluator.force_eval(&m);
    }
    let t0 = Instant::now();
    let mut iterations = 0usize;
    loop {
        if iterations >= cfg.stop.max_rules || t0.elapsed() >= cfg.stop.time_limit {
            done.store(true, Ordering::Relaxed);
            barrier.wait(); // release workers into the done check
            break;
        }
        barrier.wait(); // start iteration
        barrier.wait(); // wait for all stripes (the BSP stall point)
        std::thread::sleep(cfg.sync_overhead); // master gather/scatter cost

        let (stump, alpha) = {
            let bests = bests.lock().unwrap();
            let best = bests
                .iter()
                .max_by(|a, b| a.edge.abs().partial_cmp(&b.edge.abs()).unwrap())
                .copied()
                .unwrap();
            if best.sum_w <= 0.0 || best.edge == 0.0 {
                done.store(true, Ordering::Relaxed);
                barrier.wait();
                break;
            }
            let corr = clamp_correlation(best.edge / best.sum_w, cfg.max_corr);
            if corr.abs() < 1e-9 {
                done.store(true, Ordering::Relaxed);
                barrier.wait();
                break;
            }
            let sign = if corr >= 0.0 { 1.0f32 } else { -1.0 };
            (
                Stump::new(best.feature as u32, grid.row(best.feature)[best.t], sign),
                alpha_for_correlation(corr.abs()) as f32,
            )
        };
        {
            let mut m = model.lock().unwrap();
            m.push(stump, alpha);
            let mut sc = scores.lock().unwrap();
            for i in 0..train.n {
                sc[i] += alpha * stump.predict(train.row(i));
            }
            iterations += 1;
        }
        let m = model.lock().unwrap().clone();
        if let Some(loss) = evaluator.maybe_eval(&m) {
            if cfg.stop.target_loss > 0.0 && loss <= cfg.stop.target_loss {
                done.store(true, Ordering::Relaxed);
                barrier.wait();
                break;
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let final_model = model.lock().unwrap().clone();
    evaluator.force_eval(&final_model);
    BaselineOutcome {
        model: final_model,
        series: evaluator.series,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthGen;
    use crate::data::SynthConfig;
    use crate::eval::exp_loss;

    fn synth(n: usize, seed: u64) -> DataBlock {
        SynthGen::new(SynthConfig {
            f: 8,
            pos_rate: 0.4,
            informative: 4,
            signal: 0.9,
            flip_rate: 0.02,
            seed,
        })
        .next_block(n)
    }

    fn quick_cfg(workers: usize, rules: usize) -> BulkSyncConfig {
        BulkSyncConfig {
            workers,
            stop: StopConditions {
                max_rules: rules,
                time_limit: Duration::from_secs(30),
                target_loss: 0.0,
                eval_interval: Duration::ZERO,
            },
            sync_overhead: Duration::from_micros(100),
            ..BulkSyncConfig::default()
        }
    }

    #[test]
    fn learns_with_multiple_workers() {
        let train = synth(4000, 1);
        let test = synth(800, 2);
        let out = train_bulk_sync(&train, &test, &quick_cfg(4, 8), "bs");
        assert_eq!(out.model.len(), 8);
        assert!(exp_loss(&out.model, &train) < 0.95);
    }

    #[test]
    fn matches_fullscan_choice_per_iteration() {
        // BSP over stripes picks the same global best as a full scan
        use crate::baselines::fullscan::{train_fullscan, FullScanConfig};
        use crate::baselines::DataSource;
        let train = synth(3000, 3);
        let test = synth(300, 4);
        let bs = train_bulk_sync(&train, &test, &quick_cfg(3, 5), "bs");
        let fs = train_fullscan(
            &DataSource::memory(train.clone()),
            &test,
            &FullScanConfig {
                stop: StopConditions {
                    max_rules: 5,
                    time_limit: Duration::from_secs(30),
                    target_loss: 0.0,
                    eval_interval: Duration::ZERO,
                },
                ..FullScanConfig::default()
            },
            "fs",
        )
        .unwrap();
        // same grid quantiles (both use the 4096-pilot) → identical models
        assert_eq!(bs.model, fs.model);
    }

    #[test]
    fn laggard_slows_whole_cluster() {
        let train = synth(20_000, 5);
        let test = synth(100, 6);
        let t0 = Instant::now();
        let _ = train_bulk_sync(&train, &test, &quick_cfg(3, 4), "fast");
        let fast = t0.elapsed();

        let mut slow_cfg = quick_cfg(3, 4);
        slow_cfg.laggards = vec![(1, 10.0)];
        let t0 = Instant::now();
        let _ = train_bulk_sync(&train, &test, &slow_cfg, "slow");
        let slow = t0.elapsed();
        // every barrier waits for the 10× laggard
        assert!(slow > fast.mul_f64(1.5), "fast={fast:?} slow={slow:?}");
    }

    #[test]
    fn single_worker_degenerates_to_serial() {
        let train = synth(2000, 7);
        let test = synth(200, 8);
        let out = train_bulk_sync(&train, &test, &quick_cfg(1, 3), "bs1");
        assert_eq!(out.model.len(), 3);
    }
}
