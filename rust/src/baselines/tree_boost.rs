//! Boosting with multi-level trees — the paper's §5 future-work feature
//! ("extend the algorithm to boosting full trees"), implemented for the
//! full-scan baseline family (true-to-XGBoost depth-k trees over the same
//! candidate grid).

use std::time::Instant;

use crate::baselines::{DataSource, StopConditions, TimedEvaluator};
use crate::boosting::{
    alpha::{alpha_for_correlation, clamp_correlation},
    CandidateGrid,
};
use crate::data::DataBlock;
use crate::eval::MetricSeries;
use crate::model::tree::{DecisionTree, TreeEnsemble};

/// Tree-booster configuration.
#[derive(Debug, Clone)]
pub struct TreeBoostConfig {
    pub depth: usize,
    pub nthr: usize,
    pub stop: StopConditions,
    pub max_corr: f64,
}

impl Default for TreeBoostConfig {
    fn default() -> Self {
        TreeBoostConfig {
            depth: 2,
            nthr: 4,
            stop: StopConditions::default(),
            max_corr: 0.8,
        }
    }
}

/// Tree-booster outcome.
#[derive(Debug)]
pub struct TreeBoostOutcome {
    pub model: TreeEnsemble,
    pub series: MetricSeries,
    pub iterations: usize,
}

/// Train an AdaBoost ensemble of depth-`depth` trees.
///
/// Tree construction needs node-local example subsets, so the training set
/// is materialized in memory (the paper's in-memory tier; XGBoost does the
/// same for its exact/approx tree method).
pub fn train_tree_boost(
    source: &DataSource,
    test: &DataBlock,
    cfg: &TreeBoostConfig,
    label: &str,
) -> std::io::Result<TreeBoostOutcome> {
    assert!(cfg.depth >= 1);
    let mut train = DataBlock::empty(source.num_features());
    source.for_each_block(8192, |b, _| train.extend(b))?;
    assert!(train.n > 0, "empty training set");
    let pilot = train.select(&(0..train.n.min(4096)).collect::<Vec<_>>());
    let grid = CandidateGrid::from_quantiles(&pilot, cfg.nthr);

    let mut model = TreeEnsemble::new();
    let mut scores = vec![0f32; train.n];
    let mut w = vec![1f32; train.n];
    let t0 = Instant::now();

    // evaluator needs scores on the test set: maintain incrementally
    let mut test_scores = vec![0f32; test.n];
    let mut evaluator = TimedEvaluator::new(test, cfg.stop.eval_interval, label);
    evaluator.force_eval_scores(&test_scores, 0);

    let mut iterations = 0usize;
    while iterations < cfg.stop.max_rules && t0.elapsed() < cfg.stop.time_limit {
        let tree = DecisionTree::fit(&train, &w, &grid, cfg.depth);
        // weighted correlation of the fitted tree
        let (mut m, mut sum_w) = (0f64, 0f64);
        let preds: Vec<f32> = (0..train.n).map(|i| tree.predict(train.row(i))).collect();
        for i in 0..train.n {
            m += w[i] as f64 * train.label(i) as f64 * preds[i] as f64;
            sum_w += w[i] as f64;
        }
        if sum_w <= 0.0 {
            break;
        }
        let corr = clamp_correlation(m / sum_w, cfg.max_corr);
        if corr <= 1e-9 {
            break; // greedy tree no better than chance under current weights
        }
        let alpha = alpha_for_correlation(corr) as f32;
        model.push(tree.clone(), alpha);
        iterations += 1;

        for i in 0..train.n {
            scores[i] += alpha * preds[i];
            w[i] = (-(train.label(i)) * scores[i]).exp();
        }
        for i in 0..test.n {
            test_scores[i] += alpha * tree.predict(test.row(i));
        }
        if let Some(loss) = evaluator.maybe_eval_scores(&test_scores, model.len() as u64) {
            if cfg.stop.target_loss > 0.0 && loss <= cfg.stop.target_loss {
                break;
            }
        }
    }
    evaluator.force_eval_scores(&test_scores, model.len() as u64);
    Ok(TreeBoostOutcome {
        model,
        series: evaluator.series,
        iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn xor_block(n: usize, seed: u64) -> DataBlock {
        let mut rng = Rng::new(seed);
        let mut b = DataBlock::empty(2);
        for _ in 0..n {
            let x0 = rng.gauss() as f32;
            let x1 = rng.gauss() as f32;
            let noisy = rng.bernoulli(0.05);
            let mut y = if x0 * x1 > 0.0 { 1.0 } else { -1.0 };
            if noisy {
                y = -y;
            }
            b.push(&[x0, x1], y);
        }
        b
    }

    fn cfg(depth: usize, rules: usize) -> TreeBoostConfig {
        TreeBoostConfig {
            depth,
            // median-only candidate grid: see model::tree::tests — greedy
            // roots on pure XOR need the centered threshold
            nthr: 1,
            stop: StopConditions {
                max_rules: rules,
                time_limit: Duration::from_secs(30),
                target_loss: 0.0,
                eval_interval: Duration::ZERO,
            },
            ..TreeBoostConfig::default()
        }
    }

    #[test]
    fn depth2_trees_learn_xor_where_stumps_cannot() {
        let train = xor_block(3000, 1);
        let test = xor_block(1000, 2);
        let src = DataSource::memory(train.clone());

        // stumps (depth 1): stuck near chance on XOR
        let d1 = train_tree_boost(&src, &test, &cfg(1, 10), "d1").unwrap();
        // depth 2: learns
        let d2 = train_tree_boost(&src, &test, &cfg(2, 10), "d2").unwrap();

        let err = |ens: &TreeEnsemble, data: &DataBlock| {
            (0..data.n)
                .filter(|&i| ens.predict(data.row(i)) != data.label(i))
                .count() as f64
                / data.n as f64
        };
        let e1 = err(&d1.model, &test);
        let e2 = err(&d2.model, &test);
        assert!(e2 < 0.15, "depth-2 test error {e2}");
        assert!(e2 < e1 - 0.2, "depth-2 ({e2}) must beat depth-1 ({e1})");
    }

    #[test]
    fn series_recorded_and_improving() {
        let train = xor_block(2000, 3);
        let src = DataSource::memory(train.clone());
        let out = train_tree_boost(&src, &train, &cfg(2, 8), "t").unwrap();
        assert!(out.iterations >= 1);
        let first = out.series.points.first().unwrap().exp_loss;
        let last = out.series.points.last().unwrap().exp_loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn depth1_matches_fullscan_family_behaviour() {
        // depth-1 tree boosting is stump boosting; training loss drops
        let train = xor_block(1500, 4); // XOR: won't drop much, use easy data instead
        let mut easy = DataBlock::empty(2);
        for i in 0..train.n {
            let y = train.label(i);
            easy.push(&[y * (1.0 + (i % 7) as f32 * 0.1), train.row(i)[1]], y);
        }
        let src = DataSource::memory(easy.clone());
        let out = train_tree_boost(&src, &easy, &cfg(1, 5), "d1easy").unwrap();
        let loss = crate::eval::exp_loss_scores(
            &(0..easy.n).map(|i| out.model.score(easy.row(i))).collect::<Vec<_>>(),
            &easy.labels,
        );
        assert!(loss < 0.5, "loss={loss}");
    }
}
