//! Cluster event log: every TMSN protocol action, timestamped on a shared
//! clock, collected from all workers without synchronizing them.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::sim::clock::{Clock, RealClock};
use crate::util::json::Json;

/// What happened (the Figure-1 vocabulary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// worker certified a new weak rule locally
    LocalImprovement,
    /// worker broadcast its model
    Broadcast,
    /// worker received a remote model
    Receive,
    /// received model accepted (scanner interrupted & restarted)
    Accept,
    /// received model rejected (certificate not better)
    Reject,
    /// worker began building a new in-memory sample
    ResampleStart,
    /// new sample built (blocking mode: also installed)
    ResampleEnd,
    /// background-built sample swapped in at a batch boundary
    SampleSwap,
    /// in-flight background build invalidated by a model adoption
    BuildAbort,
    /// worker halved its target edge γ after a fruitless pass
    GammaShrink,
    /// worker crashed (failure injection)
    Crash,
    /// worker finished
    Finish,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::LocalImprovement => "local_improvement",
            EventKind::Broadcast => "broadcast",
            EventKind::Receive => "receive",
            EventKind::Accept => "accept",
            EventKind::Reject => "reject",
            EventKind::ResampleStart => "resample_start",
            EventKind::ResampleEnd => "resample_end",
            EventKind::SampleSwap => "sample_swap",
            EventKind::BuildAbort => "build_abort",
            EventKind::GammaShrink => "gamma_shrink",
            EventKind::Crash => "crash",
            EventKind::Finish => "finish",
        }
    }
}

/// One timestamped event.
#[derive(Debug, Clone)]
pub struct Event {
    pub elapsed: Duration,
    pub worker: usize,
    pub kind: EventKind,
    /// model version `(origin worker, sequence)` if applicable
    pub model: Option<(usize, u64)>,
    /// free-form detail (loss bound, γ, …)
    pub value: f64,
}

/// Collects events from many worker threads over a channel; the shared
/// epoch gives all workers one clock (no synchronization — just a shared
/// `Instant` to subtract). Timestamps are read through a [`Clock`], so
/// the same pipeline stamps **virtual** time when handed a
/// [`crate::sim::SimClock`] (the simulator's deterministic traces) and
/// wall time everywhere else.
#[derive(Clone)]
pub struct EventLog {
    epoch: Instant,
    clock: Arc<dyn Clock>,
    tx: Sender<Event>,
}

impl EventLog {
    pub fn new() -> (EventLog, Receiver<Event>) {
        EventLog::with_clock(Arc::new(RealClock))
    }

    /// An event log whose `elapsed` stamps come from `clock` (epoch =
    /// the clock's now at construction).
    pub fn with_clock(clock: Arc<dyn Clock>) -> (EventLog, Receiver<Event>) {
        let (tx, rx) = channel();
        (
            EventLog {
                epoch: clock.now(),
                clock,
                tx,
            },
            rx,
        )
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    pub fn record(&self, worker: usize, kind: EventKind, model: Option<(usize, u64)>, value: f64) {
        // send failures mean the collector is gone (run over) — ignore
        let _ = self.tx.send(Event {
            elapsed: self.clock.now().saturating_duration_since(self.epoch),
            worker,
            kind,
            model,
            value,
        });
    }
}

/// Drain every event currently buffered (collector side).
pub fn drain(rx: &Receiver<Event>) -> Vec<Event> {
    let mut out: Vec<Event> = rx.try_iter().collect();
    out.sort_by_key(|e| e.elapsed);
    out
}

/// JSON-lines rendering for offline analysis.
pub fn to_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        let mut o = Json::obj();
        o.set("t", e.elapsed.as_secs_f64())
            .set("worker", e.worker)
            .set("kind", e.kind.as_str())
            .set("value", e.value);
        if let Some((w, s)) = e.model {
            o.set("model_origin", w).set("model_seq", s);
        }
        out.push_str(&o.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_drain_ordered() {
        let (log, rx) = EventLog::new();
        log.record(2, EventKind::Broadcast, Some((2, 1)), 0.9);
        log.record(0, EventKind::Receive, Some((2, 1)), 0.9);
        log.record(1, EventKind::Accept, Some((2, 1)), 0.9);
        let events = drain(&rx);
        assert_eq!(events.len(), 3);
        assert!(events.windows(2).all(|w| w[0].elapsed <= w[1].elapsed));
        assert_eq!(events[0].worker, 2);
    }

    #[test]
    fn clone_shares_channel_and_epoch() {
        let (log, rx) = EventLog::new();
        let log2 = log.clone();
        assert_eq!(log.epoch(), log2.epoch());
        log2.record(7, EventKind::Finish, None, 0.0);
        assert_eq!(drain(&rx).len(), 1);
    }

    #[test]
    fn virtual_clock_stamps_virtual_time() {
        use crate::sim::SimClock;
        let clock = Arc::new(SimClock::new());
        let (log, rx) = EventLog::with_clock(clock.clone());
        log.record(0, EventKind::Broadcast, None, 1.0);
        clock.advance(Duration::from_secs(5));
        log.record(1, EventKind::Accept, None, 1.0);
        let events = drain(&rx);
        // exact virtual stamps, no wall time leaked in
        assert_eq!(events[0].elapsed, Duration::ZERO);
        assert_eq!(events[1].elapsed, Duration::from_secs(5));
    }

    #[test]
    fn record_after_collector_drop_is_safe() {
        let (log, rx) = EventLog::new();
        drop(rx);
        log.record(0, EventKind::Crash, None, 0.0); // must not panic
    }

    #[test]
    fn jsonl_format() {
        let (log, rx) = EventLog::new();
        log.record(1, EventKind::Accept, Some((0, 3)), 0.5);
        let events = drain(&rx);
        let line = to_jsonl(&events);
        assert!(line.contains("\"kind\":\"accept\""));
        assert!(line.contains("\"model_origin\":0"));
        assert!(line.contains("\"model_seq\":3"));
        assert!(line.ends_with('\n'));
    }

    #[test]
    fn kind_names_unique() {
        use EventKind::*;
        let kinds = [
            LocalImprovement,
            Broadcast,
            Receive,
            Accept,
            Reject,
            ResampleStart,
            ResampleEnd,
            SampleSwap,
            BuildAbort,
            GammaShrink,
            Crash,
            Finish,
        ];
        let mut names: Vec<&str> = kinds.iter().map(|k| k.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), kinds.len());
    }
}
