//! Cluster event log: every TMSN protocol action, timestamped on a shared
//! clock, collected from all workers without synchronizing them.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::metrics::counters::LiveCounters;
use crate::sim::clock::{Clock, RealClock};
use crate::util::json::Json;

/// What happened (the Figure-1 vocabulary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// worker certified a new weak rule locally
    LocalImprovement,
    /// worker broadcast its model
    Broadcast,
    /// worker received a remote model
    Receive,
    /// received model accepted (scanner interrupted & restarted)
    Accept,
    /// received model rejected (certificate not better)
    Reject,
    /// worker began building a new in-memory sample
    ResampleStart,
    /// new sample built (blocking mode: also installed)
    ResampleEnd,
    /// background-built sample swapped in at a batch boundary
    SampleSwap,
    /// in-flight background build invalidated by a model adoption
    BuildAbort,
    /// worker halved its target edge γ after a fruitless pass
    GammaShrink,
    /// worker crashed (failure injection)
    Crash,
    /// worker finished
    Finish,
    /// tiered store spilled examples to chunk files (value = rows)
    Spill,
    /// readahead served an already-buffered chunk (value = chunks)
    ReadaheadHit,
    /// builder had to wait for a chunk read (value = chunks)
    ReadaheadMiss,
    /// worker joined an in-flight run (dynamic membership)
    Join,
    /// crashed worker resumed from its checkpoint (value = checkpoint
    /// certificate summary)
    Rejoin,
    /// accepted payload re-forwarded to gossip peers (fanout mode)
    Forward,
    /// TCP fabric established (or re-established) a live link to a peer
    PeerUp,
    /// TCP fabric lost a peer link (timeout, heartbeat miss, or EOF)
    PeerDown,
    /// redial of a down peer succeeded (value = attempt number)
    Reconnect,
    /// bounded send queue full: oldest frame dropped (safe — TMSN is
    /// no-FIFO/lossy-tolerant, DESIGN.md §13)
    QueueDrop,
}

impl EventKind {
    /// Every event kind, in declaration order. This is the canonical
    /// enumeration the live-counter array, the `metrics.snapshot` RPC,
    /// and the OPERATIONS.md coverage check are all indexed by — adding
    /// a variant without extending it is a compile error (the `match`
    /// in [`EventKind::index`] is exhaustive).
    pub const ALL: [EventKind; 22] = [
        EventKind::LocalImprovement,
        EventKind::Broadcast,
        EventKind::Receive,
        EventKind::Accept,
        EventKind::Reject,
        EventKind::ResampleStart,
        EventKind::ResampleEnd,
        EventKind::SampleSwap,
        EventKind::BuildAbort,
        EventKind::GammaShrink,
        EventKind::Crash,
        EventKind::Finish,
        EventKind::Spill,
        EventKind::ReadaheadHit,
        EventKind::ReadaheadMiss,
        EventKind::Join,
        EventKind::Rejoin,
        EventKind::Forward,
        EventKind::PeerUp,
        EventKind::PeerDown,
        EventKind::Reconnect,
        EventKind::QueueDrop,
    ];

    /// Position of this kind in [`EventKind::ALL`] (dense index for
    /// per-kind counter arrays).
    pub fn index(&self) -> usize {
        match self {
            EventKind::LocalImprovement => 0,
            EventKind::Broadcast => 1,
            EventKind::Receive => 2,
            EventKind::Accept => 3,
            EventKind::Reject => 4,
            EventKind::ResampleStart => 5,
            EventKind::ResampleEnd => 6,
            EventKind::SampleSwap => 7,
            EventKind::BuildAbort => 8,
            EventKind::GammaShrink => 9,
            EventKind::Crash => 10,
            EventKind::Finish => 11,
            EventKind::Spill => 12,
            EventKind::ReadaheadHit => 13,
            EventKind::ReadaheadMiss => 14,
            EventKind::Join => 15,
            EventKind::Rejoin => 16,
            EventKind::Forward => 17,
            EventKind::PeerUp => 18,
            EventKind::PeerDown => 19,
            EventKind::Reconnect => 20,
            EventKind::QueueDrop => 21,
        }
    }

    /// Stable wire name (JSONL `kind` field and `metrics.snapshot` key).
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::LocalImprovement => "local_improvement",
            EventKind::Broadcast => "broadcast",
            EventKind::Receive => "receive",
            EventKind::Accept => "accept",
            EventKind::Reject => "reject",
            EventKind::ResampleStart => "resample_start",
            EventKind::ResampleEnd => "resample_end",
            EventKind::SampleSwap => "sample_swap",
            EventKind::BuildAbort => "build_abort",
            EventKind::GammaShrink => "gamma_shrink",
            EventKind::Crash => "crash",
            EventKind::Finish => "finish",
            EventKind::Spill => "spill",
            EventKind::ReadaheadHit => "readahead_hit",
            EventKind::ReadaheadMiss => "readahead_miss",
            EventKind::Join => "join",
            EventKind::Rejoin => "rejoin",
            EventKind::Forward => "forward",
            EventKind::PeerUp => "peer_up",
            EventKind::PeerDown => "peer_down",
            EventKind::Reconnect => "reconnect",
            EventKind::QueueDrop => "queue_drop",
        }
    }
}

/// One timestamped event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Time since the log's shared epoch.
    pub elapsed: Duration,
    /// Id of the worker that recorded the event.
    pub worker: usize,
    /// What happened.
    pub kind: EventKind,
    /// model version `(origin worker, sequence)` if applicable
    pub model: Option<(usize, u64)>,
    /// free-form detail (loss bound, γ, …)
    pub value: f64,
}

/// Collects events from many worker threads over a channel; the shared
/// epoch gives all workers one clock (no synchronization — just a shared
/// `Instant` to subtract). Timestamps are read through a [`Clock`], so
/// the same pipeline stamps **virtual** time when handed a
/// [`crate::sim::SimClock`] (the simulator's deterministic traces) and
/// wall time everywhere else.
#[derive(Clone)]
pub struct EventLog {
    epoch: Instant,
    clock: Arc<dyn Clock>,
    tx: Sender<Event>,
    counters: Option<Arc<LiveCounters>>,
}

impl EventLog {
    /// A wall-clock log plus the collector end of its channel.
    ///
    /// ```
    /// use sparrow::metrics::{drain, EventKind, EventLog};
    ///
    /// let (log, rx) = EventLog::new();
    /// log.record(0, EventKind::Broadcast, Some((0, 1)), 0.9);
    /// let events = drain(&rx);
    /// assert_eq!(events.len(), 1);
    /// assert_eq!(events[0].kind.as_str(), "broadcast");
    /// ```
    pub fn new() -> (EventLog, Receiver<Event>) {
        EventLog::with_clock(Arc::new(RealClock))
    }

    /// An event log whose `elapsed` stamps come from `clock` (epoch =
    /// the clock's now at construction).
    pub fn with_clock(clock: Arc<dyn Clock>) -> (EventLog, Receiver<Event>) {
        let (tx, rx) = channel();
        (
            EventLog {
                epoch: clock.now(),
                clock,
                tx,
                counters: None,
            },
            rx,
        )
    }

    /// The same log, additionally bumping `counters` on every
    /// [`EventLog::record`] — the live feed behind the admin RPC's
    /// `metrics.snapshot` (DESIGN.md §10). The bump happens *after* the
    /// event is queued to the collector, so a counter snapshot never
    /// exceeds what a later drain of the event log will show.
    pub fn with_counters(mut self, counters: Arc<LiveCounters>) -> EventLog {
        self.counters = Some(counters);
        self
    }

    /// The shared epoch every `elapsed` stamp is measured from.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Record one event: timestamp it, queue it to the collector, then
    /// bump the live counter for `kind` (if counters are attached).
    pub fn record(&self, worker: usize, kind: EventKind, model: Option<(usize, u64)>, value: f64) {
        // send failures mean the collector is gone (run over) — ignore
        let _ = self.tx.send(Event {
            elapsed: self.clock.now().saturating_duration_since(self.epoch),
            worker,
            kind,
            model,
            value,
        });
        // after the send: snapshot ≤ eventual drain, the invariant the
        // control-plane storm test asserts
        if let Some(c) = &self.counters {
            c.bump(kind);
        }
    }
}

/// Drain every event currently buffered (collector side).
pub fn drain(rx: &Receiver<Event>) -> Vec<Event> {
    let mut out: Vec<Event> = rx.try_iter().collect();
    out.sort_by_key(|e| e.elapsed);
    out
}

/// JSON-lines rendering for offline analysis.
pub fn to_jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        let mut o = Json::obj();
        o.set("t", e.elapsed.as_secs_f64())
            .set("worker", e.worker)
            .set("kind", e.kind.as_str())
            .set("value", e.value);
        if let Some((w, s)) = e.model {
            o.set("model_origin", w).set("model_seq", s);
        }
        out.push_str(&o.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_drain_ordered() {
        let (log, rx) = EventLog::new();
        log.record(2, EventKind::Broadcast, Some((2, 1)), 0.9);
        log.record(0, EventKind::Receive, Some((2, 1)), 0.9);
        log.record(1, EventKind::Accept, Some((2, 1)), 0.9);
        let events = drain(&rx);
        assert_eq!(events.len(), 3);
        assert!(events.windows(2).all(|w| w[0].elapsed <= w[1].elapsed));
        assert_eq!(events[0].worker, 2);
    }

    #[test]
    fn clone_shares_channel_and_epoch() {
        let (log, rx) = EventLog::new();
        let log2 = log.clone();
        assert_eq!(log.epoch(), log2.epoch());
        log2.record(7, EventKind::Finish, None, 0.0);
        assert_eq!(drain(&rx).len(), 1);
    }

    #[test]
    fn virtual_clock_stamps_virtual_time() {
        use crate::sim::SimClock;
        let clock = Arc::new(SimClock::new());
        let (log, rx) = EventLog::with_clock(clock.clone());
        log.record(0, EventKind::Broadcast, None, 1.0);
        clock.advance(Duration::from_secs(5));
        log.record(1, EventKind::Accept, None, 1.0);
        let events = drain(&rx);
        // exact virtual stamps, no wall time leaked in
        assert_eq!(events[0].elapsed, Duration::ZERO);
        assert_eq!(events[1].elapsed, Duration::from_secs(5));
    }

    #[test]
    fn record_after_collector_drop_is_safe() {
        let (log, rx) = EventLog::new();
        drop(rx);
        log.record(0, EventKind::Crash, None, 0.0); // must not panic
    }

    #[test]
    fn jsonl_format() {
        let (log, rx) = EventLog::new();
        log.record(1, EventKind::Accept, Some((0, 3)), 0.5);
        let events = drain(&rx);
        let line = to_jsonl(&events);
        assert!(line.contains("\"kind\":\"accept\""));
        assert!(line.contains("\"model_origin\":0"));
        assert!(line.contains("\"model_seq\":3"));
        assert!(line.ends_with('\n'));
    }

    #[test]
    fn kind_names_unique() {
        let mut names: Vec<&str> = EventKind::ALL.iter().map(|k| k.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), EventKind::ALL.len());
    }

    #[test]
    fn kind_index_matches_all_order() {
        for (i, k) in EventKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "{} out of order", k.as_str());
        }
    }

    #[test]
    fn counters_track_records() {
        let counters = Arc::new(LiveCounters::new());
        let (log, rx) = EventLog::new();
        let log = log.with_counters(Arc::clone(&counters));
        log.record(0, EventKind::Accept, Some((1, 2)), 0.9);
        log.record(1, EventKind::Accept, Some((1, 2)), 0.9);
        log.record(0, EventKind::Reject, Some((0, 1)), 0.95);
        assert_eq!(counters.get(EventKind::Accept), 2);
        assert_eq!(counters.get(EventKind::Reject), 1);
        // counters never exceed what the log drains
        let events = drain(&rx);
        let accepts = events.iter().filter(|e| e.kind == EventKind::Accept).count();
        assert_eq!(accepts as u64, counters.get(EventKind::Accept));
    }

    #[test]
    fn counters_survive_collector_drop() {
        let counters = Arc::new(LiveCounters::new());
        let (log, rx) = EventLog::new();
        let log = log.with_counters(Arc::clone(&counters));
        drop(rx);
        log.record(0, EventKind::Crash, None, 0.0);
        assert_eq!(counters.get(EventKind::Crash), 1);
    }
}
