//! ASCII rendering of the TMSN execution timeline (paper Figure 1):
//! one lane per worker, glyphs for local improvements, broadcasts,
//! receptions (accept = the "yellow explosion" interrupt, reject = dot).

use std::time::Duration;

use crate::metrics::{Event, EventKind};

/// Render `events` into a lane-per-worker timeline of `width` columns.
pub fn render_timeline(events: &[Event], workers: usize, width: usize) -> String {
    let tmax = events
        .iter()
        .map(|e| e.elapsed)
        .max()
        .unwrap_or(Duration::ZERO)
        .as_secs_f64()
        .max(1e-9);
    let col = |t: Duration| -> usize {
        (((t.as_secs_f64() / tmax) * (width - 1) as f64) as usize).min(width - 1)
    };
    let mut lanes = vec![vec![b'-'; width]; workers];
    // crashes terminate the lane visually
    for e in events {
        if e.worker >= workers {
            continue;
        }
        let x = col(e.elapsed);
        let lane = &mut lanes[e.worker];
        let glyph = match e.kind {
            EventKind::LocalImprovement => b'F', // Found
            EventKind::Broadcast => b'B',
            EventKind::Accept => b'!', // interrupt ("explosion")
            EventKind::Reject => b'.',
            EventKind::Receive => continue, // implied by accept/reject
            EventKind::ResampleStart => b'[',
            EventKind::ResampleEnd => b']',
            EventKind::SampleSwap => b's',
            EventKind::BuildAbort => b'~',
            EventKind::GammaShrink => b'g',
            EventKind::Crash => b'X',
            EventKind::Finish => b'|',
            EventKind::Join => b'+',   // joined the swarm mid-run
            EventKind::Rejoin => b'^', // resumed from checkpoint after a crash
            // gossip relay hop: transport detail, not a protocol action
            EventKind::Forward => continue,
            // tiered-store I/O detail, not a Figure-1 protocol action
            EventKind::Spill | EventKind::ReadaheadHit | EventKind::ReadaheadMiss => continue,
            EventKind::PeerUp => b'u',
            EventKind::PeerDown => b'd',
            EventKind::Reconnect => b'r',
            // per-frame transport detail, not a Figure-1 protocol action
            EventKind::QueueDrop => continue,
        };
        // don't let low-priority glyphs overwrite high-priority ones
        let priority = |g: u8| match g {
            b'X' => 5,
            b'!' | b'B' | b'F' | b'+' | b'^' => 4,
            b'[' | b']' | b'|' | b's' => 3,
            b'g' | b'~' | b'r' => 2,
            b'.' | b'u' | b'd' => 1,
            _ => 0,
        };
        if priority(glyph) >= priority(lane[x]) {
            lane[x] = glyph;
        }
    }
    // blank out lanes after crash
    for e in events {
        if e.kind == EventKind::Crash && e.worker < workers {
            let x = col(e.elapsed);
            for c in lanes[e.worker][x + 1..].iter_mut() {
                *c = b' ';
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "timeline ({} workers, {:.2}s span)  F=found B=broadcast !=accepted-interrupt .=rejected [ ]=resample s=swap ~=build-abort g=gamma/2 X=crash u=peer-up d=peer-down r=reconnect\n",
        workers, tmax
    ));
    for (i, lane) in lanes.iter().enumerate() {
        out.push_str(&format!("w{i:<2} |"));
        out.push_str(std::str::from_utf8(lane).unwrap());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ms: u64, worker: usize, kind: EventKind) -> Event {
        Event {
            elapsed: Duration::from_millis(ms),
            worker,
            kind,
            model: None,
            value: 0.0,
        }
    }

    #[test]
    fn renders_lanes_and_glyphs() {
        let events = vec![
            ev(10, 0, EventKind::LocalImprovement),
            ev(11, 0, EventKind::Broadcast),
            ev(20, 1, EventKind::Accept),
            ev(30, 2, EventKind::Reject),
            ev(90, 1, EventKind::Finish),
        ];
        let s = render_timeline(&events, 3, 40);
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains('B') || s.contains('F'));
        assert!(s.contains('!'));
        assert!(s.contains('.'));
    }

    #[test]
    fn crash_blanks_rest_of_lane() {
        let events = vec![
            ev(10, 0, EventKind::Crash),
            ev(90, 1, EventKind::Finish),
        ];
        let s = render_timeline(&events, 2, 40);
        let lane0 = s.lines().nth(1).unwrap();
        assert!(lane0.contains('X'));
        assert!(lane0.trim_end().len() < 20, "{lane0:?}");
    }

    #[test]
    fn empty_events_safe() {
        let s = render_timeline(&[], 2, 20);
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn out_of_range_worker_ignored() {
        let events = vec![ev(5, 9, EventKind::Broadcast)];
        let s = render_timeline(&events, 2, 20);
        // lanes (all lines after the header) contain no broadcast glyph
        let lanes: Vec<&str> = s.lines().skip(1).collect();
        assert!(lanes.iter().all(|l| !l.contains('B')), "{lanes:?}");
    }
}
