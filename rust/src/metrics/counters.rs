//! Live, lock-free event counters — the data behind the admin RPC's
//! `metrics.snapshot` (DESIGN.md §10).
//!
//! The post-hoc event timeline answers "what happened"; an operator
//! steering a live swarm needs "what is happening *now*" without stopping
//! the run or scraping logs. [`LiveCounters`] keeps one atomic counter per
//! [`EventKind`], bumped by [`crate::metrics::EventLog::record`] *after*
//! the event is queued to the collector — so at any instant the counter
//! value is a count of events already on the collector channel, and a
//! snapshot can never claim an event the log will not eventually show
//! (the consistency invariant the control-plane tests pin down).
//!
//! # Example
//!
//! ```
//! use std::sync::Arc;
//! use sparrow::metrics::{EventKind, EventLog, LiveCounters};
//!
//! let counters = Arc::new(LiveCounters::new());
//! let (log, _rx) = EventLog::new();
//! let log = log.with_counters(Arc::clone(&counters));
//! log.record(0, EventKind::Accept, Some((1, 3)), 0.9);
//! log.record(0, EventKind::Reject, Some((2, 1)), 0.95);
//! assert_eq!(counters.get(EventKind::Accept), 1);
//! assert_eq!(counters.get(EventKind::Reject), 1);
//! assert_eq!(counters.get(EventKind::Broadcast), 0);
//! ```

use std::sync::atomic::{AtomicU64, Ordering};

use crate::metrics::EventKind;

/// One atomic counter per [`EventKind`]; cheap to share across every
/// thread that holds an [`crate::metrics::EventLog`] clone.
#[derive(Debug, Default)]
pub struct LiveCounters {
    counts: [AtomicU64; EventKind::ALL.len()],
}

impl LiveCounters {
    /// All counters at zero.
    pub fn new() -> LiveCounters {
        LiveCounters::default()
    }

    /// Bump the counter for `kind` (called by `EventLog::record`).
    pub fn bump(&self, kind: EventKind) {
        self.counts[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Current count for `kind`.
    pub fn get(&self, kind: EventKind) -> u64 {
        self.counts[kind.index()].load(Ordering::Relaxed)
    }

    /// `(wire name, count)` for every event kind, in [`EventKind::ALL`]
    /// order — the rows of the `metrics.snapshot` RPC's `events` object.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        EventKind::ALL
            .iter()
            .map(|k| (k.as_str(), self.get(*k)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_and_get() {
        let c = LiveCounters::new();
        assert_eq!(c.get(EventKind::Accept), 0);
        c.bump(EventKind::Accept);
        c.bump(EventKind::Accept);
        c.bump(EventKind::Crash);
        assert_eq!(c.get(EventKind::Accept), 2);
        assert_eq!(c.get(EventKind::Crash), 1);
        assert_eq!(c.get(EventKind::Reject), 0);
    }

    #[test]
    fn snapshot_covers_every_kind_once() {
        let c = LiveCounters::new();
        for k in EventKind::ALL {
            c.bump(k);
        }
        let snap = c.snapshot();
        assert_eq!(snap.len(), EventKind::ALL.len());
        assert!(snap.iter().all(|(_, n)| *n == 1));
        let mut names: Vec<&str> = snap.iter().map(|(n, _)| *n).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), EventKind::ALL.len());
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let c = Arc::new(LiveCounters::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.bump(EventKind::Broadcast);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(EventKind::Broadcast), 4000);
    }
}
