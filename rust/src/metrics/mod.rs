//! Event log and execution timeline — the instrumentation behind Figure 1
//! (the TMSN execution timeline), the §Perf counters, and the live
//! `metrics.snapshot` admin RPC (DESIGN.md §10).

#![warn(missing_docs)]

pub mod counters;
pub mod events;
pub mod timeline;

pub use counters::LiveCounters;
pub use events::{drain, Event, EventKind, EventLog};
pub use timeline::render_timeline;
