//! Event log and execution timeline — the instrumentation behind Figure 1
//! (the TMSN execution timeline) and the §Perf counters.

pub mod events;
pub mod timeline;

pub use events::{drain, Event, EventKind, EventLog};
pub use timeline::render_timeline;
