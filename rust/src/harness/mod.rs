//! Experiment harness shared by `rust/benches/` and `examples/` — builds
//! the standard synthetic splice-site workload (cached on disk), runs each
//! trainer with consistent settings, and extracts the Table-1 /
//! Figure-3/4 measurements.
//!
//! Scale: every experiment honors `SPARROW_BENCH_SCALE` (default 1.0) so a
//! quick smoke run (`SPARROW_BENCH_SCALE=0.1 cargo bench`) and the full
//! reproduction use the same code path.

use std::path::PathBuf;
use std::time::Duration;

use crate::baselines::{
    train_bulk_sync, train_fullscan, train_goss, BulkSyncConfig, DataSource, FullScanConfig,
    GossConfig, StopConditions,
};
use crate::config::TrainConfig;
use crate::coordinator::{train_cluster, ClusterOutcome};
use crate::data::synth::SynthGen;
use crate::data::{DataBlock, DiskStore, SynthConfig};
use crate::eval::MetricSeries;

/// The standard experiment workload (DESIGN.md E1-E6).
#[derive(Debug, Clone)]
pub struct Workload {
    pub train_n: usize,
    pub test_n: usize,
    pub features: usize,
    pub synth: SynthConfig,
}

impl Workload {
    /// Default splice-site-like workload, scaled by `SPARROW_BENCH_SCALE`.
    pub fn standard() -> Workload {
        let scale = bench_scale();
        let train_n = ((60_000.0 * scale) as usize).max(2_000);
        let test_n = ((8_000.0 * scale) as usize).max(500);
        let features = 32;
        Workload {
            train_n,
            test_n,
            features,
            synth: SynthConfig {
                f: features,
                pos_rate: 0.08,
                informative: 12,
                signal: 0.45,
                flip_rate: 0.02,
                seed: 0xBEEF,
            },
        }
    }

    /// Bigger variant for the end-to-end example (`splice_site.rs`).
    pub fn large() -> Workload {
        let scale = bench_scale();
        let w = Workload::standard();
        Workload {
            train_n: ((200_000.0 * scale) as usize).max(5_000),
            test_n: ((20_000.0 * scale) as usize).max(1_000),
            features: 64,
            synth: SynthConfig {
                f: 64,
                informative: 24,
                ..w.synth
            },
        }
    }

    /// Build (or reuse) the on-disk store + in-memory test block.
    /// Stores are cached under the target dir, keyed by the size and the
    /// full [`SynthConfig`] fingerprint — two workloads differing in *any*
    /// generator field (pos_rate, signal, flip_rate, …) must never reuse
    /// each other's store.
    pub fn materialize(&self) -> std::io::Result<(PathBuf, DataBlock)> {
        let dir = std::env::temp_dir().join("sparrow_workloads");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!(
            "w_{}_{}_{:016x}.sprw",
            self.train_n,
            self.features,
            self.synth.fingerprint()
        ));
        let mut gen = SynthGen::new(self.synth.clone());
        if !path.exists() || DiskStore::open(&path).map(|s| s.len()).unwrap_or(0) != self.train_n {
            gen.write_store(&path, self.train_n)?;
        } else {
            // advance the generator stream as if we had written the store,
            // so the test block is identical whether or not we hit cache
            let mut remaining = self.train_n;
            while remaining > 0 {
                let take = remaining.min(8192);
                gen.next_block(take);
                remaining -= take;
            }
        }
        let test = gen.next_block(self.test_n);
        Ok((path, test))
    }
}

/// `SPARROW_BENCH_SCALE` (default 1.0).
pub fn bench_scale() -> f64 {
    std::env::var("SPARROW_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Default stop conditions for experiments.
pub fn stop(max_rules: usize, secs: f64, target_loss: f64) -> StopConditions {
    StopConditions {
        max_rules,
        time_limit: Duration::from_secs_f64(secs),
        target_loss,
        eval_interval: Duration::from_millis(100),
    }
}

/// Sparrow cluster run with native backend (benches default to native so
/// they measure the algorithms, not PJRT dispatch; ablation_backend
/// measures the backends explicitly).
pub fn run_sparrow(
    workers: usize,
    store: &std::path::Path,
    test: &DataBlock,
    label: &str,
    patch: impl FnOnce(&mut TrainConfig),
) -> anyhow::Result<ClusterOutcome> {
    let mut cfg = TrainConfig {
        num_workers: workers,
        sample_size: 4096,
        max_rules: 400,
        time_limit: Duration::from_secs(120),
        eval_interval: Duration::from_millis(100),
        seed: 7,
        ..TrainConfig::default()
    };
    patch(&mut cfg);
    train_cluster(&cfg, store, test, label, &|_| {
        Ok(Box::new(crate::scanner::NativeBackend))
    })
}

/// Baseline runners returning their metric series.
pub fn run_fullscan(
    source: &DataSource,
    test: &DataBlock,
    stop: StopConditions,
    label: &str,
) -> MetricSeries {
    train_fullscan(
        source,
        test,
        &FullScanConfig {
            stop,
            ..FullScanConfig::default()
        },
        label,
    )
    .expect("fullscan")
    .series
}

pub fn run_goss(
    source: &DataSource,
    test: &DataBlock,
    stop: StopConditions,
    label: &str,
) -> MetricSeries {
    train_goss(
        source,
        test,
        &GossConfig {
            stop,
            ..GossConfig::default()
        },
        label,
    )
    .expect("goss")
    .series
}

pub fn run_bulk_sync(
    train: &DataBlock,
    test: &DataBlock,
    workers: usize,
    laggards: Vec<(usize, f64)>,
    stop: StopConditions,
    label: &str,
) -> MetricSeries {
    train_bulk_sync(
        train,
        test,
        &BulkSyncConfig {
            workers,
            laggards,
            stop,
            ..BulkSyncConfig::default()
        },
        label,
    )
    .series
}

/// "time to target" cell for Table 1: seconds, or "—" if never reached.
pub fn time_to(series: &MetricSeries, target: f64) -> String {
    series
        .time_to_loss(target)
        .map(|d| format!("{:.2}", d.as_secs_f64()))
        .unwrap_or_else(|| "—".to_string())
}

/// The off-memory disk bandwidth used by Table-1 style experiments.
/// Chosen so a full pass over the standard workload costs visible-but-
/// bounded time on this testbed (models the x1e vs r3 tier gap).
pub fn off_memory_bandwidth() -> f64 {
    100.0 * 1024.0 * 1024.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_materialize_is_cached_and_deterministic() {
        let w = Workload {
            train_n: 500,
            test_n: 100,
            features: 8,
            synth: SynthConfig {
                f: 8,
                pos_rate: 0.3,
                informative: 4,
                signal: 0.8,
                flip_rate: 0.0,
                seed: 0xAB,
            },
        };
        let (p1, t1) = w.materialize().unwrap();
        let (p2, t2) = w.materialize().unwrap();
        assert_eq!(p1, p2);
        assert_eq!(t1, t2, "test block must not depend on cache state");
        assert_eq!(DiskStore::open(&p1).unwrap().len(), 500);
    }

    #[test]
    fn cache_key_distinguishes_generator_fields() {
        // Regression: the cache filename used to omit pos_rate, signal,
        // and flip_rate, silently handing one workload another's store.
        let base = Workload {
            train_n: 400,
            test_n: 100,
            features: 8,
            synth: SynthConfig {
                f: 8,
                pos_rate: 0.3,
                informative: 4,
                signal: 0.8,
                flip_rate: 0.0,
                seed: 0xCA7,
            },
        };
        let (p_base, _) = base.materialize().unwrap();
        let patches: [fn(&mut SynthConfig); 3] = [
            |s| s.pos_rate = 0.31,
            |s| s.signal = 0.81,
            |s| s.flip_rate = 0.01,
        ];
        for patch in patches {
            let mut w = base.clone();
            patch(&mut w.synth);
            let (p, _) = w.materialize().unwrap();
            assert_ne!(p, p_base, "distinct configs must get distinct stores");
        }
    }

    #[test]
    fn standard_workload_scales() {
        let w = Workload::standard();
        assert!(w.train_n >= 2000);
        assert_eq!(w.features, w.synth.f);
    }

    #[test]
    fn time_to_formats() {
        let mut s = MetricSeries::new("x");
        s.push(crate::eval::MetricPoint {
            elapsed: Duration::from_millis(1500),
            iterations: 1,
            exp_loss: 0.5,
            auprc: 0.5,
        });
        assert_eq!(time_to(&s, 0.6), "1.50");
        assert_eq!(time_to(&s, 0.1), "—");
    }
}
