//! Deterministic fault-injection simulator (DESIGN.md §9, §12).
//!
//! The paper's headline resilience claim — TMSN "does not require
//! synchronization or a head node and is highly resilient against failing
//! machines or laggards" — is validated here as a *replayable* property:
//! a seeded, single-threaded discrete-event simulator runs the real
//! protocol state machine ([`crate::tmsn::Tmsn`]) over a simulated wire
//! ([`SimNet`], implementing the generic [`crate::tmsn::Link`]) under
//! **virtual time** ([`SimClock`]), while a scripted [`Scenario`] injects
//! crashes, checkpoint-resuming restarts, mid-run joins, laggards, and
//! (one- or two-way) partitions at exact virtual timestamps.
//!
//! Because every stochastic choice flows from one seeded RNG and the
//! event loop is single-threaded with a total deterministic order over
//! simultaneous events, the run's full [`SimTrace`] is a pure function of
//! `(seed, config, scenario)` — byte-identical across runs, asserted in
//! `tests/sim_cluster.rs`. The engine also checks the TMSN invariants
//! *continuously* while faults fire:
//!
//! 1. **verdict soundness** — a message is accepted iff its certificate
//!    is strictly better than the worker's current one;
//! 2. **certificate monotonicity** — no worker's certificate ever
//!    worsens (per incarnation; a resumed incarnation starts from its
//!    checkpoint, never worse than empty);
//! 3. **local-improvement soundness** — a worker never publishes a
//!    payload that does not strictly improve on its own.
//!
//! Violations are collected (not panicked) so a failing scenario reports
//! every broken invariant alongside its replayable trace — which
//! [`crate::sim::minimize`] can then shrink to a minimal repro.
//!
//! In gossip mode ([`crate::network::BroadcastMode::Fanout`]) the engine
//! adds the relay rule: a worker that *accepts* a payload with remaining
//! TTL re-forwards it to `k` peers with `ttl − 1`. Rejected (dominated)
//! payloads are never forwarded, so only the improving frontier floods.

pub mod clock;
pub mod minimize;
pub mod net;
pub mod scenario;
pub mod trace;
pub mod workloads;

pub use clock::{Clock, RealClock, SimClock};
pub use minimize::{minimize, Minimized};
pub use net::{EdgeFaults, SimEndpoint, SimNet, SimNetConfig, SimNetStats};
pub use scenario::{Scenario, ScenarioEvent};
pub use trace::SimTrace;
pub use workloads::{sgd_sim_fixture, BoostSimWorker, SgdSimWorker, SimWorker};

use std::sync::Arc;
use std::time::Duration;

use crate::metrics::{events, Event, EventKind, EventLog};
use crate::tmsn::{Certified, Link, Payload, Tmsn, Verdict};
use crate::util::rng::Rng;

/// Configuration of one simulated cluster run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// initial cluster size (the swarm may grow via
    /// [`ScenarioEvent::Join`])
    pub workers: usize,
    /// master seed: forked into the net's fault RNG (workload seeds are
    /// derived by the caller's spawn function)
    pub seed: u64,
    /// wire fault model and broadcast mode
    pub net: SimNetConfig,
    /// scripted fault schedule
    pub scenario: Scenario,
    /// virtual-time budget for local work; after the horizon no new work
    /// units start, in-flight messages drain, and survivors do one final
    /// inbox sweep
    pub horizon: Duration,
    /// per-worker cap on work units (safety backstop)
    pub max_steps: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            workers: 4,
            seed: 1,
            net: SimNetConfig::default(),
            scenario: Scenario::new(),
            horizon: Duration::from_millis(1500),
            max_steps: 100_000,
        }
    }
}

/// Final per-worker accounting (accumulated across incarnations).
#[derive(Debug, Clone)]
pub struct WorkerSummary {
    /// worker id
    pub id: usize,
    /// alive at the end of the run (never crashed, or restarted)
    pub alive: bool,
    /// number of restarts (incarnations − 1)
    pub restarts: u64,
    /// work units performed
    pub steps: u64,
    /// payloads published
    pub published: u64,
    /// messages accepted / rejected by the verdict rule
    pub accepts: u64,
    /// see `accepts`
    pub rejects: u64,
    /// final certificate summary (lower = better for both workloads)
    pub final_summary: f64,
}

/// Everything one simulated run produces.
#[derive(Debug)]
pub struct SimReport<P: Payload> {
    /// best payload ever published on the wire
    pub best: P,
    /// per-worker accounting (grows if the scenario joins workers)
    pub workers: Vec<WorkerSummary>,
    /// TMSN invariant violations observed (empty = the claims held)
    pub violations: Vec<String>,
    /// the deterministic event trace (byte-identical per seed)
    pub trace: String,
    /// protocol events with **virtual** timestamps, via the unmodified
    /// metrics pipeline
    pub events: Vec<Event>,
    /// wire counters
    pub net: SimNetStats,
    /// virtual time at the end of the run
    pub virtual_elapsed: Duration,
}

impl<P: Payload> SimReport<P> {
    /// Did every surviving worker end on the best published certificate?
    /// (The §2 convergence claim; meaningful when the scenario heals all
    /// partitions and the wire has no iid drop.)
    pub fn survivors_converged(&self) -> bool {
        let best = self.best.cert().summary();
        self.workers
            .iter()
            .filter(|w| w.alive)
            .all(|w| w.final_summary == best)
    }
}

struct Slot<P: Payload, W> {
    tmsn: Tmsn<P>,
    worker: W,
    ep: SimEndpoint<P>,
    alive: bool,
    speed: f64,
    next_ready: Duration,
    steps: u64,
    published: u64,
    restarts: u64,
    /// verdict counters of completed incarnations
    acc_accepts: u64,
    acc_rejects: u64,
    /// last certificate, for the monotonicity invariant (reset to the
    /// checkpoint on resume)
    prev_cert: <P as Payload>::Cert,
}

/// Drain one worker's inbox through the real verdict rule, checking the
/// accept-iff-strictly-better and monotonicity invariants per message.
/// In fanout mode, accepted payloads with hop budget left are re-forwarded
/// (gossip relay); rejected payloads die here.
fn drain_inbox<P: Payload, W: SimWorker<P>>(
    slot: &mut Slot<P, W>,
    t: Duration,
    log: &EventLog,
    trace: &mut SimTrace,
    violations: &mut Vec<String>,
) {
    while let Some((msg, ttl)) = slot.ep.poll_with_ttl() {
        let id = slot.tmsn.worker_id();
        let (origin, seq) = (msg.cert().origin(), msg.cert().seq());
        let val = msg.cert().summary();
        let expected = msg.cert().better_than(slot.tmsn.cert());
        log.record(id, EventKind::Receive, Some((origin, seq)), val);
        match slot.tmsn.on_message(msg) {
            Verdict::Accept => {
                log.record(id, EventKind::Accept, Some((origin, seq)), val);
                trace.push(t, &format!("w{id}   accept  {origin}#{seq} cert={val:.9}"));
                if !expected {
                    violations.push(format!(
                        "worker {id} ACCEPTED a not-strictly-better cert {origin}#{seq} at {t:?}"
                    ));
                }
                let adopted = slot.tmsn.payload().clone();
                slot.worker.on_adopt(&adopted);
                if ttl > 0 {
                    log.record(id, EventKind::Forward, Some((origin, seq)), val);
                    trace.push(t, &format!("w{id}   forward {origin}#{seq} ttl={}", ttl - 1));
                    slot.ep.forward(adopted, ttl - 1);
                }
            }
            Verdict::Reject => {
                log.record(id, EventKind::Reject, Some((origin, seq)), val);
                trace.push(t, &format!("w{id}   reject  {origin}#{seq}"));
                if expected {
                    violations.push(format!(
                        "worker {id} REJECTED a strictly-better cert {origin}#{seq} at {t:?}"
                    ));
                }
            }
        }
        check_monotone(slot, t, violations);
    }
}

fn check_monotone<P: Payload, W>(slot: &mut Slot<P, W>, t: Duration, violations: &mut Vec<String>) {
    let cur = slot.tmsn.cert().clone();
    if slot.prev_cert.better_than(&cur) {
        violations.push(format!(
            "worker {} certificate WORSENED ({} -> {}) at {t:?}",
            slot.tmsn.worker_id(),
            slot.prev_cert.summary(),
            cur.summary()
        ));
    }
    slot.prev_cert = cur;
}

/// One worker turn: receive path, one local work unit, send path.
#[allow(clippy::too_many_arguments)]
fn worker_turn<P: Payload, W: SimWorker<P>>(
    slot: &mut Slot<P, W>,
    t: Duration,
    log: &EventLog,
    trace: &mut SimTrace,
    violations: &mut Vec<String>,
    best: &mut P,
) {
    drain_inbox(slot, t, log, trace, violations);
    let current = slot.tmsn.payload().clone();
    let (base_cost, candidate) = slot.worker.step(&current);
    slot.steps += 1;
    // never let a zero-cost step freeze virtual time
    let cost = base_cost.mul_f64(slot.speed).max(Duration::from_micros(1));
    slot.next_ready = t + cost;
    if let Some(p) = candidate {
        let id = slot.tmsn.worker_id();
        if p.cert().better_than(slot.tmsn.cert()) {
            let msg = slot.tmsn.local_update(p);
            let seq = msg.cert().seq();
            let val = msg.cert().summary();
            log.record(id, EventKind::LocalImprovement, Some((id, seq)), val);
            slot.ep.send(msg.clone());
            log.record(id, EventKind::Broadcast, Some((id, seq)), val);
            trace.push(t, &format!("w{id}   publish seq={seq} cert={val:.9}"));
            slot.published += 1;
            if msg.cert().better_than(best.cert()) {
                *best = msg;
            }
        } else {
            violations.push(format!(
                "worker {id} produced a NON-IMPROVING candidate at {t:?}"
            ));
        }
    }
    check_monotone(slot, t, violations);
}

fn fresh_slot<P: Payload, W>(id: usize, worker: W, ep: SimEndpoint<P>, t: Duration) -> Slot<P, W> {
    Slot {
        tmsn: Tmsn::new(id),
        worker,
        ep,
        alive: true,
        speed: 1.0,
        next_ready: t,
        steps: 0,
        published: 0,
        restarts: 0,
        acc_accepts: 0,
        acc_rejects: 0,
        prev_cert: <P as Payload>::Cert::initial(),
    }
}

/// Run one scenario to completion and report.
///
/// `spawn(id, incarnation)` builds a worker's local-search state;
/// incarnation 0 is the initial boot, 1+ follow restarts. Derive any
/// workload randomness from both arguments so restarted workers are
/// deterministic too.
///
/// Panics if the scenario fails [`Scenario::validate`] against
/// `cfg.workers` (out-of-range references or non-dense joins).
pub fn run_scenario<P, W, F>(cfg: &SimConfig, mut spawn: F) -> SimReport<P>
where
    P: Payload,
    W: SimWorker<P>,
    F: FnMut(usize, u64) -> W,
{
    assert!(cfg.workers >= 1, "need at least one worker");
    let final_size = cfg
        .scenario
        .validate(cfg.workers)
        .unwrap_or_else(|e| panic!("invalid scenario: {e}"));

    let clock = Arc::new(SimClock::new());
    let (log, event_rx) = EventLog::with_clock(clock.clone());
    let mut master = Rng::new(cfg.seed);
    let (net, endpoints) = SimNet::<P>::new(cfg.workers, cfg.net.clone(), master.fork(0xE7));
    let mut trace = SimTrace::new();
    let mut violations: Vec<String> = Vec::new();
    let mut best = P::initial();

    let mut slots: Vec<Slot<P, W>> = endpoints
        .into_iter()
        .enumerate()
        .map(|(id, ep)| fresh_slot(id, spawn(id, 0), ep, Duration::ZERO))
        .collect();

    let sched = cfg.scenario.sorted();
    let mut sidx = 0usize;

    loop {
        // earliest pending event across the three sources
        let t_scn = (sidx < sched.len()).then(|| sched[sidx].0);
        let t_net = net.next_due();
        let t_work = slots
            .iter()
            .filter(|s| s.alive && s.steps < cfg.max_steps && s.next_ready <= cfg.horizon)
            .map(|s| s.next_ready)
            .min();
        let Some(t) = [t_scn, t_net, t_work].into_iter().flatten().min() else {
            break;
        };
        clock.advance_to(t);
        net.set_now(t);

        // 1) scenario events due at t (stable order)
        while sidx < sched.len() && sched[sidx].0 <= t {
            let ev = &sched[sidx].1;
            trace.push(t, &ev.describe());
            match ev {
                ScenarioEvent::Crash(i) => {
                    let s = &mut slots[*i];
                    if s.alive {
                        s.alive = false;
                        net.set_down(*i, true);
                        log.record(*i, EventKind::Crash, None, 0.0);
                    }
                }
                ScenarioEvent::Restart(i) => {
                    let s = &mut slots[*i];
                    if !s.alive {
                        s.acc_accepts += s.tmsn.accepts;
                        s.acc_rejects += s.tmsn.rejects;
                        s.restarts += 1;
                        s.alive = true;
                        // checkpoint-based rejoin (DESIGN.md §12): the new
                        // incarnation resumes from the last committed
                        // payload instead of starting empty, and catches
                        // up from broadcasts alone
                        let checkpoint = s.tmsn.payload().clone();
                        s.tmsn = Tmsn::resume(*i, checkpoint);
                        s.worker = spawn(*i, s.restarts);
                        s.worker.on_adopt(s.tmsn.payload());
                        s.prev_cert = s.tmsn.cert().clone();
                        s.next_ready = t;
                        net.set_down(*i, false);
                        let val = s.tmsn.cert().summary();
                        log.record(*i, EventKind::Rejoin, None, val);
                        trace.push(t, &format!("w{i}   resume  cert={val:.9}"));
                    }
                }
                ScenarioEvent::Join(i) => {
                    // dynamic membership: the swarm grows by one; the new
                    // worker starts empty and converges from broadcasts
                    assert_eq!(*i, slots.len(), "joins are dense (validated)");
                    let ep = net.join();
                    debug_assert_eq!(ep.id(), *i);
                    log.record(*i, EventKind::Join, None, 0.0);
                    slots.push(fresh_slot(*i, spawn(*i, 0), ep, t));
                }
                ScenarioEvent::Laggard(i, k) => {
                    assert!(*k > 0.0, "laggard factor must be positive");
                    slots[*i].speed = *k;
                }
                ScenarioEvent::Partition(groups) => net.partition(groups),
                ScenarioEvent::PartitionOneWay(edges) => net.partition_oneway(edges),
                ScenarioEvent::Heal => net.heal(),
            }
            sidx += 1;
        }

        // 2) wire deliveries due at t
        net.deliver_due(t);
        for (wt, line) in net.drain_wire_log() {
            trace.push(wt, &line);
        }

        // 3) worker turns due at t (ascending id — deterministic)
        for i in 0..slots.len() {
            let due = slots[i].alive
                && slots[i].steps < cfg.max_steps
                && slots[i].next_ready <= t
                && slots[i].next_ready <= cfg.horizon;
            if due {
                worker_turn(&mut slots[i], t, &log, &mut trace, &mut violations, &mut best);
            }
        }
        // send-time wire events (drops/dups/blocks) from this round's turns
        for (wt, line) in net.drain_wire_log() {
            trace.push(wt, &line);
        }
    }

    // quiescence: survivors sweep their inboxes (adopt-only). In fanout
    // mode an accept during the sweep re-forwards, putting new gossip on
    // the wire — so iterate to a fixpoint: drain inboxes, deliver the next
    // due batch, repeat until nothing is in flight. Terminates because
    // every forward is triggered by a strict improvement and the set of
    // published certificates is finite.
    let mut t_end = clock.now_virtual();
    loop {
        for slot in slots.iter_mut() {
            if slot.alive {
                drain_inbox(slot, t_end, &log, &mut trace, &mut violations);
            }
        }
        for (wt, line) in net.drain_wire_log() {
            trace.push(wt, &line);
        }
        let Some(due) = net.next_due() else { break };
        t_end = t_end.max(due);
        clock.advance_to(t_end);
        net.set_now(t_end);
        net.deliver_due(t_end);
        for (wt, line) in net.drain_wire_log() {
            trace.push(wt, &line);
        }
    }
    for slot in slots.iter_mut() {
        if slot.alive {
            log.record(slot.tmsn.worker_id(), EventKind::Finish, None, slot.tmsn.cert().summary());
        }
    }

    let workers: Vec<WorkerSummary> = slots
        .iter()
        .map(|s| WorkerSummary {
            id: s.tmsn.worker_id(),
            alive: s.alive,
            restarts: s.restarts,
            steps: s.steps,
            published: s.published,
            accepts: s.acc_accepts + s.tmsn.accepts,
            rejects: s.acc_rejects + s.tmsn.rejects,
            final_summary: s.tmsn.cert().summary(),
        })
        .collect();

    debug_assert_eq!(workers.len(), final_size, "every validated join must have fired");
    debug_assert_eq!(net.queue_len(), 0, "event loop exited with messages in flight");
    SimReport {
        best,
        workers,
        violations,
        trace: trace.text(),
        events: events::drain(&event_rx),
        net: net.stats(),
        virtual_elapsed: t_end,
    }
}

/// Named scenario presets shared by the test suite and the `sparrow sim`
/// CLI; all timestamps are inside the default 1.5 s horizon.
pub const PRESETS: &[&str] = &[
    "calm",
    "crash",
    "laggard",
    "partition",
    "churn",
    "join",
    "churn_large",
];

/// Build a preset schedule for an `n`-worker cluster; `None` for unknown
/// names. See [`PRESETS`]. Every preset is a pure function of `n`, so the
/// run trace stays a pure function of `(seed, preset, n)`.
pub fn preset(name: &str, n: usize) -> Option<Scenario> {
    let ms = Duration::from_millis;
    Some(match name {
        // fault-free control run
        "calm" => Scenario::new(),
        // staggered fail-stop of the top half of the cluster
        "crash" => (0..n / 2).fold(Scenario::new(), |s, k| {
            s.at(ms(300 + 120 * k as u64), ScenarioEvent::Crash(n - 1 - k))
        }),
        // one machine turns 8x slower early on
        "laggard" => Scenario::new().at(ms(100), ScenarioEvent::Laggard(1 % n, 8.0)),
        // clean split, healed while work continues
        "partition" => {
            let a: Vec<usize> = (0..n / 2).collect();
            let b: Vec<usize> = (n / 2..n).collect();
            Scenario::new()
                .at(ms(300), ScenarioEvent::Partition(vec![a, b]))
                .at(ms(900), ScenarioEvent::Heal)
        }
        // everything at once: laggard + crash + partition + heal + restart
        "churn" => {
            let a: Vec<usize> = (0..n / 2).collect();
            let b: Vec<usize> = (n / 2..n).collect();
            Scenario::new()
                .at(ms(200), ScenarioEvent::Laggard(0, 4.0))
                .at(ms(300), ScenarioEvent::Crash(1 % n))
                .at(ms(500), ScenarioEvent::Partition(vec![a, b]))
                .at(ms(800), ScenarioEvent::Heal)
                .at(ms(900), ScenarioEvent::Restart(1 % n))
                .at(ms(1200), ScenarioEvent::Crash(n - 1))
        }
        // elastic membership: two workers join mid-run, one original
        // worker crashes and resumes from its checkpoint
        "join" => Scenario::new()
            .at(ms(200), ScenarioEvent::Join(n))
            .at(ms(400), ScenarioEvent::Join(n + 1))
            .at(ms(600), ScenarioEvent::Crash(0))
            .at(ms(900), ScenarioEvent::Restart(0)),
        // the full elastic-swarm battery: seeded joins, crash/rejoin
        // waves, laggards, a symmetric split, and a one-way fault — scales
        // from 5 to 1000 workers as a pure function of n
        "churn_large" => {
            let mut rng = Rng::new(0xC0FF_EE ^ n as u64);
            let mut s = Scenario::new();
            // dense joins at non-decreasing times: id order must agree
            // with time order, so no per-join jitter
            let joins = (n / 5).clamp(1, 200);
            for j in 0..joins {
                let t = 150 + (j as u64 * 400) / joins as u64;
                s = s.at(ms(t), ScenarioEvent::Join(n + j));
            }
            // crash a quarter of the initial swarm; every second victim
            // resumes from its checkpoint later
            let mut victims: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut victims);
            let crashes = (n / 4).max(1);
            let mut crashed = vec![false; n];
            for (c, &w) in victims.iter().take(crashes).enumerate() {
                let t = 250 + rng.below(551);
                s = s.at(ms(t), ScenarioEvent::Crash(w));
                crashed[w] = true;
                if c % 2 == 1 {
                    s = s.at(ms(t + 150 + rng.below(251)), ScenarioEvent::Restart(w));
                }
            }
            // a few laggards among the never-crashed
            for &w in victims.iter().rev().take((n / 20).max(1)) {
                if !crashed[w] {
                    let t = 100 + rng.below(301);
                    s = s.at(ms(t), ScenarioEvent::Laggard(w, 2.0 + rng.f64() * 6.0));
                }
            }
            // a symmetric split (joined workers are isolated until heal),
            // then an asymmetric fault
            if n >= 3 {
                let a: Vec<usize> = (0..n / 3).collect();
                let b: Vec<usize> = (n / 3..n).collect();
                s = s
                    .at(ms(400), ScenarioEvent::Partition(vec![a, b]))
                    .at(ms(650), ScenarioEvent::Heal);
            }
            if n >= 4 {
                s = s
                    .at(ms(700), ScenarioEvent::PartitionOneWay(vec![(0, 1), (2, 3)]))
                    .at(ms(1000), ScenarioEvent::Heal);
            }
            s
        }
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::BroadcastMode;
    use crate::tmsn::testpay::TestPayload;

    /// Trivial deterministic workload: improve by 10% every step.
    struct Halver {
        score: f64,
    }
    impl SimWorker<TestPayload> for Halver {
        fn step(&mut self, _current: &TestPayload) -> (Duration, Option<TestPayload>) {
            self.score *= 0.9;
            (
                Duration::from_millis(10),
                Some(TestPayload::scored("h", self.score)),
            )
        }
        fn on_adopt(&mut self, adopted: &TestPayload) {
            // continue from the adopted score so candidates keep improving
            self.score = self.score.min(adopted.cert.score);
        }
    }

    fn cfg(workers: usize, scenario: Scenario) -> SimConfig {
        SimConfig {
            workers,
            scenario,
            horizon: Duration::from_millis(200),
            ..SimConfig::default()
        }
    }

    fn run(c: &SimConfig) -> SimReport<TestPayload> {
        run_scenario(c, |id, _inc| Halver {
            score: 100.0 + id as f64,
        })
    }

    #[test]
    fn trivial_run_converges_and_is_deterministic() {
        let c = cfg(3, Scenario::new());
        let a = run(&c);
        let b = run(&c);
        assert_eq!(a.trace, b.trace, "trace must be a pure function of the seed");
        assert!(a.violations.is_empty(), "{:?}", a.violations);
        assert!(a.survivors_converged());
        assert!(a.best.cert.score < 100.0);
        assert!(a.net.delivered > 0, "peers must actually hear each other");
        assert!(a.workers.iter().all(|w| w.steps > 0));
    }

    #[test]
    fn crash_stops_a_worker_and_survivors_continue() {
        let c = cfg(
            3,
            Scenario::new().at(Duration::from_millis(50), ScenarioEvent::Crash(2)),
        );
        let r = run(&c);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert!(!r.workers[2].alive);
        let crashed_steps = r.workers[2].steps;
        assert!(crashed_steps < r.workers[0].steps, "crash must stop work");
        assert!(r.survivors_converged());
        assert!(r.trace.contains("w2   crash"));
    }

    #[test]
    fn restart_resumes_from_checkpoint() {
        let c = cfg(
            2,
            Scenario::new()
                .at(Duration::from_millis(40), ScenarioEvent::Crash(1))
                .at(Duration::from_millis(120), ScenarioEvent::Restart(1)),
        );
        let r = run(&c);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert!(r.workers[1].alive);
        assert_eq!(r.workers[1].restarts, 1);
        assert!(r.survivors_converged(), "restarted worker must catch up");
        assert!(r.trace.contains("w1   restart"));
        // the resume line proves the incarnation started from its
        // checkpoint, not from the empty model
        assert!(r.trace.contains("w1   resume  cert="), "{}", r.trace);
        assert!(!r.trace.contains("cert=inf"), "checkpoint must not be empty");
    }

    #[test]
    fn join_grows_the_swarm_and_the_joiner_converges() {
        let c = cfg(
            2,
            Scenario::new().at(Duration::from_millis(60), ScenarioEvent::Join(2)),
        );
        let r = run(&c);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.workers.len(), 3, "report covers the joined worker");
        assert!(r.workers[2].alive);
        assert!(r.workers[2].steps > 0, "joined worker must do work");
        assert!(
            r.workers[2].steps < r.workers[0].steps,
            "it joined late, so it did less"
        );
        assert!(r.survivors_converged(), "join order must not break adoption");
        assert!(r.trace.contains("w2   join"));
    }

    #[test]
    fn laggard_slows_only_itself() {
        let base = run(&cfg(3, Scenario::new()));
        let lag = run(&cfg(
            3,
            Scenario::new().at(Duration::ZERO, ScenarioEvent::Laggard(2, 10.0)),
        ));
        // the no-barrier claim, structurally: peers' work is untouched
        assert_eq!(base.workers[0].steps, lag.workers[0].steps);
        assert_eq!(base.workers[1].steps, lag.workers[1].steps);
        assert!(lag.workers[2].steps < base.workers[2].steps);
        assert!(lag.violations.is_empty());
    }

    #[test]
    fn fanout_mode_converges_via_gossip_relay() {
        let mut c = cfg(4, Scenario::new());
        c.net.mode = BroadcastMode::Fanout { k: 1, ttl: 0 };
        let r = run(&c);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert!(
            r.net.forwarded > 0,
            "k=1 on a 4-cluster must rely on re-forwarding"
        );
        assert!(
            r.survivors_converged(),
            "alive-ring + ttl=n must reach everyone: {}",
            r.trace
        );
        assert!(r.trace.contains("forward"), "relay must appear in the trace");
    }

    #[test]
    fn unknown_preset_is_none_and_known_presets_validate() {
        assert!(preset("nope", 4).is_none());
        for name in PRESETS {
            let s = preset(name, 5).expect(name);
            // presets may join workers beyond n, so the membership walk
            // (not max_worker) is the correctness check
            let size = s.validate(5);
            assert!(size.is_ok(), "{name}: {size:?}");
        }
        // the battery preset must also build at swarm scale
        let big = preset("churn_large", 100).unwrap();
        assert_eq!(big.validate(100), Ok(120), "100 initial + 20 joins");
    }
}
