//! Workload adapters for the simulator: what a worker *does* between
//! protocol interactions.
//!
//! The engine owns the protocol (the real [`crate::tmsn::Tmsn`] state
//! machine over [`super::SimNet`]); a [`SimWorker`] supplies only the
//! local search. Costs are **virtual**: a step reports how long it would
//! have taken, and the engine advances the virtual clock — which is what
//! makes laggard factors, crash timing, and every trace byte exactly
//! reproducible.
//!
//! Two instantiations mirror the repo's two production workloads:
//!
//! * [`BoostSimWorker`] — the paper's boosting payload
//!   ([`crate::tmsn::BoostPayload`]): a seeded search that certifies weak
//!   rules with advantage γ and tightens the loss bound by
//!   `sqrt(1 − 4γ²)` per find, exactly the production certificate
//!   arithmetic (the scanner's statistics are abstracted into a seeded
//!   hit-rate; the protocol math is the real thing).
//! * [`SgdSimWorker`] — certified async SGD ([`crate::sgd::SgdPayload`])
//!   running the **identical** gradient arithmetic as the threaded
//!   cluster ([`crate::sgd::sgd_steps`]) on a real shard, with the real
//!   held-out-loss certificate — full numerical convergence, in virtual
//!   time.

use std::sync::Arc;
use std::time::Duration;

use crate::data::DataBlock;
use crate::model::Stump;
use crate::sgd::{logistic_loss, sgd_steps, SgdPayload};
use crate::tmsn::{BoostPayload, Payload};
use crate::util::rng::Rng;

/// One simulated worker's local search.
pub trait SimWorker<P: Payload> {
    /// Perform one unit of local work against the currently certified
    /// payload. Returns the unit's *base* virtual cost (the engine scales
    /// it by the worker's laggard factor) and, if the search succeeded, a
    /// strictly-better payload to publish.
    fn step(&mut self, current: &P) -> (Duration, Option<P>);

    /// A strictly-better remote payload was adopted; repair any local
    /// state derived from the old one (e.g. scratch weights).
    fn on_adopt(&mut self, adopted: &P);
}

/// Seeded boosting search: certifies a weak rule with probability
/// `hit_rate` per unit, with advantage γ ~ U[0.05, 0.30].
pub struct BoostSimWorker {
    rng: Rng,
    /// fixed virtual cost of one search unit
    pub step_cost: Duration,
    /// mean of the exponential jitter added per unit
    pub jitter_mean: Duration,
    /// probability one unit certifies a weak rule
    pub hit_rate: f64,
    /// *independent certificate stream* (DESIGN.md §12): when set, the
    /// candidate's bound is the worker's **own** cumulative product of
    /// `sqrt(1 − 4γ²)` over its own hits — a pure function of the
    /// worker's seed, never of adopted payloads. The global best bound is
    /// then invariant to the broadcast mode (full vs fanout deliver the
    /// same publishes in different orders), which is what lets the test
    /// suite assert fanout/full *bitwise* final-model equivalence.
    pub independent: bool,
    /// cumulative own bound (independent mode only)
    own_bound: f64,
}

impl BoostSimWorker {
    /// A worker with the default cost model (2 ms + Exp(1 ms) per unit,
    /// 70% hit rate), seeded independently per worker.
    pub fn new(seed: u64) -> BoostSimWorker {
        BoostSimWorker {
            rng: Rng::new(seed),
            step_cost: Duration::from_millis(2),
            jitter_mean: Duration::from_millis(1),
            hit_rate: 0.7,
            independent: false,
            own_bound: 1.0,
        }
    }

    /// The canonical per-`(run seed, worker, incarnation)` search stream —
    /// the one derivation shared by the test suite and `sparrow sim`, so
    /// both provably run the same workload.
    pub fn for_run(run_seed: u64, id: usize, incarnation: u64) -> BoostSimWorker {
        BoostSimWorker::new(
            run_seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (incarnation << 48),
        )
    }

    /// [`BoostSimWorker::for_run`] with the independent certificate
    /// stream enabled (see the `independent` field) — the workload the
    /// fanout-vs-full equivalence battery runs.
    pub fn independent_for_run(run_seed: u64, id: usize, incarnation: u64) -> BoostSimWorker {
        let mut w = BoostSimWorker::for_run(run_seed, id, incarnation);
        w.independent = true;
        w
    }
}

impl SimWorker<BoostPayload> for BoostSimWorker {
    fn step(&mut self, current: &BoostPayload) -> (Duration, Option<BoostPayload>) {
        let jitter = if self.jitter_mean > Duration::ZERO {
            Duration::from_secs_f64(self.rng.exponential(1.0 / self.jitter_mean.as_secs_f64()))
        } else {
            Duration::ZERO
        };
        let cost = self.step_cost + jitter;
        if !self.rng.bernoulli(self.hit_rate) {
            return (cost, None);
        }
        let gamma = 0.05 + self.rng.f64() * 0.25;
        let alpha = 0.5 * ((1.0 + 2.0 * gamma) / (1.0 - 2.0 * gamma)).ln();
        let mut model = current.model.clone();
        model.push(
            Stump::new(
                self.rng.below(64) as u32,
                self.rng.gauss() as f32,
                if self.rng.bernoulli(0.5) { 1.0 } else { -1.0 },
            ),
            alpha as f32,
        );
        if self.independent {
            // all RNG draws above happen in both branches, so the search
            // stream (and every virtual cost) is identical whether or not
            // this flag is set — only the certificate arithmetic differs
            self.own_bound *= (1.0 - 4.0 * gamma * gamma).sqrt();
            if self.own_bound < current.cert.loss_bound {
                return (cost, Some(BoostPayload::resume(model, self.own_bound)));
            }
            return (cost, None);
        }
        (cost, Some(current.improved(model, gamma)))
    }

    fn on_adopt(&mut self, _adopted: &BoostPayload) {}
}

/// Certified async SGD on a real data shard — the production gradient
/// arithmetic ([`sgd_steps`]) and certificate ([`logistic_loss`] on the
/// shared held-out set), under virtual time.
pub struct SgdSimWorker {
    shard: Arc<DataBlock>,
    valid: Arc<DataBlock>,
    w: Vec<f32>,
    cursor: usize,
    f: usize,
    /// learning rate
    pub lr: f32,
    /// gradient steps per work unit
    pub steps_per_unit: usize,
    /// ε gap: publish only when undercutting the certified loss by this
    pub min_gain: f64,
}

/// The canonical SGD sim fixture: per-worker private shards plus the
/// shared held-out set, derived from the run seed — one builder shared by
/// the test suite and `sparrow sim`.
pub fn sgd_sim_fixture(run_seed: u64, workers: usize) -> (Vec<Arc<DataBlock>>, Arc<DataBlock>) {
    let mut gen = crate::data::synth::SynthGen::new(crate::data::SynthConfig {
        f: 12,
        pos_rate: 0.35,
        informative: 6,
        signal: 0.9,
        flip_rate: 0.02,
        seed: run_seed ^ 0x51D0,
    });
    let shards = (0..workers).map(|_| Arc::new(gen.next_block(800))).collect();
    let valid = Arc::new(gen.next_block(400));
    (shards, valid)
}

impl SgdSimWorker {
    /// A worker over its private `shard`, certifying on the shared
    /// `valid` set. `id` decorrelates the shard walk across workers
    /// (same scheme as the threaded cluster).
    pub fn new(id: usize, shard: Arc<DataBlock>, valid: Arc<DataBlock>) -> SgdSimWorker {
        assert!(!shard.is_empty() && !valid.is_empty());
        let f = shard.f;
        SgdSimWorker {
            shard,
            valid,
            w: vec![0.0; f],
            cursor: id * 31,
            f,
            lr: 0.05,
            steps_per_unit: 100,
            min_gain: 1e-3,
        }
    }
}

impl SimWorker<SgdPayload> for SgdSimWorker {
    fn step(&mut self, current: &SgdPayload) -> (Duration, Option<SgdPayload>) {
        sgd_steps(&mut self.w, &self.shard, self.lr, &mut self.cursor, self.steps_per_unit);
        // deterministic cost model: 10 µs of virtual compute per step
        let cost = Duration::from_micros(10 * self.steps_per_unit as u64);
        let loss = logistic_loss(&self.w, &self.valid);
        if loss.is_finite() && loss < current.cert.loss - self.min_gain {
            (cost, Some(SgdPayload::certified(self.w.clone(), loss)))
        } else {
            (cost, None)
        }
    }

    fn on_adopt(&mut self, adopted: &SgdPayload) {
        // resync local scratch to the adopted weights (uncertified local
        // progress is discarded, like the threaded worker's resync)
        self.w.clear();
        self.w.extend_from_slice(&adopted.w);
        self.w.resize(self.f, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthGen;
    use crate::data::SynthConfig;
    use crate::tmsn::Certified;

    #[test]
    fn boost_worker_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut w = BoostSimWorker::new(seed);
            let mut p = BoostPayload::initial();
            let mut hist = Vec::new();
            for _ in 0..30 {
                let (cost, cand) = w.step(&p);
                if let Some(c) = cand {
                    hist.push((cost, c.cert.loss_bound));
                    p = c;
                } else {
                    hist.push((cost, f64::NAN));
                }
            }
            format!("{hist:?}")
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn boost_candidates_strictly_improve() {
        let mut w = BoostSimWorker::new(3);
        let mut p = BoostPayload::initial();
        for _ in 0..50 {
            if let (_, Some(c)) = w.step(&p) {
                assert!(c.cert().better_than(p.cert()));
                assert!(c.model.len() == p.model.len() + 1);
                p = c;
            }
        }
        assert!(p.cert.loss_bound < 1.0, "no improvement ever found");
    }

    #[test]
    fn independent_stream_is_invariant_to_what_gets_adopted() {
        // feed the same seeded worker two different adoption histories:
        // (a) adopt every own candidate, (b) never adopt (current pinned
        // at the initial payload). The published bound sequence must be
        // bitwise identical — the property the fanout-vs-full equivalence
        // battery rests on.
        let bounds = |adopt_own: bool| {
            let mut w = BoostSimWorker::independent_for_run(42, 3, 0);
            let mut p = BoostPayload::initial();
            let mut out = Vec::new();
            for _ in 0..60 {
                if let (_, Some(c)) = w.step(&p) {
                    out.push(c.cert.loss_bound.to_bits());
                    if adopt_own {
                        p = c;
                    }
                }
            }
            out
        };
        let a = bounds(true);
        let b = bounds(false);
        assert!(!a.is_empty());
        assert_eq!(a, b, "own-bound stream must not depend on adoption history");
    }

    #[test]
    fn independent_candidates_still_strictly_improve() {
        let mut w = BoostSimWorker::independent_for_run(7, 0, 0);
        let mut p = BoostPayload::initial();
        let mut found = 0;
        for _ in 0..50 {
            if let (_, Some(c)) = w.step(&p) {
                assert!(c.cert().better_than(p.cert()));
                p = c;
                found += 1;
            }
        }
        assert!(found > 0);
    }

    fn sgd_fixture() -> (Arc<DataBlock>, Arc<DataBlock>) {
        let mut gen = SynthGen::new(SynthConfig {
            f: 8,
            pos_rate: 0.4,
            informative: 4,
            signal: 1.0,
            flip_rate: 0.01,
            seed: 0xDA7A,
        });
        (Arc::new(gen.next_block(400)), Arc::new(gen.next_block(200)))
    }

    #[test]
    fn sgd_worker_publishes_only_with_min_gain() {
        let (shard, valid) = sgd_fixture();
        let mut w = SgdSimWorker::new(0, shard, valid);
        let mut p = SgdPayload::initial();
        let mut published = 0;
        for _ in 0..40 {
            let (cost, cand) = w.step(&p);
            assert_eq!(cost, Duration::from_micros(1000));
            if let Some(c) = cand {
                assert!(
                    c.cert.loss < p.cert.loss - w.min_gain || p.cert.loss.is_infinite(),
                    "published without the ε gap"
                );
                p = c;
                published += 1;
            }
        }
        assert!(published > 0, "sgd never certified an improvement");
        assert!(p.cert.loss < std::f64::consts::LN_2);
    }

    #[test]
    fn sgd_adopt_resyncs_scratch_weights() {
        let (shard, valid) = sgd_fixture();
        let mut w = SgdSimWorker::new(1, shard, valid);
        let adopted = SgdPayload::certified(vec![1.0, -1.0], 0.5);
        w.on_adopt(&adopted);
        assert_eq!(&w.w[..2], &[1.0, -1.0]);
        assert_eq!(w.w.len(), 8, "scratch padded back to full width");
        assert!(w.w[2..].iter().all(|&v| v == 0.0));
    }
}
