//! Scenario schedules: scripted fault injection at virtual timestamps.
//!
//! A [`Scenario`] is a list of `(virtual time, event)` pairs the engine
//! applies while the cluster runs — the replayable encoding of "machine 2
//! dies at t=300ms, the rack splits at t=500ms and heals at t=800ms, …".
//! Because the schedule is data (not sleeps on real threads), the same
//! scenario replays identically under any seed and can be asserted on in
//! CI (DESIGN.md §9).

use std::time::Duration;

/// One scripted fault (or recovery) applied to the simulated cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioEvent {
    /// Worker halts: no more local work, its inbox is discarded, and every
    /// message delivered to it while down is dropped.
    Crash(usize),
    /// A crashed worker rejoins with a fresh (empty) model — the paper's
    /// no-ceremony recovery: it catches up purely by receiving broadcasts.
    Restart(usize),
    /// Worker's compute slows by the given factor (≥ 1); a factor of 1
    /// restores full speed.
    Laggard(usize, f64),
    /// Network splits into the given groups; messages sent across group
    /// boundaries are silently blocked. Workers not listed in any group
    /// are isolated. Replaces any previous partition.
    Partition(Vec<Vec<usize>>),
    /// Remove the partition: all links work again (messages blocked while
    /// partitioned are *not* retransmitted — TMSN needs no replay, later
    /// broadcasts carry strictly-better state).
    Heal,
}

impl ScenarioEvent {
    /// Short rendering for the event trace.
    pub fn describe(&self) -> String {
        match self {
            ScenarioEvent::Crash(w) => format!("w{w}   crash"),
            ScenarioEvent::Restart(w) => format!("w{w}   restart"),
            ScenarioEvent::Laggard(w, k) => format!("w{w}   laggard x{k}"),
            ScenarioEvent::Partition(groups) => format!("net  partition {groups:?}"),
            ScenarioEvent::Heal => "net  heal".to_string(),
        }
    }

    /// The worker this event targets, if any (used for validation).
    pub fn worker(&self) -> Option<usize> {
        match self {
            ScenarioEvent::Crash(w) | ScenarioEvent::Restart(w) | ScenarioEvent::Laggard(w, _) => {
                Some(*w)
            }
            _ => None,
        }
    }
}

/// An ordered fault schedule over virtual time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Scenario {
    events: Vec<(Duration, ScenarioEvent)>,
}

impl Scenario {
    /// The empty (fault-free) scenario.
    pub fn new() -> Scenario {
        Scenario { events: Vec::new() }
    }

    /// Schedule `event` at virtual time `t` (builder style). Events may be
    /// added in any order; same-timestamp events apply in insertion order.
    pub fn at(mut self, t: Duration, event: ScenarioEvent) -> Scenario {
        self.events.push((t, event));
        self
    }

    /// The schedule sorted by timestamp (stable: insertion order breaks
    /// ties), as consumed by the engine.
    pub fn sorted(&self) -> Vec<(Duration, ScenarioEvent)> {
        let mut v = self.events.clone();
        v.sort_by_key(|(t, _)| *t);
        v
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True for the fault-free scenario.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Largest worker index referenced anywhere in the schedule.
    pub fn max_worker(&self) -> Option<usize> {
        self.events
            .iter()
            .flat_map(|(_, e)| match e {
                ScenarioEvent::Partition(groups) => {
                    groups.iter().flatten().copied().collect::<Vec<_>>()
                }
                other => other.worker().into_iter().collect(),
            })
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn sorted_orders_by_time_stably() {
        let s = Scenario::new()
            .at(ms(500), ScenarioEvent::Heal)
            .at(ms(100), ScenarioEvent::Crash(1))
            .at(ms(100), ScenarioEvent::Laggard(0, 2.0)); // same t: after Crash(1)
        let sorted = s.sorted();
        assert_eq!(sorted[0].1, ScenarioEvent::Crash(1));
        assert_eq!(sorted[1].1, ScenarioEvent::Laggard(0, 2.0));
        assert_eq!(sorted[2].1, ScenarioEvent::Heal);
    }

    #[test]
    fn max_worker_scans_partitions_too() {
        let s = Scenario::new()
            .at(ms(1), ScenarioEvent::Crash(2))
            .at(ms(2), ScenarioEvent::Partition(vec![vec![0, 5], vec![1]]));
        assert_eq!(s.max_worker(), Some(5));
        assert_eq!(Scenario::new().max_worker(), None);
    }

    #[test]
    fn describe_is_stable() {
        assert_eq!(ScenarioEvent::Crash(3).describe(), "w3   crash");
        assert_eq!(ScenarioEvent::Heal.describe(), "net  heal");
        assert_eq!(ScenarioEvent::Laggard(1, 4.0).describe(), "w1   laggard x4");
    }
}
