//! Scenario schedules: scripted fault injection at virtual timestamps.
//!
//! A [`Scenario`] is a list of `(virtual time, event)` pairs the engine
//! applies while the cluster runs — the replayable encoding of "machine 2
//! dies at t=300ms, the rack splits at t=500ms and heals at t=800ms, …".
//! Because the schedule is data (not sleeps on real threads), the same
//! scenario replays identically under any seed and can be asserted on in
//! CI (DESIGN.md §9), shrunk to a minimal repro by the delta-debugger
//! ([`crate::sim::minimize`]), and extended with elastic-membership
//! events ([`ScenarioEvent::Join`]) without touching the engine's
//! determinism story (DESIGN.md §12).

use std::time::Duration;

/// One scripted fault (or recovery) applied to the simulated cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioEvent {
    /// Worker halts: no more local work, its inbox is discarded, and every
    /// message delivered to it while down is dropped. Its last committed
    /// payload survives as its checkpoint (see [`ScenarioEvent::Restart`]).
    Crash(usize),
    /// A crashed worker rejoins with a fresh incarnation, *resuming from
    /// its last committed payload* (checkpoint-based rejoin via
    /// `Tmsn::resume`) — the paper's no-ceremony recovery, hardened: it
    /// loses nothing it had certified and catches the cluster up purely
    /// by receiving broadcasts.
    Restart(usize),
    /// A worker unknown at t=0 joins the in-flight run with an empty
    /// model. Join ids must be assigned densely: the `i`-th join in
    /// schedule order must carry id `initial_workers + i` (checked by
    /// [`Scenario::validate`]).
    Join(usize),
    /// Worker's compute slows by the given factor (≥ 1); a factor of 1
    /// restores full speed.
    Laggard(usize, f64),
    /// Network splits into the given groups; messages sent across group
    /// boundaries are silently blocked. Workers not listed in any group
    /// (including ones that join while the split is active) are isolated.
    /// Replaces any previous group partition.
    Partition(Vec<Vec<usize>>),
    /// Asymmetric (one-way) partition: each `(a, b)` edge blocks messages
    /// `a → b` while `b → a` still delivers. Replaces any previous
    /// one-way edge set; composes with [`ScenarioEvent::Partition`].
    PartitionOneWay(Vec<(usize, usize)>),
    /// Remove every partition, group and one-way alike: all links work
    /// again (messages blocked while partitioned are *not* retransmitted —
    /// TMSN needs no replay, later broadcasts carry strictly-better
    /// state).
    Heal,
}

impl ScenarioEvent {
    /// Short rendering for the event trace.
    pub fn describe(&self) -> String {
        match self {
            ScenarioEvent::Crash(w) => format!("w{w}   crash"),
            ScenarioEvent::Restart(w) => format!("w{w}   restart"),
            ScenarioEvent::Join(w) => format!("w{w}   join"),
            ScenarioEvent::Laggard(w, k) => format!("w{w}   laggard x{k}"),
            ScenarioEvent::Partition(groups) => format!("net  partition {groups:?}"),
            ScenarioEvent::PartitionOneWay(edges) => {
                format!("net  partition-oneway {edges:?}")
            }
            ScenarioEvent::Heal => "net  heal".to_string(),
        }
    }

    /// The worker this event targets, if any (used for validation).
    pub fn worker(&self) -> Option<usize> {
        match self {
            ScenarioEvent::Crash(w)
            | ScenarioEvent::Restart(w)
            | ScenarioEvent::Join(w)
            | ScenarioEvent::Laggard(w, _) => Some(*w),
            _ => None,
        }
    }
}

/// An ordered fault schedule over virtual time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Scenario {
    events: Vec<(Duration, ScenarioEvent)>,
}

impl Scenario {
    /// The empty (fault-free) scenario.
    pub fn new() -> Scenario {
        Scenario { events: Vec::new() }
    }

    /// Schedule `event` at virtual time `t` (builder style). Events may be
    /// added in any order; same-timestamp events apply in insertion order.
    pub fn at(mut self, t: Duration, event: ScenarioEvent) -> Scenario {
        self.events.push((t, event));
        self
    }

    /// Rebuild a scenario from an explicit event list (used by the
    /// delta-debugging minimizer to propose reduced schedules).
    pub fn from_events(events: Vec<(Duration, ScenarioEvent)>) -> Scenario {
        Scenario { events }
    }

    /// The raw schedule in insertion order.
    pub fn events(&self) -> &[(Duration, ScenarioEvent)] {
        &self.events
    }

    /// The schedule sorted by timestamp (stable: insertion order breaks
    /// ties), as consumed by the engine.
    pub fn sorted(&self) -> Vec<(Duration, ScenarioEvent)> {
        let mut v = self.events.clone();
        v.sort_by_key(|(t, _)| *t);
        v
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True for the fault-free scenario.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Largest worker index referenced anywhere in the schedule.
    pub fn max_worker(&self) -> Option<usize> {
        self.events
            .iter()
            .flat_map(|(_, e)| match e {
                ScenarioEvent::Partition(groups) => {
                    groups.iter().flatten().copied().collect::<Vec<_>>()
                }
                ScenarioEvent::PartitionOneWay(edges) => {
                    edges.iter().flat_map(|&(a, b)| [a, b]).collect()
                }
                other => other.worker().into_iter().collect(),
            })
            .max()
    }

    /// Walk the schedule in replay order and check the dynamic-membership
    /// rules: every referenced worker must already be a member when its
    /// event fires, and joins must be dense (`Join(size)` when the swarm
    /// holds `size` workers). Returns the final swarm size.
    pub fn validate(&self, initial_workers: usize) -> Result<usize, String> {
        let mut size = initial_workers;
        for (t, e) in self.sorted() {
            match &e {
                ScenarioEvent::Join(w) => {
                    if *w != size {
                        return Err(format!(
                            "join of worker {w} at {t:?} but the swarm holds {size} \
                             workers (joins must be dense)"
                        ));
                    }
                    size += 1;
                }
                ScenarioEvent::Partition(groups) => {
                    for &w in groups.iter().flatten() {
                        if w >= size {
                            return Err(format!(
                                "partition at {t:?} references worker {w} of {size}"
                            ));
                        }
                    }
                }
                ScenarioEvent::PartitionOneWay(edges) => {
                    for &(a, b) in edges {
                        if a >= size || b >= size {
                            return Err(format!(
                                "one-way partition at {t:?} references edge \
                                 ({a},{b}) of {size}"
                            ));
                        }
                        if a == b {
                            return Err(format!("one-way self-edge ({a},{b}) at {t:?}"));
                        }
                    }
                }
                other => {
                    if let Some(w) = other.worker() {
                        if w >= size {
                            return Err(format!(
                                "event at {t:?} references worker {w} of {size}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn sorted_orders_by_time_stably() {
        let s = Scenario::new()
            .at(ms(500), ScenarioEvent::Heal)
            .at(ms(100), ScenarioEvent::Crash(1))
            .at(ms(100), ScenarioEvent::Laggard(0, 2.0)); // same t: after Crash(1)
        let sorted = s.sorted();
        assert_eq!(sorted[0].1, ScenarioEvent::Crash(1));
        assert_eq!(sorted[1].1, ScenarioEvent::Laggard(0, 2.0));
        assert_eq!(sorted[2].1, ScenarioEvent::Heal);
    }

    #[test]
    fn max_worker_scans_partitions_too() {
        let s = Scenario::new()
            .at(ms(1), ScenarioEvent::Crash(2))
            .at(ms(2), ScenarioEvent::Partition(vec![vec![0, 5], vec![1]]));
        assert_eq!(s.max_worker(), Some(5));
        let s = Scenario::new().at(ms(1), ScenarioEvent::PartitionOneWay(vec![(1, 7)]));
        assert_eq!(s.max_worker(), Some(7));
        assert_eq!(Scenario::new().max_worker(), None);
    }

    #[test]
    fn describe_is_stable() {
        assert_eq!(ScenarioEvent::Crash(3).describe(), "w3   crash");
        assert_eq!(ScenarioEvent::Heal.describe(), "net  heal");
        assert_eq!(ScenarioEvent::Laggard(1, 4.0).describe(), "w1   laggard x4");
        assert_eq!(ScenarioEvent::Join(6).describe(), "w6   join");
        assert_eq!(
            ScenarioEvent::PartitionOneWay(vec![(0, 2)]).describe(),
            "net  partition-oneway [(0, 2)]"
        );
    }

    #[test]
    fn validate_walks_membership_in_replay_order() {
        // join makes worker 3 legal for later events, even when the later
        // event was *inserted* first
        let s = Scenario::new()
            .at(ms(50), ScenarioEvent::Crash(3))
            .at(ms(10), ScenarioEvent::Join(3));
        assert_eq!(s.validate(3), Ok(4));
        // same events, join too late: the crash references a non-member
        let s = Scenario::new()
            .at(ms(50), ScenarioEvent::Crash(3))
            .at(ms(99), ScenarioEvent::Join(3));
        assert!(s.validate(3).is_err());
    }

    #[test]
    fn validate_rejects_sparse_joins() {
        let s = Scenario::new().at(ms(10), ScenarioEvent::Join(5));
        assert!(s.validate(3).is_err(), "join must target the next id");
        let s = Scenario::new()
            .at(ms(10), ScenarioEvent::Join(3))
            .at(ms(20), ScenarioEvent::Join(4));
        assert_eq!(s.validate(3), Ok(5));
    }

    #[test]
    fn validate_checks_partition_membership_and_self_edges() {
        let s = Scenario::new().at(ms(1), ScenarioEvent::Partition(vec![vec![0, 4]]));
        assert!(s.validate(3).is_err());
        let s = Scenario::new().at(ms(1), ScenarioEvent::PartitionOneWay(vec![(0, 0)]));
        assert!(s.validate(3).is_err(), "self-edges are meaningless");
        let s = Scenario::new().at(ms(1), ScenarioEvent::PartitionOneWay(vec![(0, 2)]));
        assert_eq!(s.validate(3), Ok(3));
    }
}
