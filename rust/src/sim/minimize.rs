//! Delta-debugging scenario minimization (DESIGN.md §12).
//!
//! When a sim run violates a TMSN invariant, the raw repro is often a
//! hundred-event churn schedule over hundreds of workers — useless for a
//! human. [`minimize`] shrinks it greedily to a *minimal* failing
//! configuration: it repeatedly tries to drop scenario events, pull event
//! timestamps earlier, halve the horizon, and shrink the worker count,
//! keeping a candidate only if the failure predicate still holds on the
//! candidate's (fully deterministic) run. The result is replayable
//! byte-identically — `sparrow sim --minimize` prints the reduced
//! schedule and its trace.
//!
//! Candidates that fail [`Scenario::validate`] (e.g. a worker-count
//! shrink that orphans a membership reference) are rejected *without*
//! running, so the shrinker never panics the engine.

use std::time::Duration;

use crate::tmsn::Payload;

use super::scenario::Scenario;
use super::workloads::SimWorker;
use super::{run_scenario, SimConfig, SimReport};

/// Outcome of a successful minimization.
#[derive(Debug)]
pub struct Minimized {
    /// the reduced configuration (scenario, worker count, horizon)
    pub cfg: SimConfig,
    /// candidate runs executed while shrinking
    pub probes: usize,
    /// invariant violations of the minimized run (non-empty)
    pub violations: Vec<String>,
    /// deterministic trace of the minimized run
    pub trace: String,
}

/// Shrink `cfg` to a minimal configuration on which `failing` still
/// returns true. Returns `None` if the original run does not fail.
///
/// `spawn` must be the same worker factory used for the original run —
/// minimization replays the *same* deterministic system, only smaller.
pub fn minimize<P, W, S, F>(cfg: &SimConfig, spawn: &S, failing: &F) -> Option<Minimized>
where
    P: Payload,
    W: SimWorker<P>,
    S: Fn(usize, u64) -> W,
    F: Fn(&SimReport<P>) -> bool,
{
    let mut probes = 0usize;
    let mut probe = |c: &SimConfig| -> SimReport<P> {
        probes += 1;
        run_scenario(c, |id, inc| spawn(id, inc))
    };

    if !failing(&probe(cfg)) {
        return None;
    }
    let mut cur = cfg.clone();

    loop {
        let mut shrunk = false;

        // 1) drop events one at a time (left to right; index stays put
        // after a successful removal because the next event slid into it)
        let mut i = 0;
        while i < cur.scenario.len() {
            let mut events = cur.scenario.events().to_vec();
            events.remove(i);
            let cand = SimConfig {
                scenario: Scenario::from_events(events),
                ..cur.clone()
            };
            if cand.scenario.validate(cand.workers).is_ok() && failing(&probe(&cand)) {
                cur = cand;
                shrunk = true;
            } else {
                i += 1;
            }
        }

        // 2) pull each surviving event earlier (halve its timestamp)
        for i in 0..cur.scenario.len() {
            let mut events = cur.scenario.events().to_vec();
            if events[i].0 > Duration::ZERO {
                events[i].0 /= 2;
                let cand = SimConfig {
                    scenario: Scenario::from_events(events),
                    ..cur.clone()
                };
                if cand.scenario.validate(cand.workers).is_ok() && failing(&probe(&cand)) {
                    cur = cand;
                    shrunk = true;
                }
            }
        }

        // 3) halve the horizon
        if cur.horizon > Duration::from_millis(1) {
            let cand = SimConfig {
                horizon: cur.horizon / 2,
                ..cur.clone()
            };
            if failing(&probe(&cand)) {
                cur = cand;
                shrunk = true;
            }
        }

        // 4) shrink the cluster: halve first, then decrement
        for w in [cur.workers / 2, cur.workers.saturating_sub(1)] {
            if w >= 1 && w < cur.workers {
                let cand = SimConfig {
                    workers: w,
                    ..cur.clone()
                };
                if cand.scenario.validate(w).is_ok() && failing(&probe(&cand)) {
                    cur = cand;
                    shrunk = true;
                }
            }
        }

        if !shrunk {
            break;
        }
    }

    let report = probe(&cur);
    debug_assert!(failing(&report), "minimized repro must still fail");
    Some(Minimized {
        cfg: cur,
        probes,
        violations: report.violations,
        trace: report.trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ScenarioEvent;
    use crate::tmsn::testpay::TestPayload;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    /// Planted bug: after its first remote adoption the worker starts
    /// regurgitating its current payload as a "candidate" — a
    /// non-improving publish the engine flags as a violation. Needs at
    /// least 2 workers (no adoption ever happens solo).
    struct Buggy {
        score: f64,
        poisoned: bool,
    }
    impl SimWorker<TestPayload> for Buggy {
        fn step(&mut self, current: &TestPayload) -> (Duration, Option<TestPayload>) {
            if self.poisoned {
                return (ms(10), Some(current.clone()));
            }
            self.score *= 0.9;
            (ms(10), Some(TestPayload::scored("b", self.score)))
        }
        fn on_adopt(&mut self, _adopted: &TestPayload) {
            self.poisoned = true;
        }
    }

    fn spawn(id: usize, _inc: u64) -> Buggy {
        Buggy {
            score: 100.0 + id as f64,
            poisoned: false,
        }
    }

    #[test]
    fn shrinks_a_planted_violation_to_the_minimal_repro() {
        // 5 workers, 300 ms, and a pile of junk events that have nothing
        // to do with the planted bug
        let cfg = SimConfig {
            workers: 5,
            horizon: ms(300),
            scenario: Scenario::new()
                .at(ms(100), ScenarioEvent::Laggard(3, 4.0))
                .at(ms(120), ScenarioEvent::Crash(4))
                .at(ms(130), ScenarioEvent::Partition(vec![vec![0, 1], vec![2, 3]]))
                .at(ms(150), ScenarioEvent::Restart(4))
                .at(ms(160), ScenarioEvent::Heal),
            ..SimConfig::default()
        };
        let failing = |r: &SimReport<TestPayload>| !r.violations.is_empty();
        let m = minimize(&cfg, &spawn, &failing).expect("planted bug must fail the base run");

        assert!(m.cfg.scenario.is_empty(), "all junk events removed: {:?}", m.cfg.scenario);
        assert_eq!(m.cfg.workers, 2, "bug needs an adoption, so exactly 2 workers");
        assert!(m.cfg.horizon < ms(300), "horizon shrunk");
        assert!(!m.violations.is_empty());
        assert!(m.probes > 5, "the shrinker actually searched");

        // the minimized repro is byte-identical on replay
        let a = run_scenario(&m.cfg, spawn);
        let b = run_scenario(&m.cfg, spawn);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.trace, m.trace, "reported trace is the replayed trace");
        assert!(!a.violations.is_empty());
    }

    #[test]
    fn healthy_run_is_not_minimized() {
        // same workload, but with one worker no adoption ever happens,
        // so nothing fails and minimize declines
        let cfg = SimConfig {
            workers: 1,
            horizon: ms(100),
            ..SimConfig::default()
        };
        let failing = |r: &SimReport<TestPayload>| !r.violations.is_empty();
        assert!(minimize(&cfg, &spawn, &failing).is_none());
    }
}
