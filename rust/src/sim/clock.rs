//! Clock abstraction: real wall-clock time vs. simulated virtual time.
//!
//! Every time-dependent component in the repo (fabric delays, I/O and
//! compute throttles, metrics timestamps) reads time through [`Clock`]
//! instead of calling `Instant::now()` / `thread::sleep` directly. With
//! [`RealClock`] (the default everywhere) behavior is byte-identical to
//! the pre-clock code; with [`SimClock`] the same components run under
//! **virtual time**: "sleeping" advances a counter instead of the OS
//! clock, so a simulated hour costs nanoseconds of wall time and a fixed
//! seed yields the exact same timestamps on every run (DESIGN.md §9).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A source of time plus the ability to wait.
///
/// `now()` returns an [`Instant`] so existing `Instant`-based arithmetic
/// (`duration_since`, heap ordering of due times) works unmodified;
/// [`SimClock`] mints instants as a fixed base plus the virtual offset.
pub trait Clock: Send + Sync {
    /// The current time on this clock.
    fn now(&self) -> Instant;

    /// Wait for `d`: a real sleep on [`RealClock`], a virtual-time advance
    /// on [`SimClock`] (returns immediately).
    fn sleep(&self, d: Duration);

    /// Virtual clocks return `true` so code that waits on *real* OS
    /// primitives (channel `recv_timeout`, condvars) caps its real wait
    /// and re-reads the clock instead of blocking for a virtual duration.
    fn is_virtual(&self) -> bool {
        false
    }
}

/// The operating-system clock: `Instant::now()` + `thread::sleep`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RealClock;

impl Clock for RealClock {
    fn now(&self) -> Instant {
        Instant::now()
    }

    fn sleep(&self, d: Duration) {
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }
}

/// A virtual clock: time is a monotone nanosecond counter advanced
/// explicitly (by the simulator's event loop) or implicitly (by
/// [`Clock::sleep`], which models the sleep instead of performing it).
///
/// Shared via `Arc`; all readers observe one timeline. The counter only
/// moves forward — `advance_to` with a past timestamp is a no-op.
#[derive(Debug)]
pub struct SimClock {
    base: Instant,
    nanos: AtomicU64,
}

impl Default for SimClock {
    fn default() -> Self {
        SimClock::new()
    }
}

impl SimClock {
    /// A fresh virtual clock at t = 0.
    pub fn new() -> SimClock {
        SimClock {
            base: Instant::now(),
            nanos: AtomicU64::new(0),
        }
    }

    /// Virtual time elapsed since the clock's epoch.
    pub fn now_virtual(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Acquire))
    }

    /// Advance virtual time by `d`.
    pub fn advance(&self, d: Duration) {
        self.nanos
            .fetch_add(d.as_nanos().min(u64::MAX as u128) as u64, Ordering::AcqRel);
    }

    /// Advance virtual time *to* `t` (no-op if already past it).
    pub fn advance_to(&self, t: Duration) {
        self.nanos
            .fetch_max(t.as_nanos().min(u64::MAX as u128) as u64, Ordering::AcqRel);
    }
}

impl Clock for SimClock {
    fn now(&self) -> Instant {
        self.base + self.now_virtual()
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn real_clock_advances_on_its_own() {
        let c = RealClock;
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(!c.is_virtual());
    }

    #[test]
    fn sim_clock_only_moves_when_told() {
        let c = SimClock::new();
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(c.now(), t0, "wall time must not leak into virtual time");
        c.advance(Duration::from_secs(3600));
        assert_eq!(c.now_virtual(), Duration::from_secs(3600));
        assert_eq!(c.now().duration_since(t0), Duration::from_secs(3600));
        assert!(c.is_virtual());
    }

    #[test]
    fn sim_sleep_is_instant_and_advances() {
        let c = SimClock::new();
        let wall = Instant::now();
        c.sleep(Duration::from_secs(1000));
        assert!(wall.elapsed() < Duration::from_millis(100));
        assert_eq!(c.now_virtual(), Duration::from_secs(1000));
    }

    #[test]
    fn advance_to_is_monotone() {
        let c = SimClock::new();
        c.advance_to(Duration::from_millis(50));
        c.advance_to(Duration::from_millis(20)); // in the past: no-op
        assert_eq!(c.now_virtual(), Duration::from_millis(50));
        c.advance_to(Duration::from_millis(70));
        assert_eq!(c.now_virtual(), Duration::from_millis(70));
    }

    #[test]
    fn trait_object_is_shareable() {
        let c: Arc<dyn Clock> = Arc::new(SimClock::new());
        let c2 = Arc::clone(&c);
        let base = c.now();
        let h = std::thread::spawn(move || c2.sleep(Duration::from_millis(7)));
        h.join().unwrap();
        // the advance from the other thread is visible here
        assert_eq!(c.now().duration_since(base), Duration::from_millis(7));
    }
}
