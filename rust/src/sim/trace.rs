//! The simulator's event-trace recorder.
//!
//! Every observable simulator action — scenario application, wire
//! delivery/drop, protocol publish/accept/reject, crash/restart — is
//! appended as one line stamped with integer virtual microseconds. The
//! whole trace is a **pure function of the run's seed and configuration**:
//! the engine is single-threaded, all randomness flows from one seeded
//! RNG, and virtual timestamps are exact integers, so two runs of the same
//! scenario produce byte-identical text (asserted in
//! `tests/sim_cluster.rs`). A diff of two traces is therefore a replayable
//! description of *exactly* where two configurations diverge.

use std::time::Duration;

/// Append-only, deterministic trace of one simulation run.
#[derive(Debug, Default)]
pub struct SimTrace {
    lines: Vec<String>,
}

impl SimTrace {
    /// An empty trace.
    pub fn new() -> SimTrace {
        SimTrace { lines: Vec::new() }
    }

    /// Append one line at virtual time `t`.
    pub fn push(&mut self, t: Duration, line: &str) {
        self.lines.push(format!("[{:>10}us] {line}", t.as_micros()));
    }

    /// Number of recorded lines.
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    /// True if nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// The full trace as newline-terminated text (the byte-compared
    /// artifact of the determinism guarantee).
    pub fn text(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_are_stamped_with_integer_micros() {
        let mut t = SimTrace::new();
        t.push(Duration::from_micros(1500), "w0   publish seq=1");
        t.push(Duration::from_millis(2), "net  deliver 0->1");
        let text = t.text();
        assert!(text.contains("[      1500us] w0   publish seq=1\n"), "{text}");
        assert!(text.contains("[      2000us] net  deliver 0->1\n"), "{text}");
        assert_eq!(t.len(), 2);
        assert!(!t.is_empty());
    }

    #[test]
    fn empty_trace_is_empty_text() {
        assert_eq!(SimTrace::new().text(), "");
    }

    #[test]
    fn identical_pushes_identical_text() {
        let mut a = SimTrace::new();
        let mut b = SimTrace::new();
        for i in 0..50u64 {
            a.push(Duration::from_micros(i * 17), &format!("line {i}"));
            b.push(Duration::from_micros(i * 17), &format!("line {i}"));
        }
        assert_eq!(a.text(), b.text());
    }
}
