//! Deterministic simulated broadcast network with per-edge fault injection.
//!
//! [`SimNet`] is the virtual-time counterpart of the threaded
//! [`crate::network::Fabric`]: same broadcast-only, no-acknowledgement
//! semantics, but single-threaded and driven explicitly by the simulator's
//! event loop — `send` schedules deliveries at virtual due times,
//! [`SimNet::deliver_due`] moves them into per-worker inboxes, and every
//! random choice (delay, drop, duplication, reordering) comes from one
//! seeded [`Rng`], so the whole wire history is a pure function of the
//! seed.
//!
//! [`SimEndpoint`] implements the generic [`crate::tmsn::Link`], so the
//! production protocol driver ([`crate::tmsn::Driver`]) and state machine
//! run over the simulated net **unmodified** — that is the point: the
//! resilience tests exercise the real protocol code, only the wire and the
//! clock are simulated.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::tmsn::{Link, Payload};
use crate::util::rng::Rng;

/// Fault model of one directed edge.
#[derive(Debug, Clone)]
pub struct EdgeFaults {
    /// minimum propagation delay
    pub delay_min: Duration,
    /// maximum *base* propagation delay (uniform in `[min, max]`)
    pub delay_max: Duration,
    /// iid probability a message is silently lost
    pub drop: f64,
    /// probability a message is delivered twice (independent delays, so
    /// the copies may arrive in either order)
    pub dup: f64,
    /// probability a message gets up to 2× the `[min, max]` span of extra
    /// delay — enough to overtake later messages (reordering); the total
    /// delay stays bounded by `min + 3·(max − min)`
    pub reorder: f64,
}

impl Default for EdgeFaults {
    fn default() -> Self {
        EdgeFaults {
            delay_min: Duration::from_micros(500),
            delay_max: Duration::from_millis(3),
            drop: 0.0,
            dup: 0.0,
            reorder: 0.0,
        }
    }
}

impl EdgeFaults {
    /// A lossy/chaotic edge profile for stress scenarios.
    pub fn lossy(drop: f64, dup: f64, reorder: f64) -> EdgeFaults {
        EdgeFaults {
            drop,
            dup,
            reorder,
            ..EdgeFaults::default()
        }
    }
}

/// Network-wide configuration: a default edge profile plus per-edge
/// `(src, dst)` overrides.
#[derive(Debug, Clone, Default)]
pub struct SimNetConfig {
    /// fault model applied to every edge without an override
    pub edge: EdgeFaults,
    /// per-directed-edge overrides (first match wins)
    pub overrides: Vec<(usize, usize, EdgeFaults)>,
}

/// Wire counters. `offered` counts per-destination send attempts (one
/// broadcast to an `n`-cluster offers `n − 1` messages); after the queue
/// drains, `delivered + to_down == offered − dropped − partition_blocked
/// + duplicated` — asserted in the test suite.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimNetStats {
    /// broadcasts submitted by workers
    pub broadcasts: u64,
    /// per-destination messages considered (broadcasts × (n − 1))
    pub offered: u64,
    /// messages placed into an inbox
    pub delivered: u64,
    /// messages lost to the iid drop fault
    pub dropped: u64,
    /// extra copies injected by the duplication fault
    pub duplicated: u64,
    /// messages given extra reordering delay
    pub reordered: u64,
    /// messages blocked at send time by an active partition
    pub partition_blocked: u64,
    /// messages that arrived at a crashed worker and were discarded
    pub to_down: u64,
}

/// A message in flight, ordered as a min-heap by `(due, seq)` — the
/// tie-break makes delivery order deterministic even at equal due times.
struct InFlight<P> {
    due: Duration,
    seq: u64,
    src: usize,
    dst: usize,
    msg: P,
}

impl<P> PartialEq for InFlight<P> {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl<P> Eq for InFlight<P> {}
impl<P> PartialOrd for InFlight<P> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<P> Ord for InFlight<P> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: invert for (due, seq) min-heap order
        other
            .due
            .cmp(&self.due)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Inner<P> {
    cfg: SimNetConfig,
    rng: Rng,
    now: Duration,
    seq: u64,
    queue: BinaryHeap<InFlight<P>>,
    inboxes: Vec<VecDeque<P>>,
    /// partition: group index per worker (`None` = fully connected)
    group_of: Option<Vec<Option<usize>>>,
    down: Vec<bool>,
    stats: SimNetStats,
    /// timestamped wire-event lines, drained into the run trace
    wire_log: Vec<(Duration, String)>,
}

impl<P: Payload> Inner<P> {
    fn faults(&self, src: usize, dst: usize) -> EdgeFaults {
        self.cfg
            .overrides
            .iter()
            .find(|(s, d, _)| *s == src && *d == dst)
            .map(|(_, _, f)| f.clone())
            .unwrap_or_else(|| self.cfg.edge.clone())
    }

    fn blocked(&self, src: usize, dst: usize) -> bool {
        match &self.group_of {
            None => false,
            // isolated (unlisted) workers can reach nobody
            Some(g) => match (g[src], g[dst]) {
                (Some(a), Some(b)) => a != b,
                _ => true,
            },
        }
    }

    fn draw_delay(&mut self, f: &EdgeFaults) -> Duration {
        let span = f.delay_max.saturating_sub(f.delay_min);
        f.delay_min + span.mul_f64(self.rng.f64())
    }

    fn enqueue(&mut self, src: usize, dst: usize, due: Duration, msg: P) {
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(InFlight {
            due,
            seq,
            src,
            dst,
            msg,
        });
    }

    fn broadcast(&mut self, src: usize, msg: P) {
        self.stats.broadcasts += 1;
        let now = self.now;
        for dst in 0..self.inboxes.len() {
            if dst == src {
                continue;
            }
            self.stats.offered += 1;
            if self.blocked(src, dst) {
                self.stats.partition_blocked += 1;
                self.wire_log.push((now, format!("net  block {src}->{dst} (partition)")));
                continue;
            }
            let f = self.faults(src, dst);
            if f.drop > 0.0 && self.rng.bernoulli(f.drop) {
                self.stats.dropped += 1;
                self.wire_log.push((now, format!("net  drop  {src}->{dst}")));
                continue;
            }
            let mut delay = self.draw_delay(&f);
            if f.reorder > 0.0 && self.rng.bernoulli(f.reorder) {
                let span = f.delay_max.saturating_sub(f.delay_min);
                delay += span.mul_f64(self.rng.f64() * 2.0);
                self.stats.reordered += 1;
            }
            self.enqueue(src, dst, now + delay, msg.clone());
            if f.dup > 0.0 && self.rng.bernoulli(f.dup) {
                let d2 = self.draw_delay(&f);
                self.stats.duplicated += 1;
                self.wire_log.push((now, format!("net  dup   {src}->{dst}")));
                self.enqueue(src, dst, now + d2, msg.clone());
            }
        }
    }

    fn deliver_due(&mut self, t: Duration) {
        self.now = self.now.max(t);
        while self.queue.peek().map_or(false, |m| m.due <= t) {
            let m = self.queue.pop().unwrap();
            if self.down[m.dst] {
                self.stats.to_down += 1;
                self.wire_log
                    .push((m.due, format!("net  drop  {}->{} (down)", m.src, m.dst)));
            } else {
                self.stats.delivered += 1;
                self.wire_log
                    .push((m.due, format!("net  deliver {}->{}", m.src, m.dst)));
                self.inboxes[m.dst].push_back(m.msg);
            }
        }
    }
}

/// The simulated network. Endpoints share the inner state; the engine
/// drives delivery through [`SimNet::deliver_due`].
pub struct SimNet<P> {
    inner: Arc<Mutex<Inner<P>>>,
}

/// One worker's attachment to the simulated network; implements the
/// generic [`Link`] so protocol code is transport-agnostic.
pub struct SimEndpoint<P> {
    id: usize,
    inner: Arc<Mutex<Inner<P>>>,
}

impl<P: Payload> SimNet<P> {
    /// A simulated `n`-cluster. All fault randomness is drawn from `rng`.
    pub fn new(n: usize, cfg: SimNetConfig, rng: Rng) -> (SimNet<P>, Vec<SimEndpoint<P>>) {
        assert!(n >= 1);
        assert!(
            cfg.edge.delay_max >= cfg.edge.delay_min,
            "delay_max must be >= delay_min"
        );
        for (s, d, f) in &cfg.overrides {
            assert!(*s < n && *d < n, "override edge ({s},{d}) out of range");
            assert!(f.delay_max >= f.delay_min);
        }
        let inner = Arc::new(Mutex::new(Inner {
            cfg,
            rng,
            now: Duration::ZERO,
            seq: 0,
            queue: BinaryHeap::new(),
            inboxes: (0..n).map(|_| VecDeque::new()).collect(),
            group_of: None,
            down: vec![false; n],
            stats: SimNetStats::default(),
            wire_log: Vec::new(),
        }));
        let endpoints = (0..n)
            .map(|id| SimEndpoint {
                id,
                inner: Arc::clone(&inner),
            })
            .collect();
        (SimNet { inner }, endpoints)
    }

    /// Advance the net's notion of "now" (affects the due time of
    /// subsequent sends). Monotone.
    pub fn set_now(&self, t: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.now = g.now.max(t);
    }

    /// Virtual due time of the earliest in-flight message.
    pub fn next_due(&self) -> Option<Duration> {
        self.inner.lock().unwrap().queue.peek().map(|m| m.due)
    }

    /// Deliver every message due at or before `t` into its inbox (or drop
    /// it if the destination is down), in deterministic `(due, seq)` order.
    pub fn deliver_due(&self, t: Duration) {
        self.inner.lock().unwrap().deliver_due(t);
    }

    /// Install a partition: messages crossing group boundaries (or
    /// touching an unlisted worker) are blocked at send time.
    pub fn partition(&self, groups: &[Vec<usize>]) {
        let mut g = self.inner.lock().unwrap();
        let n = g.inboxes.len();
        let mut group_of: Vec<Option<usize>> = vec![None; n];
        for (gi, members) in groups.iter().enumerate() {
            for &w in members {
                assert!(w < n, "partition member {w} out of range");
                assert!(group_of[w].is_none(), "worker {w} in two partition groups");
                group_of[w] = Some(gi);
            }
        }
        g.group_of = Some(group_of);
    }

    /// Remove any partition. Blocked messages are *not* retransmitted.
    pub fn heal(&self) {
        self.inner.lock().unwrap().group_of = None;
    }

    /// Mark a worker crashed (`down = true`: inbox cleared, future
    /// deliveries discarded) or recovered.
    pub fn set_down(&self, id: usize, down: bool) {
        let mut g = self.inner.lock().unwrap();
        g.down[id] = down;
        if down {
            g.inboxes[id].clear();
        }
    }

    /// Snapshot of the wire counters.
    pub fn stats(&self) -> SimNetStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Messages still in flight.
    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Take the buffered wire-event lines (for the run trace).
    pub fn drain_wire_log(&self) -> Vec<(Duration, String)> {
        std::mem::take(&mut self.inner.lock().unwrap().wire_log)
    }
}

impl<P: Payload> SimEndpoint<P> {
    /// This endpoint's worker id.
    pub fn id(&self) -> usize {
        self.id
    }
}

impl<P: Payload> Link<P> for SimEndpoint<P> {
    fn send(&self, msg: P) {
        self.inner.lock().unwrap().broadcast(self.id, msg);
    }

    fn poll(&self) -> Option<P> {
        self.inner.lock().unwrap().inboxes[self.id].pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tmsn::testpay::TestPayload;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    fn fixed_delay(d: Duration) -> SimNetConfig {
        SimNetConfig {
            edge: EdgeFaults {
                delay_min: d,
                delay_max: d,
                ..EdgeFaults::default()
            },
            overrides: Vec::new(),
        }
    }

    fn payload(tag: &str) -> TestPayload {
        TestPayload::scored(tag, 0.5)
    }

    #[test]
    fn broadcast_reaches_all_other_endpoints_after_delay() {
        let (net, eps) = SimNet::new(3, fixed_delay(ms(5)), Rng::new(1));
        eps[0].send(payload("hi"));
        assert!(eps[1].poll().is_none(), "nothing delivered before due time");
        assert_eq!(net.next_due(), Some(ms(5)));
        net.deliver_due(ms(5));
        assert_eq!(eps[1].poll().unwrap().body, "hi");
        assert_eq!(eps[2].poll().unwrap().body, "hi");
        assert!(eps[0].poll().is_none(), "no self-delivery");
        let s = net.stats();
        assert_eq!((s.broadcasts, s.offered, s.delivered), (1, 2, 2));
    }

    #[test]
    fn schedule_is_a_pure_function_of_the_seed() {
        let run = |seed: u64| {
            let cfg = SimNetConfig {
                edge: EdgeFaults {
                    drop: 0.3,
                    dup: 0.3,
                    reorder: 0.5,
                    ..EdgeFaults::default()
                },
                overrides: Vec::new(),
            };
            let (net, eps) = SimNet::new(4, cfg, Rng::new(seed));
            for i in 0..20 {
                net.set_now(Duration::from_micros(i * 137));
                eps[(i % 4) as usize].send(payload(&format!("m{i}")));
            }
            net.deliver_due(Duration::from_secs(1));
            let log: Vec<String> = net.drain_wire_log().into_iter().map(|(_, l)| l).collect();
            (log, net.stats())
        };
        let (la, sa) = run(7);
        let (lb, sb) = run(7);
        assert_eq!(la, lb, "same seed must give an identical wire history");
        assert_eq!(sa, sb);
        let (lc, _) = run(8);
        assert_ne!(la, lc, "different seeds must diverge");
    }

    #[test]
    fn partition_blocks_cross_group_only() {
        let (net, eps) = SimNet::new(4, fixed_delay(ms(1)), Rng::new(2));
        net.partition(&[vec![0, 1], vec![2, 3]]);
        eps[0].send(payload("a"));
        net.deliver_due(ms(1));
        assert!(eps[1].poll().is_some(), "same-group delivery survives");
        assert!(eps[2].poll().is_none());
        assert!(eps[3].poll().is_none());
        assert_eq!(net.stats().partition_blocked, 2);
        net.heal();
        eps[0].send(payload("b"));
        net.deliver_due(ms(10));
        assert!(eps[2].poll().is_some(), "heal restores the link");
    }

    #[test]
    fn unlisted_workers_are_isolated_by_a_partition() {
        let (net, eps) = SimNet::new(3, fixed_delay(ms(1)), Rng::new(3));
        net.partition(&[vec![0, 1]]);
        eps[2].send(payload("x"));
        eps[0].send(payload("y"));
        net.deliver_due(ms(1));
        assert!(eps[0].poll().is_none(), "isolated worker reaches nobody");
        assert!(eps[1].poll().unwrap().body == "y");
        assert!(eps[2].poll().is_none(), "nobody reaches the isolated worker");
    }

    #[test]
    fn down_worker_discards_deliveries_and_inbox() {
        let (net, eps) = SimNet::new(2, fixed_delay(ms(1)), Rng::new(4));
        eps[0].send(payload("queued"));
        net.deliver_due(ms(1));
        assert_eq!(net.stats().delivered, 1);
        // message sits unread in w1's inbox; the crash clears it
        net.set_down(1, true);
        assert!(eps[1].poll().is_none(), "crash clears the inbox");
        eps[0].send(payload("while-down"));
        net.deliver_due(ms(10));
        assert_eq!(net.stats().to_down, 1);
        net.set_down(1, false);
        assert!(eps[1].poll().is_none(), "nothing replayed after recovery");
    }

    #[test]
    fn duplication_delivers_twice_and_is_counted() {
        let cfg = SimNetConfig {
            edge: EdgeFaults {
                delay_min: ms(1),
                delay_max: ms(2),
                dup: 1.0,
                ..EdgeFaults::default()
            },
            overrides: Vec::new(),
        };
        let (net, eps) = SimNet::new(2, cfg, Rng::new(5));
        eps[0].send(payload("d"));
        net.deliver_due(ms(10));
        assert!(eps[1].poll().is_some());
        assert!(eps[1].poll().is_some(), "duplicate copy must arrive too");
        assert!(eps[1].poll().is_none());
        let s = net.stats();
        assert_eq!((s.duplicated, s.delivered), (1, 2));
    }

    #[test]
    fn per_edge_override_applies_to_that_edge_only() {
        let cfg = SimNetConfig {
            edge: fixed_delay(ms(1)).edge,
            overrides: vec![(0, 2, EdgeFaults { drop: 1.0, ..fixed_delay(ms(1)).edge })],
        };
        let (net, eps) = SimNet::new(3, cfg, Rng::new(6));
        eps[0].send(payload("o"));
        net.deliver_due(ms(5));
        assert!(eps[1].poll().is_some(), "default edge delivers");
        assert!(eps[2].poll().is_none(), "overridden edge drops everything");
        assert_eq!(net.stats().dropped, 1);
    }

    #[test]
    fn single_node_cluster_broadcast_is_a_noop() {
        let (net, eps) = SimNet::new(1, fixed_delay(ms(1)), Rng::new(7));
        eps[0].send(payload("solo"));
        net.deliver_due(ms(10));
        assert!(eps[0].poll().is_none());
        let s = net.stats();
        assert_eq!((s.broadcasts, s.offered, s.delivered), (1, 0, 0));
    }
}
