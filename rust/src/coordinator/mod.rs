//! Cluster coordinator — spawn/observe/collect, never synchronize.
//!
//! TMSN has no head node: the "coordinator" here is launch + observation
//! infrastructure. It spawns worker threads, attaches a passive observer
//! endpoint to the broadcast fabric (so it sees the same model stream
//! every worker sees — it is just another listener, not a barrier), and
//! periodically evaluates the best-certified model on the held-out set to
//! produce the paper's metric-vs-time series.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::boosting::{grid::partition_features, CandidateGrid};
use crate::config::TrainConfig;
use crate::data::{DataBlock, DiskStore};
use crate::eval::{auprc, exp_loss_scores, MetricPoint, MetricSeries};
use crate::eval::metrics::scores;
use crate::metrics::{events, Event, EventLog};
use crate::model::StrongRule;
use crate::network::{Fabric, NetConfig};
use crate::tmsn::{BoostPayload, Certified, LossBoundCert};
use crate::worker::{run_worker, WorkerParams, WorkerResult};

/// Everything a cluster run produces.
#[derive(Debug)]
pub struct ClusterOutcome {
    /// best model by certified bound at shutdown
    pub model: StrongRule,
    pub loss_bound: f64,
    pub series: MetricSeries,
    pub events: Vec<Event>,
    pub workers: Vec<WorkerResult>,
    pub elapsed: Duration,
    /// (sent, delivered, dropped) fabric counters
    pub net: (u64, u64, u64),
}

impl ClusterOutcome {
    /// Render the Figure-1 execution timeline.
    pub fn timeline(&self, width: usize) -> String {
        crate::metrics::render_timeline(&self.events, self.workers.len(), width)
    }
}

/// Train a Sparrow cluster on `store`, evaluating against `test`.
///
/// `make_backend` constructs each worker's scan backend (native or PJRT —
/// see `runtime::make_backend` for the config-driven factory).
pub fn train_cluster(
    cfg: &TrainConfig,
    store_path: &std::path::Path,
    test: &DataBlock,
    label: &str,
    make_backend: &dyn Fn(usize) -> anyhow::Result<Box<dyn crate::scanner::ScanBackend>>,
) -> anyhow::Result<ClusterOutcome> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    let t0 = Instant::now();
    let store = DiskStore::open(store_path)?;
    let f = store.num_features();
    anyhow::ensure!(
        f >= cfg.num_workers,
        "need at least one feature per worker ({f} features, {} workers)",
        cfg.num_workers
    );

    // Pilot sample → shared candidate grid (workers agree on candidates so
    // broadcast models are interpretable everywhere).
    let pilot_n = 4096.min(store.len());
    let pilot = store
        .stream(crate::data::IoThrottle::unlimited())?
        .next_block(pilot_n)?;
    let grid = CandidateGrid::from_quantiles(&pilot, cfg.nthr);
    let stripes = partition_features(f, cfg.num_workers);

    // Fabric: one endpoint per worker + a passive observer (index n).
    let net = NetConfig {
        seed: cfg.seed ^ 0xFA8,
        ..cfg.net.clone()
    };
    let (fabric, mut endpoints) = Fabric::<BoostPayload>::new(cfg.num_workers + 1, net);
    let observer = endpoints.pop().expect("observer endpoint");

    let (log, event_rx) = EventLog::new();
    let stop = Arc::new(AtomicBool::new(false));

    // Spawn workers.
    let mut handles = Vec::new();
    for (id, endpoint) in endpoints.into_iter().enumerate() {
        let params = WorkerParams {
            id,
            cfg: cfg.clone(),
            grid: grid.clone(),
            stripe: stripes[id],
            store: DiskStore::open(store_path)?,
            endpoint: Box::new(endpoint),
            log: log.clone(),
            stop: Arc::clone(&stop),
            backend: make_backend(id)?,
            laggard: cfg
                .laggards
                .iter()
                .find(|(w, _)| *w == id)
                .map(|(_, k)| *k)
                .unwrap_or(1.0),
            crash_after: cfg
                .crashes
                .iter()
                .find(|(w, _)| *w == id)
                .map(|(_, t)| *t),
            seed: cfg.seed,
            control: None,
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("sparrow-worker-{id}"))
                .spawn(move || run_worker(params))?,
        );
    }

    // Observe: track the best certified model seen on the wire; evaluate
    // on the held-out set every eval_interval.
    let mut best_model = StrongRule::new();
    let mut best_cert = LossBoundCert::initial();
    let mut series = MetricSeries::new(label);
    let mut next_eval = Instant::now();
    let mut iterations_seen = 0u64;
    loop {
        while let Some(msg) = observer.try_recv() {
            iterations_seen = iterations_seen.max(msg.model.len() as u64);
            if msg.cert.loss_bound < best_cert.loss_bound {
                best_cert = msg.cert;
                best_model = msg.model;
            }
        }
        if Instant::now() >= next_eval {
            next_eval = Instant::now() + cfg.eval_interval;
            let sc = scores(&best_model, test);
            let point = MetricPoint {
                elapsed: t0.elapsed(),
                iterations: best_model.len() as u64,
                exp_loss: exp_loss_scores(&sc, &test.labels),
                auprc: auprc(&sc, &test.labels),
            };
            series.push(point);
            if cfg.target_loss > 0.0 && point.exp_loss <= cfg.target_loss {
                stop.store(true, Ordering::Relaxed);
            }
        }
        if t0.elapsed() >= cfg.time_limit {
            stop.store(true, Ordering::Relaxed);
        }
        if handles.iter().all(|h| h.is_finished()) {
            break;
        }
        if stop.load(Ordering::Relaxed) && handles.iter().all(|h| h.is_finished()) {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }

    let workers: Vec<WorkerResult> = handles
        .into_iter()
        .map(|h| h.join().expect("worker panicked"))
        .collect();

    // Workers may have certified improvements the observer's last poll
    // missed; fold their final states in.
    while let Some(msg) = observer.try_recv() {
        if msg.cert.loss_bound < best_cert.loss_bound {
            best_cert = msg.cert;
            best_model = msg.model;
        }
    }
    for w in &workers {
        if w.loss_bound < best_cert.loss_bound {
            best_cert.loss_bound = w.loss_bound;
            best_model = w.model.clone();
        }
    }

    // Final evaluation point.
    let sc = scores(&best_model, test);
    series.push(MetricPoint {
        elapsed: t0.elapsed(),
        iterations: best_model.len() as u64,
        exp_loss: exp_loss_scores(&sc, &test.labels),
        auprc: auprc(&sc, &test.labels),
    });

    let net_stats = fabric.stats.snapshot();
    fabric.shutdown();
    let collected = events::drain(&event_rx);

    Ok(ClusterOutcome {
        model: best_model,
        loss_bound: best_cert.loss_bound,
        series,
        events: collected,
        workers,
        elapsed: t0.elapsed(),
        net: net_stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthGen;
    use crate::data::SynthConfig;
    use crate::scanner::NativeBackend;

    fn make_store(n: usize, f: usize, seed: u64) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sparrow_coord_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("train_{seed}_{n}_{f}.sprw"));
        let cfg = SynthConfig {
            f,
            pos_rate: 0.3,
            informative: f / 2,
            signal: 0.8,
            flip_rate: 0.02,
            seed,
        };
        SynthGen::new(cfg).write_store(&path, n).unwrap();
        path
    }

    fn test_block(f: usize, seed: u64) -> DataBlock {
        let cfg = SynthConfig {
            f,
            pos_rate: 0.3,
            informative: f / 2,
            signal: 0.8,
            flip_rate: 0.02,
            seed,
        };
        SynthGen::new(cfg).next_block(2000)
    }

    fn native_factory() -> impl Fn(usize) -> anyhow::Result<Box<dyn crate::scanner::ScanBackend>> {
        |_| Ok(Box::new(NativeBackend) as Box<dyn crate::scanner::ScanBackend>)
    }

    #[test]
    fn single_worker_learns() {
        let store = make_store(20_000, 16, 21);
        let test = test_block(16, 22);
        let cfg = TrainConfig {
            num_workers: 1,
            sample_size: 2000,
            max_rules: 10,
            time_limit: Duration::from_secs(20),
            gamma0: 0.2,
            ..TrainConfig::default()
        };
        let out = train_cluster(&cfg, &store, &test, "t", &native_factory()).unwrap();
        assert!(!out.model.is_empty(), "no rules learned");
        assert!(out.loss_bound < 1.0);
        let final_loss = out.series.final_loss().unwrap();
        assert!(final_loss < 1.0, "loss={final_loss}");
        assert!(out.workers[0].found > 0);
    }

    #[test]
    fn multi_worker_cluster_converges_and_communicates() {
        let store = make_store(20_000, 16, 23);
        let test = test_block(16, 24);
        let cfg = TrainConfig {
            num_workers: 4,
            sample_size: 1500,
            max_rules: 12,
            time_limit: Duration::from_secs(30),
            gamma0: 0.2,
            ..TrainConfig::default()
        };
        let out = train_cluster(&cfg, &store, &test, "t4", &native_factory()).unwrap();
        assert!(out.model.len() >= 2);
        let (sent, delivered, _) = out.net;
        assert!(sent > 0, "no broadcasts");
        assert!(delivered > 0, "no deliveries");
        // someone accepted someone else's model
        let total_accepts: u64 = out.workers.iter().map(|w| w.accepts).sum();
        assert!(total_accepts > 0, "no TMSN adoption happened");
        // events recorded
        assert!(out
            .events
            .iter()
            .any(|e| e.kind == crate::metrics::EventKind::Broadcast));
        let timeline = out.timeline(60);
        assert!(timeline.contains("w0"));
    }

    #[test]
    fn crash_injection_does_not_stop_cluster() {
        let store = make_store(10_000, 8, 25);
        let test = test_block(8, 26);
        let cfg = TrainConfig {
            num_workers: 3,
            sample_size: 1000,
            // large enough that the cluster is still running when the
            // crash deadline arrives (the deadline is checked per loop)
            max_rules: 500,
            time_limit: Duration::from_secs(5),
            gamma0: 0.2,
            crashes: vec![(1, Duration::from_millis(30))],
            ..TrainConfig::default()
        };
        let out = train_cluster(&cfg, &store, &test, "crash", &native_factory()).unwrap();
        assert!(out.workers[1].crashed);
        // the survivors still learned a model
        assert!(!out.model.is_empty());
        assert!(out
            .events
            .iter()
            .any(|e| e.kind == crate::metrics::EventKind::Crash));
    }

    #[test]
    fn observer_sees_every_broadcast_and_never_perturbs() {
        // The coordinator's observer endpoint is just another listener on
        // the fabric: it must see every broadcast, never send, and leave
        // the workers' verdict counters exactly as a two-party exchange
        // would (satellite: passive-observer coverage).
        use crate::metrics::EventLog;
        use crate::model::Stump;
        use crate::tmsn::{Driver, Tmsn};
        use std::time::Duration;

        let (fabric, mut eps) = Fabric::<BoostPayload>::new(3, NetConfig::ideal());
        let observer = eps.pop().expect("observer endpoint");
        let b_ep = eps.pop().unwrap();
        let a_ep = eps.pop().unwrap();
        let log = EventLog::new().0;
        let mut a = Driver::new(Tmsn::<BoostPayload>::new(0), a_ep, log.clone());
        let mut b = Driver::new(Tmsn::<BoostPayload>::new(1), b_ep, log);

        // a certifies three improvements, b one (worse than a's last)
        for (i, g) in [(0u32, 0.3), (1, 0.2), (2, 0.1)] {
            let mut m = a.payload().model.clone();
            m.push(Stump::new(i, 0.0, 1.0), 0.2);
            a.publish(a.payload().improved(m, g));
        }
        let mut m = b.payload().model.clone();
        m.push(Stump::new(9, 0.0, 1.0), 0.2);
        b.publish(b.payload().improved(m, 0.05));

        // the observer sees all four broadcasts …
        let mut seen = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while seen.len() < 4 && Instant::now() < deadline {
            match observer.recv_timeout(Duration::from_millis(50)) {
                Some(msg) => seen.push((msg.cert.origin, msg.cert.seq)),
                None => {}
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![(0, 1), (0, 2), (0, 3), (1, 1)]);

        // … while each worker's verdicts reflect only its peer's messages
        let deadline = Instant::now() + Duration::from_secs(5);
        while (a.state().accepts + a.state().rejects < 1
            || b.state().accepts + b.state().rejects < 3)
            && Instant::now() < deadline
        {
            a.poll_adopt(&mut |_, _| {});
            b.poll_adopt(&mut |_, _| {});
            std::thread::sleep(Duration::from_millis(2));
        }
        // b's single broadcast (bound ~0.995) is worse than a's final
        assert_eq!((a.state().accepts, a.state().rejects), (0, 1));
        // a's chain arrives in order: every hop strictly improves on the
        // previous, and all beat b's own certificate
        assert_eq!((b.state().accepts, b.state().rejects), (3, 0));

        // the observer sent nothing: the fabric counted only 4 broadcasts
        let (sent, _, dropped) = fabric.stats.snapshot();
        assert_eq!((sent, dropped), (4, 0));
        fabric.shutdown();
    }

    #[test]
    fn cluster_sends_come_only_from_workers() {
        // End-to-end passivity: every fabric broadcast in a cluster run is
        // a worker's local improvement — the observer contributes none.
        let store = make_store(10_000, 8, 31);
        let test = test_block(8, 32);
        let cfg = TrainConfig {
            num_workers: 2,
            sample_size: 1000,
            max_rules: 8,
            time_limit: Duration::from_secs(20),
            gamma0: 0.2,
            ..TrainConfig::default()
        };
        let out = train_cluster(&cfg, &store, &test, "obs", &native_factory()).unwrap();
        let total_found: u64 = out.workers.iter().map(|w| w.found).sum();
        let (sent, _, _) = out.net;
        assert!(total_found > 0);
        // `sent` is counted by the dispatcher thread, so a broadcast made
        // just before shutdown may not be tallied yet — but every tallied
        // send must be a worker's local improvement. An observer that
        // broadcast would (eventually) push `sent` above `total_found`.
        assert!(sent > 0);
        assert!(
            sent <= total_found,
            "observer must never broadcast: sent {sent} > found {total_found}"
        );
    }

    #[test]
    fn respects_time_limit() {
        let store = make_store(5_000, 8, 27);
        let test = test_block(8, 28);
        let cfg = TrainConfig {
            num_workers: 2,
            sample_size: 1000,
            max_rules: 100_000,                    // never reached
            gamma_min: 1e-9,                       // keep halving forever
            time_limit: Duration::from_millis(1500),
            ..TrainConfig::default()
        };
        let t0 = Instant::now();
        let _ = train_cluster(&cfg, &store, &test, "tl", &native_factory()).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(15));
    }
}
