//! Chaos proxy: seeded TCP fault injection against *real* sockets
//! (DESIGN.md §13).
//!
//! PR 5's simulator proves TMSN's resilience claims under a virtual wire;
//! this module re-runs the same fault vocabulary against the real TCP
//! fabric. A [`ChaosProxy`] is an in-process forwarder for one directed
//! edge: peers dial the proxy's listen address instead of the upstream
//! worker, and every byte of the dialer→upstream direction passes through
//! a fault gate consulted per frame. Faults live in a shared
//! [`ChaosRules`] table so a test harness — or the admin RPC's
//! `fault.inject` — can flip them at runtime:
//!
//! * [`ChaosFault::Delay`] — hold each frame for a fixed latency;
//! * [`ChaosFault::Drop`] — discard each frame with seeded probability
//!   `p` (deterministic per `(seed, edge)`);
//! * [`ChaosFault::Blackhole`] — swallow every frame while still reading
//!   the socket, so the sender sees a healthy connection that delivers
//!   nothing (the "silent partition" case);
//! * [`ChaosFault::HalfOpen`] — stop reading entirely without closing,
//!   so the sender's kernel buffers fill and its writes stall — the
//!   failure mode that pinned `receive_loop` threads before PR 9's
//!   write timeouts.
//!
//! The proxy keeps a bounded pcap-style frame trace (edge, direction,
//! frame length, action, timestamp) in the rules table; the chaos CI job
//! dumps it as a JSONL artifact when a battery fails.
//!
//! Fidelity notes (the honest caveats, expanded in DESIGN.md §13): the
//! proxy injects faults on the dialer→upstream direction of each edge it
//! fronts, at frame granularity. It cannot reorder within a connection
//! (TCP's per-link FIFO survives), cannot corrupt checksummed bytes in a
//! way the kernel would deliver, and a `restart` seen through it is a
//! connectivity restart, not a process death — the integration tests kill
//! the real worker for that.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::network::tcp::{frame_bytes, peek_frame, MAX_PAYLOAD};
use crate::util::rng::Rng;

/// One injectable fault for a directed edge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChaosFault {
    /// Hold every frame for this many milliseconds before forwarding.
    Delay {
        /// added one-way latency per frame
        ms: u64,
    },
    /// Discard each frame independently with probability `p` (seeded).
    Drop {
        /// per-frame drop probability in `[0, 1]`
        p: f64,
    },
    /// Read and discard everything: the sender sees a live, accepting
    /// connection that never delivers.
    Blackhole,
    /// Stop reading without closing: the sender's buffers fill and its
    /// writes stall until its write timeout trips.
    HalfOpen,
}

impl ChaosFault {
    /// Stable lowercase name (trace records, admin params).
    pub fn as_str(&self) -> &'static str {
        match self {
            ChaosFault::Delay { .. } => "delay",
            ChaosFault::Drop { .. } => "drop",
            ChaosFault::Blackhole => "blackhole",
            ChaosFault::HalfOpen => "half_open",
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Rule {
    fault: ChaosFault,
    /// expiry for timed faults (`fault.inject` partitions with `ms`);
    /// `None` = until cleared
    until: Option<Instant>,
}

/// One pcap-style trace record: what the proxy did to one frame.
#[derive(Debug, Clone)]
pub struct TraceRec {
    /// milliseconds since the rules table was created
    pub t_ms: u64,
    /// edge name (e.g. `"w1->w0"`)
    pub edge: String,
    /// what happened to the frame (`"forward"`, `"drop"`, `"delay"`,
    /// `"blackhole"`)
    pub action: &'static str,
    /// frame payload length in bytes
    pub len: usize,
}

/// Bound on retained trace records — a long battery must not grow memory
/// without limit; the newest records win.
const TRACE_CAP: usize = 100_000;

/// The shared fault table all proxies of one harness consult, plus the
/// frame trace they append to. Cheap to clone an `Arc` of; every mutation
/// takes effect on the next frame through any attached proxy.
pub struct ChaosRules {
    seed: u64,
    epoch: Instant,
    edges: Mutex<HashMap<String, Rule>>,
    trace: Mutex<Vec<TraceRec>>,
}

impl ChaosRules {
    /// A fresh table; `seed` drives every probabilistic fault, so a
    /// battery is reproducible from `(seed, edge names, schedule)`.
    pub fn new(seed: u64) -> Arc<ChaosRules> {
        Arc::new(ChaosRules {
            seed,
            epoch: Instant::now(),
            edges: Mutex::new(HashMap::new()),
            trace: Mutex::new(Vec::new()),
        })
    }

    /// Install `fault` on `edge` until cleared.
    pub fn set(&self, edge: &str, fault: ChaosFault) {
        self.edges
            .lock()
            .unwrap()
            .insert(edge.to_string(), Rule { fault, until: None });
    }

    /// Install `fault` on `edge` for `dur`, then auto-heal.
    pub fn set_for(&self, edge: &str, fault: ChaosFault, dur: Duration) {
        self.edges.lock().unwrap().insert(
            edge.to_string(),
            Rule {
                fault,
                until: Some(Instant::now() + dur),
            },
        );
    }

    /// Remove any fault on `edge`.
    pub fn clear(&self, edge: &str) {
        self.edges.lock().unwrap().remove(edge);
    }

    /// Remove every fault (the admin plane's `heal`).
    pub fn clear_all(&self) {
        self.edges.lock().unwrap().clear();
    }

    /// The fault currently active on `edge`, resolving timed expiry.
    pub fn active(&self, edge: &str) -> Option<ChaosFault> {
        let mut edges = self.edges.lock().unwrap();
        match edges.get(edge) {
            None => None,
            Some(rule) => match rule.until {
                Some(t) if Instant::now() >= t => {
                    edges.remove(edge);
                    None
                }
                _ => Some(rule.fault),
            },
        }
    }

    /// Deterministic per-edge RNG (drop decisions).
    fn edge_rng(&self, edge: &str) -> Rng {
        // FNV-1a over the edge name, folded into the battery seed
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in edge.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        Rng::new(self.seed ^ h)
    }

    fn note(&self, edge: &str, action: &'static str, len: usize) {
        let mut trace = self.trace.lock().unwrap();
        if trace.len() >= TRACE_CAP {
            trace.remove(0);
        }
        trace.push(TraceRec {
            t_ms: self.epoch.elapsed().as_millis() as u64,
            edge: edge.to_string(),
            action,
            len,
        });
    }

    /// Number of trace records currently retained.
    pub fn trace_len(&self) -> usize {
        self.trace.lock().unwrap().len()
    }

    /// The frame trace as JSONL — the failing-battery artifact the chaos
    /// CI job uploads.
    pub fn trace_jsonl(&self) -> String {
        use crate::util::json::Json;
        let trace = self.trace.lock().unwrap();
        let mut out = String::new();
        for rec in trace.iter() {
            let mut o = Json::obj();
            o.set("t_ms", rec.t_ms)
                .set("edge", rec.edge.as_str())
                .set("action", rec.action)
                .set("len", rec.len);
            out.push_str(&o.to_string());
            out.push('\n');
        }
        out
    }
}

/// An in-process TCP forwarder for one directed edge: listens on an
/// ephemeral port, forwards to `upstream`, applies the edge's
/// [`ChaosRules`] entry to every dialer→upstream frame. Dropping the
/// proxy stops its threads and closes the listener.
pub struct ChaosProxy {
    listen_addr: SocketAddr,
    upstream: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
}

impl ChaosProxy {
    /// Bind `127.0.0.1:0` and start forwarding to `upstream`, applying
    /// `rules[edge]` per frame.
    pub fn spawn(
        upstream: &str,
        rules: &Arc<ChaosRules>,
        edge: &str,
    ) -> io::Result<ChaosProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let listen_addr = listener.local_addr()?;
        let upstream = Arc::new(Mutex::new(upstream.to_string()));
        let stop = Arc::new(AtomicBool::new(false));

        let up = Arc::clone(&upstream);
        let st = Arc::clone(&stop);
        let rl = Arc::clone(rules);
        let edge = edge.to_string();
        std::thread::Builder::new()
            .name(format!("chaos-{edge}"))
            .spawn(move || {
                for client in listener.incoming() {
                    if st.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(client) = client else { break };
                    let target = up.lock().unwrap().clone();
                    // upstream down (killed worker): refuse by closing the
                    // client socket, so the dialer's writer sees the death
                    // immediately and enters its redial schedule
                    let Ok(server) = TcpStream::connect(&target) else {
                        drop(client);
                        continue;
                    };
                    let (c2, s2) = (client.try_clone(), server.try_clone());
                    let (Ok(c2), Ok(s2)) = (c2, s2) else { continue };
                    let rl_f = Arc::clone(&rl);
                    let st_f = Arc::clone(&st);
                    let edge_f = edge.clone();
                    std::thread::spawn(move || {
                        pump_faulted(client, server, rl_f, st_f, &edge_f)
                    });
                    let st_b = Arc::clone(&st);
                    std::thread::spawn(move || pump_raw(s2, c2, st_b));
                }
            })?;

        Ok(ChaosProxy {
            listen_addr,
            upstream,
            stop,
        })
    }

    /// Where peers should dial (hand this out instead of the worker's
    /// real listen address).
    pub fn listen_addr(&self) -> SocketAddr {
        self.listen_addr
    }

    /// Retarget the proxy — the restart path: a worker killed and rebound
    /// on a fresh port keeps its public (proxy) address, so surviving
    /// peers' redial schedules find it without re-discovery.
    pub fn set_upstream(&self, addr: &str) {
        *self.upstream.lock().unwrap() = addr.to_string();
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop so it observes the stop flag
        let _ = TcpStream::connect(self.listen_addr);
    }
}

/// Faulted direction (dialer → upstream). Accumulates bytes, carves
/// complete frames, applies the edge's active fault to each. On a
/// non-TMSN byte stream (bad magic) it degrades to a transparent
/// chunk-level forwarder — the proxy is a wire, not a validator.
fn pump_faulted(
    mut from: TcpStream,
    mut to: TcpStream,
    rules: Arc<ChaosRules>,
    stop: Arc<AtomicBool>,
    edge: &str,
) {
    let mut rng = rules.edge_rng(edge);
    from.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    let mut transparent = false;
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // half-open: park without reading, so the sender's buffers fill
        if matches!(rules.active(edge), Some(ChaosFault::HalfOpen)) {
            std::thread::sleep(Duration::from_millis(20));
            continue;
        }
        match from.read(&mut chunk) {
            Ok(0) => return, // dialer closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return,
        }
        if transparent {
            let fault = rules.active(edge);
            if forward_opaque(&mut to, &mut buf, fault, &mut rng, &rules, edge).is_err() {
                return;
            }
            continue;
        }
        // carve complete frames off the front of the buffer
        loop {
            match peek_frame(&buf) {
                Ok(None) => break, // incomplete: wait for more bytes
                Err(_) => {
                    // not TMSN framing: forward everything verbatim from
                    // here on (still subject to blackhole/half-open)
                    transparent = true;
                    let fault = rules.active(edge);
                    if forward_opaque(&mut to, &mut buf, fault, &mut rng, &rules, edge)
                        .is_err()
                    {
                        return;
                    }
                    break;
                }
                Ok(Some(frame_len)) => {
                    let payload: Vec<u8> = buf[8..frame_len].to_vec();
                    buf.drain(..frame_len);
                    match rules.active(edge) {
                        None => {
                            rules.note(edge, "forward", payload.len());
                            if to.write_all(&frame_bytes(&payload)).is_err() {
                                return;
                            }
                        }
                        Some(ChaosFault::Delay { ms }) => {
                            rules.note(edge, "delay", payload.len());
                            std::thread::sleep(Duration::from_millis(ms));
                            if to.write_all(&frame_bytes(&payload)).is_err() {
                                return;
                            }
                        }
                        Some(ChaosFault::Drop { p }) => {
                            if rng.bernoulli(p) {
                                rules.note(edge, "drop", payload.len());
                            } else {
                                rules.note(edge, "forward", payload.len());
                                if to.write_all(&frame_bytes(&payload)).is_err() {
                                    return;
                                }
                            }
                        }
                        Some(ChaosFault::Blackhole) => {
                            rules.note(edge, "blackhole", payload.len());
                        }
                        // half-open flipped on mid-carve: the frame is
                        // already ours — swallow it and park on the next
                        // loop iteration
                        Some(ChaosFault::HalfOpen) => {
                            rules.note(edge, "blackhole", payload.len());
                        }
                    }
                }
            }
        }
    }
}

/// Transparent-mode forwarding: the buffer is opaque bytes; apply
/// blackhole/drop at chunk granularity, else pass through.
fn forward_opaque(
    to: &mut TcpStream,
    buf: &mut Vec<u8>,
    fault: Option<ChaosFault>,
    rng: &mut Rng,
    rules: &ChaosRules,
    edge: &str,
) -> io::Result<()> {
    if buf.is_empty() {
        return Ok(());
    }
    let len = buf.len();
    let res = match fault {
        Some(ChaosFault::Blackhole) | Some(ChaosFault::HalfOpen) => {
            rules.note(edge, "blackhole", len);
            Ok(())
        }
        Some(ChaosFault::Drop { p }) if rng.bernoulli(p) => {
            rules.note(edge, "drop", len);
            Ok(())
        }
        Some(ChaosFault::Delay { ms }) => {
            rules.note(edge, "delay", len);
            std::thread::sleep(Duration::from_millis(ms));
            to.write_all(buf)
        }
        _ => {
            rules.note(edge, "forward", len);
            to.write_all(buf)
        }
    };
    buf.clear();
    res
}

/// Raw direction (upstream → dialer): transparent byte pump with a stop
/// check. Our links are written dialer→listener, so this side normally
/// carries nothing, but transparency keeps the proxy honest for any
/// bidirectional protocol riding it.
fn pump_raw(mut from: TcpStream, mut to: TcpStream, stop: Arc<AtomicBool>) {
    from.set_read_timeout(Some(Duration::from_millis(100))).ok();
    let mut chunk = [0u8; 16 * 1024];
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match from.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => {
                if to.write_all(&chunk[..n]).is_err() {
                    return;
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return,
        }
    }
}

// `MAX_PAYLOAD` is re-used by `peek_frame`'s bounds check; referencing it
// here keeps the dependency explicit for readers of this module.
const _: () = assert!(MAX_PAYLOAD > 0);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::TcpEndpoint;
    use crate::tmsn::testpay::{TestCert, TestPayload};

    fn msg(seq: u64) -> TestPayload {
        TestPayload {
            body: "chaos".into(),
            cert: TestCert {
                score: 0.5,
                origin: 1,
                seq,
            },
        }
    }

    /// a → proxy(edge) → b
    fn proxied_pair(
        rules: &Arc<ChaosRules>,
        edge: &str,
    ) -> (TcpEndpoint<TestPayload>, TcpEndpoint<TestPayload>, ChaosProxy) {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let proxy =
            ChaosProxy::spawn(&b.local_addr().to_string(), rules, edge).unwrap();
        a.connect(&proxy.listen_addr().to_string()).unwrap();
        (a, b, proxy)
    }

    #[test]
    fn clean_edge_forwards() {
        let rules = ChaosRules::new(1);
        let (a, b, _proxy) = proxied_pair(&rules, "a->b");
        a.broadcast(&msg(1));
        let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 1);
        assert!(rules.trace_len() >= 1);
    }

    #[test]
    fn delay_injects_latency() {
        let rules = ChaosRules::new(2);
        let (a, b, _proxy) = proxied_pair(&rules, "a->b");
        rules.set("a->b", ChaosFault::Delay { ms: 300 });
        let t0 = Instant::now();
        a.broadcast(&msg(2));
        let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 2);
        assert!(
            t0.elapsed() >= Duration::from_millis(200),
            "delay fault must add latency (saw {:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn blackhole_swallows_then_heals() {
        let rules = ChaosRules::new(3);
        let (a, b, _proxy) = proxied_pair(&rules, "a->b");
        rules.set("a->b", ChaosFault::Blackhole);
        a.broadcast(&msg(3));
        assert!(
            b.recv_timeout(Duration::from_millis(400)).is_none(),
            "blackholed frame must not arrive"
        );
        rules.clear("a->b");
        a.broadcast(&msg(4));
        let got = b.recv_timeout(Duration::from_secs(5)).expect("healed delivery");
        assert_eq!(got.cert.seq, 4);
    }

    #[test]
    fn drop_probability_one_drops_everything() {
        let rules = ChaosRules::new(4);
        let (a, b, _proxy) = proxied_pair(&rules, "a->b");
        rules.set("a->b", ChaosFault::Drop { p: 1.0 });
        for i in 0..5 {
            a.broadcast(&msg(i));
        }
        assert!(b.recv_timeout(Duration::from_millis(400)).is_none());
        rules.clear("a->b");
        a.broadcast(&msg(99));
        assert_eq!(
            b.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq,
            99
        );
    }

    #[test]
    fn timed_fault_auto_heals() {
        let rules = ChaosRules::new(5);
        rules.set_for("e", ChaosFault::Blackhole, Duration::from_millis(100));
        assert_eq!(rules.active("e"), Some(ChaosFault::Blackhole));
        std::thread::sleep(Duration::from_millis(150));
        assert_eq!(rules.active("e"), None);
    }

    #[test]
    fn upstream_death_closes_client_and_retarget_revives() {
        let rules = ChaosRules::new(6);
        let b1 = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let proxy =
            ChaosProxy::spawn(&b1.local_addr().to_string(), &rules, "a->b").unwrap();
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.connect(&proxy.listen_addr().to_string()).unwrap();
        a.broadcast(&msg(1));
        assert_eq!(b1.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 1);

        // kill b1; the proxy refuses new upstream connections, a's writer
        // goes into redial; then "restart" b on a fresh port
        drop(b1);
        let deadline = Instant::now() + Duration::from_secs(10);
        while a.peer_count() > 0 {
            assert!(Instant::now() < deadline, "peer death never detected");
            std::thread::sleep(Duration::from_millis(20));
        }
        let b2 = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        proxy.set_upstream(&b2.local_addr().to_string());
        while a.peer_count() == 0 {
            assert!(Instant::now() < deadline, "reconnect never happened");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(a.reconnect_count() >= 1);
        a.broadcast(&msg(2));
        let got = b2.recv_timeout(Duration::from_secs(10)).expect("post-restart delivery");
        assert_eq!(got.cert.seq, 2);
    }

    #[test]
    fn trace_is_jsonl_and_bounded() {
        let rules = ChaosRules::new(7);
        rules.note("x->y", "forward", 42);
        rules.note("x->y", "drop", 7);
        let dump = rules.trace_jsonl();
        assert_eq!(dump.lines().count(), 2);
        assert!(dump.contains("\"action\":\"drop\""));
        assert!(dump.contains("\"edge\":\"x->y\""));
    }
}
