//! Peer exchange (PEX): seed-node discovery for the TCP fabric
//! (DESIGN.md §13).
//!
//! A joiner no longer needs the full `--peers` list — it dials any one
//! live member (`--seed-peers`), announces its own listen address in a
//! versioned `PEX` frame, and the swarm does the rest: the seed dials the
//! joiner back, replies with its full known peer set, and gossips the
//! announcement onward with a decremented TTL (the same hop-budget
//! envelope the §12 fanout dialect uses), so every member learns the new
//! address within one flood.
//!
//! This module is transport-free on purpose: it holds the wire codec
//! ([`encode_pex`] / [`decode_pex`]) and the membership table
//! ([`PexTable`]) so `rust/tests/robustness.rs` can fuzz both without a
//! socket in sight. The socket-facing state machine (who to dial, when to
//! reply, when to relay) lives in [`crate::network::tcp`].
//!
//! Wire body (rides inside a `[TAG_PEX][ttl u8]` link frame, all
//! little-endian):
//!
//! ```text
//!     version  u64   sender's membership epoch (bumped per table change)
//!     count    u16   number of addresses (≤ MAX_ADDRS)
//!     repeated count times:
//!       len    u16   address byte length (1 ..= MAX_ADDR_LEN)
//!       addr   [u8]  UTF-8 socket address ("host:port")
//! ```
//!
//! Every decode failure is a hard error — a malformed PEX frame drops the
//! link, it never panics and never partially applies (fail closed).

use std::collections::HashSet;

/// Hard cap on addresses per PEX frame (bounds allocation under fuzzing
/// and caps what a hostile peer can make us absorb in one frame).
pub const MAX_ADDRS: usize = 1024;

/// Hard cap on one address string ("host:port"; a DNS name maxes out at
/// 253 bytes).
pub const MAX_ADDR_LEN: usize = 256;

/// Hard cap on the membership table itself — a gossip storm of unique
/// fake addresses must not grow memory without bound.
pub const MAX_KNOWN: usize = 10_000;

/// One decoded peer-exchange message: the sender's membership epoch plus
/// the addresses it is telling us about (its own for an announce, its
/// whole table for a full-set reply).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PexMsg {
    /// Sender's membership epoch; monotone per sender, bumped whenever
    /// its table changes. Purely observational (dedup is by address, not
    /// version) but lets an operator order gossip in a frame trace.
    pub version: u64,
    /// The addresses being exchanged.
    pub addrs: Vec<String>,
}

/// Encode a [`PexMsg`] body (the caller wraps it in the link frame).
pub fn encode_pex(msg: &PexMsg) -> Vec<u8> {
    let count = msg.addrs.len().min(MAX_ADDRS);
    let mut out = Vec::with_capacity(10 + count * 24);
    out.extend_from_slice(&msg.version.to_le_bytes());
    out.extend_from_slice(&(count as u16).to_le_bytes());
    for addr in msg.addrs.iter().take(count) {
        let bytes = addr.as_bytes();
        debug_assert!(bytes.len() <= MAX_ADDR_LEN);
        out.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
        out.extend_from_slice(bytes);
    }
    out
}

/// Decode a PEX body. Fails closed: truncation, an oversized count or
/// address, an empty address, non-UTF-8 bytes, or trailing garbage are
/// all errors (→ the caller drops the link).
pub fn decode_pex(body: &[u8]) -> Result<PexMsg, String> {
    if body.len() < 10 {
        return Err(format!("pex body truncated at {} bytes", body.len()));
    }
    let version = u64::from_le_bytes(body[0..8].try_into().unwrap());
    let count = u16::from_le_bytes(body[8..10].try_into().unwrap()) as usize;
    if count > MAX_ADDRS {
        return Err(format!("pex count {count} exceeds {MAX_ADDRS}"));
    }
    let mut addrs = Vec::with_capacity(count.min(64));
    let mut pos = 10usize;
    for i in 0..count {
        let Some(len_bytes) = body.get(pos..pos + 2) else {
            return Err(format!("pex truncated before addr {i} length"));
        };
        let len = u16::from_le_bytes(len_bytes.try_into().unwrap()) as usize;
        if len == 0 || len > MAX_ADDR_LEN {
            return Err(format!("pex addr {i} length {len} out of range"));
        }
        pos += 2;
        let Some(bytes) = body.get(pos..pos + len) else {
            return Err(format!("pex truncated inside addr {i}"));
        };
        let addr = std::str::from_utf8(bytes)
            .map_err(|_| format!("pex addr {i} is not UTF-8"))?;
        addrs.push(addr.to_string());
        pos += len;
    }
    if pos != body.len() {
        return Err(format!("pex has {} trailing bytes", body.len() - pos));
    }
    Ok(PexMsg { version, addrs })
}

/// The fabric's membership table: this endpoint's advertised address plus
/// every peer address it has learned, with a monotone version stamp.
///
/// [`PexTable::absorb`] is the whole anti-loop argument: an incoming
/// address is *fresh* only if it is not our own advertised address and
/// not already known — so a self-announce echoed back to us produces an
/// empty fresh set (nothing dialed, nothing relayed: the loop dies
/// immediately), and a gossip storm of repeats converges because only
/// fresh addresses are ever re-forwarded.
#[derive(Debug)]
pub struct PexTable {
    self_addr: String,
    version: u64,
    known: HashSet<String>,
}

impl PexTable {
    /// A table that knows only its own advertised address.
    pub fn new(self_addr: &str) -> PexTable {
        PexTable {
            self_addr: self_addr.to_string(),
            version: 0,
            known: HashSet::new(),
        }
    }

    /// The address this endpoint tells peers to dial (the chaos-proxy
    /// address when the endpoint is fronted by one).
    pub fn self_addr(&self) -> &str {
        &self.self_addr
    }

    /// Current membership epoch (bumped by every table change).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Every known peer address (not including our own), unordered.
    pub fn known(&self) -> Vec<String> {
        self.known.iter().cloned().collect()
    }

    /// Record an address we dialed directly (CLI `--peers` /
    /// `--seed-peers`) so a later PEX echo of it is not fresh.
    pub fn note_direct(&mut self, addr: &str) {
        if addr != self.self_addr && self.known.insert(addr.to_string()) {
            self.version += 1;
        }
    }

    /// Merge an incoming message, returning the genuinely new addresses
    /// (never our own, never a repeat, never beyond [`MAX_KNOWN`]).
    pub fn absorb(&mut self, msg: &PexMsg) -> Vec<String> {
        let mut fresh = Vec::new();
        for addr in &msg.addrs {
            if addr == &self.self_addr || self.known.contains(addr) {
                continue;
            }
            if self.known.len() >= MAX_KNOWN {
                break; // fail closed on table exhaustion, don't evict
            }
            self.known.insert(addr.clone());
            fresh.push(addr.clone());
        }
        if !fresh.is_empty() {
            self.version += 1;
        }
        fresh
    }

    /// The announce message: just our own advertised address.
    pub fn announce(&self) -> PexMsg {
        PexMsg {
            version: self.version,
            addrs: vec![self.self_addr.clone()],
        }
    }

    /// The full-set reply a seed sends a joiner: everything we know,
    /// including ourselves, so one frame bootstraps the whole mesh view.
    pub fn full_set(&self) -> PexMsg {
        let mut addrs: Vec<String> = self.known.iter().cloned().collect();
        addrs.push(self.self_addr.clone());
        addrs.sort(); // deterministic frame bytes for traces/tests
        PexMsg {
            version: self.version,
            addrs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let msg = PexMsg {
            version: 7,
            addrs: vec!["127.0.0.1:7701".into(), "10.0.0.2:9000".into()],
        };
        let body = encode_pex(&msg);
        assert_eq!(decode_pex(&body).unwrap(), msg);
    }

    #[test]
    fn roundtrip_empty() {
        let msg = PexMsg {
            version: 0,
            addrs: vec![],
        };
        assert_eq!(decode_pex(&encode_pex(&msg)).unwrap(), msg);
    }

    #[test]
    fn decode_rejects_truncation_at_every_byte() {
        let body = encode_pex(&PexMsg {
            version: 3,
            addrs: vec!["127.0.0.1:7701".into(), "127.0.0.1:7702".into()],
        });
        for cut in 0..body.len() {
            assert!(
                decode_pex(&body[..cut]).is_err(),
                "truncation to {cut} bytes must fail closed"
            );
        }
    }

    #[test]
    fn decode_rejects_trailing_garbage() {
        let mut body = encode_pex(&PexMsg {
            version: 1,
            addrs: vec!["a:1".into()],
        });
        body.push(0);
        assert!(decode_pex(&body).is_err());
    }

    #[test]
    fn decode_rejects_oversized_count_and_lengths() {
        // count over the cap
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&(MAX_ADDRS as u16 + 1).to_le_bytes());
        assert!(decode_pex(&body).is_err());
        // zero-length address
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&1u16.to_le_bytes());
        body.extend_from_slice(&0u16.to_le_bytes());
        assert!(decode_pex(&body).is_err());
        // non-UTF-8 address
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&1u16.to_le_bytes());
        body.extend_from_slice(&2u16.to_le_bytes());
        body.extend_from_slice(&[0xFF, 0xFE]);
        assert!(decode_pex(&body).is_err());
    }

    #[test]
    fn absorb_filters_self_and_repeats() {
        let mut t = PexTable::new("127.0.0.1:7700");
        let msg = PexMsg {
            version: 1,
            addrs: vec![
                "127.0.0.1:7700".into(), // self: never fresh
                "127.0.0.1:7701".into(),
                "127.0.0.1:7701".into(), // duplicate within one frame
            ],
        };
        assert_eq!(t.absorb(&msg), vec!["127.0.0.1:7701".to_string()]);
        // echoed back later: nothing fresh, version unchanged
        let v = t.version();
        assert!(t.absorb(&msg).is_empty());
        assert_eq!(t.version(), v);
    }

    #[test]
    fn self_announce_loop_fails_closed() {
        // a frame containing only the receiver's own address must be a
        // complete no-op: no fresh addrs to dial, relay, or reply to
        let mut t = PexTable::new("127.0.0.1:7700");
        let echo = PexMsg {
            version: 99,
            addrs: vec!["127.0.0.1:7700".into()],
        };
        assert!(t.absorb(&echo).is_empty());
        assert_eq!(t.version(), 0);
        assert!(t.known().is_empty());
    }

    #[test]
    fn table_growth_is_bounded() {
        let mut t = PexTable::new("self:0");
        let addrs: Vec<String> = (0..MAX_KNOWN + 500).map(|i| format!("h:{i}")).collect();
        for chunk in addrs.chunks(MAX_ADDRS) {
            t.absorb(&PexMsg {
                version: 0,
                addrs: chunk.to_vec(),
            });
        }
        assert_eq!(t.known().len(), MAX_KNOWN);
    }

    #[test]
    fn full_set_includes_self_and_is_sorted() {
        let mut t = PexTable::new("b:2");
        t.note_direct("c:3");
        t.note_direct("a:1");
        let full = t.full_set();
        assert_eq!(full.addrs, vec!["a:1", "b:2", "c:3"]);
    }
}
