//! TCP broadcast transport — run TMSN across real processes/machines.
//!
//! The in-process [`crate::network::Fabric`] simulates a cluster inside
//! one binary (benches, failure injection). This module is the *real*
//! transport the original Sparrow used: every worker process listens on a
//! socket, dials its peers, and broadcasts certified payloads with no
//! acknowledgements and no ordering guarantees beyond TCP's per-link
//! FIFO — faithfully TMSN: a dead peer just stops receiving.
//!
//! The transport is payload-generic: framing wraps [`Payload::encode`] /
//! [`Payload::decode`], so any workload's messages ride the same sockets.
//!
//! Wire format (little-endian):
//!     magic  u32  = 0x54_4D_53_4E ("TMSN")
//!     len    u32  = payload bytes
//!     payload     = `P::encode()` (e.g. certificate line + model text
//!                   for the boosting payload)
//!
//! In **fanout (gossip) mode** (DESIGN.md §12; enabled cluster-wide via
//! [`TcpEndpoint::enable_fanout`], so all peers speak the same dialect)
//! the payload area gains a one-byte hop-budget envelope:
//!     payload     = `[ttl u8][P::encode()]`
//! A publish goes to `k` seeded random peers instead of all of them; a
//! receiver that sees a payload for the first time pushes it to its inbox
//! and — if `ttl > 0` — relays it to `k` of its own peers with `ttl − 1`.
//! Duplicates are suppressed by `(origin, seq, cert-bits)` dedup, the
//! same key the simulator's gossip proof uses. The frame *header* is
//! untouched, so the admin RPC's shared framing keeps working.

use std::collections::HashSet;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::metrics::{EventKind, EventLog};
use crate::network::BroadcastMode;
use crate::tmsn::{Certified, Payload};
use crate::util::rng::Rng;

const MAGIC: u32 = 0x544D_534E;
/// hard cap on accepted payloads (a model of 10⁶ stumps ≈ 30 MB text)
pub(crate) const MAX_PAYLOAD: u32 = 64 << 20;

/// Frame a payload for the wire.
pub fn encode<P: Payload>(msg: &P) -> Vec<u8> {
    let payload = msg.encode();
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode a payload (after framing) back into a message.
pub fn decode<P: Payload>(payload: &[u8]) -> Result<P, String> {
    P::decode(payload)
}

/// Frame raw bytes for the wire (same magic + length header the payload
/// transport uses). The control plane's RPC endpoints (DESIGN.md §10)
/// ship JSON request/response bodies in these frames, so an admin socket
/// and a broadcast socket speak one framing dialect.
pub(crate) fn frame_bytes(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Read one length-prefixed frame. `Ok(None)` = clean EOF between frames
/// (peer closed); `InvalidData` errors = corrupt stream (bad magic,
/// oversized length), after which the link must be dropped.
pub(crate) fn read_frame(stream: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut head = [0u8; 8];
    if let Err(e) = stream.read_exact(&mut head) {
        // clean EOF between frames = peer closed
        return if e.kind() == io::ErrorKind::UnexpectedEof {
            Ok(None)
        } else {
            Err(e)
        };
    }
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    if len > MAX_PAYLOAD {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "oversized frame"));
    }
    let mut payload = vec![0u8; len as usize];
    stream.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Gossip-mode dedup key: `(origin, seq, certificate bits)`. The cert-bits
/// component disambiguates incarnations — a resumed worker restamps its
/// checkpoint `(id, 0)`, but any payload it re-publishes carries a
/// strictly-better (hence bit-different) certificate summary. Mirrors the
/// simulator's `dedup_key` exactly.
fn gossip_key<P: Payload>(msg: &P) -> (usize, u64, u64) {
    let c = msg.cert();
    (c.origin(), c.seq(), c.summary().to_bits())
}

/// Frame a payload with the fanout hop-budget envelope:
/// `[ttl u8][P::encode()]` inside the ordinary magic+len frame.
fn encode_fanout<P: Payload>(msg: &P, ttl: u32) -> Vec<u8> {
    let body = msg.encode();
    let mut payload = Vec::with_capacity(1 + body.len());
    payload.push(ttl.min(u8::MAX as u32) as u8);
    payload.extend_from_slice(&body);
    frame_bytes(&payload)
}

/// Write `frame` to `k` seeded-random distinct peers (all of them when
/// `k >= peers.len()`); peers whose write fails are pruned, like
/// full-mode broadcast.
fn send_to_k(peers: &mut Vec<TcpStream>, rng: &mut Rng, k: usize, frame: &[u8]) {
    if peers.is_empty() || k == 0 {
        return;
    }
    let k = k.min(peers.len());
    let mut dead: Vec<usize> = rng
        .sample_indices(peers.len(), k)
        .into_iter()
        .filter(|&i| peers[i].write_all(frame).is_err())
        .collect();
    dead.sort_unstable();
    for i in dead.into_iter().rev() {
        peers.remove(i);
    }
}

/// Per-endpoint gossip state, shared with the receive threads (they do
/// the re-forwarding). `None` = full-broadcast mode, no envelopes.
struct FanoutRt {
    k: usize,
    ttl: u32,
    rng: Rng,
    seen: HashSet<(usize, u64, u64)>,
    forwards: u64,
    log: Option<(EventLog, usize)>,
}

/// A worker's TCP attachment: listens for peers, dials peers, broadcasts.
pub struct TcpEndpoint<P: Payload> {
    peers: Arc<Mutex<Vec<TcpStream>>>,
    inbox: Receiver<P>,
    local_addr: SocketAddr,
    fanout: Arc<Mutex<Option<FanoutRt>>>,
    // keep the sender alive for acceptor threads spawned later
    _inbox_tx: Sender<P>,
}

impl<P: Payload> TcpEndpoint<P> {
    /// Bind a listener (`addr` like "127.0.0.1:0") and start accepting.
    pub fn bind(addr: &str) -> io::Result<TcpEndpoint<P>> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let (tx, rx) = channel::<P>();
        let peers: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let fanout: Arc<Mutex<Option<FanoutRt>>> = Arc::new(Mutex::new(None));

        let tx_acceptor = tx.clone();
        let peers_acceptor = Arc::clone(&peers);
        let fanout_acceptor = Arc::clone(&fanout);
        std::thread::Builder::new()
            .name(format!("tmsn-accept-{local_addr}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { break };
                    let tx = tx_acceptor.clone();
                    let peers = Arc::clone(&peers_acceptor);
                    let fanout = Arc::clone(&fanout_acceptor);
                    std::thread::spawn(move || receive_loop(stream, tx, peers, fanout));
                }
            })?;

        Ok(TcpEndpoint {
            peers,
            inbox: rx,
            local_addr,
            fanout,
            _inbox_tx: tx,
        })
    }

    /// Switch this endpoint into gossip mode (no-op for
    /// [`BroadcastMode::Full`]). Must be applied to **every** endpoint in
    /// the cluster with the same mode — the envelope is a cluster-wide
    /// dialect, not negotiated per link. `n` is the cluster size (resolves
    /// the `ttl: 0` auto sentinel to `n` hops); `seed` drives peer
    /// selection, forked per worker by the caller for determinism.
    pub fn enable_fanout(&self, mode: BroadcastMode, n: usize, seed: u64) {
        if let BroadcastMode::Fanout { k, .. } = mode {
            *self.fanout.lock().unwrap() = Some(FanoutRt {
                k,
                ttl: mode.resolved_ttl(n),
                rng: Rng::new(seed),
                seen: HashSet::new(),
                forwards: 0,
                log: None,
            });
        }
    }

    /// Attach an event log to the gossip relay: each re-forward records a
    /// [`EventKind::Forward`] for `worker_id`. No-op in full mode or
    /// before [`TcpEndpoint::enable_fanout`].
    pub fn fanout_event_log(&self, log: EventLog, worker_id: usize) {
        if let Some(rt) = self.fanout.lock().unwrap().as_mut() {
            rt.log = Some((log, worker_id));
        }
    }

    /// Gossip relays performed by this endpoint's receive threads
    /// (0 in full mode).
    pub fn forward_count(&self) -> u64 {
        self.fanout.lock().unwrap().as_ref().map_or(0, |rt| rt.forwards)
    }

    /// The bound address (useful after binding port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Dial a peer; broadcasts will be pushed to it. Retries briefly so
    /// cluster bring-up order doesn't matter.
    pub fn connect(&self, addr: &str) -> io::Result<()> {
        let mut last_err = io::Error::new(io::ErrorKind::Other, "no attempt");
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    self.peers.lock().unwrap().push(s);
                    return Ok(());
                }
                Err(e) => {
                    last_err = e;
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
        Err(last_err)
    }

    /// Fire-and-forget broadcast. Dead peers are dropped silently —
    /// exactly TMSN's failure semantics. In fanout mode the publish goes
    /// to `k` seeded-random peers with the full hop budget instead of to
    /// everyone (lock order here and in the receive path is fanout →
    /// peers, so gossip relays can't deadlock against a publish).
    pub fn broadcast(&self, msg: &P) {
        let mut fo = self.fanout.lock().unwrap();
        match fo.as_mut() {
            None => {
                drop(fo);
                let frame = encode(msg);
                let mut peers = self.peers.lock().unwrap();
                peers.retain_mut(|p| p.write_all(&frame).is_ok());
            }
            Some(rt) => {
                // remember our own publish so a gossip echo of it is
                // suppressed instead of re-delivered/re-forwarded
                rt.seen.insert(gossip_key(msg));
                let frame = encode_fanout(msg, rt.ttl);
                let k = rt.k;
                let mut peers = self.peers.lock().unwrap();
                send_to_k(&mut peers, &mut rt.rng, k, &frame);
            }
        }
    }

    /// Non-blocking poll of the inbox.
    pub fn try_recv(&self) -> Option<P> {
        self.inbox.try_recv().ok()
    }

    /// Blocking poll of the inbox; `None` if `timeout` passes quietly.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<P> {
        self.inbox.recv_timeout(timeout).ok()
    }

    /// Number of live outbound links (dead peers are pruned on broadcast).
    pub fn peer_count(&self) -> usize {
        self.peers.lock().unwrap().len()
    }
}

fn receive_loop<P: Payload>(
    mut stream: TcpStream,
    tx: Sender<P>,
    peers: Arc<Mutex<Vec<TcpStream>>>,
    fanout: Arc<Mutex<Option<FanoutRt>>>,
) {
    loop {
        match read_frame(&mut stream) {
            Ok(Some(payload)) => {
                let mut fo = fanout.lock().unwrap();
                let msg = if let Some(rt) = fo.as_mut() {
                    // fanout dialect: strip the [ttl u8] envelope
                    if payload.is_empty() {
                        eprintln!("tmsn-tcp: dropping peer after empty fanout frame");
                        return;
                    }
                    let ttl = payload[0] as u32;
                    match P::decode(&payload[1..]) {
                        Ok(msg) => {
                            let key = gossip_key(&msg);
                            if !rt.seen.insert(key) {
                                continue; // gossip duplicate: suppress
                            }
                            if ttl > 0 {
                                // first sight with hops left: relay with
                                // one less hop before delivering locally
                                rt.forwards += 1;
                                if let Some((log, id)) = &rt.log {
                                    log.record(
                                        *id,
                                        EventKind::Forward,
                                        Some((key.0, key.1)),
                                        msg.cert().summary(),
                                    );
                                }
                                let frame = encode_fanout(&msg, ttl - 1);
                                let k = rt.k;
                                let mut ps = peers.lock().unwrap();
                                send_to_k(&mut ps, &mut rt.rng, k, &frame);
                            }
                            msg
                        }
                        Err(e) => {
                            eprintln!("tmsn-tcp: dropping peer after bad payload: {e}");
                            return;
                        }
                    }
                } else {
                    drop(fo);
                    match P::decode(&payload) {
                        Ok(msg) => msg,
                        Err(e) => {
                            // malformed message from a peer: drop the link,
                            // never crash the worker (resilience semantics)
                            eprintln!("tmsn-tcp: dropping peer after bad payload: {e}");
                            return;
                        }
                    }
                };
                if tx.send(msg).is_err() {
                    return; // endpoint dropped
                }
            }
            Ok(None) | Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    // the shared workload-agnostic test payload — the TCP layer must not
    // care what rides inside its frames
    use crate::tmsn::testpay::{TestCert, TestPayload};
    use crate::util::prop::prop_check;
    use std::io::Cursor;

    fn msg(seq: u64) -> TestPayload {
        TestPayload {
            body: "payload body".into(),
            cert: TestCert {
                score: 0.9,
                origin: 7,
                seq,
            },
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let m = msg(5);
        let frame = encode(&m);
        // strip framing
        assert_eq!(u32::from_le_bytes(frame[0..4].try_into().unwrap()), MAGIC);
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        assert_eq!(8 + len, frame.len());
        let back: TestPayload = decode(&frame[8..8 + len]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn prop_frame_roundtrip() {
        // Any payload survives framing + deframing + decoding exactly.
        prop_check("tcp frame roundtrip", 64, |rng| {
            let body: String = (0..rng.below(200))
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            let m = TestPayload {
                body,
                cert: TestCert {
                    score: rng.f64(),
                    origin: rng.below(256) as usize,
                    seq: rng.below(1 << 40),
                },
            };
            let frame = encode(&m);
            let mut cursor = Cursor::new(frame.as_slice());
            let payload = read_frame(&mut cursor)
                .map_err(|e| e.to_string())?
                .ok_or("unexpected EOF")?;
            let back: TestPayload = decode(&payload).map_err(|e| e.to_string())?;
            if back != m {
                return Err(format!("{back:?} != {m:?}"));
            }
            // the frame is fully consumed: a second read is a clean EOF
            if read_frame(&mut cursor).map_err(|e| e.to_string())?.is_some() {
                return Err("trailing bytes after frame".into());
            }
            Ok(())
        });
    }

    #[test]
    fn frame_bytes_roundtrips_through_read_frame() {
        // the RPC layer's raw framing is byte-compatible with the
        // payload transport's reader
        let body = b"{\"v\":1,\"id\":7,\"method\":\"ping\"}";
        let frame = frame_bytes(body);
        let mut cursor = Cursor::new(frame.as_slice());
        let back = read_frame(&mut cursor).unwrap().expect("one frame");
        assert_eq!(back, body);
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn read_frame_clean_eof_between_frames() {
        let mut empty = Cursor::new(&[][..]);
        assert!(read_frame(&mut empty).unwrap().is_none());
    }

    #[test]
    fn read_frame_rejects_truncated_header() {
        // fewer than 8 header bytes, but not zero: a torn frame, not EOF —
        // read_exact reports UnexpectedEof which maps to clean close
        let frame = encode(&msg(1));
        let mut torn = Cursor::new(&frame[..5]);
        assert!(read_frame(&mut torn).unwrap().is_none());
    }

    #[test]
    fn read_frame_rejects_truncated_payload() {
        let frame = encode(&msg(1));
        // header promises more bytes than the stream carries
        let mut torn = Cursor::new(&frame[..frame.len() - 3]);
        let err = read_frame(&mut torn).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn read_frame_rejects_bad_magic() {
        let mut frame = encode(&msg(1));
        frame[0] ^= 0xFF;
        let err = read_frame(&mut Cursor::new(frame.as_slice())).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert_eq!(err.to_string(), "bad magic");
    }

    #[test]
    fn read_frame_rejects_oversized_len() {
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        frame.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut Cursor::new(frame.as_slice())).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert_eq!(err.to_string(), "oversized frame");
        // exactly MAX_PAYLOAD is allowed by framing (would read the bytes)
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&MAX_PAYLOAD.to_le_bytes());
        let err = read_frame(&mut Cursor::new(frame.as_slice())).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode::<TestPayload>(b"nonsense").is_err());
        assert!(decode::<TestPayload>(b"test abc 0 0\nbody").is_err());
        assert!(decode::<TestPayload>(&[0xFF, 0xFE, 0x00]).is_err());
    }

    #[test]
    fn two_endpoints_exchange_messages() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        b.connect(&a.local_addr().to_string()).unwrap();
        assert_eq!(a.peer_count(), 1);

        a.broadcast(&msg(1));
        let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 1);

        b.broadcast(&msg(2));
        let got = a.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 2);
    }

    #[test]
    fn three_node_broadcast_reaches_all() {
        let nodes: Vec<TcpEndpoint<TestPayload>> = (0..3)
            .map(|_| TcpEndpoint::bind("127.0.0.1:0").unwrap())
            .collect();
        for i in 0..3 {
            for j in 0..3 {
                if i != j {
                    nodes[i].connect(&nodes[j].local_addr().to_string()).unwrap();
                }
            }
        }
        nodes[0].broadcast(&msg(9));
        for n in &nodes[1..] {
            let got = n.recv_timeout(Duration::from_secs(5)).expect("delivery");
            assert_eq!(got.cert.seq, 9);
        }
        // the sender itself receives nothing
        assert!(nodes[0].recv_timeout(Duration::from_millis(100)).is_none());
    }

    #[test]
    fn dead_peer_dropped_without_error() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        drop(b);
        // broadcasting into a closed peer must not panic; peer is pruned
        // (possibly after one buffered write succeeds)
        for i in 0..10 {
            a.broadcast(&msg(i));
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(a.peer_count(), 0);
    }

    #[test]
    fn malformed_payload_drops_link_not_worker() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        // dial the endpoint raw and ship a well-framed but undecodable
        // payload: the receiver must drop the link and keep serving others
        let mut raw = TcpStream::connect(a.local_addr()).unwrap();
        let garbage = b"not a wire payload";
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&(garbage.len() as u32).to_le_bytes());
        frame.extend_from_slice(garbage);
        raw.write_all(&frame).unwrap();
        assert!(a.recv_timeout(Duration::from_millis(200)).is_none());

        // a healthy peer still gets through
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        b.connect(&a.local_addr().to_string()).unwrap();
        b.broadcast(&msg(3));
        let got = a.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 3);
    }

    /// n endpoints in gossip mode; edges\[i\] lists i's outbound links.
    fn gossip_cluster(
        edges: &[&[usize]],
        k: usize,
        ttl: u32,
    ) -> Vec<TcpEndpoint<TestPayload>> {
        let nodes: Vec<TcpEndpoint<TestPayload>> = (0..edges.len())
            .map(|_| TcpEndpoint::bind("127.0.0.1:0").unwrap())
            .collect();
        for (i, outs) in edges.iter().enumerate() {
            for &j in outs.iter() {
                nodes[i].connect(&nodes[j].local_addr().to_string()).unwrap();
            }
        }
        for (i, n) in nodes.iter().enumerate() {
            n.enable_fanout(BroadcastMode::Fanout { k, ttl }, edges.len(), 0xFA_0 + i as u64);
        }
        nodes
    }

    #[test]
    fn fanout_relay_walks_a_line() {
        // 0 → 1 → 2 → 3, k = 1: every hop has exactly one outbound peer,
        // so the gossip path is deterministic; ttl 8 covers 3 hops
        let nodes = gossip_cluster(&[&[1], &[2], &[3], &[]], 1, 8);
        nodes[0].broadcast(&msg(4));
        for n in &nodes[1..] {
            let got = n.recv_timeout(Duration::from_secs(5)).expect("relayed delivery");
            assert_eq!(got.cert.seq, 4);
        }
        // middle nodes actually relayed (not direct delivery from 0)
        assert!(nodes[1].forward_count() >= 1);
        assert!(nodes[2].forward_count() >= 1);
        // the publisher hears no echo
        assert!(nodes[0].recv_timeout(Duration::from_millis(100)).is_none());
    }

    #[test]
    fn fanout_ttl_bounds_the_relay_depth() {
        // same line, ttl = 1: node 1 relays with ttl 0, node 2 delivers
        // but must not relay, node 3 never hears
        let nodes = gossip_cluster(&[&[1], &[2], &[3], &[]], 1, 1);
        nodes[0].broadcast(&msg(7));
        assert_eq!(nodes[1].recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 7);
        assert_eq!(nodes[2].recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 7);
        assert!(nodes[3].recv_timeout(Duration::from_millis(300)).is_none());
        assert_eq!(nodes[2].forward_count(), 0, "ttl 0 must not be re-forwarded");
    }

    #[test]
    fn fanout_dedup_delivers_each_payload_once() {
        // diamond: 0 → {1,2}, both relay to 3; k = 2 ≥ every out-degree,
        // so both copies reach 3 — dedup must deliver exactly one
        let nodes = gossip_cluster(&[&[1, 2], &[3], &[3], &[]], 2, 8);
        nodes[0].broadcast(&msg(11));
        for n in &nodes[1..3] {
            assert_eq!(n.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 11);
        }
        assert_eq!(nodes[3].recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 11);
        // the second wire copy is suppressed, never delivered
        assert!(nodes[3].recv_timeout(Duration::from_millis(300)).is_none());
    }

    #[test]
    fn enable_fanout_with_full_mode_is_a_no_op() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.enable_fanout(BroadcastMode::Full, 2, 1);
        b.enable_fanout(BroadcastMode::Full, 2, 2);
        a.connect(&b.local_addr().to_string()).unwrap();
        a.broadcast(&msg(5));
        assert_eq!(b.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 5);
        assert_eq!(a.forward_count(), 0);
        assert_eq!(b.forward_count(), 0);
    }

    #[test]
    fn ordered_per_link() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        for i in 0..20 {
            a.broadcast(&msg(i));
        }
        for i in 0..20 {
            let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
            assert_eq!(got.cert.seq, i, "TCP must preserve per-link order");
        }
    }
}
