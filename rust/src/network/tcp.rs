//! TCP broadcast transport — run TMSN across real processes/machines.
//!
//! The in-process [`crate::network::Fabric`] simulates a cluster inside
//! one binary (benches, failure injection). This module is the *real*
//! transport the original Sparrow used: every worker process listens on a
//! socket, dials its peers, and broadcasts certified payloads with no
//! acknowledgements and no ordering guarantees beyond TCP's per-link
//! FIFO — faithfully TMSN: a dead peer just stops receiving.
//!
//! Since PR 9 the fabric is **self-healing** (DESIGN.md §13):
//!
//! * every outbound link is owned by a dedicated writer thread behind a
//!   **bounded drop-oldest send queue**, so [`TcpEndpoint::broadcast`]
//!   enqueues and returns — a slow or blackholed peer backpressures only
//!   its own queue, never a publish and never another peer. Dropping the
//!   oldest frame is safe because TMSN tolerates loss and needs no FIFO:
//!   a newer certified payload supersedes anything older on the wire;
//! * a dead link (write error, write timeout, heartbeat silence) moves to
//!   a **redial schedule** with exponential backoff + seeded jitter,
//!   emitting `peer_down` / `reconnect` / `peer_up` events; queued frames
//!   survive the outage and flush on reconnect;
//! * idle links carry **`PING` heartbeats**, and every socket gets
//!   `TCP_NODELAY` plus read/write timeouts, so half-open peers are
//!   detected on both ends instead of pinning threads forever;
//! * with **peer exchange** enabled ([`TcpEndpoint::enable_pex`]), a
//!   joiner dials one live seed node, announces its own address in a
//!   `PEX` frame, and the swarm gossips the announcement: the seed dials
//!   back, replies with its full known peer set, and relays the announce
//!   onward — `--peers` becomes optional (see [`crate::network::pex`]).
//!
//! The transport is payload-generic: framing wraps [`Payload::encode`] /
//! [`Payload::decode`], so any workload's messages ride the same sockets.
//!
//! Wire format (little-endian), unchanged outer frame:
//!     magic  u32  = 0x54_4D_53_4E ("TMSN")
//!     len    u32  = payload bytes
//!     payload     = link dialect, below
//!
//! Inside a peer-link frame the payload always starts with a tag byte:
//!     [0x00 = DATA][ttl u8][P::encode()]   certified payload
//!     [0x01 = PING]                        heartbeat, no body
//!     [0x02 = PEX ][ttl u8][pex body]      peer exchange (pex.rs codec)
//! An unknown tag or a malformed body drops the link, never the worker
//! (fail closed). Full-broadcast mode sends `ttl = 0` and never relays;
//! **fanout (gossip) mode** (DESIGN.md §12, [`TcpEndpoint::enable_fanout`])
//! uses the ttl as its hop budget: a receiver seeing a payload for the
//! first time delivers it and — if `ttl > 0` — relays it to `k` of its own
//! peers with `ttl − 1`, with `(origin, seq, cert-bits)` dedup exactly
//! like the simulator's gossip proof.
//!
//! The admin RPC rides its own socket with raw [`frame_bytes`] framing
//! (no tag byte) — the control plane's dialect is untouched.

use std::collections::{HashSet, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::{EventKind, EventLog};
use crate::network::pex::{decode_pex, encode_pex, PexMsg, PexTable};
use crate::network::BroadcastMode;
use crate::tmsn::{Certified, Payload};
use crate::util::rng::Rng;

const MAGIC: u32 = 0x544D_534E;
/// hard cap on accepted payloads (a model of 10⁶ stumps ≈ 30 MB text)
pub(crate) const MAX_PAYLOAD: u32 = 64 << 20;

/// link dialect tags (first payload byte of every peer-link frame)
const TAG_DATA: u8 = 0x00;
const TAG_PING: u8 = 0x01;
const TAG_PEX: u8 = 0x02;

/// Hop budget on a fresh PEX announce. Loop termination comes from the
/// known-set dedup in [`PexTable::absorb`]; the ttl only bounds how far a
/// single announce can travel per flood, and 4 hops covers any mesh a
/// seed-node join can produce (each hop re-floods to all up peers).
const PEX_TTL: u8 = 4;

/// Frame a payload for the wire.
pub fn encode<P: Payload>(msg: &P) -> Vec<u8> {
    let payload = msg.encode();
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode a payload (after framing) back into a message.
pub fn decode<P: Payload>(payload: &[u8]) -> Result<P, String> {
    P::decode(payload)
}

/// Frame raw bytes for the wire (same magic + length header the payload
/// transport uses). The control plane's RPC endpoints (DESIGN.md §10)
/// ship JSON request/response bodies in these frames, so an admin socket
/// and a broadcast socket speak one framing dialect.
pub(crate) fn frame_bytes(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Read one length-prefixed frame. `Ok(None)` = clean EOF between frames
/// (peer closed); `InvalidData` errors = corrupt stream (bad magic,
/// oversized length), after which the link must be dropped.
pub(crate) fn read_frame(stream: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut head = [0u8; 8];
    if let Err(e) = stream.read_exact(&mut head) {
        // clean EOF between frames = peer closed
        return if e.kind() == io::ErrorKind::UnexpectedEof {
            Ok(None)
        } else {
            Err(e)
        };
    }
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    if len > MAX_PAYLOAD {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "oversized frame"));
    }
    let mut payload = vec![0u8; len as usize];
    stream.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Inspect the front of a byte buffer for one complete frame without
/// consuming it: `Ok(Some(total))` = a full frame of `total` bytes
/// (8-byte header + payload) is present, `Ok(None)` = incomplete, `Err` =
/// corrupt stream (bad magic / oversized length). The chaos proxy's
/// frame-level fault gate is built on this.
pub(crate) fn peek_frame(buf: &[u8]) -> Result<Option<usize>, String> {
    if buf.len() < 8 {
        return Ok(None);
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err("bad magic".into());
    }
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err("oversized frame".into());
    }
    let total = 8 + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    Ok(Some(total))
}

/// Gossip-mode dedup key: `(origin, seq, certificate bits)`. The cert-bits
/// component disambiguates incarnations — a resumed worker restamps its
/// checkpoint `(id, 0)`, but any payload it re-publishes carries a
/// strictly-better (hence bit-different) certificate summary. Mirrors the
/// simulator's `dedup_key` exactly.
fn gossip_key<P: Payload>(msg: &P) -> (usize, u64, u64) {
    let c = msg.cert();
    (c.origin(), c.seq(), c.summary().to_bits())
}

/// `[TAG_DATA][ttl][body]` link payload.
fn data_payload(body: &[u8], ttl: u8) -> Vec<u8> {
    let mut p = Vec::with_capacity(2 + body.len());
    p.push(TAG_DATA);
    p.push(ttl);
    p.extend_from_slice(body);
    p
}

/// A framed `[TAG_PEX][ttl][pex body]` wire frame.
fn pex_frame_bytes(msg: &PexMsg, ttl: u8) -> Vec<u8> {
    let body = encode_pex(msg);
    let mut p = Vec::with_capacity(2 + body.len());
    p.push(TAG_PEX);
    p.push(ttl);
    p.extend_from_slice(&body);
    frame_bytes(&p)
}

/// A framed heartbeat.
fn ping_frame() -> Vec<u8> {
    frame_bytes(&[TAG_PING])
}

/// Deterministic per-peer jitter stream (FNV-1a of the dial address).
fn addr_seed(addr: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in addr.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Socket/liveness knobs for the self-healing fabric. Apply with
/// [`TcpEndpoint::tune`] (ideally before connecting; live changes take
/// effect on the next write/dial/accept).
#[derive(Debug, Clone, Copy)]
pub struct TcpTuning {
    /// idle writer sends a `PING` after this long without traffic
    pub heartbeat: Duration,
    /// receiver drops a link after this long without any frame (heartbeats
    /// included) — half-open detection on the inbound side
    pub read_timeout: Duration,
    /// a blocked write fails after this long — half-open detection on the
    /// outbound side (the writer then enters its redial schedule)
    pub write_timeout: Duration,
    /// bounded send queue per peer; when full the **oldest** frame is
    /// dropped (`queue_drop`), which TMSN tolerates by design
    pub queue_cap: usize,
    /// first backoff delay of the redial schedule (attempt 1 is immediate)
    pub backoff_base: Duration,
    /// backoff ceiling; the schedule is `min(base · 2^(n−1), cap)` with
    /// ×[0.5, 1.5) seeded jitter
    pub backoff_cap: Duration,
}

impl Default for TcpTuning {
    fn default() -> Self {
        TcpTuning {
            heartbeat: Duration::from_millis(500),
            read_timeout: Duration::from_secs(3),
            write_timeout: Duration::from_secs(2),
            queue_cap: 1024,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
        }
    }
}

/// One row of [`TcpEndpoint::peer_table`]: the live view of one outbound
/// link, the `peers.list` admin RPC's payload.
#[derive(Debug, Clone)]
pub struct PeerInfo {
    /// the address this endpoint dials (a peer's advertised address)
    pub addr: String,
    /// link currently established
    pub up: bool,
    /// frames waiting in the bounded send queue
    pub queue_len: usize,
    /// ms since the last successful write or dial on this link
    pub last_seen_ms: u64,
    /// successful redials after a loss (0 for a never-lost link)
    pub reconnects: u64,
    /// frames dropped from this link's queue (drop-oldest policy)
    pub drops: u64,
}

/// One outbound link: its bounded queue plus liveness state. The writer
/// thread is the only consumer; everyone else just pushes.
struct PeerHandle {
    addr: String,
    queue: Mutex<VecDeque<Vec<u8>>>,
    cv: Condvar,
    up: AtomicBool,
    ever_up: AtomicBool,
    queue_len: AtomicUsize,
    drops: AtomicU64,
    reconnects: AtomicU64,
    last_seen: Mutex<Instant>,
}

impl PeerHandle {
    fn new(addr: &str) -> PeerHandle {
        PeerHandle {
            addr: addr.to_string(),
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            up: AtomicBool::new(false),
            ever_up: AtomicBool::new(false),
            queue_len: AtomicUsize::new(0),
            drops: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            last_seen: Mutex::new(Instant::now()),
        }
    }

    /// Enqueue a frame, evicting the oldest when full. Returns whether an
    /// eviction happened. Never blocks beyond the queue mutex.
    fn push(&self, frame: Vec<u8>, cap: usize) -> bool {
        let mut q = self.queue.lock().unwrap();
        let mut dropped = false;
        if q.len() >= cap.max(1) {
            q.pop_front();
            self.drops.fetch_add(1, Ordering::SeqCst);
            dropped = true;
        }
        q.push_back(frame);
        self.queue_len.store(q.len(), Ordering::SeqCst);
        self.cv.notify_one();
        dropped
    }
}

/// Generic-free shared state: peer set, liveness knobs, membership table,
/// event sink. Writer threads and admin closures hold an `Arc<Inner>`
/// without dragging the payload type parameter along.
///
/// Lock order (outermost first): `fanout → pex → peers → queue → log /
/// tuning`. `log` and `tuning` are leaves — nothing is acquired while
/// they are held.
struct Inner {
    peers: Mutex<Vec<Arc<PeerHandle>>>,
    stop: AtomicBool,
    tuning: Mutex<TcpTuning>,
    log: Mutex<Option<(EventLog, usize)>>,
    pex: Mutex<Option<PexTable>>,
    queue_drops: AtomicU64,
    reconnects: AtomicU64,
}

impl Inner {
    fn tuning(&self) -> TcpTuning {
        *self.tuning.lock().unwrap()
    }

    fn emit(&self, kind: EventKind, value: f64) {
        if let Some((log, id)) = self.log.lock().unwrap().as_ref() {
            log.record(*id, kind, None, value);
        }
    }

    /// Enqueue to one peer, accounting queue drops globally.
    fn push_to(&self, peer: &PeerHandle, frame: Vec<u8>) {
        let cap = self.tuning().queue_cap;
        if peer.push(frame, cap) {
            let total = self.queue_drops.fetch_add(1, Ordering::SeqCst) + 1;
            self.emit(EventKind::QueueDrop, total as f64);
        }
    }

    /// Register a peer (dedup by address) and start its writer thread.
    /// `stream` carries an already-established socket (sync connect); with
    /// `None` the writer dials asynchronously (PEX dial-backs, redials).
    fn add_peer(self: &Arc<Inner>, addr: &str, stream: Option<TcpStream>) {
        let peer = {
            let mut peers = self.peers.lock().unwrap();
            if peers.iter().any(|p| p.addr == addr) {
                return; // already linked (drops a redundant socket, if any)
            }
            let p = Arc::new(PeerHandle::new(addr));
            if stream.is_some() {
                // the link is live right now: make peer_count() reflect it
                // before this call returns (the writer emits the event)
                p.up.store(true, Ordering::SeqCst);
                p.ever_up.store(true, Ordering::SeqCst);
            }
            peers.push(Arc::clone(&p));
            p
        };
        let inner = Arc::clone(self);
        std::thread::Builder::new()
            .name(format!("tmsn-writer-{addr}"))
            .spawn(move || writer_loop(inner, peer, stream))
            .ok();
    }

    fn peer_table(&self) -> Vec<PeerInfo> {
        let mut out: Vec<PeerInfo> = self
            .peers
            .lock()
            .unwrap()
            .iter()
            .map(|p| PeerInfo {
                addr: p.addr.clone(),
                up: p.up.load(Ordering::SeqCst),
                queue_len: p.queue_len.load(Ordering::SeqCst),
                last_seen_ms: p.last_seen.lock().unwrap().elapsed().as_millis() as u64,
                reconnects: p.reconnects.load(Ordering::SeqCst),
                drops: p.drops.load(Ordering::SeqCst),
            })
            .collect();
        out.sort_by(|a, b| a.addr.cmp(&b.addr));
        out
    }
}

enum Popped {
    Frame(Vec<u8>),
    Idle,
    Stop,
}

/// Pop the next frame, or report an idle heartbeat interval, or notice
/// shutdown. Blocks on the queue condvar, never on a socket.
fn pop_or_idle(peer: &PeerHandle, inner: &Inner, heartbeat: Duration) -> Popped {
    let mut q = peer.queue.lock().unwrap();
    loop {
        if inner.stop.load(Ordering::SeqCst) {
            return Popped::Stop;
        }
        if let Some(f) = q.pop_front() {
            peer.queue_len.store(q.len(), Ordering::SeqCst);
            return Popped::Frame(f);
        }
        let (guard, res) = peer.cv.wait_timeout(q, heartbeat).unwrap();
        q = guard;
        if res.timed_out() {
            return if inner.stop.load(Ordering::SeqCst) {
                Popped::Stop
            } else {
                Popped::Idle
            };
        }
    }
}

/// Configure a fresh link, mark it up, and announce ourselves on it when
/// peer exchange is on (the announce precedes any queued frame).
fn on_link_up(inner: &Inner, peer: &PeerHandle, s: &TcpStream) {
    let t = inner.tuning();
    s.set_nodelay(true).ok();
    s.set_write_timeout(Some(t.write_timeout)).ok();
    peer.up.store(true, Ordering::SeqCst);
    peer.ever_up.store(true, Ordering::SeqCst);
    *peer.last_seen.lock().unwrap() = Instant::now();
    inner.emit(EventKind::PeerUp, 0.0);
    let announce = inner
        .pex
        .lock()
        .unwrap()
        .as_ref()
        .map(|table| pex_frame_bytes(&table.announce(), PEX_TTL));
    if let Some(frame) = announce {
        let _ = (&*s).write_all(&frame);
    }
}

/// The per-peer writer: pop frames (or heartbeat when idle) while the
/// link is up; redial with exponential backoff + jitter while it is down.
/// The peers mutex is never held across any of this — a blocking write
/// can stall only this one link.
fn writer_loop(inner: Arc<Inner>, peer: Arc<PeerHandle>, mut stream: Option<TcpStream>) {
    let mut rng = Rng::new(0x9E37_79B9 ^ addr_seed(&peer.addr));
    if let Some(s) = &stream {
        on_link_up(&inner, &peer, s);
    }
    let mut attempt: u64 = 0;
    loop {
        if inner.stop.load(Ordering::SeqCst) {
            return;
        }
        match stream.as_mut() {
            Some(s) => {
                let hb = inner.tuning().heartbeat;
                let frame = match pop_or_idle(&peer, &inner, hb) {
                    Popped::Stop => return,
                    Popped::Frame(f) => f,
                    Popped::Idle => ping_frame(),
                };
                if s.write_all(&frame).is_ok() {
                    *peer.last_seen.lock().unwrap() = Instant::now();
                } else {
                    stream = None;
                    attempt = 0;
                    peer.up.store(false, Ordering::SeqCst);
                    inner.emit(EventKind::PeerDown, 0.0);
                }
            }
            None => {
                attempt += 1;
                if attempt > 1 {
                    // attempt 1 is immediate; then min(base·2^(n−1), cap)
                    // with ×[0.5, 1.5) jitter so a kill wave's survivors
                    // don't redial in lockstep
                    let t = inner.tuning();
                    let base = t.backoff_base.as_millis().max(1) as u64;
                    let cap = t.backoff_cap.as_millis().max(1) as u64;
                    let shift = (attempt - 2).min(16) as u32;
                    let delay = base.saturating_shl(shift).min(cap);
                    let jittered = (delay as f64 * rng.range_f64(0.5, 1.5)) as u64;
                    let deadline = Instant::now() + Duration::from_millis(jittered.max(1));
                    while Instant::now() < deadline {
                        if inner.stop.load(Ordering::SeqCst) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
                if let Ok(s) = TcpStream::connect(&peer.addr) {
                    let was_ever_up = peer.ever_up.load(Ordering::SeqCst);
                    on_link_up(&inner, &peer, &s);
                    if was_ever_up {
                        peer.reconnects.fetch_add(1, Ordering::SeqCst);
                        inner.reconnects.fetch_add(1, Ordering::SeqCst);
                        inner.emit(EventKind::Reconnect, attempt as f64);
                    }
                    stream = Some(s);
                    attempt = 0;
                }
            }
        }
    }
}

/// `u64` has no stable `saturating_shl`; a tiny local shim keeps the
/// backoff arithmetic overflow-safe at absurd attempt counts.
trait SatShl {
    fn saturating_shl(self, shift: u32) -> u64;
}
impl SatShl for u64 {
    fn saturating_shl(self, shift: u32) -> u64 {
        if shift >= 63 || self.leading_zeros() <= shift {
            u64::MAX
        } else {
            self << shift
        }
    }
}

/// Per-endpoint gossip state, shared with the receive threads (they do
/// the re-forwarding). `None` = full-broadcast mode (ttl 0, no relays).
struct FanoutRt {
    k: usize,
    ttl: u32,
    rng: Rng,
    seen: HashSet<(usize, u64, u64)>,
    forwards: u64,
    log: Option<(EventLog, usize)>,
}

/// A worker's TCP attachment: listens for peers, dials peers, broadcasts.
pub struct TcpEndpoint<P: Payload> {
    inner: Arc<Inner>,
    inbox: Receiver<P>,
    local_addr: SocketAddr,
    fanout: Arc<Mutex<Option<FanoutRt>>>,
    // keep the sender alive for acceptor threads spawned later
    _inbox_tx: Sender<P>,
}

impl<P: Payload> TcpEndpoint<P> {
    /// Bind a listener (`addr` like "127.0.0.1:0") and start accepting.
    pub fn bind(addr: &str) -> io::Result<TcpEndpoint<P>> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let (tx, rx) = channel::<P>();
        let inner = Arc::new(Inner {
            peers: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            tuning: Mutex::new(TcpTuning::default()),
            log: Mutex::new(None),
            pex: Mutex::new(None),
            queue_drops: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
        });
        let fanout: Arc<Mutex<Option<FanoutRt>>> = Arc::new(Mutex::new(None));

        let tx_acceptor = tx.clone();
        let inner_acceptor = Arc::clone(&inner);
        let fanout_acceptor = Arc::clone(&fanout);
        std::thread::Builder::new()
            .name(format!("tmsn-accept-{local_addr}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    // endpoint dropped: exit so the listener closes and
                    // the port is actually released (redials to a dead
                    // endpoint must fail, not half-connect)
                    if inner_acceptor.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { break };
                    let tx = tx_acceptor.clone();
                    let inner = Arc::clone(&inner_acceptor);
                    let fanout = Arc::clone(&fanout_acceptor);
                    std::thread::spawn(move || receive_loop(stream, tx, inner, fanout));
                }
            })?;

        Ok(TcpEndpoint {
            inner,
            inbox: rx,
            local_addr,
            fanout,
            _inbox_tx: tx,
        })
    }

    /// Switch this endpoint into gossip mode (no-op for
    /// [`BroadcastMode::Full`]). Must be applied to **every** endpoint in
    /// the cluster with the same mode — the envelope is a cluster-wide
    /// dialect, not negotiated per link. `n` is the cluster size (resolves
    /// the `ttl: 0` auto sentinel to `n` hops); `seed` drives peer
    /// selection, forked per worker by the caller for determinism.
    pub fn enable_fanout(&self, mode: BroadcastMode, n: usize, seed: u64) {
        if let BroadcastMode::Fanout { k, .. } = mode {
            *self.fanout.lock().unwrap() = Some(FanoutRt {
                k,
                ttl: mode.resolved_ttl(n),
                rng: Rng::new(seed),
                seen: HashSet::new(),
                forwards: 0,
                log: None,
            });
        }
    }

    /// Attach an event log to the gossip relay: each re-forward records a
    /// [`EventKind::Forward`] for `worker_id`. No-op in full mode or
    /// before [`TcpEndpoint::enable_fanout`].
    pub fn fanout_event_log(&self, log: EventLog, worker_id: usize) {
        if let Some(rt) = self.fanout.lock().unwrap().as_mut() {
            rt.log = Some((log, worker_id));
        }
    }

    /// Attach an event log to the fabric itself: link state changes record
    /// `peer_up` / `peer_down` / `reconnect` (value = redial attempt) and
    /// queue evictions record `queue_drop` (value = running total), all
    /// attributed to `worker_id`.
    pub fn event_log(&self, log: EventLog, worker_id: usize) {
        *self.inner.log.lock().unwrap() = Some((log, worker_id));
    }

    /// Gossip relays performed by this endpoint's receive threads
    /// (0 in full mode).
    pub fn forward_count(&self) -> u64 {
        self.fanout.lock().unwrap().as_ref().map_or(0, |rt| rt.forwards)
    }

    /// The bound address (useful after binding port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Replace the fabric's liveness/queue knobs. Call before connecting
    /// for full effect; live changes apply from the next write/dial.
    pub fn tune(&self, tuning: TcpTuning) {
        *self.inner.tuning.lock().unwrap() = tuning;
    }

    /// Turn on peer exchange, advertising the bound listen address.
    /// Opt-in and cluster-wide like the fanout dialect: endpoints without
    /// PEX silently ignore incoming `PEX` frames. Enable *before* dialing
    /// the seed so the announce rides every fresh link.
    pub fn enable_pex(&self) {
        self.enable_pex_as(&self.local_addr.to_string());
    }

    /// Turn on peer exchange advertising `advertised` instead of the
    /// bound address — required when this endpoint is fronted by a chaos
    /// proxy (peers must dial the proxy, not the naked socket).
    pub fn enable_pex_as(&self, advertised: &str) {
        let mut table = PexTable::new(advertised);
        let mut guard = self.inner.pex.lock().unwrap();
        for p in self.inner.peers.lock().unwrap().iter() {
            table.note_direct(&p.addr);
        }
        *guard = Some(table);
    }

    /// Dial a peer; broadcasts will be pushed to it. Retries briefly so
    /// cluster bring-up order doesn't matter; after this returns, link
    /// maintenance (heartbeats, redials) is automatic.
    pub fn connect(&self, addr: &str) -> io::Result<()> {
        if let Some(table) = self.inner.pex.lock().unwrap().as_mut() {
            table.note_direct(addr);
        }
        if self.inner.peers.lock().unwrap().iter().any(|p| p.addr == addr) {
            return Ok(());
        }
        let mut last_err = io::Error::new(io::ErrorKind::Other, "no attempt");
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    self.inner.add_peer(addr, Some(s));
                    return Ok(());
                }
                Err(e) => {
                    last_err = e;
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
        Err(last_err)
    }

    /// Add a peer without waiting for the dial (PEX dial-backs use this):
    /// the peer's writer thread establishes the link with the usual
    /// backoff schedule and the link comes up asynchronously.
    pub fn add_peer(&self, addr: &str) {
        if let Some(table) = self.inner.pex.lock().unwrap().as_mut() {
            table.note_direct(addr);
        }
        self.inner.add_peer(addr, None);
    }

    /// Fire-and-forget broadcast: enqueue on every peer's bounded queue
    /// and return. Never blocks on a socket — a slow, blackholed, or dead
    /// peer costs exactly one queue push (its writer thread owns the
    /// stall). Frames queued to a down peer flush when its redial lands,
    /// which is what re-converges a restarted worker. In fanout mode the
    /// publish goes to `k` seeded-random up peers with the full hop
    /// budget instead of to everyone.
    pub fn broadcast(&self, msg: &P) {
        let mut fo = self.fanout.lock().unwrap();
        match fo.as_mut() {
            None => {
                drop(fo);
                let frame = frame_bytes(&data_payload(&msg.encode(), 0));
                let peers = self.inner.peers.lock().unwrap();
                for p in peers.iter() {
                    self.inner.push_to(p, frame.clone());
                }
            }
            Some(rt) => {
                // remember our own publish so a gossip echo of it is
                // suppressed instead of re-delivered/re-forwarded
                rt.seen.insert(gossip_key(msg));
                let ttl = rt.ttl.min(u8::MAX as u32) as u8;
                let frame = frame_bytes(&data_payload(&msg.encode(), ttl));
                let k = rt.k;
                push_to_k(&self.inner, &mut rt.rng, k, &frame);
            }
        }
    }

    /// Non-blocking poll of the inbox.
    pub fn try_recv(&self) -> Option<P> {
        self.inbox.try_recv().ok()
    }

    /// Blocking poll of the inbox; `None` if `timeout` passes quietly.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<P> {
        self.inbox.recv_timeout(timeout).ok()
    }

    /// Number of currently-**up** outbound links. Down peers being
    /// redialed are excluded (see [`TcpEndpoint::peer_table`] for them).
    pub fn peer_count(&self) -> usize {
        self.inner
            .peers
            .lock()
            .unwrap()
            .iter()
            .filter(|p| p.up.load(Ordering::SeqCst))
            .count()
    }

    /// Live per-peer state (sorted by address): the `peers.list` admin
    /// view.
    pub fn peer_table(&self) -> Vec<PeerInfo> {
        self.inner.peer_table()
    }

    /// A payload-type-free closure over [`TcpEndpoint::peer_table`], for
    /// wiring into the admin control plane.
    pub fn peer_table_handle(&self) -> Arc<dyn Fn() -> Vec<PeerInfo> + Send + Sync> {
        let inner = Arc::clone(&self.inner);
        Arc::new(move || inner.peer_table())
    }

    /// Total frames evicted from full send queues (drop-oldest policy).
    pub fn queue_drop_count(&self) -> u64 {
        self.inner.queue_drops.load(Ordering::SeqCst)
    }

    /// Total successful redials of previously-up links.
    pub fn reconnect_count(&self) -> u64 {
        self.inner.reconnects.load(Ordering::SeqCst)
    }
}

impl<P: Payload> Drop for TcpEndpoint<P> {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        // wake every writer parked on its queue condvar
        for p in self.inner.peers.lock().unwrap().iter() {
            p.cv.notify_all();
        }
        // wake the acceptor so it observes the stop flag and releases the
        // listen port (otherwise redials to this dead endpoint would
        // half-connect forever)
        let _ = TcpStream::connect(self.local_addr);
    }
}

/// Enqueue `frame` to `k` seeded-random distinct **up** peers (all of
/// them when `k >= up-count`). The gossip relay path.
fn push_to_k(inner: &Inner, rng: &mut Rng, k: usize, frame: &[u8]) {
    let peers = inner.peers.lock().unwrap();
    let ups: Vec<&Arc<PeerHandle>> = peers
        .iter()
        .filter(|p| p.up.load(Ordering::SeqCst))
        .collect();
    if ups.is_empty() || k == 0 {
        return;
    }
    let k = k.min(ups.len());
    for i in rng.sample_indices(ups.len(), k) {
        inner.push_to(ups[i], frame.to_vec());
    }
}

fn receive_loop<P: Payload>(
    mut stream: TcpStream,
    tx: Sender<P>,
    inner: Arc<Inner>,
    fanout: Arc<Mutex<Option<FanoutRt>>>,
) {
    {
        let t = inner.tuning();
        stream.set_read_timeout(Some(t.read_timeout)).ok();
        stream.set_nodelay(true).ok();
    }
    loop {
        if inner.stop.load(Ordering::SeqCst) {
            return;
        }
        match read_frame(&mut stream) {
            Ok(Some(payload)) => {
                let Some((&tag, rest)) = payload.split_first() else {
                    eprintln!("tmsn-tcp: dropping peer after empty frame");
                    return;
                };
                match tag {
                    // heartbeat: its arrival already refreshed the read
                    // timeout; body (if any) is ignored
                    TAG_PING => continue,
                    TAG_DATA => match handle_data::<P>(rest, &inner, &fanout) {
                        Ok(None) => {}
                        Ok(Some(msg)) => {
                            if tx.send(msg).is_err() {
                                return; // endpoint dropped
                            }
                        }
                        Err(e) => {
                            // malformed message from a peer: drop the
                            // link, never crash the worker
                            eprintln!("tmsn-tcp: dropping peer after bad payload: {e}");
                            return;
                        }
                    },
                    TAG_PEX => {
                        if let Err(e) = handle_pex(rest, &inner) {
                            eprintln!("tmsn-tcp: dropping peer after bad pex: {e}");
                            return;
                        }
                    }
                    t => {
                        eprintln!("tmsn-tcp: dropping peer after unknown tag {t:#04x}");
                        return;
                    }
                }
            }
            Ok(None) | Err(_) => return,
        }
    }
}

/// One inbound `DATA` frame (`rest` = `[ttl][P::encode()]`). Returns the
/// payload to deliver, `None` for a suppressed gossip duplicate, `Err`
/// to drop the link.
fn handle_data<P: Payload>(
    rest: &[u8],
    inner: &Arc<Inner>,
    fanout: &Arc<Mutex<Option<FanoutRt>>>,
) -> Result<Option<P>, String> {
    let Some((&ttl, body)) = rest.split_first() else {
        return Err("empty data frame".into());
    };
    let mut fo = fanout.lock().unwrap();
    match fo.as_mut() {
        None => {
            drop(fo);
            P::decode(body).map(Some)
        }
        Some(rt) => {
            let msg = P::decode(body)?;
            let key = gossip_key(&msg);
            if !rt.seen.insert(key) {
                return Ok(None); // gossip duplicate: suppress
            }
            if ttl > 0 {
                // first sight with hops left: relay with one less hop
                // before delivering locally
                rt.forwards += 1;
                if let Some((log, id)) = &rt.log {
                    log.record(
                        *id,
                        EventKind::Forward,
                        Some((key.0, key.1)),
                        msg.cert().summary(),
                    );
                }
                // forward the received body byte-for-byte
                let frame = frame_bytes(&data_payload(body, ttl - 1));
                let k = rt.k;
                push_to_k(inner, &mut rt.rng, k, &frame);
            }
            Ok(Some(msg))
        }
    }
}

/// One inbound `PEX` frame (`rest` = `[ttl][pex body]`): absorb, dial
/// back every fresh address, reply our full set to the fresh peers, and
/// relay the fresh announce to everyone else while the ttl lasts.
/// Ignored entirely when this endpoint has PEX disabled; the known-set
/// dedup plus the self-address filter in [`PexTable::absorb`] make
/// announce loops terminate (an echo of ourselves absorbs to nothing).
fn handle_pex(rest: &[u8], inner: &Arc<Inner>) -> Result<(), String> {
    let Some((&ttl, body)) = rest.split_first() else {
        return Err("empty pex frame".into());
    };
    let msg = decode_pex(body)?;
    let (fresh, full) = {
        let mut guard = inner.pex.lock().unwrap();
        let Some(table) = guard.as_mut() else {
            return Ok(()); // PEX disabled here: tolerate, don't join
        };
        let fresh = table.absorb(&msg);
        if fresh.is_empty() {
            return Ok(()); // nothing new: the flood dies here
        }
        (fresh, table.full_set())
    };
    for addr in &fresh {
        inner.add_peer(addr, None);
    }
    let full_frame = pex_frame_bytes(&full, 0);
    let relay_frame = if ttl > 0 {
        let relay = PexMsg {
            version: full.version,
            addrs: fresh.clone(),
        };
        Some(pex_frame_bytes(&relay, ttl - 1))
    } else {
        None
    };
    let peers = inner.peers.lock().unwrap();
    for p in peers.iter() {
        if fresh.iter().any(|a| a == &p.addr) {
            // bootstrap the newcomer with our whole view (ttl 0: a full
            // set is a reply, not a flood)
            inner.push_to(p, full_frame.clone());
        } else if let Some(rf) = &relay_frame {
            if p.up.load(Ordering::SeqCst) {
                inner.push_to(p, rf.clone());
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    // the shared workload-agnostic test payload — the TCP layer must not
    // care what rides inside its frames
    use crate::tmsn::testpay::{TestCert, TestPayload};
    use crate::util::prop::prop_check;
    use std::io::Cursor;

    fn msg(seq: u64) -> TestPayload {
        TestPayload {
            body: "payload body".into(),
            cert: TestCert {
                score: 0.9,
                origin: 7,
                seq,
            },
        }
    }

    /// Poll `cond` until true or `secs` elapse (then panic with `what`).
    fn wait_for(what: &str, secs: u64, mut cond: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(secs);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let m = msg(5);
        let frame = encode(&m);
        // strip framing
        assert_eq!(u32::from_le_bytes(frame[0..4].try_into().unwrap()), MAGIC);
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        assert_eq!(8 + len, frame.len());
        let back: TestPayload = decode(&frame[8..8 + len]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn prop_frame_roundtrip() {
        // Any payload survives framing + deframing + decoding exactly.
        prop_check("tcp frame roundtrip", 64, |rng| {
            let body: String = (0..rng.below(200))
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            let m = TestPayload {
                body,
                cert: TestCert {
                    score: rng.f64(),
                    origin: rng.below(256) as usize,
                    seq: rng.below(1 << 40),
                },
            };
            let frame = encode(&m);
            let mut cursor = Cursor::new(frame.as_slice());
            let payload = read_frame(&mut cursor)
                .map_err(|e| e.to_string())?
                .ok_or("unexpected EOF")?;
            let back: TestPayload = decode(&payload).map_err(|e| e.to_string())?;
            if back != m {
                return Err(format!("{back:?} != {m:?}"));
            }
            // the frame is fully consumed: a second read is a clean EOF
            if read_frame(&mut cursor).map_err(|e| e.to_string())?.is_some() {
                return Err("trailing bytes after frame".into());
            }
            Ok(())
        });
    }

    #[test]
    fn frame_bytes_roundtrips_through_read_frame() {
        // the RPC layer's raw framing is byte-compatible with the
        // payload transport's reader
        let body = b"{\"v\":1,\"id\":7,\"method\":\"ping\"}";
        let frame = frame_bytes(body);
        let mut cursor = Cursor::new(frame.as_slice());
        let back = read_frame(&mut cursor).unwrap().expect("one frame");
        assert_eq!(back, body);
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn peek_frame_matches_read_frame() {
        let frame = frame_bytes(b"hello");
        // incomplete at every prefix
        for cut in 0..frame.len() {
            assert_eq!(peek_frame(&frame[..cut]).unwrap(), None, "cut={cut}");
        }
        assert_eq!(peek_frame(&frame).unwrap(), Some(frame.len()));
        // trailing bytes don't confuse the peek
        let mut two = frame.clone();
        two.extend_from_slice(&frame);
        assert_eq!(peek_frame(&two).unwrap(), Some(frame.len()));
        // corrupt magic / oversized length fail
        let mut bad = frame.clone();
        bad[0] ^= 0xFF;
        assert!(peek_frame(&bad).is_err());
        let mut big = Vec::new();
        big.extend_from_slice(&MAGIC.to_le_bytes());
        big.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(peek_frame(&big).is_err());
    }

    #[test]
    fn read_frame_clean_eof_between_frames() {
        let mut empty = Cursor::new(&[][..]);
        assert!(read_frame(&mut empty).unwrap().is_none());
    }

    #[test]
    fn read_frame_rejects_truncated_header() {
        // fewer than 8 header bytes, but not zero: a torn frame, not EOF —
        // read_exact reports UnexpectedEof which maps to clean close
        let frame = encode(&msg(1));
        let mut torn = Cursor::new(&frame[..5]);
        assert!(read_frame(&mut torn).unwrap().is_none());
    }

    #[test]
    fn read_frame_rejects_truncated_payload() {
        let frame = encode(&msg(1));
        // header promises more bytes than the stream carries
        let mut torn = Cursor::new(&frame[..frame.len() - 3]);
        let err = read_frame(&mut torn).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn read_frame_rejects_bad_magic() {
        let mut frame = encode(&msg(1));
        frame[0] ^= 0xFF;
        let err = read_frame(&mut Cursor::new(frame.as_slice())).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert_eq!(err.to_string(), "bad magic");
    }

    #[test]
    fn read_frame_rejects_oversized_len() {
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        frame.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut Cursor::new(frame.as_slice())).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert_eq!(err.to_string(), "oversized frame");
        // exactly MAX_PAYLOAD is allowed by framing (would read the bytes)
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&MAX_PAYLOAD.to_le_bytes());
        let err = read_frame(&mut Cursor::new(frame.as_slice())).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode::<TestPayload>(b"nonsense").is_err());
        assert!(decode::<TestPayload>(b"test abc 0 0\nbody").is_err());
        assert!(decode::<TestPayload>(&[0xFF, 0xFE, 0x00]).is_err());
    }

    #[test]
    fn two_endpoints_exchange_messages() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        b.connect(&a.local_addr().to_string()).unwrap();
        assert_eq!(a.peer_count(), 1);

        a.broadcast(&msg(1));
        let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 1);

        b.broadcast(&msg(2));
        let got = a.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 2);
    }

    #[test]
    fn three_node_broadcast_reaches_all() {
        let nodes: Vec<TcpEndpoint<TestPayload>> = (0..3)
            .map(|_| TcpEndpoint::bind("127.0.0.1:0").unwrap())
            .collect();
        for i in 0..3 {
            for j in 0..3 {
                if i != j {
                    nodes[i].connect(&nodes[j].local_addr().to_string()).unwrap();
                }
            }
        }
        nodes[0].broadcast(&msg(9));
        for n in &nodes[1..] {
            let got = n.recv_timeout(Duration::from_secs(5)).expect("delivery");
            assert_eq!(got.cert.seq, 9);
        }
        // the sender itself receives nothing
        assert!(nodes[0].recv_timeout(Duration::from_millis(100)).is_none());
    }

    #[test]
    fn dead_peer_detected_and_marked_down() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        assert_eq!(a.peer_count(), 1);
        drop(b);
        // broadcasting into a closed peer must not panic or block; the
        // heartbeat + write failure marks the link down (the writer keeps
        // redialing, but b's port is released so redials fail)
        for i in 0..5 {
            a.broadcast(&msg(i));
        }
        wait_for("dead peer to be marked down", 10, || a.peer_count() == 0);
        let table = a.peer_table();
        assert_eq!(table.len(), 1, "the peer stays in the redial table");
        assert!(!table[0].up);
    }

    #[test]
    fn endpoint_drop_releases_the_listen_port() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let addr = a.local_addr().to_string();
        drop(a);
        // acceptor shutdown is asynchronous: poll the rebind
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match TcpEndpoint::<TestPayload>::bind(&addr) {
                Ok(_) => break,
                Err(e) => {
                    assert!(
                        Instant::now() < deadline,
                        "port never released after drop: {e}"
                    );
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    }

    #[test]
    fn broadcast_never_blocks_on_a_stalled_peer() {
        // a raw peer that accepts and then never reads: the kernel buffers
        // fill, the writer thread stalls, and broadcast() must still cost
        // only a queue push per call, evicting oldest frames once full
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let held = std::thread::spawn(move || listener.accept().map(|(s, _)| s));

        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.tune(TcpTuning {
            queue_cap: 8,
            ..TcpTuning::default()
        });
        a.connect(&addr).unwrap();
        let _stalled = held.join().unwrap().unwrap(); // hold without reading

        let big = TestPayload {
            body: "x".repeat(128 * 1024),
            cert: TestCert {
                score: 0.1,
                origin: 1,
                seq: 0,
            },
        };
        let t0 = Instant::now();
        for _ in 0..200 {
            a.broadcast(&big);
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_secs(2),
            "broadcast must not block on a stalled peer (took {elapsed:?})"
        );
        assert!(
            a.queue_drop_count() >= 1,
            "full bounded queue must evict oldest frames"
        );
        let table = a.peer_table();
        assert!(table[0].queue_len <= 8);
    }

    #[test]
    fn ordered_per_link() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        for i in 0..20 {
            a.broadcast(&msg(i));
        }
        for i in 0..20 {
            let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
            assert_eq!(got.cert.seq, i, "queued frames must keep per-link order");
        }
    }

    #[test]
    fn heartbeats_keep_an_idle_link_alive() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        // b drops silent links after 1s; a heartbeats every 200ms
        b.tune(TcpTuning {
            read_timeout: Duration::from_secs(1),
            ..TcpTuning::default()
        });
        a.tune(TcpTuning {
            heartbeat: Duration::from_millis(200),
            ..TcpTuning::default()
        });
        a.connect(&b.local_addr().to_string()).unwrap();
        std::thread::sleep(Duration::from_millis(2500));
        assert_eq!(a.peer_count(), 1, "pings must keep the idle link up");
        a.broadcast(&msg(42));
        let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 42);
    }

    #[test]
    fn malformed_payload_drops_link_not_worker() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        // dial the endpoint raw and ship a well-framed but undecodable
        // payload (first byte is an unknown dialect tag): the receiver
        // must drop the link and keep serving others
        let mut raw = TcpStream::connect(a.local_addr()).unwrap();
        let garbage = b"not a wire payload";
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&(garbage.len() as u32).to_le_bytes());
        frame.extend_from_slice(garbage);
        raw.write_all(&frame).unwrap();
        assert!(a.recv_timeout(Duration::from_millis(200)).is_none());

        // a healthy peer still gets through
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        b.connect(&a.local_addr().to_string()).unwrap();
        b.broadcast(&msg(3));
        let got = a.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 3);
    }

    #[test]
    fn seed_node_discovery_builds_full_mesh() {
        // a is the only seed; b and c join knowing nothing but a's address
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let c = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.enable_pex();
        b.enable_pex();
        c.enable_pex();
        b.connect(&a.local_addr().to_string()).unwrap();
        c.connect(&a.local_addr().to_string()).unwrap();
        // announce → dial-back → full-set reply → relay converges to a
        // full mesh: every endpoint ends with two up links
        wait_for("pex full mesh", 15, || {
            a.peer_count() == 2 && b.peer_count() == 2 && c.peer_count() == 2
        });
        // the discovered mesh actually carries traffic: c (who never heard
        // of b from the CLI) reaches both a and b directly
        c.broadcast(&msg(77));
        assert_eq!(a.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 77);
        assert_eq!(b.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 77);
    }

    #[test]
    fn pex_disabled_endpoint_ignores_pex_frames() {
        // a speaks PEX, b does not: b must tolerate the announce without
        // joining the exchange or dropping the link
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.enable_pex();
        a.connect(&b.local_addr().to_string()).unwrap();
        a.broadcast(&msg(8)); // rides the same link as the announce
        let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 8);
        assert_eq!(b.peer_count(), 0, "no dial-back without PEX");
    }

    /// n endpoints in gossip mode; edges\[i\] lists i's outbound links.
    fn gossip_cluster(
        edges: &[&[usize]],
        k: usize,
        ttl: u32,
    ) -> Vec<TcpEndpoint<TestPayload>> {
        let nodes: Vec<TcpEndpoint<TestPayload>> = (0..edges.len())
            .map(|_| TcpEndpoint::bind("127.0.0.1:0").unwrap())
            .collect();
        for (i, outs) in edges.iter().enumerate() {
            for &j in outs.iter() {
                nodes[i].connect(&nodes[j].local_addr().to_string()).unwrap();
            }
        }
        for (i, n) in nodes.iter().enumerate() {
            n.enable_fanout(BroadcastMode::Fanout { k, ttl }, edges.len(), 0xFA_0 + i as u64);
        }
        nodes
    }

    #[test]
    fn fanout_relay_walks_a_line() {
        // 0 → 1 → 2 → 3, k = 1: every hop has exactly one outbound peer,
        // so the gossip path is deterministic; ttl 8 covers 3 hops
        let nodes = gossip_cluster(&[&[1], &[2], &[3], &[]], 1, 8);
        nodes[0].broadcast(&msg(4));
        for n in &nodes[1..] {
            let got = n.recv_timeout(Duration::from_secs(5)).expect("relayed delivery");
            assert_eq!(got.cert.seq, 4);
        }
        // middle nodes actually relayed (not direct delivery from 0)
        assert!(nodes[1].forward_count() >= 1);
        assert!(nodes[2].forward_count() >= 1);
        // the publisher hears no echo
        assert!(nodes[0].recv_timeout(Duration::from_millis(100)).is_none());
    }

    #[test]
    fn fanout_ttl_bounds_the_relay_depth() {
        // same line, ttl = 1: node 1 relays with ttl 0, node 2 delivers
        // but must not relay, node 3 never hears
        let nodes = gossip_cluster(&[&[1], &[2], &[3], &[]], 1, 1);
        nodes[0].broadcast(&msg(7));
        assert_eq!(nodes[1].recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 7);
        assert_eq!(nodes[2].recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 7);
        assert!(nodes[3].recv_timeout(Duration::from_millis(300)).is_none());
        assert_eq!(nodes[2].forward_count(), 0, "ttl 0 must not be re-forwarded");
    }

    #[test]
    fn fanout_dedup_delivers_each_payload_once() {
        // diamond: 0 → {1,2}, both relay to 3; k = 2 ≥ every out-degree,
        // so both copies reach 3 — dedup must deliver exactly one
        let nodes = gossip_cluster(&[&[1, 2], &[3], &[3], &[]], 2, 8);
        nodes[0].broadcast(&msg(11));
        for n in &nodes[1..3] {
            assert_eq!(n.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 11);
        }
        assert_eq!(nodes[3].recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 11);
        // the second wire copy is suppressed, never delivered
        assert!(nodes[3].recv_timeout(Duration::from_millis(300)).is_none());
    }

    #[test]
    fn enable_fanout_with_full_mode_is_a_no_op() {
        let a = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::<TestPayload>::bind("127.0.0.1:0").unwrap();
        a.enable_fanout(BroadcastMode::Full, 2, 1);
        b.enable_fanout(BroadcastMode::Full, 2, 2);
        a.connect(&b.local_addr().to_string()).unwrap();
        a.broadcast(&msg(5));
        assert_eq!(b.recv_timeout(Duration::from_secs(5)).unwrap().cert.seq, 5);
        assert_eq!(a.forward_count(), 0);
        assert_eq!(b.forward_count(), 0);
    }
}
