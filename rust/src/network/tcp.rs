//! TCP broadcast transport — run TMSN across real processes/machines.
//!
//! The in-process [`crate::network::Fabric`] simulates a cluster inside
//! one binary (benches, failure injection). This module is the *real*
//! transport the original Sparrow used: every worker process listens on a
//! socket, dials its peers, and broadcasts `(model, certificate)` messages
//! with no acknowledgements and no ordering guarantees beyond TCP's
//! per-link FIFO — faithfully TMSN: a dead peer just stops receiving.
//!
//! Wire format (little-endian):
//!     magic  u32  = 0x54_4D_53_4E ("TMSN")
//!     len    u32  = payload bytes
//!     payload     = certificate line + model text (see `encode`)

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::model::StrongRule;
use crate::tmsn::{Certificate, ModelMessage};

const MAGIC: u32 = 0x544D_534E;
/// hard cap on accepted payloads (a model of 10⁶ stumps ≈ 30 MB text)
const MAX_PAYLOAD: u32 = 64 << 20;

/// Encode a model message for the wire.
pub fn encode(msg: &ModelMessage) -> Vec<u8> {
    let header = format!(
        "cert {} {} {}\n",
        msg.cert.loss_bound, msg.cert.origin, msg.cert.seq
    );
    let body = msg.model.to_text();
    let payload = [header.as_bytes(), body.as_bytes()].concat();
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode a payload (after framing) back into a message.
pub fn decode(payload: &[u8]) -> Result<ModelMessage, String> {
    let text = std::str::from_utf8(payload).map_err(|_| "non-utf8 payload")?;
    let (first, rest) = text.split_once('\n').ok_or("missing cert line")?;
    let mut it = first.split_whitespace();
    if it.next() != Some("cert") {
        return Err("bad cert line".into());
    }
    let loss_bound: f64 = it.next().ok_or("missing bound")?.parse().map_err(|_| "bad bound")?;
    let origin: usize = it.next().ok_or("missing origin")?.parse().map_err(|_| "bad origin")?;
    let seq: u64 = it.next().ok_or("missing seq")?.parse().map_err(|_| "bad seq")?;
    if !loss_bound.is_finite() || loss_bound < 0.0 {
        return Err("bound must be finite and non-negative".into());
    }
    let model = StrongRule::from_text(rest)?;
    Ok(ModelMessage {
        model,
        cert: Certificate {
            loss_bound,
            origin,
            seq,
        },
    })
}

fn read_frame(stream: &mut TcpStream) -> io::Result<Option<Vec<u8>>> {
    let mut head = [0u8; 8];
    if let Err(e) = stream.read_exact(&mut head) {
        // clean EOF between frames = peer closed
        return if e.kind() == io::ErrorKind::UnexpectedEof {
            Ok(None)
        } else {
            Err(e)
        };
    }
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    if len > MAX_PAYLOAD {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "oversized frame"));
    }
    let mut payload = vec![0u8; len as usize];
    stream.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// A worker's TCP attachment: listens for peers, dials peers, broadcasts.
pub struct TcpEndpoint {
    peers: Arc<Mutex<Vec<TcpStream>>>,
    inbox: Receiver<ModelMessage>,
    local_addr: SocketAddr,
    // keep the sender alive for acceptor threads spawned later
    _inbox_tx: Sender<ModelMessage>,
}

impl TcpEndpoint {
    /// Bind a listener (`addr` like "127.0.0.1:0") and start accepting.
    pub fn bind(addr: &str) -> io::Result<TcpEndpoint> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let (tx, rx) = channel::<ModelMessage>();
        let peers: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));

        let tx_acceptor = tx.clone();
        std::thread::Builder::new()
            .name(format!("tmsn-accept-{local_addr}"))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { break };
                    let tx = tx_acceptor.clone();
                    std::thread::spawn(move || receive_loop(stream, tx));
                }
            })?;

        Ok(TcpEndpoint {
            peers,
            inbox: rx,
            local_addr,
            _inbox_tx: tx,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Dial a peer; broadcasts will be pushed to it. Retries briefly so
    /// cluster bring-up order doesn't matter.
    pub fn connect(&self, addr: &str) -> io::Result<()> {
        let mut last_err = io::Error::new(io::ErrorKind::Other, "no attempt");
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    self.peers.lock().unwrap().push(s);
                    return Ok(());
                }
                Err(e) => {
                    last_err = e;
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
        Err(last_err)
    }

    /// Fire-and-forget broadcast. Dead peers are dropped silently —
    /// exactly TMSN's failure semantics.
    pub fn broadcast(&self, msg: &ModelMessage) {
        let frame = encode(msg);
        let mut peers = self.peers.lock().unwrap();
        peers.retain_mut(|p| p.write_all(&frame).is_ok());
    }

    pub fn try_recv(&self) -> Option<ModelMessage> {
        self.inbox.try_recv().ok()
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Option<ModelMessage> {
        self.inbox.recv_timeout(timeout).ok()
    }

    pub fn peer_count(&self) -> usize {
        self.peers.lock().unwrap().len()
    }
}

fn receive_loop(mut stream: TcpStream, tx: Sender<ModelMessage>) {
    loop {
        match read_frame(&mut stream) {
            Ok(Some(payload)) => match decode(&payload) {
                Ok(msg) => {
                    if tx.send(msg).is_err() {
                        return; // endpoint dropped
                    }
                }
                Err(e) => {
                    // malformed message from a peer: drop the link, never
                    // crash the worker (resilience semantics)
                    eprintln!("tmsn-tcp: dropping peer after bad payload: {e}");
                    return;
                }
            },
            Ok(None) | Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;

    fn msg(seq: u64) -> ModelMessage {
        let mut model = StrongRule::new();
        model.push(Stump::new(3, 0.5, 1.0), 0.25);
        ModelMessage {
            model,
            cert: Certificate {
                loss_bound: 0.9,
                origin: 7,
                seq,
            },
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let m = msg(5);
        let frame = encode(&m);
        // strip framing
        assert_eq!(u32::from_le_bytes(frame[0..4].try_into().unwrap()), MAGIC);
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        let back = decode(&frame[8..8 + len]).unwrap();
        assert_eq!(back.model, m.model);
        assert_eq!(back.cert, m.cert);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(b"nonsense").is_err());
        assert!(decode(b"cert abc 0 0\nstrongrule v1 0\n").is_err());
        assert!(decode(b"cert 0.5 0 0\nnot a model").is_err());
        assert!(decode(&[0xFF, 0xFE, 0x00]).is_err());
    }

    #[test]
    fn two_endpoints_exchange_messages() {
        let a = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        b.connect(&a.local_addr().to_string()).unwrap();
        assert_eq!(a.peer_count(), 1);

        a.broadcast(&msg(1));
        let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 1);

        b.broadcast(&msg(2));
        let got = a.recv_timeout(Duration::from_secs(5)).expect("delivery");
        assert_eq!(got.cert.seq, 2);
    }

    #[test]
    fn three_node_broadcast_reaches_all() {
        let nodes: Vec<TcpEndpoint> = (0..3)
            .map(|_| TcpEndpoint::bind("127.0.0.1:0").unwrap())
            .collect();
        for i in 0..3 {
            for j in 0..3 {
                if i != j {
                    nodes[i].connect(&nodes[j].local_addr().to_string()).unwrap();
                }
            }
        }
        nodes[0].broadcast(&msg(9));
        for n in &nodes[1..] {
            let got = n.recv_timeout(Duration::from_secs(5)).expect("delivery");
            assert_eq!(got.cert.seq, 9);
        }
        // the sender itself receives nothing
        assert!(nodes[0].recv_timeout(Duration::from_millis(100)).is_none());
    }

    #[test]
    fn dead_peer_dropped_without_error() {
        let a = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        drop(b);
        // broadcasting into a closed peer must not panic; peer is pruned
        // (possibly after one buffered write succeeds)
        for i in 0..10 {
            a.broadcast(&msg(i));
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(a.peer_count(), 0);
    }

    #[test]
    fn ordered_per_link() {
        let a = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        let b = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        a.connect(&b.local_addr().to_string()).unwrap();
        for i in 0..20 {
            a.broadcast(&msg(i));
        }
        for i in 0..20 {
            let got = b.recv_timeout(Duration::from_secs(5)).expect("delivery");
            assert_eq!(got.cert.seq, i, "TCP must preserve per-link order");
        }
    }
}
